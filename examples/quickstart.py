"""Quickstart: the paper's primitives on one device in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (matmul_lower_bound,
                        nystrom_reference, relative_error, report_matmul,
                        select_matmul_grid, sketch_reference)
from repro.kernels import sketch_matmul

# --- communication lower bounds (Theorem 2) -------------------------------
n1 = n2 = 50_000
r = 500
for P in (64, 256, 4096, 10**6):
    rep = report_matmul(n1, n2, r, P)
    print(f"P={P:>8}: regime {rep.regime}, "
          f"W >= {rep.words_lower_bound:.3e} words "
          f"(GEMM would need {rep.gemm_words:.3e}; "
          f"savings {rep.savings_vs_gemm:.2f}x)")

# --- optimal grid selection (§4.3) -----------------------------------------
g = select_matmul_grid(n1, n2, r, 4096)
print(f"optimal grid for P=4096: {g.shape} "
      f"(alg cost {g.bandwidth_words:.3e} words == bound: "
      f"{abs(g.bandwidth_words - matmul_lower_bound(n1, n2, r, 4096)) < 1e-6})")

# --- sketching + Nyström numerically ---------------------------------------
A = jax.random.normal(jax.random.key(0), (256, 16))
S = A @ A.T                                  # rank-16 PSD matrix
B, C = nystrom_reference(S, seed=7, r=64)
print(f"Nyström rank-64 error on a rank-16 matrix: "
      f"{float(relative_error(S, B, C)):.2e}")

# --- the fused Pallas kernel (Omega generated in VMEM, interpret mode) -----
X = jax.random.normal(jax.random.key(1), (128, 256))
Bk = sketch_matmul(X, seed=7, r=32, bm=64, bn=32, bk=128, interpret=True)
Br = sketch_reference(X, 7, 32)
print(f"fused kernel vs reference max err: "
      f"{float(jnp.abs(Bk - Br).max()):.1e}")
