"""Distributed sketching demo on 8 simulated devices: Algorithm 1 across
grids, the zero-communication regime, and the Nyström Redist/No-Redist
crossover (paper Figs. 4 and 7).

    PYTHONPATH=src python examples/sketch_scaling.py
(re-executes itself with XLA_FLAGS for 8 host devices)
"""
import os
import subprocess
import sys

SNIPPET = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import (rand_matmul, sketch_reference, make_grid_mesh,
                        nystrom_no_redist, nystrom_redist,
                        matmul_lower_bound)
from repro.core.sketch import input_sharding
from repro.roofline.hlo import collective_bytes_of

n1, n2, r = 256, 512, 32
A = jax.random.normal(jax.random.key(0), (n1, n2))
ref = sketch_reference(A, 7, r)
print("Algorithm 1 across processor grids (8 devices):")
for shape in [(8, 1, 1), (2, 2, 2), (1, 4, 2)]:
    mesh = make_grid_mesh(*shape)
    Ash = jax.device_put(A, input_sharding(mesh))
    fn = jax.jit(lambda a: rand_matmul(a, 7, r, mesh))
    B = fn(Ash)
    cb = collective_bytes_of(fn.lower(Ash).compile().as_text()).total
    err = float(jnp.abs(B - ref).max())
    print(f"  grid {shape}: max err {err:.1e}, "
          f"collective bytes/device {cb:.0f}"
          + ("   <- paper regime 1: ZERO communication" if cb == 0 else ""))

print()
print("Nyström Redist vs No-Redist (paper Fig. 7 crossover at P ~ n/r):")
mesh = Mesh(np.asarray(jax.devices()), ("x",))
for (n, rr) in ((1024, 32), (512, 128)):
    S = jax.random.normal(jax.random.key(2), (n, n)); S = S @ S.T / n
    Ssh = jax.device_put(S, NamedSharding(mesh, P("x", None)))
    row = []
    for name, f in (("no_redist", nystrom_no_redist),
                    ("redist", nystrom_redist)):
        jfn = jax.jit(lambda a, f=f: f(a, 5, rr, mesh))
        cb = collective_bytes_of(jfn.lower(Ssh).compile().as_text()).total
        row.append((name, cb))
    win = min(row, key=lambda t: t[1])[0]
    print(f"  n/r = {n//rr:>3} vs P=8: "
          + ", ".join(f"{n_} {b:.0f}B" for n_, b in row)
          + f"   -> {win} wins "
          + ("(P < n/r)" if n//rr > 8 else "(P > n/r)"))
"""

if __name__ == "__main__":
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    here = os.path.dirname(os.path.abspath(__file__))
    env["PYTHONPATH"] = (os.path.join(here, "..", "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    sys.exit(subprocess.run([sys.executable, "-c", SNIPPET],
                            env=env).returncode)
