"""Batched serving example: continuous-batching-lite over a reduced model.

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-2b]
"""
import sys

if "--requests" not in " ".join(sys.argv):
    sys.argv += ["--requests", "6", "--slots", "3", "--max-new", "8"]

from repro.launch.serve import main

if __name__ == "__main__":
    main()
