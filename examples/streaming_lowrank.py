"""Streaming one-pass low-rank approximation without ever holding A.

A rank-k matrix (plus noise) arrives as row blocks; the StreamingSketch
folds each block into (Y = A·Omega, W = Psi·A) and a single linear-algebra
pass on the small factors reconstructs A ~= Q·(Psi Q)†·W.  Omega and Psi
are regenerated from the seed at every step — nothing random is stored or
communicated (the source paper's claim, inherited by the streaming model
of Tropp et al.).

    PYTHONPATH=src python examples/streaming_lowrank.py
"""
import jax
import numpy as np

from repro.core import sketch_reference
from repro.serve import make_sketch_service
from repro.stream import (StreamConfig, StreamingSketch,
                          reconstruction_error)

n1, n2, rank, r = 1024, 768, 12, 48
M = (jax.random.normal(jax.random.key(1), (n1, rank))
     @ jax.random.normal(jax.random.key(2), (rank, n2))
     + 1e-4 * jax.random.normal(jax.random.key(3), (n1, n2)))

# --- stream the rows in, 128 at a time ------------------------------------
cfg = StreamConfig(n1=n1, n2=n2, r=r, seed=7)
st = StreamingSketch(cfg)
for i in range(0, n1, 128):
    st.update_rows(i, M[i:i + 128])
print(f"streamed {st.num_updates} row blocks; sketch state is "
      f"{st.sketch.shape} + {st.corange_sketch.shape} "
      f"(~{(st.sketch.size + st.corange_sketch.size) / M.size:.1%} of A)")

# the accumulated sketch is BITWISE the one-shot Alg.-1 output
bitwise = np.array_equal(np.asarray(st.sketch),
                         np.asarray(sketch_reference(M, cfg.seed, r)))
print(f"bitwise-equal to one-shot sketch_reference: {bitwise}")

# --- one-pass reconstruction ----------------------------------------------
lr = st.reconstruct(rank=rank)
print(f"rank-{rank} one-pass reconstruction error: "
      f"{float(reconstruction_error(M, lr)):.3e}")

# --- the serving front end: many concurrent streams, one mesh -------------
svc = make_sketch_service()
ids = [svc.open(StreamConfig(n1=256, n2=n2, r=32, seed=s)) for s in (1, 2, 3)]
X = jax.random.normal(jax.random.key(9), (256, n2))
for i in range(0, 256, 64):
    for sid in ids:                       # interleaved multi-tenant ingest
        svc.update(sid, X[i:i + 64], row0=i)
print(f"service: {svc.stats()} — "
      f"{len(ids)} streams share {svc.num_compiled} compiled update")
