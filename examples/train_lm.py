"""End-to-end training driver example: train a reduced llama-family model
for a few hundred steps on the synthetic pipeline with checkpointing.

    PYTHONPATH=src python examples/train_lm.py [--arch gemma2-2b]
"""
import sys

sys.argv = [sys.argv[0], *sys.argv[1:]]
if "--steps" not in " ".join(sys.argv):
    sys.argv += ["--steps", "200", "--batch", "8", "--seq", "128"]

from repro.launch.train import main

if __name__ == "__main__":
    main()
