"""Planner walkthrough: from the paper's cost model to an executed sketch.

    PYTHONPATH=src python examples/plan_dispatch.py

Shows the three layers of repro.plan on one device:
  1. plan_sketch / plan_nystrom — analytic dispatch with a bound audit;
  2. regime_sweep — the chosen variant/grid across processor counts
     (the planner's view of the paper's regimes and the Fig.-7 crossover);
  3. autotune — measured refinement with the on-disk cache.
Multi-device planning works the same way (P > 1 plans execute on a mesh of
fake XLA devices; see tests/test_plan.py for that path).
"""
import os
import tempfile

import jax

from repro.core import sketch_reference
from repro.plan import (
    PRESETS,
    autotune,
    explain,
    plan_nystrom,
    plan_sketch,
    plan_stream,
    regime_sweep,
)

# --- 1. analytic plans, audited against Theorems 2/3 -----------------------
print(explain(plan_sketch(4096, 4096, 256, P=64, machine=PRESETS["tpu_v5e"])))
print()
print(explain(plan_nystrom(49152, 4096, P=64, machine=PRESETS["cpu"])))
print()

# --- 2. the regime picture the planner sees --------------------------------
print("plan_sketch across P (paper regimes 1 -> 3):")
print(regime_sweep(plan_sketch, (4096, 4096, 256),
                   [1, 64, 4096, 262144], machine=PRESETS["tpu_v5e"]))
print()
print("plan_nystrom across P (Fig.-7 crossover at P ~ n/r = 12):")
print(regime_sweep(plan_nystrom, (49152, 4096),
                   [4, 8, 16, 64], machine=PRESETS["cpu"]))
print()

# where the 1-D variants cannot run (r < P: neither divides), the §5.3
# bound-driven general two-grid pair is the only executable plan — it runs
# stage 1 on p, stage 2 on q, with the §5.2 Redistribute of B in between
print("r < P: only the general two-grid (bound_driven) plan can execute:")
print(explain(plan_nystrom(4096, 32, P=64, machine=PRESETS["cpu"])))
print()

# --- 3. execute + autotune on this machine ---------------------------------
A = jax.random.normal(jax.random.key(0), (512, 768))
plan = plan_sketch(512, 768, 64, P=1)
B = plan.execute(A, seed=7)
print(f"executed {plan.variant}: max |B - reference| = "
      f"{float(abs(B - sketch_reference(A, 7, 64)).max()):.1e}")

cache = os.path.join(tempfile.mkdtemp(), "tune.json")
tuned = autotune(plan, cache=cache)
print(f"autotuned -> {tuned.variant} "
      f"(measured {tuned.measured_seconds * 1e6:.0f} us, cached at "
      f"{os.path.basename(cache)})")
tuned2 = autotune(plan, cache=cache)   # second call: pure cache hit
print(f"second call hit the cache: {tuned2.measured_seconds == tuned.measured_seconds}")

# streaming plans dispatch to the accumulator subsystem
splan = plan_stream(512, 768, 64, P=1, chunk_rows=128)
acc = splan.execute(A, seed=7)
print(f"stream plan ({splan.variant}, chunk_rows={splan.chunk_rows}): "
      f"{acc.num_updates} updates, sketch bitwise = "
      f"{bool((acc.sketch == B).all()) if plan.variant == 'local_xla' else 'n/a'}")
