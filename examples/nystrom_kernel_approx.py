"""Paper §6.2 / Tab. 2 end-to-end: Nyström approximation of kernel matrices
at several ranks, on the linear and RBF kernels.

    PYTHONPATH=src python examples/nystrom_kernel_approx.py
"""
import jax
import jax.numpy as jnp

from repro.core import nystrom_reference, relative_error

n, d = 2048, 128
X = jax.random.normal(jax.random.key(0), (n, d))

kernels = {}
kernels["linear"] = X @ X.T
sq = jnp.sum(X * X, 1)
d2 = sq[:, None] + sq[None, :] - 2 * X @ X.T
sigma = float(jnp.linalg.norm(X)) / (n ** 0.5)
kernels[f"rbf sigma={sigma:.2f}"] = jnp.exp(-d2 / (2 * sigma ** 2))
kernels["rbf sigma=1"] = jnp.exp(-d2 / 2.0)

print(f"{'kernel':>18} | " + " | ".join(f"r={r:<5}" for r in (64, 256, 512)))
for name, A in kernels.items():
    errs = []
    for r in (64, 256, 512):
        B, C = nystrom_reference(A, seed=11, r=r)
        errs.append(float(relative_error(A, B, C)))
    print(f"{name:>18} | " + " | ".join(f"{e:.1e}" for e in errs))
print("\nExpected pattern (paper Tab. 2): linear kernel -> machine precision"
      "\nonce r exceeds the true rank; well-scaled RBF decays; sigma=1 RBF"
      "\nstays O(1) (numerically full-rank).")
