"""repro — communication-optimal distributed sketching (Al Daas et al.,
CS.DC 2026) as a production JAX training/serving framework."""
__version__ = "1.0.0"
