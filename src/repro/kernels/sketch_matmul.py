"""Pallas TPU kernel: fused sketch-matmul with in-VMEM Omega generation.

The paper removes Omega from the *network*; this kernel removes it from
*HBM*: each (bk x bn) tile of Omega is generated inside the kernel with
Philox-4x32-10 keyed by its global coordinates, lives only in VMEM/VREGs,
and is consumed immediately by the MXU accumulation.  HBM traffic drops from
``n1*n2 + n2*r + n1*r`` words (classic GEMM) to ``n1*n2 + n1*r`` — the
memory-roofline analogue of the paper's zero-communication claim.

Kernels:
  * ``sketch_matmul_kernel``    — B = A @ Omega          (A: n1 x n2)
  * ``sketch_t_matmul_kernel``  — C = Omega^T @ B        (B: n x r2)
  * ``gen_omega_kernel``        — materialize an Omega tile (bitwise oracle
                                  check for the in-kernel generator)

Tiling: grid (n1/bm, r/bn, n2/bk) with the contraction dim innermost; an
f32 VMEM scratch accumulates across k-steps so inputs/outputs may be bf16.
Block shapes default to MXU-aligned multiples of 128 on TPU; tests sweep
small blocks in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import rng
from repro.core.compat import vmem_scratch as _vmem_scratch


# ---------------------------------------------------------------------------
# In-kernel Omega tile (shared with the jnp reference — bitwise identical)
# ---------------------------------------------------------------------------

def _omega_tile_kernel(seed: int, row0, col0, rows: int, cols: int,
                       kind: str, salt: int = 0):
    key0 = jnp.uint32(seed & 0xFFFFFFFF)
    key1 = jnp.uint32((seed >> 32) & 0xFFFFFFFF)
    row0 = jnp.asarray(row0, jnp.uint32)
    col0 = jnp.asarray(col0, jnp.uint32)
    if kind == "normal":
        return rng.philox_normal_grid(key0, key1, row0, col0, rows, cols, salt)
    if kind == "uniform":
        return rng.philox_uniform_grid(key0, key1, row0, col0, rows, cols, salt)
    if kind == "rademacher":
        u = rng.philox_uniform_grid(key0, key1, row0, col0, rows, cols, salt)
        return jnp.where(u < 0.5, jnp.float32(-1), jnp.float32(1))
    raise ValueError(f"unknown omega kind {kind!r}")


# ---------------------------------------------------------------------------
# B = A @ Omega
# ---------------------------------------------------------------------------

def _sketch_matmul_body(a_ref, o_ref, acc_ref, *, seed: int, bk: int, bn: int,
                        nsteps_k: int, kind: str, salt: int):
    k = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    om = _omega_tile_kernel(seed, k * bk, j * bn, bk, bn, kind, salt)
    a = a_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(a, om,
                                preferred_element_type=jnp.float32)

    @pl.when(k == nsteps_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def sketch_matmul_pallas(A, seed: int, r: int, *,
                         bm: int = 256, bn: int = 128, bk: int = 512,
                         kind: str = "normal", salt: int = 0,
                         out_dtype=None, interpret: bool = False):
    """B = A @ Omega with Omega generated in-kernel. Shapes must be multiples
    of the block sizes (use :func:`repro.kernels.ops.sketch_matmul` for the
    padded general wrapper)."""
    n1, n2 = A.shape
    assert n1 % bm == 0 and n2 % bk == 0 and r % bn == 0, (A.shape, r, (bm, bn, bk))
    out_dtype = out_dtype or A.dtype
    nsteps_k = n2 // bk
    grid = (n1 // bm, r // bn, nsteps_k)

    return pl.pallas_call(
        functools.partial(_sketch_matmul_body, seed=seed, bk=bk, bn=bn,
                          nsteps_k=nsteps_k, kind=kind, salt=salt),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n1, r), out_dtype),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(A)


# ---------------------------------------------------------------------------
# C = Omega^T @ B    (contraction over Omega rows: the Nystrom second stage)
# ---------------------------------------------------------------------------

def _sketch_t_matmul_body(b_ref, o_ref, acc_ref, *, seed: int, bk: int,
                          bm: int, nsteps_k: int, kind: str, salt: int):
    k = pl.program_id(2)
    i = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Omega tile rows k*bk..k*bk+bk map to the contraction; cols i*bm..
    om = _omega_tile_kernel(seed, k * bk, i * bm, bk, bm, kind, salt)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(om.T, b,
                                preferred_element_type=jnp.float32)

    @pl.when(k == nsteps_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def sketch_t_matmul_pallas(B, seed: int, r: int, *,
                           bm: int = 128, bn: int = 128, bk: int = 512,
                           kind: str = "normal", salt: int = 0,
                           out_dtype=None, interpret: bool = False):
    """C = Omega^T @ B where Omega is (n x r) and B is (n x r2), generated
    in-kernel.  Output (r, r2)."""
    n, r2 = B.shape
    assert n % bk == 0 and r % bm == 0 and r2 % bn == 0, (B.shape, r, (bm, bn, bk))
    out_dtype = out_dtype or B.dtype
    nsteps_k = n // bk
    grid = (r // bm, r2 // bn, nsteps_k)

    return pl.pallas_call(
        functools.partial(_sketch_t_matmul_body, seed=seed, bk=bk, bm=bm,
                          nsteps_k=nsteps_k, kind=kind, salt=salt),
        grid=grid,
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, r2), out_dtype),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(B)


# ---------------------------------------------------------------------------
# Omega materialization kernel (oracle check of the in-kernel generator)
# ---------------------------------------------------------------------------

def _gen_omega_body(o_ref, *, seed: int, br: int, bc: int, kind: str,
                    salt: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    o_ref[...] = _omega_tile_kernel(seed, i * br, j * bc, br, bc, kind,
                                    salt).astype(o_ref.dtype)


def gen_omega_pallas(seed: int, n2: int, r: int, *,
                     br: int = 256, bc: int = 128, kind: str = "normal",
                     salt: int = 0, dtype=jnp.float32,
                     interpret: bool = False):
    assert n2 % br == 0 and r % bc == 0
    return pl.pallas_call(
        functools.partial(_gen_omega_body, seed=seed, br=br, bc=bc, kind=kind,
                          salt=salt),
        grid=(n2 // br, r // bc),
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n2, r), dtype),
        interpret=interpret,
    )()
