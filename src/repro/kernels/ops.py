"""Public jit'd wrappers around the Pallas sketch kernels.

Handles arbitrary (non-block-aligned) shapes by zero-padding A up to block
multiples (zero rows of A contribute nothing to B; zero *columns* of A would
pair with extra Omega rows, so the contraction dim must instead clamp the
generated Omega — we pad the contraction with zeros in A AND generate the
padded Omega rows anyway: zero x anything = 0, so the result is exact).
Block sizes default to MXU-aligned values for the TPU target; interpret=True
executes the kernel body in Python on CPU for validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .sketch_matmul import (
    gen_omega_pallas,
    sketch_matmul_pallas,
    sketch_t_matmul_pallas,
)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("r", "bm", "bn", "bk", "kind",
                                             "salt", "interpret", "seed"))
def sketch_matmul(A, *, seed: int, r: int,
                  bm: int = 256, bn: int = 128, bk: int = 512,
                  kind: str = "normal", salt: int = 0,
                  interpret: bool = False):
    """B = A @ Omega(n2, r) with in-kernel Omega generation; any shape."""
    n1, n2 = A.shape
    bm_ = min(bm, _round_up(n1, 8))
    bn_ = min(bn, _round_up(r, 8))
    bk_ = min(bk, _round_up(n2, 8))
    n1p, n2p, rp = _round_up(n1, bm_), _round_up(n2, bk_), _round_up(r, bn_)
    Ap = jnp.pad(A, ((0, n1p - n1), (0, n2p - n2)))
    # NOTE: padded contraction rows of Omega multiply zero columns of A.
    # Padded output columns [r:rp] are generated but sliced away.
    Bp = sketch_matmul_pallas(Ap, seed, rp, bm=bm_, bn=bn_, bk=bk_,
                              kind=kind, salt=salt, interpret=interpret)
    return Bp[:n1, :r]


@functools.partial(jax.jit, static_argnames=("r", "bm", "bn", "bk", "kind",
                                             "salt", "interpret", "seed"))
def sketch_t_matmul(B, *, seed: int, r: int,
                    bm: int = 128, bn: int = 128, bk: int = 512,
                    kind: str = "normal", salt: int = 0,
                    interpret: bool = False):
    """C = Omega(n, r)^T @ B with in-kernel Omega generation; any shape.

    CAUTION: the contraction dim (rows of B / rows of Omega) must not be
    padded with generated Omega rows against zero B rows — zeros kill them,
    so padding is exact here too.
    """
    n, r2 = B.shape
    bm_ = min(bm, _round_up(r, 8))
    bn_ = min(bn, _round_up(r2, 8))
    bk_ = min(bk, _round_up(n, 8))
    np_, r2p, rp = _round_up(n, bk_), _round_up(r2, bn_), _round_up(r, bm_)
    Bp = jnp.pad(B, ((0, np_ - n), (0, r2p - r2)))
    Cp = sketch_t_matmul_pallas(Bp, seed, rp, bm=bm_, bn=bn_, bk=bk_,
                                kind=kind, salt=salt, interpret=interpret)
    return Cp[:r, :r2]


@functools.partial(jax.jit, static_argnames=("n2", "r", "br", "bc", "kind",
                                             "salt", "interpret", "seed",
                                             "dtype"))
def gen_omega(*, seed: int, n2: int, r: int, br: int = 256, bc: int = 128,
              kind: str = "normal", salt: int = 0, dtype=jnp.float32,
              interpret: bool = False):
    """Materialize Omega via the kernel's generator (oracle parity checks)."""
    br_ = min(br, _round_up(n2, 8))
    bc_ = min(bc, _round_up(r, 8))
    n2p, rp = _round_up(n2, br_), _round_up(r, bc_)
    om = gen_omega_pallas(seed, n2p, rp, br=br_, bc=bc_, kind=kind,
                          salt=salt, dtype=dtype, interpret=interpret)
    return om[:n2, :r]


def nystrom_fused(A, *, seed: int, r: int, kind: str = "normal",
                  interpret: bool = False, **blocks):
    """(B, C) of the Nyström pair with Omega never materialized in HBM:
    B = A·Omega via the fused kernel, then C = Omega^T·B likewise."""
    B = sketch_matmul(A, seed=seed, r=r, kind=kind, interpret=interpret,
                      **{k: v for k, v in blocks.items()
                         if k in ("bm", "bn", "bk")})
    C = sketch_t_matmul(B, seed=seed, r=r, kind=kind, interpret=interpret)
    return B, C
