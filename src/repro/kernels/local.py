"""Offset-aware fused local GEMM backends for every distributed hot path.

The paper's Theorems 2/3 remove Omega from the *network*; the Pallas
kernels remove it from *HBM*.  Until now only the single-device entry
points (``kernels/ops.py``) got the fused treatment — every shard_map body
(Alg. 1's ``rand_matmul``, both Nyström stages, the streaming updates)
still materialized its per-shard Omega block via ``omega_tile`` and paid
the full ``n1·n2 + n2·r + n1·r`` local HBM traffic.  This module closes
that gap: it exposes the two local GEMM bodies those paths need,

  * ``sketch_block``    —  acc? + A · Omega[row0:, col0:col0+cols]
  * ``sketch_t_block``  —  acc? + Omega[row0:, col0:col0+cols]^T · B

with the Omega (or Psi) tile generated at *global* Philox coordinates —
``row0``/``col0`` and the key pair may be **traced** (they are
``axis_index`` products inside shard_map bodies), entering the kernel as
scalar-prefetch operands.  ``acc`` fuses the streaming accumulation
``Y += H·Omega`` into the kernel accumulator so Y makes one HBM round trip
(read into VMEM at k==0, written at the flush) instead of two.

Backends:

  * ``"jnp"``    — the expression the shard_map bodies have always
                   inlined (``omega_tile`` + ``jnp.matmul``), normalized
                   to f32 accumulation: bit-identical to the historical
                   bodies for f32 inputs; for bf16 inputs the historical
                   bodies accumulated in bf16 (see the jnp-backend
                   section below).  The reference semantics.
  * ``"pallas"`` — the fused kernel; native on TPU, interpret mode
                   elsewhere (a correctness tool, not a fast path).
  * ``"auto"``   — ``"pallas"`` on TPU, else ``"jnp"``.

Bitwise contract (pinned by tests/test_local_backend.py): whenever the
contraction dimension is not tiled (``nsteps_k == 1`` — guaranteed by the
default block policy in interpret mode, which takes the whole operand as
one tile), the Pallas backend reproduces the jnp backend bit for bit: the
Irwin–Hall generator makes the Omega *entries* invariant to tiling and
compilation context (core/rng.py), and an un-split ``lax.dot`` on the same
f32 operands is the same reduction.  Tilings that split the contraction
agree to f32 reduction order (~1e-6), same as any re-blocked GEMM.

HBM roofline (the point): per local GEMM the jnp backend touches
``m·k + k·n + m·n`` words (+ ``2·m·n`` more for a read-modify-write
accumulation); the fused backend touches ``m·k + m·n`` — the ``k·n``
Omega stream never exists.  ``plan.model`` prices both so the planner
picks the backend analytically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import rng
from repro.core.sketch import omega_tile, seed_keys

BACKENDS = ("jnp", "pallas", "auto")


def resolve_backend(backend: str) -> str:
    """Normalize a backend knob to a concrete backend name.

    ``auto`` resolves to the fused Pallas path only where it is a fast
    path (native TPU); everywhere else the jnp body is both the fastest
    and the reference-bitwise choice.  ``xla`` is accepted as an alias of
    ``jnp`` (the streaming accumulator's historical name for it).
    """
    if backend in ("xla", None):
        return "jnp"
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown backend {backend!r} (want jnp|pallas|auto)")
    return backend


def _interpret() -> bool:
    """Pallas interpret mode everywhere but native TPU."""
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# VMEM budget for the default (no explicit ``blocks``) native-TPU tiling;
# deliberately below the physical per-core VMEM so double buffering fits.
_VMEM_BUDGET = 12 * 2 ** 20


def vmem_fit_bytes(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """Resident VMEM bytes of one fused-GEMM tile set: the A (or B) panel,
    the generated Omega tile, and the f32 accumulator + output tile.
    Single source of truth for the default block policy here and the
    autotuner's block-sweep filter (plan/autotune.py)."""
    return itemsize * (bm * bk + bk * bn + 2 * bm * bn)


def default_local_blocks(m: int, n: int, k: int,
                         interpret: bool) -> tuple:
    """(bm, bn, bk) for a local fused GEMM.

    Interpret mode: one exact tile — no padding, no k split — so the
    kernel performs literally the same single ``lax.dot`` as the jnp
    body (the bitwise default the backend matrix tests pin).  Native TPU:
    MXU-aligned tiles shrunk to the VMEM budget, splitting m then n and
    only then the contraction (k splits cost the bitwise property).
    """
    if interpret:
        return (m, n, k)
    bm, bn, bk = _round_up(m, 8), _round_up(n, 128), _round_up(k, 128)

    def fit(bm, bn, bk):
        return vmem_fit_bytes(bm, bn, bk) <= _VMEM_BUDGET

    while not fit(bm, bn, bk) and bm > 256:
        bm = _round_up(bm // 2, 8)
    while not fit(bm, bn, bk) and bn > 256:
        bn = _round_up(bn // 2, 128)
    while not fit(bm, bn, bk) and bk > 512:
        bk = _round_up(bk // 2, 128)
    return (bm, bn, bk)


# ---------------------------------------------------------------------------
# jnp backend — the expression the shard_map bodies always inlined, with
# one deliberate normalization: accumulation is f32 on every input dtype
# (Omega drawn at f32, operands upcast, output cast back).  For f32 inputs
# — the dtype every bitwise contract in this repo covers — this is
# bit-identical to the historical inline bodies (astype is the identity);
# for sub-f32 inputs (bf16) the historical bodies quantized Omega to the
# input dtype and accumulated there, so their bits differ from this path.
# The normalization is what makes the two backends comparable at all:
# the Pallas kernel accumulates in f32 by construction (MXU), and the
# backend-parity matrix (tests/test_local_backend.py) pins jnp == pallas
# bitwise for bf16 under exactly this rule.
# ---------------------------------------------------------------------------

def _omega_f32(seed, row0, col0, rows: int, cols: int, kind: str, salt: int,
               scale):
    om = omega_tile(seed, row0, col0, rows, cols, kind, jnp.float32,
                    salt=salt)
    if scale is not None:
        om = om * jnp.float32(scale)
    return om


def _sketch_block_jnp(A, seed, cols, row0, col0, kind, salt, scale,
                      precision, acc, out_dtype):
    om = _omega_f32(seed, row0, col0, A.shape[1], cols, kind, salt, scale)
    out = jnp.matmul(A.astype(jnp.float32), om, precision=precision)
    if acc is not None:
        out = acc.astype(jnp.float32) + out
    return out.astype(out_dtype)


def _sketch_t_block_jnp(B, seed, cols, row0, col0, kind, salt, scale,
                        precision, acc, out_dtype):
    om = _omega_f32(seed, row0, col0, B.shape[0], cols, kind, salt, scale)
    out = jnp.matmul(om.T, B.astype(jnp.float32), precision=precision)
    if acc is not None:
        out = acc.astype(jnp.float32) + out
    return out.astype(out_dtype)


# ---------------------------------------------------------------------------
# pallas backend — Omega generated in VMEM at global coordinates; the key
# pair and base offsets arrive as scalar-prefetch operands so shard_map
# bodies can pass traced axis_index products.
# ---------------------------------------------------------------------------

def _om_block(meta_ref, r_off, c_off, rows: int, cols: int, kind: str,
              salt: int, scale):
    """An Omega tile inside the kernel at meta's base + static tile offset."""
    key0 = meta_ref[0]
    key1 = meta_ref[1]
    row0 = meta_ref[2] + jnp.uint32(r_off)
    col0 = meta_ref[3] + jnp.uint32(c_off)
    if kind == "normal":
        om = rng.philox_normal_grid(key0, key1, row0, col0, rows, cols, salt)
    elif kind == "uniform":
        om = rng.philox_uniform_grid(key0, key1, row0, col0, rows, cols, salt)
    elif kind == "rademacher":
        u = rng.philox_uniform_grid(key0, key1, row0, col0, rows, cols, salt)
        om = jnp.where(u < 0.5, jnp.float32(-1), jnp.float32(1))
    else:
        raise ValueError(f"unknown omega kind {kind!r}")
    if scale is not None:
        om = om * jnp.float32(scale)
    return om


def _fwd_body(meta_ref, a_ref, o_ref, acc_ref, *, bk, bn, nsteps_k, kind,
              salt, scale):
    import jax.experimental.pallas as pl
    k = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    om = _om_block(meta_ref, k * bk, j * bn, bk, bn, kind, salt, scale)
    a = a_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(a, om, preferred_element_type=jnp.float32)

    @pl.when(k == nsteps_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _fwd_acc_body(meta_ref, a_ref, y_ref, o_ref, acc_ref, *, bk, bn,
                  nsteps_k, kind, salt, scale):
    import jax.experimental.pallas as pl
    k = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        # the fused accumulation: Y enters the VMEM accumulator once...
        acc_ref[...] = y_ref[...].astype(jnp.float32)

    om = _om_block(meta_ref, k * bk, j * bn, bk, bn, kind, salt, scale)
    a = a_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(a, om, preferred_element_type=jnp.float32)

    @pl.when(k == nsteps_k - 1)
    def _flush():
        # ...and leaves once — one HBM round trip instead of two.
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _t_body(meta_ref, b_ref, o_ref, acc_ref, *, bk, bm, nsteps_k, kind,
            salt, scale):
    import jax.experimental.pallas as pl
    k = pl.program_id(2)
    i = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    om = _om_block(meta_ref, k * bk, i * bm, bk, bm, kind, salt, scale)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(om.T, b, preferred_element_type=jnp.float32)

    @pl.when(k == nsteps_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _t_acc_body(meta_ref, b_ref, w_ref, o_ref, acc_ref, *, bk, bm, nsteps_k,
                kind, salt, scale):
    import jax.experimental.pallas as pl
    k = pl.program_id(2)
    i = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = w_ref[...].astype(jnp.float32)

    om = _om_block(meta_ref, k * bk, i * bm, bk, bm, kind, salt, scale)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(om.T, b, preferred_element_type=jnp.float32)

    @pl.when(k == nsteps_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _meta(seed, row0, col0):
    """(4,) uint32 scalar-prefetch vector: key pair + global base offsets."""
    k0, k1 = seed_keys(seed)
    return jnp.stack([k0, k1,
                      jnp.asarray(row0, jnp.uint32),
                      jnp.asarray(col0, jnp.uint32)])


def _pad2(X, m: int, n: int):
    if X.shape == (m, n):
        return X
    return jnp.pad(X, ((0, m - X.shape[0]), (0, n - X.shape[1])))


def _sketch_block_pallas(A, seed, cols, row0, col0, kind, salt, scale,
                         acc, out_dtype, blocks, interpret):
    import jax.experimental.pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    from repro.core.compat import vmem_scratch

    m, k = A.shape
    bm, bn, bk = blocks or default_local_blocks(m, cols, k, interpret)
    bm, bn, bk = min(bm, _round_up(m, 8)), min(bn, _round_up(cols, 8)), \
        min(bk, _round_up(k, 8))
    mp, np_, kp = _round_up(m, bm), _round_up(cols, bn), _round_up(k, bk)
    # Padding contract (see kernels/ops.py): padded contraction rows of
    # Omega draw at their own global coordinates but multiply zero columns
    # of A; padded output columns are drawn and sliced away.  In-range
    # entries keep their global coordinates, so padding never shifts draws.
    Ap = _pad2(A, mp, kp)
    meta = _meta(seed, row0, col0)
    grid = (mp // bm, np_ // bn, kp // bk)
    body = _fwd_acc_body if acc is not None else _fwd_body
    kernel = functools.partial(body, bk=bk, bn=bn, nsteps_k=kp // bk,
                               kind=kind, salt=salt, scale=scale)
    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, kk, m_: (i, kk))]
    operands = [meta, Ap]
    aliases = {}
    if acc is not None:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk, m_: (i, j)))
        operands.append(_pad2(acc.astype(out_dtype), mp, np_))
        aliases = {2: 0}        # acc operand (after meta, A) aliases the out
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, m_: (i, j)),
        scratch_shapes=[vmem_scratch((bm, bn), jnp.float32)])
    out = pl.pallas_call(
        kernel, grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        input_output_aliases=aliases,
        interpret=interpret)(*operands)
    return out[:m, :cols]


def _sketch_t_block_pallas(B, seed, cols, row0, col0, kind, salt, scale,
                           acc, out_dtype, blocks, interpret):
    import jax.experimental.pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    from repro.core.compat import vmem_scratch

    k, r2 = B.shape           # contraction over rows of B / rows of Omega
    bm, bn, bk = blocks or default_local_blocks(cols, r2, k, interpret)
    bm, bn, bk = min(bm, _round_up(cols, 8)), min(bn, _round_up(r2, 8)), \
        min(bk, _round_up(k, 8))
    mp, np_, kp = _round_up(cols, bm), _round_up(r2, bn), _round_up(k, bk)
    Bp = _pad2(B, kp, np_)
    meta = _meta(seed, row0, col0)
    grid = (mp // bm, np_ // bn, kp // bk)
    body = _t_acc_body if acc is not None else _t_body
    kernel = functools.partial(body, bk=bk, bm=bm, nsteps_k=kp // bk,
                               kind=kind, salt=salt, scale=scale)
    in_specs = [pl.BlockSpec((bk, bn), lambda i, j, kk, m_: (kk, j))]
    operands = [meta, Bp]
    aliases = {}
    if acc is not None:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk, m_: (i, j)))
        operands.append(_pad2(acc.astype(out_dtype), mp, np_))
        aliases = {2: 0}
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, m_: (i, j)),
        scratch_shapes=[vmem_scratch((bm, bn), jnp.float32)])
    out = pl.pallas_call(
        kernel, grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        input_output_aliases=aliases,
        interpret=interpret)(*operands)
    return out[:cols, :r2]


# ---------------------------------------------------------------------------
# Dense fused GEMM: acc? + alpha·(A·B) with both operands resident in HBM.
# The gradient-compression backward pass needs two GEMMs whose right-hand
# side is DATA-DEPENDENT (P̂ᵀ·M and P̂·Qᵀ) — not a Philox-generated tile, so
# ``sketch_block`` cannot express them.  What the fused backend still buys
# is the accumulator aliasing: the error-feedback update
# ``E' = M − P̂·Q_locᵀ`` is exactly ``gemm_block(P̂, Q_loc, acc=M, alpha=-1)``
# with M aliased in-place — one HBM round trip instead of the jnp body's
# materialized delta + read-modify-write (``plan.model.grad_compress_cost``
# prices the 4·m·n → 2·m·n halving).  Bitwise-when-untiled for free: both
# backends run one identical ``lax.dot`` on the same f32 operands, scale by
# the same static alpha, then add the accumulator.
# ---------------------------------------------------------------------------

def _gemm_jnp(A, B, alpha, precision, acc, out_dtype):
    out = jnp.matmul(A.astype(jnp.float32), B.astype(jnp.float32),
                     precision=precision)
    if alpha != 1.0:
        out = out * jnp.float32(alpha)
    if acc is not None:
        out = acc.astype(jnp.float32) + out
    return out.astype(out_dtype)


def _gemm_body(a_ref, b_ref, o_ref, acc_ref, *, nsteps_k, alpha):
    import jax.experimental.pallas as pl
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == nsteps_k - 1)
    def _flush():
        d = acc_ref[...]
        if alpha != 1.0:
            d = d * jnp.float32(alpha)
        o_ref[...] = d.astype(o_ref.dtype)


def _gemm_acc_body(a_ref, b_ref, y_ref, o_ref, acc_ref, *, nsteps_k, alpha):
    import jax.experimental.pallas as pl
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == nsteps_k - 1)
    def _flush():
        # same association as the jnp body: acc + (dot · alpha) — the
        # accumulator enters once at the flush and leaves through the
        # aliased output, one HBM round trip.
        d = acc_ref[...]
        if alpha != 1.0:
            d = d * jnp.float32(alpha)
        o_ref[...] = (y_ref[...].astype(jnp.float32) + d).astype(o_ref.dtype)


def _gemm_pallas(A, B, alpha, acc, out_dtype, blocks, interpret):
    import jax.experimental.pallas as pl
    from repro.core.compat import vmem_scratch

    m, k = A.shape
    _, n = B.shape
    bm, bn, bk = blocks or default_local_blocks(m, n, k, interpret)
    bm, bn, bk = min(bm, _round_up(m, 8)), min(bn, _round_up(n, 8)), \
        min(bk, _round_up(k, 8))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    Ap, Bp = _pad2(A, mp, kp), _pad2(B, kp, np_)
    grid = (mp // bm, np_ // bn, kp // bk)
    body = _gemm_acc_body if acc is not None else _gemm_body
    kernel = functools.partial(body, nsteps_k=kp // bk, alpha=alpha)
    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))]
    operands = [Ap, Bp]
    aliases = {}
    if acc is not None:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
        operands.append(_pad2(acc.astype(out_dtype), mp, np_))
        aliases = {2: 0}        # acc operand aliases the output in-place
    out = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[vmem_scratch((bm, bn), jnp.float32)],
        input_output_aliases=aliases,
        interpret=interpret)(*operands)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Row-slab fold: Y += zero-padded dY placed at a traced row offset — the
# streaming ``update_rows`` accumulation (stream/distributed.py).  The jnp
# body materializes the zero-padded frame in HBM (write + read of
# (k + 2m)·n words) before the slice-add; the pallas body performs the
# identical concatenate + dynamic_slice + add INSIDE the kernel, so the
# padded frame lives only in VMEM and Y (aliased in-place) makes one HBM
# round trip.  Bitwise-identical by construction: both backends run the
# same ops on the same operands.
# ---------------------------------------------------------------------------

def _fold_rows_jnp(y, d, start, nvalid=None):
    m, c = y.shape
    pad = jnp.zeros((m, c), d.dtype)
    dpad = jnp.concatenate([pad, d, pad], axis=0)
    win = jax.lax.dynamic_slice(dpad, (start, jnp.int32(0)), (m, c))
    if nvalid is None:
        return y + win
    # masked fold: only y rows whose frame coordinate lands inside the
    # first ``nvalid`` rows of d change — every other row keeps y's EXACT
    # bits (a ragged bucket's padded tail must not even add +0.0, which
    # would flip a resident -0.0)
    idx = jnp.int32(start) + jnp.arange(m, dtype=jnp.int32)
    live = (idx >= m) & (idx < m + jnp.int32(nvalid))
    return jnp.where(live[:, None], y + win, y)


def _fold_rows_body(meta_ref, y_ref, d_ref, o_ref, *, m, masked):
    start = meta_ref[0]
    y = y_ref[...]
    d = d_ref[...]
    pad = jnp.zeros((m, d.shape[1]), d.dtype)
    dpad = jnp.concatenate([pad, d, pad], axis=0)
    win = jax.lax.dynamic_slice(dpad, (start, 0), (m, d.shape[1]))
    if masked:
        idx = start + jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)
        live = (idx >= m) & (idx < m + meta_ref[1])
        o_ref[...] = jnp.where(live, y + win, y).astype(o_ref.dtype)
    else:
        o_ref[...] = (y + win).astype(o_ref.dtype)


def _fold_rows_pallas(y, d, start, interpret, pad_to=None, nvalid=None):
    import jax.experimental.pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    m, c = y.shape
    k = d.shape[0]
    if pad_to is not None:            # tests: force the padded (native) path
        mp, cp, kp = pad_to
    elif interpret:
        mp, cp, kp = m, c, k          # one exact tile — the bitwise default
    else:
        mp, cp, kp = _round_up(m, 8), _round_up(c, 128), _round_up(k, 8)
    yp = _pad2(y, mp, cp)
    dp = _pad2(d, kp, cp)
    # The caller's ``start`` indexes a frame whose top pad is the LOGICAL
    # shard height m; the in-kernel frame's top pad is the padded height
    # mp, so shift by the difference — otherwise row-padding would slide
    # the slab delta mp - m rows down (same padding contract as the
    # sketch kernels: padding never shifts in-range placement).
    masked = nvalid is not None
    meta = jnp.stack([
        jnp.asarray(start, jnp.int32) + jnp.int32(mp - m),
        jnp.asarray(nvalid if masked else k, jnp.int32)])
    kernel = functools.partial(_fold_rows_body, m=mp, masked=masked)
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(1,),
        in_specs=[pl.BlockSpec((mp, cp), lambda i, m_: (0, 0)),
                  pl.BlockSpec((kp, cp), lambda i, m_: (0, 0))],
        out_specs=pl.BlockSpec((mp, cp), lambda i, m_: (0, 0)))
    out = pl.pallas_call(
        kernel, grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((mp, cp), y.dtype),
        input_output_aliases={1: 0},    # y aliases the output in-place
        interpret=interpret)(meta, yp, dp)
    return out[:m, :c]


def fold_rows_block(y, d, start, backend: str = "jnp", interpret=None,
                    nvalid=None):
    """``y + [0_m; d; 0_m][start : start + m]`` — the row-slab Y fold.

    ``y``: (m, c) resident shard; ``d``: (k, c) slab delta; ``start`` may
    be traced (the shard-relative clipped offset, see
    ``stream/distributed.py``).  Shards outside the slab slice pure zeros,
    so row-disjoint ingest reproduces the full-shape path bitwise.  The
    pallas backend keeps the zero-padded frame in VMEM and aliases ``y``
    in-place — 2·m·c accumulate HBM words instead of the jnp body's
    materialized-frame 4·k·c-class traffic (``plan.model``'s
    ``stream_update_cost`` prices both).

    ``nvalid`` (may be traced) restricts the fold to the first ``nvalid``
    rows of ``d``: y rows fed by rows >= nvalid keep their EXACT input
    bits — not even a +0.0 is added, which is what makes a ragged bucket's
    padded tail provably dead (stream/service.py ``update_ragged``; a +0.0
    add would flip a resident -0.0).  Both backends run the same
    mask + where on the same operands, so the fold stays bitwise-identical
    across backends, and this entry point vmaps over a leading lane axis
    (the batched ragged programs vmap it directly — in interpret mode the
    lane axis becomes one more grid dimension of the same kernel).
    """
    b = resolve_backend(backend)
    if b == "jnp":
        return _fold_rows_jnp(y, d, start, nvalid=nvalid)
    interpret = _interpret() if interpret is None else interpret
    return _fold_rows_pallas(y, d, start, interpret, nvalid=nvalid)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def sketch_block(A, seed, cols: int, *, row0=0, col0=0, kind: str = "normal",
                 salt: int = 0, scale=None, precision=None, acc=None,
                 out_dtype=None, backend: str = "jnp", blocks=None,
                 interpret=None):
    """``acc? + A @ Omega[row0:row0+k, col0:col0+cols]`` (k = A.shape[1]).

    The local body of Alg. 1 / the streaming range update.  ``seed`` may be
    an int or a traced (2,) uint32 key pair; ``row0``/``col0`` may be
    traced (shard offsets).  Accumulation is f32 on both backends; the
    result is cast to ``out_dtype`` (default: A's dtype).  ``acc`` fuses an
    accumulation into the kernel (``Y += ...``); with the Pallas backend
    the accumulator is aliased in-place, one HBM round trip.
    """
    b = resolve_backend(backend)
    out_dtype = out_dtype or A.dtype
    if b == "jnp":
        return _sketch_block_jnp(A, seed, cols, row0, col0, kind, salt,
                                 scale, precision, acc, out_dtype)
    interpret = _interpret() if interpret is None else interpret
    return _sketch_block_pallas(A, seed, cols, row0, col0, kind, salt,
                                scale, acc, out_dtype, blocks, interpret)


def sketch_t_block(B, seed, cols: int, *, row0=0, col0=0,
                   kind: str = "normal", salt: int = 0, scale=None,
                   precision=None, acc=None, out_dtype=None,
                   backend: str = "jnp", blocks=None, interpret=None):
    """``acc? + Omega[row0:row0+n, col0:col0+cols]^T @ B`` (n = B.shape[0]).

    The local body of the Nyström second stages (C = Omega^T·B) and the
    streaming co-range update (W += Psi·H, with Psi's salt).  Same traced
    seed/offset and f32-accumulation contract as :func:`sketch_block`.
    """
    b = resolve_backend(backend)
    out_dtype = out_dtype or B.dtype
    if b == "jnp":
        return _sketch_t_block_jnp(B, seed, cols, row0, col0, kind, salt,
                                   scale, precision, acc, out_dtype)
    interpret = _interpret() if interpret is None else interpret
    return _sketch_t_block_pallas(B, seed, cols, row0, col0, kind, salt,
                                  scale, acc, out_dtype, blocks, interpret)


def gemm_block(A, B, *, alpha: float = 1.0, precision=None, acc=None,
               out_dtype=None, backend: str = "jnp", blocks=None,
               interpret=None):
    """``acc? + alpha · (A @ B)`` — dense fused local GEMM.

    The data-dependent sibling of :func:`sketch_block` for bodies whose
    right operand is NOT a Philox tile — the gradient-compression factors
    ``P̂ᵀ·M``, ``P̂·Qᵀ`` and the error-feedback update
    ``E' = gemm_block(P̂, Q_loc, acc=M, alpha=-1)`` (the accumulator is
    aliased in-place on the pallas backend: one HBM round trip, the
    2·m·n vs 4·m·n term in ``plan.model.grad_compress_cost``).

    ``alpha`` must be static (baked into the kernel body).  Accumulation
    is f32 on both backends and the association is fixed as
    ``acc + (dot · alpha)``, so an untiled contraction (the interpret-mode
    default block policy) is bitwise-identical across backends — the same
    single ``lax.dot`` on the same operands.
    """
    b = resolve_backend(backend)
    out_dtype = out_dtype or A.dtype
    alpha = float(alpha)
    if b == "jnp":
        return _gemm_jnp(A, B, alpha, precision, acc, out_dtype)
    interpret = _interpret() if interpret is None else interpret
    return _gemm_pallas(A, B, alpha, acc, out_dtype, blocks, interpret)
