"""Pure-jnp oracles for the Pallas kernels.

The Omega construction calls the *same* Philox helpers as the kernel bodies,
keyed by global coordinates, so oracle and kernel agree bitwise on Omega;
results agree to float accumulation order.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import rng


def omega_ref(seed: int, n2: int, r: int, kind: str = "normal",
              salt: int = 0, dtype=jnp.float32):
    key0 = jnp.uint32(seed & 0xFFFFFFFF)
    key1 = jnp.uint32((seed >> 32) & 0xFFFFFFFF)
    z = jnp.uint32(0)
    if kind == "normal":
        om = rng.philox_normal_grid(key0, key1, z, z, n2, r, salt)
    elif kind == "uniform":
        om = rng.philox_uniform_grid(key0, key1, z, z, n2, r, salt)
    elif kind == "rademacher":
        u = rng.philox_uniform_grid(key0, key1, z, z, n2, r, salt)
        om = jnp.where(u < 0.5, jnp.float32(-1), jnp.float32(1))
    else:
        raise ValueError(kind)
    return om.astype(dtype)


def sketch_matmul_ref(A, seed: int, r: int, kind: str = "normal",
                      salt: int = 0, out_dtype=None):
    """B = A @ Omega, f32 accumulation."""
    n2 = A.shape[-1]
    om = omega_ref(seed, n2, r, kind, salt)
    out = jnp.matmul(A.astype(jnp.float32), om)
    return out.astype(out_dtype or A.dtype)


def sketch_t_matmul_ref(B, seed: int, r: int, kind: str = "normal",
                        salt: int = 0, out_dtype=None):
    """C = Omega^T @ B, f32 accumulation; Omega is (n x r)."""
    n = B.shape[0]
    om = omega_ref(seed, n, r, kind, salt)
    out = jnp.matmul(om.T, B.astype(jnp.float32))
    return out.astype(out_dtype or B.dtype)
