"""Pallas TPU kernels for the paper's compute hot-spot: the sketch GEMM with
in-VMEM Omega generation (HBM-level analogue of regenerate-don't-communicate).
Validated in interpret mode on CPU; targeted at TPU MXU tiling."""
from .ops import (  # noqa: F401
    gen_omega, nystrom_fused, sketch_matmul, sketch_t_matmul,
)
from .sketch_matmul import (  # noqa: F401
    gen_omega_pallas, sketch_matmul_pallas, sketch_t_matmul_pallas,
)
from .local import (  # noqa: F401
    resolve_backend, sketch_block, sketch_t_block,
)
from . import local, ref  # noqa: F401
