"""Zamba2-1.2B — Mamba-2 backbone with a shared attention block.
[arXiv:2411.15242; hf]
38L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=32000 ssm_state=64.

Long-context: above 64k the shared block's attention switches to Nyström
landmark attention (the paper's sketched two-product structure), keeping the
hybrid sub-quadratic for the long_500k cell."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    d_inner=4096,
    ssm_heads=64,               # headdim 64
    d_conv=4,
    mamba_version=2,
    shared_attn_every=6,
    nystrom_attn_above=65536,
    nystrom_landmarks=256,
)
