"""Whisper-tiny — encoder-decoder, conv frontend stubbed (precomputed frame
embeddings per assignment). [arXiv:2212.04356; unverified]
4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.

Note: decode_32k is lowered mechanically (positions beyond Whisper's native
448-token decoder context clamp into the learned table); long_500k is
skipped — see DESIGN.md §Arch-applicability."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,                  # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab=51865,
    enc_seq=1500,
    abs_pos_embed=True,
    max_pos=65536,
    norm="layernorm",
    activation="gelu",
)
