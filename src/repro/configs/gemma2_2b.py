"""Gemma-2 2B — alternating local/global attention, logit softcaps,
post-norms, tied embeddings. [arXiv:2408.00118; hf]
26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    window=4096,
    alt_local_global=True,       # even layers local(4096), odd global
    attn_softcap=50.0,
    final_softcap=30.0,
    use_post_norms=True,
    tie_embeddings=True,
    embed_scale=True,
    activation="gelu",           # GeGLU
    rope_theta=1e4,
)
