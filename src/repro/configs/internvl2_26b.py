"""InternVL2-26B — InternViT-6B (stub frontend) + InternLM2-20B backbone.
[arXiv:2404.16821; hf]  48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553.  The vision tower is a STUB per assignment: ``input_specs``
supplies precomputed patch embeddings (256 tokens, dim 3200) which the
trainable projector maps into the LM stream."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    rope_theta=1e6,
    frontend="vision",
    frontend_dim=3200,
    num_frontend_tokens=256,
)
