"""H2O-Danube3-4B — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]  24L d_model=3840 32H (GQA kv=8)
d_ff=10240 vocab=32000."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab=32000,
    window=4096,                 # SWA: sub-quadratic, long_500k runnable
    rope_theta=1e4,
)
