"""Config dataclasses: model architecture, input shapes, run settings."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # attention variants
    window: int = 0               # >0: sliding-window width for SWA layers
    alt_local_global: bool = False  # gemma-2: even layers local, odd global
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    use_post_norms: bool = False  # gemma-2 double-norm residual
    use_qk_norm: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    activation: str = "silu"      # silu | gelu
    embed_scale: bool = False     # gemma: x *= sqrt(d)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_dispatch: str = "scatter"   # scatter (optimized) | einsum (GShard)

    # SSM
    ssm_state: int = 0
    d_inner: int = 0
    dt_rank: int = 0
    d_conv: int = 4
    mamba_version: int = 1
    ssm_heads: int = 0            # mamba2
    ssm_chunk: int = 256

    # hybrid (zamba): one shared attention+FFN block applied every k layers
    shared_attn_every: int = 0

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500
    abs_pos_embed: bool = False
    max_pos: int = 0              # learned abs positions table size

    # modality frontend stubs
    frontend: str = "none"        # none | vision | audio
    frontend_dim: int = 0         # precomputed embedding dim (stub output)
    num_frontend_tokens: int = 0

    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    loss_chunk: int = 512

    # long-context attention substitution (paper technique): use Nyström
    # landmark attention for full-attention blocks above this seq length
    nystrom_attn_above: int = 0   # 0 = never
    nystrom_landmarks: int = 256

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def jnp_dtype(self):
        return DTYPES[self.dtype]

    def layer_windows(self, seq_len: int) -> Tuple[int, ...]:
        """Effective attention window per layer (FULL = no limit)."""
        FULL = 1 << 30
        if self.alt_local_global:
            return tuple(self.window if (i % 2 == 0) else FULL
                         for i in range(self.n_layers))
        if self.window > 0:
            return tuple(self.window for _ in range(self.n_layers))
        return tuple(FULL for _ in range(self.n_layers))

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs have a decode path (enc-dec incl.)

    @property
    def sub_quadratic(self) -> bool:
        """Whether long-context cells are runnable (see DESIGN.md
        §Arch-applicability): SSM/hybrid, SWA-only, or local+global archs."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.window > 0:          # SWA or alternating local/global
            return True
        return False

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: same family/topology, tiny dims."""
        shrink = dict(
            n_layers=min(self.n_layers, 2 if self.family != "hybrid" else 5),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab=256,
            head_dim=16,
            window=min(self.window, 8) if self.window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            d_inner=128 if self.d_inner else 0,
            dt_rank=8 if self.dt_rank else 0,
            ssm_heads=4 if self.ssm_heads else 0,
            ssm_chunk=8,
            shared_attn_every=2 if self.shared_attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=16 if self.n_enc_layers else self.enc_seq,
            max_pos=4096 if self.max_pos else 0,
            frontend_dim=32 if self.frontend_dim else 0,
            num_frontend_tokens=(8 if self.num_frontend_tokens else 0),
            dtype="float32",
            loss_chunk=16,
            nystrom_landmarks=4,
        )
        shrink.update(overrides)
        return dataclasses.replace(self, **shrink)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training-run settings consumed by the launcher."""
    steps: int = 200
    micro_batch: Optional[int] = None      # grad accumulation if < per-dev
    learning_rate: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
    remat: bool = True
    # paper technique in training: sketched gradient compression
    grad_compress_rank: int = 0            # 0 = off
    grad_compress_min_dim: int = 1024      # legacy heuristic (planner wins)
    # local GEMM bodies of the compressed exchange (kernels/local.py):
    # "auto" = pallas on TPU, jnp elsewhere; bitwise-identical on untiled
    # leaves either way (docs/TRAINING.md "Backends")
    grad_compress_backend: str = "auto"
    # fault tolerance
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    # straggler monitor
    straggler_ewma: float = 0.9
    straggler_sigma: float = 3.0
