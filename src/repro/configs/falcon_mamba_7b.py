"""Falcon-Mamba-7B — pure Mamba-1, attention-free.
[arXiv:2410.05355; unverified]
64L d_model=4096 d_ff=0 vocab=65024 ssm_state=16."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,                   # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab=65024,
    ssm_state=16,
    d_inner=8192,
    dt_rank=256,
    d_conv=4,
    mamba_version=1,
)
