"""Architecture registry: ``get_config("<arch-id>")`` and shape helpers."""
from __future__ import annotations

from typing import Dict, List, Tuple

from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K,
                   SHAPES_BY_NAME, TRAIN_4K, ModelConfig, RunConfig,
                   ShapeConfig)

from . import (dbrx_132b, falcon_mamba_7b, gemma2_2b, granite_moe_1b,
               h2o_danube3_4b, internlm2_20b, internvl2_26b, llama3_8b,
               whisper_tiny, zamba2_1p2b)

_REGISTRY: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (internvl2_26b, h2o_danube3_4b, internlm2_20b, gemma2_2b,
              llama3_8b, granite_moe_1b, dbrx_132b, zamba2_1p2b,
              falcon_mamba_7b, whisper_tiny)
}

ARCH_IDS: Tuple[str, ...] = tuple(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    """The assigned shape set minus documented skips
    (DESIGN.md §Arch-applicability)."""
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic and cfg.family != "encdec":
        shapes.append(LONG_500K)
    return shapes


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """Non-empty string when (arch, shape) is a documented skip."""
    if shape.name != "long_500k":
        return ""
    if cfg.family == "encdec":
        return "SKIP(enc-dec: decoder context bound, 500k meaningless)"
    if not cfg.sub_quadratic:
        return "SKIP(pure full-attention arch; needs sub-quadratic attention)"
    return ""


def all_cells():
    """Every (arch, shape) pair, with its skip reason ('' = runnable)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in ALL_SHAPES:
            yield arch, shape.name, skip_reason(cfg, shape)
