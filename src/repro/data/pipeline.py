"""Deterministic synthetic data pipeline.

Stateless by construction: batch t is a pure function of (seed, step), so
checkpoint/restart resumes the stream bit-exactly from the step counter
alone (no iterator state to save), and any host regenerates any shard —
the same counter-based-PRNG discipline the paper applies to Omega.

The token stream is a Zipf-like unigram mix with a Markov backbone so the
LM loss has learnable structure (tests assert loss decreases).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # modality stubs
    frontend: str = "none"
    frontend_dim: int = 0
    num_frontend_tokens: int = 0
    enc_seq: int = 0
    d_model: int = 0


def _batch_key(seed: int, step: int):
    return jax.random.fold_in(jax.random.key(seed), step)


def synth_tokens(key, batch: int, seq: int, vocab: int):
    """Markov-ish synthetic tokens: x_{t+1} = (a*x_t + noise) mod vocab_eff.

    Learnable (low-entropy transitions) yet nondegenerate."""
    k1, k2 = jax.random.split(key)
    x0 = jax.random.randint(k1, (batch, 1), 0, vocab)
    noise = jax.random.randint(k2, (batch, seq), 0, 7)

    def step(x, n):
        nxt = (x * 31 + n * 17 + 3) % vocab
        return nxt, nxt

    _, xs = jax.lax.scan(step, x0[:, 0], noise.T)
    return jnp.concatenate([x0, xs.T[:, :-1]], axis=1).astype(jnp.int32)


def make_batch(cfg: DataConfig, step: int) -> Dict[str, Any]:
    key = _batch_key(cfg.seed, step)
    kt, kf = jax.random.split(key)
    tokens = synth_tokens(kt, cfg.global_batch, cfg.seq_len + 1, cfg.vocab)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.frontend == "vision" and cfg.num_frontend_tokens:
        batch["frontend_feats"] = jax.random.normal(
            kf, (cfg.global_batch, cfg.num_frontend_tokens,
                 cfg.frontend_dim), jnp.float32)
    if cfg.frontend == "audio" and cfg.enc_seq:
        batch["frames"] = jax.random.normal(
            kf, (cfg.global_batch, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


class Pipeline:
    """Step-indexed iterator with double-buffered prefetch."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 shardings=None, prefetch: int = 2):
        self.cfg = cfg
        self.step = start_step
        self.shardings = shardings
        self.prefetch = prefetch
        self._buf: list = []

    def _produce(self, step: int):
        b = make_batch(self.cfg, step)
        if self.shardings is not None:
            b = jax.device_put(b, self.shardings)
        return b

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while len(self._buf) < self.prefetch:
            self._buf.append((self.step + len(self._buf),
                              self._produce(self.step + len(self._buf))))
        s, b = self._buf.pop(0)
        self.step = s + 1
        return b

    def state(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.cfg.seed}

    @classmethod
    def from_state(cls, cfg: DataConfig, state: Dict[str, int], **kw):
        assert state["seed"] == cfg.seed, "seed mismatch on restore"
        return cls(cfg, start_step=state["step"], **kw)


def data_config_for(model_cfg, shape_cfg, seed: int = 0) -> DataConfig:
    n_front = getattr(model_cfg, "num_frontend_tokens", 0)
    seq = shape_cfg.seq_len - (n_front if model_cfg.family == "vlm" else 0)
    return DataConfig(
        vocab=model_cfg.vocab, seq_len=seq,
        global_batch=shape_cfg.global_batch, seed=seed,
        frontend=("vision" if model_cfg.family == "vlm"
                  else "audio" if model_cfg.family == "encdec" else "none"),
        frontend_dim=model_cfg.frontend_dim,
        num_frontend_tokens=n_front,
        enc_seq=model_cfg.enc_seq if model_cfg.family == "encdec" else 0,
        d_model=model_cfg.d_model,
    )
