from .pipeline import DataConfig, Pipeline, data_config_for, make_batch  # noqa: F401
