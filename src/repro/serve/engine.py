"""Serving engine: the repo's two request-serving workloads behind one door.

1. LM serving — family-uniform prefill / decode entry points + a simple
   batched request scheduler (continuous-batching-lite) used by examples
   and the serve driver (``launch/serve.py``).
2. Sketch serving — ``make_sketch_service`` builds a
   :class:`repro.stream.SketchService`: many concurrent streaming-sketch
   clients multiplexed onto one processor grid, each update running the
   paper's communication-optimal Alg. 1 (§4.2) with Omega regenerated, never
   communicated (§6.3).  Streams sharing a shape signature share one
   compiled update executable, so stream fan-in scales without recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import get_api
from repro.models.common import NULL_CTX, ShardCtx, matmul
from repro.models import mamba_lm, transformer, whisper as whisper_mod, zamba
from repro.obs import trace as obs_trace
from repro.stream.service import SketchService


# ---------------------------------------------------------------------------
# uniform prefill: returns (last-position logits, decode cache)
# ---------------------------------------------------------------------------

def serve_prefill(params, cfg: ModelConfig, batch: Dict[str, Any], *,
                  ctx: ShardCtx = NULL_CTX, max_len: Optional[int] = None,
                  remat: bool = True):
    """Process the prompt for every family; produce the decode cache."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        tokens = batch["tokens"]
        if fam == "vlm":
            # fold projected frontend tokens in by prefilling embeds path:
            # (kept simple: frontend tokens participate via lm_hidden; the
            # decode cache covers the text region only in this engine)
            h, _ = transformer.lm_hidden(params, cfg, tokens, ctx=ctx,
                                         frontend_feats=batch.get(
                                             "frontend_feats"), remat=remat)
            W = (params["embed"] if cfg.tie_embeddings
                 else params["lm_head"])
            logits = matmul(h[:, -1:], W.T)
            cache = None
            return logits, cache
        return transformer.prefill(params, cfg, tokens, ctx=ctx,
                                   remat=remat, max_len=max_len)
    if fam == "ssm":
        h = mamba_lm.mamba_lm_hidden(params, cfg, batch["tokens"], ctx=ctx,
                                     remat=remat)
        logits = matmul(h[:, -1:], params["lm_head"].T)
        return logits, None   # state prefill via chunked replay (below)
    if fam == "hybrid":
        h = zamba.hybrid_hidden(params, cfg, batch["tokens"], ctx=ctx,
                                remat=remat)
        logits = matmul(h[:, -1:], params["lm_head"].T)
        return logits, None
    if fam == "encdec":
        enc = whisper_mod.encode(params, cfg, batch["frames"], ctx=ctx,
                                 remat=remat)
        B = batch["frames"].shape[0]
        cache = whisper_mod.encdec_init_cache(cfg, B, max_len or 4096)
        ck, cv = whisper_mod.encdec_prepare_cross(params, cfg, enc)
        cache = dict(cache, cross_k=ck, cross_v=cv)
        bos = batch.get("tokens",
                        jnp.zeros((B, 1), jnp.int32))[:, :1]
        logits, cache = whisper_mod.encdec_decode_step(
            params, cfg, bos, cache, jnp.int32(0), ctx=ctx)
        return logits, cache
    raise ValueError(fam)


def serve_decode_step(params, cfg: ModelConfig, token, cache, pos, *,
                      ctx: ShardCtx = NULL_CTX):
    api = get_api(cfg)
    return api.decode_step(params, cfg, token, cache, pos, ctx=ctx)


# ---------------------------------------------------------------------------
# batched request scheduler (continuous-batching-lite)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Fixed-slot batched decoding: requests claim slots; finished slots are
    refilled from the queue each step (continuous batching without paged
    memory — cache slots are per-request rows of the batched cache)."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int,
                 max_len: int, eos: int = 1,
                 ctx: ShardCtx = NULL_CTX):
        self.params, self.cfg, self.ctx = params, cfg, ctx
        self.slots, self.max_len, self.eos = slots, max_len, eos
        api = get_api(cfg)
        self.cache = api.init_cache(cfg, slots, max_len)
        self.pos = [0] * slots
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self._step = jax.jit(
            lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos,
                                                 ctx=ctx))

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slots(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                self.active[s] = req
                self.pos[s] = 0
                # teacher-forced prompt replay into the cache
                with obs_trace.span("serve.prefill", cat="serve",
                                    rid=req.rid, slot=s,
                                    prompt_len=len(req.prompt)):
                    for t in req.prompt:
                        self._advance_slot(s, t)

    def _advance_slot(self, s: int, token: int) -> int:
        tok = jnp.zeros((self.slots, 1), jnp.int32).at[s, 0].set(token)
        logits, self.cache = self._step(self.params, tok, self.cache,
                                        jnp.int32(self.pos[s]))
        self.pos[s] += 1
        return int(jnp.argmax(logits[s, -1]))

    def step(self) -> bool:
        """One scheduler tick; returns False when idle."""
        with obs_trace.span("serve.step", cat="serve"):
            self._fill_slots()
            busy = False
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                busy = True
                last = req.out[-1] if req.out else req.prompt[-1]
                nxt = self._advance_slot(s, last)
                req.out.append(nxt)
                if nxt == self.eos or len(req.out) >= req.max_new \
                        or self.pos[s] >= self.max_len - 1:
                    req.done = True
                    self.active[s] = None
            return busy or bool(self.queue)

    def run(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.step():
                break


# ---------------------------------------------------------------------------
# batched sketch service (streaming workload entry point)
# ---------------------------------------------------------------------------

def make_sketch_service(grid: Optional[Tuple[int, int, int]] = None,
                        devices=None, plan=None,
                        shape: Optional[Tuple[int, int, int]] = None,
                        backend: str = "auto",
                        max_resident: Optional[int] = None,
                        spill_dir: Optional[str] = None) -> SketchService:
    """The streaming-sketch serving entry point: one mesh, many streams.

    grid:
      * ``None``      — local mode: streams live on the default device and
                        support row-block ingest (bitwise vs. the one-shot
                        reference).
      * ``(p1,p2,p3)``— distributed mode: every stream's (Y, W) state is
                        sharded per the Alg.-1 layout contract and updates
                        run ``rand_matmul`` on that grid.
      * ``"auto"``    — plan the grid with :mod:`repro.plan` for the
                        dominant stream shape, which must be passed as
                        ``shape=(n1, n2, r)``.
    plan: a precomputed :class:`repro.plan.Plan` (e.g. from ``plan_stream``
          or ``plan_sketch``); its grid places the service mesh.  Wins over
          ``grid`` (and its backend decision over ``backend``).
    backend: local GEMM body of the distributed updates
          (``"jnp"`` | ``"pallas"`` | ``"auto"`` — kernels/local.py).
    max_resident / spill_dir: the service's admission budget — at most
          ``max_resident`` streams keep device state; colder non-pinned
          streams are checkpointed to host memory (or ``spill_dir``) and
          restored bitwise on next touch.
    """
    kw = dict(max_resident=max_resident, spill_dir=spill_dir)
    if plan is None and grid == "auto":
        if shape is None:
            raise ValueError('grid="auto" needs the dominant stream shape: '
                             'shape=(n1, n2, r)')
        import jax
        from repro.plan import plan_sketch
        ndev = len(devices if devices is not None else jax.devices())
        plan = plan_sketch(*shape, P=ndev)
    if plan is not None:
        if not plan.executable:
            raise ValueError(
                f"plan {plan.variant!r} for dims={plan.dims}, "
                f"P={plan.n_procs} is analytic-only (no executable grid "
                f"divides the shape) — no service mesh can host it")
        if plan.grid is None:   # single-device plan -> local mode
            return SketchService(**kw)
        grid = plan.grid
        backend = getattr(plan, "backend", backend) or backend
    if grid is None:
        return SketchService(**kw)
    from repro.core.sketch import make_grid_mesh
    return SketchService(mesh=make_grid_mesh(*grid, devices=devices),
                         backend=backend, **kw)


def make_ingest_queue(service: SketchService, depth: int = 256,
                      window: int = 64, bucket_edges="auto",
                      expected_ks=None, **cfg):
    """Front a local-mode service with the bounded async
    :class:`repro.stream.IngestQueue`.

    ``bucket_edges="auto"`` prices bucket boundaries with
    :func:`repro.plan.choose_bucket_edges` from ``expected_ks`` (the
    anticipated lane-height distribution, e.g. a recent traffic sample);
    with no sample the queue falls back to pow2 snapping.  Any remaining
    kwargs go to IngestQueue.
    """
    from repro.stream.ingest import IngestQueue
    if bucket_edges == "auto":
        if expected_ks:
            from repro.plan import choose_bucket_edges
            sample = [cfg_k for cfg_k in expected_ks]
            any_st = next(iter(service._streams.values()), None)
            if any_st is not None:
                c = any_st.cfg
                bucket_edges = choose_bucket_edges(
                    sample, c.n2, c.r, c.sketch_l, corange=c.corange,
                    backend=service.backend)
            else:
                bucket_edges = None
        else:
            bucket_edges = None
    return IngestQueue(service, depth=depth, window=window,
                       bucket_edges=bucket_edges, **cfg)
