from .engine import (  # noqa: F401
    BatchedServer, Request, SketchService, make_sketch_service,
    serve_decode_step, serve_prefill,
)
