from .engine import BatchedServer, Request, serve_decode_step, serve_prefill  # noqa: F401
