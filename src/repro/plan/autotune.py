"""Measured refinement of analytic plans + a versioned on-disk result cache.

The analytic model (``plan.model``) ranks candidates from vendor peaks; real
machines disagree (BLAS blocking, fake-device loopback, compiler fusion), so
``autotune`` times the top-k analytic candidates on synthetic inputs and
returns the plan rebuilt around the measured winner — the approach of the
autotuned sketching libraries surveyed in Yang–Meng–Mahoney (1502.03032).

Results persist in a JSON cache keyed by
``(device kind, task, shape bucket, dtype, P)`` where the shape bucket
rounds every dim up to a power of two — one tuning run serves the whole
bucket.  The cache is versioned (schema bumps invalidate stale files) and
written atomically (tmp + rename), so concurrent processes at worst re-tune.

The timer is injectable (``timer=lambda fn: seconds``) so tests can tune
deterministically without a clock.

Two follow-on consumers of the measurements (ROADMAP open items):

  * **Tuned presets** — ``PRESET_ENTRIES`` ships known-good decisions
    (block shapes / backends) as a read-only second-level cache consulted
    on a cache miss before measuring; a real measurement always overwrites
    a preset in the local cache.  Entries carry a ``"source"`` tag
    recording whether they were measured or are vendor-roofline analytic
    defaults.
  * **Machine-model calibration** — ``sweep_records`` captures every
    measured candidate's analytic resource counts next to its seconds, and
    ``calibrate_machine_model`` least-squares fits the network terms
    (alpha, beta = 1/byte_bw) of a :class:`MachineModel` preset from those
    residuals, so the planner's seconds track the machine it actually runs
    on.  CPU-runnable with the injectable timer.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
import time
from typing import Callable, Dict, List, Optional, Sequence

from . import model as M
from .planner import Plan, _alg1_executable, _itemsize

CACHE_VERSION = 2    # v2: entries carry backend + source tags

# Pallas block-size sweep for the fused kernels (filtered by VMEM fit) —
# swept both for the single-device pallas_fused variant and for the
# pallas-backend shard_map variants (the per-shard local GEMM tiles).
BLOCK_SWEEP = (
    {"bm": 128, "bn": 128, "bk": 256},
    {"bm": 256, "bn": 128, "bk": 512},
    {"bm": 512, "bn": 128, "bk": 512},
    {"bm": 256, "bn": 256, "bk": 512},
)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

class AutotuneCache:
    """Versioned JSON cache of tuning decisions; counts hits and misses."""

    def __init__(self, path: str):
        self.path = path
        self.hits = 0
        self.misses = 0
        self._entries: Dict[str, dict] = {}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    data = json.load(f)
                if data.get("version") == CACHE_VERSION:
                    self._entries = data.get("entries", {})
            except (OSError, ValueError):
                pass  # unreadable/stale cache == empty cache

    def get(self, key: str) -> Optional[dict]:
        hit = self._entries.get(key)
        if hit is None:
            self.misses += 1
        else:
            self.hits += 1
        return hit

    def put(self, key: str, value: dict):
        self._entries[key] = value
        self._flush()

    def pop(self, key: str) -> Optional[dict]:
        """Drop one entry (drift revalidation — see
        ``repro.obs.report.revalidate_autotune``): the next ``autotune``
        call at ``key`` misses and re-measures.  Returns the dropped entry,
        or None when the key was absent (nothing is flushed then)."""
        hit = self._entries.pop(key, None)
        if hit is not None:
            self._flush()
        return hit

    def _flush(self):
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmp_tune_")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"version": CACHE_VERSION,
                           "entries": self._entries}, f, indent=1)
            os.replace(tmp, self.path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self):
        return len(self._entries)


def shape_bucket(x: int) -> int:
    """Round up to the next power of two (>= 1)."""
    return 1 << max(0, int(x - 1).bit_length())


def cache_key(plan: Plan, device_kind: Optional[str] = None) -> str:
    kind = device_kind or M.device_kind_tag()
    dims = "x".join(str(shape_bucket(d)) for d in plan.dims)
    return f"{kind}/{plan.task}/{dims}/{plan.dtype}/P{plan.n_procs}"


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------

def default_timer(fn: Callable[[], object], warmup: int = 1,
                  iters: int = 3) -> float:
    """Median wall seconds of ``fn()`` with block_until_ready."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _synthetic_input(plan: Plan):
    import jax
    import jax.numpy as jnp
    if plan.task == "nystrom":
        n, _ = plan.dims
        shape = (n, n)
    else:
        shape = (plan.dims[0], plan.dims[1])
    # normal data, not zeros: sparse-zero fast paths must not skew timings
    x = jax.random.normal(jax.random.key(0), shape)
    return x.astype(jnp.dtype(plan.dtype))


# ---------------------------------------------------------------------------
# candidate expansion (what a measured pass actually sweeps)
# ---------------------------------------------------------------------------

def _vmem_fits(blocks: dict, machine: M.MachineModel) -> bool:
    from repro.kernels.local import vmem_fit_bytes
    return vmem_fit_bytes(blocks["bm"], blocks["bn"],
                          blocks["bk"]) <= machine.vmem_bytes


def _measurable_candidates(plan: Plan, machine: M.MachineModel,
                           top_k: int) -> List[Plan]:
    """Concrete plan variants to time: the top-k executable analytic
    candidates, with a grid sweep for Alg. 1/2 and a (bm, bn, bk)
    block-shape sweep for every pallas-backed candidate — the fused
    single-device kernels AND the pallas-backend shard_map bodies."""
    isz = _itemsize(plan.dtype)
    out: List[Plan] = []

    def add(variant, grid=None, q_grid=None, blocks=None, chunk_rows=None,
            backend="jnp"):
        out.append(dataclasses.replace(
            plan, variant=variant, grid=grid, q_grid=q_grid, blocks=blocks,
            chunk_rows=chunk_rows if chunk_rows else plan.chunk_rows,
            backend=backend, executable=True))

    def add_with_blocks(variant, grid=None, q_grid=None, chunk_rows=None,
                        backend="jnp"):
        """One entry for the jnp backend; a VMEM-filtered block sweep for
        the pallas backend."""
        if backend != "pallas":
            add(variant, grid=grid, q_grid=q_grid, chunk_rows=chunk_rows)
            return
        for blocks in BLOCK_SWEEP:
            if _vmem_fits(blocks, machine):
                add(variant, grid=grid, q_grid=q_grid, blocks=blocks,
                    chunk_rows=chunk_rows, backend="pallas")

    pallas_ok = any(c.backend == "pallas" and c.executable
                    for c in plan.candidates)

    if plan.task == "sketch" and plan.n_procs > 1:
        n1, n2, r = plan.dims
        from repro.core.grid import factorizations_3d
        scored = []
        for g in factorizations_3d(plan.n_procs):
            if _alg1_executable(n1, n2, r, g):
                c = M.alg1_cost(n1, n2, r, g)
                scored.append((c.seconds(machine, isz), g))
        scored.sort(key=lambda t: t[0])
        for _, g in scored[:top_k]:
            add("alg1", grid=g)
            if pallas_ok:
                add_with_blocks("alg1", grid=g, backend="pallas")
        return out

    if plan.task == "stream":
        k0 = plan.chunk_rows or plan.dims[0]
        for k in sorted({max(1, k0 // 2), k0, min(plan.dims[0], k0 * 2)}):
            for cand in plan.candidates:
                if cand.executable:
                    add(cand.variant, grid=cand.grid, chunk_rows=k,
                        backend=cand.backend)
        return out[: max(top_k * 2, 3)]

    # P == 1 sketch/nystrom, or distributed nystrom
    for cand in [c for c in plan.candidates if c.executable][:top_k]:
        if cand.variant == "pallas_fused":
            for blocks in BLOCK_SWEEP:
                if _vmem_fits(blocks, machine):
                    add(cand.variant, blocks=blocks, backend="pallas")
        elif cand.variant in ("alg2_bound_driven",
                              "alg2_bound_driven_fused"):
            # JOINT (p, q)-pair sweep: score every executable pair of
            # factorizations of P — not just q-grids under the analytic
            # stage-1 grid — and measure the top-k.  Fused candidates are
            # restricted to pairs a shared mesh can serve
            # (core.grid.two_grid_axis_split).
            from repro.core.grid import (alg2_two_grid_executable,
                                         factorizations_3d,
                                         two_grid_axis_split)
            n, r = plan.dims
            fused = cand.variant == "alg2_bound_driven_fused"
            cost_fn = M.alg2_fused_cost if fused else M.alg2_cost
            facs = list(factorizations_3d(plan.n_procs))
            scored_pq = []
            for pg in facs:
                for qg in facs:
                    if not alg2_two_grid_executable(n, r, pg, qg):
                        continue
                    if fused and two_grid_axis_split(pg, qg) is None:
                        continue
                    c = cost_fn(n, r, pg, qg)
                    scored_pq.append((c.seconds(machine, isz), pg, qg))
            scored_pq.sort(key=lambda t: t[0])
            for _, pg, qg in scored_pq[:top_k]:
                add_with_blocks(cand.variant, grid=pg, q_grid=qg,
                                backend=cand.backend)
        else:
            add_with_blocks(cand.variant, grid=cand.grid,
                            q_grid=cand.q_grid, backend=cand.backend)
    return out


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------

def autotune(plan: Plan, *,
             cache: Optional[object] = None,
             timer: Optional[Callable[[Callable[[], object]], float]] = None,
             top_k: int = 3, seed: int = 0, devices=None,
             machine: Optional[M.MachineModel] = None,
             device_kind: Optional[str] = None,
             presets: Optional[Dict[str, dict]] = None,
             records: Optional[List[dict]] = None) -> Plan:
    """Return ``plan`` refined by measurement.

    cache : an :class:`AutotuneCache`, a path (str) to create one at, or
            ``None`` for no persistence.
    timer : callable mapping a nullary executable closure to seconds
            (default: wall clock, median of 3 after warmup).
    presets : a read-only second-level cache of shipped tuning decisions
            (default :data:`PRESET_ENTRIES`; pass ``{}`` to disable).
            Consulted only on a cache miss — a local measurement always
            wins and overwrites the preset in the writable cache.
    records : optional list that receives one measurement record per timed
            candidate (see :func:`sweep_records`) for machine-model
            calibration.

    A cache hit skips all measurement and rebuilds the plan from the stored
    decision; a preset hit does the same (and seeds the cache); a miss
    measures the candidate sweep, stores the winner, and returns it with
    ``measured_seconds`` set.
    """
    if isinstance(cache, str):
        cache = AutotuneCache(cache)
    timer = timer or default_timer
    machine = machine or M.probe_machine()
    presets = PRESET_ENTRIES if presets is None else presets

    key = cache_key(plan, device_kind)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            restored = _plan_from_entry(plan, hit)
            # the key buckets shapes to powers of two, so a stored decision
            # may not divide THIS plan's exact dims — re-validate, and fall
            # through to measuring when it doesn't.
            if restored is not None:
                return _rescore(restored, machine)
    preset = presets.get(key)
    if preset is not None:
        restored = _plan_from_entry(plan, preset)
        if restored is not None:
            if cache is not None:
                cache.put(key, dict(preset))
            return _rescore(restored, machine)

    candidates = _measurable_candidates(plan, machine, top_k)
    if not candidates:
        return plan

    A = _synthetic_input(plan)
    best = None
    for cand in candidates:
        secs = timer(lambda c=cand: c.execute(A, seed=seed, devices=devices))
        if records is not None:
            records.append(_record(cand, machine, secs))
        if best is None or secs < best[0]:
            best = (secs, cand)
    secs, winner = best
    tuned = _rescore(dataclasses.replace(winner, measured_seconds=secs),
                     machine)

    if cache is not None:
        cache.put(key, _entry_from_plan(tuned))
    return tuned


def _rescore(plan: Plan, machine: M.MachineModel) -> Plan:
    """Recompute the analytic cost fields for the plan's (possibly tuned)
    variant/grid/backend, so the bound audit and ``explain`` describe the
    variant that was actually chosen, not the pre-tune analytic favorite."""
    if plan.task == "sketch":
        n1, n2, r = plan.dims
        if plan.variant == "alg1" and plan.grid:
            c = M.alg1_cost(n1, n2, r, plan.grid, backend=plan.backend)
        elif plan.variant == "pallas_fused":
            c = M.pallas_fused_cost(n1, n2, r)
        else:
            c = M.local_cost(n1, n2, r)
    elif plan.task == "nystrom":
        n, r = plan.dims
        if plan.variant == "alg2_bound_driven_fused" and plan.grid:
            c = M.alg2_fused_cost(n, r, plan.grid, plan.q_grid or plan.grid,
                                  backend=plan.backend)
        elif plan.variant in ("alg2_no_redist", "alg2_redist",
                              "alg2_bound_driven") and plan.grid:
            c = M.alg2_cost(n, r, plan.grid, plan.q_grid or plan.grid,
                            backend=plan.backend)
        else:
            c = M.nystrom_local_cost(n, r,
                                     fused=(plan.variant == "pallas_fused"))
    else:  # stream
        n1, n2, r = plan.dims
        k = plan.chunk_rows or n1
        l = plan.sketch_l if plan.sketch_l is not None \
            else min(2 * r + 1, n1)
        grid = plan.grid if plan.variant == "stream_sharded" else (1, 1, 1)
        per = M.stream_update_cost(k, n2, r, l, grid, plan.corange,
                                   backend=plan.backend)
        n_upd = math.ceil(n1 / k)
        c = M.Cost(words=per.words * n_upd, messages=per.messages * n_upd,
                   flops=per.flops * n_upd, hbm_words=per.hbm_words * n_upd)
    return dataclasses.replace(
        plan, predicted_words=c.words, predicted_flops=c.flops,
        predicted_hbm_words=c.hbm_words,
        predicted_seconds=c.seconds(machine, _itemsize(plan.dtype)))


def _entry_from_plan(plan: Plan, source: str = "measured") -> dict:
    return {"variant": plan.variant,
            "grid": list(plan.grid) if plan.grid else None,
            "q_grid": list(plan.q_grid) if plan.q_grid else None,
            "blocks": dict(plan.blocks) if plan.blocks else None,
            "chunk_rows": plan.chunk_rows,
            "backend": plan.backend,
            "source": source,
            "seconds": plan.measured_seconds}


def _record(plan: Plan, machine: M.MachineModel, seconds: float) -> dict:
    """One calibration sample: the candidate's analytic resource counts
    (post-``_rescore``, i.e. for the variant/grid/backend actually timed)
    next to its measured seconds."""
    scored = _rescore(plan, machine)
    return {"task": plan.task, "dims": list(plan.dims),
            "P": plan.n_procs, "variant": plan.variant,
            "grid": list(plan.grid) if plan.grid else None,
            "backend": plan.backend,
            "words": scored.predicted_words,
            "messages": _messages_of(scored),
            "flops": scored.predicted_flops,
            "hbm_words": scored.predicted_hbm_words,
            "itemsize": _itemsize(plan.dtype),
            "seconds": seconds}


def _messages_of(plan: Plan) -> float:
    """Latency hops of the plan's variant (re-derived from the model)."""
    if plan.task == "sketch" and plan.variant == "alg1" and plan.grid:
        return M.alg1_cost(*plan.dims, plan.grid).messages
    if plan.task == "nystrom" and plan.grid:
        cost_fn = (M.alg2_fused_cost
                   if plan.variant == "alg2_bound_driven_fused"
                   else M.alg2_cost)
        return cost_fn(*plan.dims, plan.grid,
                       plan.q_grid or plan.grid).messages
    if plan.task == "stream":
        n1 = plan.dims[0]
        k = plan.chunk_rows or n1
        grid = plan.grid if plan.variant == "stream_sharded" else (1, 1, 1)
        l = plan.sketch_l if plan.sketch_l is not None \
            else min(2 * plan.dims[2] + 1, n1)
        per = M.stream_update_cost(k, plan.dims[1], plan.dims[2], l, grid,
                                   plan.corange)
        return per.messages * math.ceil(n1 / k)
    return 0.0


def _plan_from_entry(plan: Plan, entry: dict) -> Optional[Plan]:
    """Rebuild a plan from a cache entry; None if the stored decision does
    not apply to this plan's exact dims (pow2 bucket collision)."""
    grid = tuple(entry["grid"]) if entry.get("grid") else None
    variant = entry["variant"]
    if plan.task in ("sketch", "stream"):
        n1, n2, r = plan.dims
        if variant in ("alg1", "stream_sharded"):
            if grid is None or not _alg1_executable(n1, n2, r, grid):
                return None
    elif plan.task == "nystrom":
        n, r = plan.dims
        if variant in ("alg2_bound_driven", "alg2_bound_driven_fused"):
            from repro.core.grid import (alg2_two_grid_executable,
                                         two_grid_axis_split)
            qg = tuple(entry["q_grid"]) if entry.get("q_grid") else None
            if grid is None or qg is None \
                    or not alg2_two_grid_executable(n, r, grid, qg):
                return None
            if variant == "alg2_bound_driven_fused" \
                    and two_grid_axis_split(grid, qg) is None:
                return None
        elif variant.startswith("alg2"):
            P = plan.n_procs
            if n % P or r % P or P > n:
                return None
    return dataclasses.replace(
        plan,
        variant=variant,
        grid=grid,
        q_grid=tuple(entry["q_grid"]) if entry.get("q_grid") else None,
        blocks=dict(entry["blocks"]) if entry.get("blocks") else None,
        chunk_rows=entry.get("chunk_rows"),
        backend=entry.get("backend", "jnp"),
        measured_seconds=entry.get("seconds"),
        executable=True)


# ---------------------------------------------------------------------------
# Shipped tuned presets — a read-only second-level cache.
#
# Keys use the same format as ``cache_key`` (device-kind tag / task /
# pow2-bucketed dims / dtype / P).  TPU entries are vendor-roofline
# analytic defaults (MXU-aligned DEFAULT_BLOCKS, fused backend) pending a
# hardware sweep — tagged ``"source": "analytic"`` so a report can tell
# them from measured decisions; any local measurement overwrites them in
# the writable cache.  See scripts in benchmarks/ for regenerating.
# ---------------------------------------------------------------------------

def _preset(variant, grid=None, q_grid=None, blocks=None, backend="pallas",
            source="analytic"):
    return {"variant": variant, "grid": grid, "q_grid": q_grid,
            "blocks": blocks, "chunk_rows": None, "backend": backend,
            "source": source, "seconds": None}


_TPU_BLOCKS = {"bm": 256, "bn": 128, "bk": 512}

PRESET_ENTRIES: Dict[str, dict] = {
    # single-device fused sketch on v5e/v4 class parts: the MXU-aligned
    # default tile is the best of BLOCK_SWEEP at every pow2 bucket >= 1k
    "TPU_v5_lite/sketch/4096x4096x256/float32/P1":
        _preset("pallas_fused", blocks=_TPU_BLOCKS),
    "TPU_v5_lite/sketch/8192x8192x512/float32/P1":
        _preset("pallas_fused", blocks=_TPU_BLOCKS),
    "TPU_v4/sketch/4096x4096x256/float32/P1":
        _preset("pallas_fused", blocks=_TPU_BLOCKS),
    # 8-chip pods: regime-1 zero-comm grid + fused local body
    "TPU_v5_lite/sketch/4096x4096x256/float32/P8":
        _preset("alg1", grid=[8, 1, 1], blocks=_TPU_BLOCKS),
    "TPU_v4/sketch/4096x4096x256/float32/P8":
        _preset("alg1", grid=[8, 1, 1], blocks=_TPU_BLOCKS),
    "TPU_v5_lite/nystrom/4096x256/float32/P8":
        _preset("alg2_no_redist", grid=[8, 1, 1], q_grid=[8, 1, 1],
                blocks=_TPU_BLOCKS),
}


# ---------------------------------------------------------------------------
# Machine-model calibration from grid-sweep measurements (ROADMAP item:
# feed measured autotune results back into MachineModel alpha/beta).
# ---------------------------------------------------------------------------

def sweep_records(plan: Plan, *,
                  timer: Optional[Callable] = None, top_k: int = 4,
                  seed: int = 0, devices=None,
                  machine: Optional[M.MachineModel] = None) -> List[dict]:
    """Measure the full candidate sweep of ``plan`` and return one record
    per candidate (analytic words/messages/flops/hbm + measured seconds) —
    the grid-sweep JSON ``calibrate_machine_model`` consumes.  Never
    touches a cache; the timer is injectable like :func:`autotune`'s."""
    timer = timer or default_timer
    machine = machine or M.probe_machine()
    out: List[dict] = []
    A = _synthetic_input(plan)
    for cand in _measurable_candidates(plan, machine, top_k):
        secs = timer(lambda c=cand: c.execute(A, seed=seed, devices=devices))
        out.append(_record(cand, machine, secs))
    return out


def save_sweep(records: Sequence[dict], path: str) -> None:
    """Persist grid-sweep records as the calibration JSON."""
    with open(path, "w") as f:
        json.dump({"version": CACHE_VERSION, "records": list(records)}, f,
                  indent=1)


def load_sweep(path: str) -> List[dict]:
    with open(path) as f:
        data = json.load(f)
    return list(data.get("records", []))


def calibrate_machine_model(records: Sequence[dict],
                            base: Optional[M.MachineModel] = None,
                            name: Optional[str] = None) -> M.MachineModel:
    """Fit a :class:`MachineModel`'s network terms from measured residuals.

    The cost model predicts ``t = max(flops/F, hbm·isz/H) + words·isz/B +
    msgs·alpha``.  Holding the base preset's compute/memory rates (F, H)
    fixed, the per-record residual ``t_meas - max(flops/F, hbm·isz/H)`` is
    linear in (1/B, alpha) — a two-parameter least-squares fit over the
    grid-sweep records (``sweep_records`` / ``autotune(records=...)``).
    Records with zero words AND zero messages only pin the compute floor
    and drop out of the linear system.  Fitted values are clamped positive;
    with no informative records the base terms are kept unchanged.
    """
    import numpy as np
    base = base or M.probe_machine()
    rows, rhs = [], []
    for rec in records:
        isz = float(rec.get("itemsize", 4))
        local = max(rec["flops"] / base.flop_rate,
                    rec["hbm_words"] * isz / base.hbm_bw)
        resid = rec["seconds"] - local
        w = rec["words"] * isz
        m = rec.get("messages", 0.0)
        if w == 0.0 and m == 0.0:
            continue
        rows.append([w, m])
        rhs.append(resid)
    if not rows:
        return dataclasses.replace(
            base, name=name or f"{base.name}_calibrated")
    X = np.asarray(rows, float)
    y = np.asarray(rhs, float)
    sol, *_ = np.linalg.lstsq(X, y, rcond=None)
    inv_bw, alpha = float(sol[0]), float(sol[1])
    byte_bw = base.byte_bw if inv_bw <= 0.0 else 1.0 / inv_bw
    alpha = base.alpha if alpha <= 0.0 else alpha
    return dataclasses.replace(
        base, name=name or f"{base.name}_calibrated",
        byte_bw=byte_bw, alpha=alpha)
