"""Measured refinement of analytic plans + a versioned on-disk result cache.

The analytic model (``plan.model``) ranks candidates from vendor peaks; real
machines disagree (BLAS blocking, fake-device loopback, compiler fusion), so
``autotune`` times the top-k analytic candidates on synthetic inputs and
returns the plan rebuilt around the measured winner — the approach of the
autotuned sketching libraries surveyed in Yang–Meng–Mahoney (1502.03032).

Results persist in a JSON cache keyed by
``(device kind, task, shape bucket, dtype, P)`` where the shape bucket
rounds every dim up to a power of two — one tuning run serves the whole
bucket.  The cache is versioned (schema bumps invalidate stale files) and
written atomically (tmp + rename), so concurrent processes at worst re-tune.

The timer is injectable (``timer=lambda fn: seconds``) so tests can tune
deterministically without a clock.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
import time
from typing import Callable, Dict, List, Optional

from . import model as M
from .planner import Plan, _alg1_executable, _itemsize

CACHE_VERSION = 1

# Pallas block-size sweep for the fused kernels (filtered by VMEM fit).
BLOCK_SWEEP = (
    {"bm": 128, "bn": 128, "bk": 256},
    {"bm": 256, "bn": 128, "bk": 512},
    {"bm": 512, "bn": 128, "bk": 512},
    {"bm": 256, "bn": 256, "bk": 512},
)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

class AutotuneCache:
    """Versioned JSON cache of tuning decisions; counts hits and misses."""

    def __init__(self, path: str):
        self.path = path
        self.hits = 0
        self.misses = 0
        self._entries: Dict[str, dict] = {}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    data = json.load(f)
                if data.get("version") == CACHE_VERSION:
                    self._entries = data.get("entries", {})
            except (OSError, ValueError):
                pass  # unreadable/stale cache == empty cache

    def get(self, key: str) -> Optional[dict]:
        hit = self._entries.get(key)
        if hit is None:
            self.misses += 1
        else:
            self.hits += 1
        return hit

    def put(self, key: str, value: dict):
        self._entries[key] = value
        self._flush()

    def _flush(self):
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmp_tune_")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"version": CACHE_VERSION,
                           "entries": self._entries}, f, indent=1)
            os.replace(tmp, self.path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self):
        return len(self._entries)


def shape_bucket(x: int) -> int:
    """Round up to the next power of two (>= 1)."""
    return 1 << max(0, int(x - 1).bit_length())


def cache_key(plan: Plan, device_kind: Optional[str] = None) -> str:
    kind = device_kind or M.device_kind_tag()
    dims = "x".join(str(shape_bucket(d)) for d in plan.dims)
    return f"{kind}/{plan.task}/{dims}/{plan.dtype}/P{plan.n_procs}"


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------

def default_timer(fn: Callable[[], object], warmup: int = 1,
                  iters: int = 3) -> float:
    """Median wall seconds of ``fn()`` with block_until_ready."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _synthetic_input(plan: Plan):
    import jax
    import jax.numpy as jnp
    if plan.task == "nystrom":
        n, _ = plan.dims
        shape = (n, n)
    else:
        shape = (plan.dims[0], plan.dims[1])
    # normal data, not zeros: sparse-zero fast paths must not skew timings
    x = jax.random.normal(jax.random.key(0), shape)
    return x.astype(jnp.dtype(plan.dtype))


# ---------------------------------------------------------------------------
# candidate expansion (what a measured pass actually sweeps)
# ---------------------------------------------------------------------------

def _measurable_candidates(plan: Plan, machine: M.MachineModel,
                           top_k: int) -> List[Plan]:
    """Concrete plan variants to time: the top-k executable analytic
    candidates, with a grid sweep for Alg. 1/2 and a block-size sweep for
    the fused Pallas kernels."""
    isz = _itemsize(plan.dtype)
    out: List[Plan] = []

    def add(variant, grid=None, q_grid=None, blocks=None, chunk_rows=None):
        out.append(dataclasses.replace(
            plan, variant=variant, grid=grid, q_grid=q_grid, blocks=blocks,
            chunk_rows=chunk_rows if chunk_rows else plan.chunk_rows,
            executable=True))

    if plan.task == "sketch" and plan.n_procs > 1:
        n1, n2, r = plan.dims
        from repro.core.grid import factorizations_3d
        scored = []
        for g in factorizations_3d(plan.n_procs):
            if _alg1_executable(n1, n2, r, g):
                c = M.alg1_cost(n1, n2, r, g)
                scored.append((c.seconds(machine, isz), g))
        scored.sort(key=lambda t: t[0])
        for _, g in scored[:top_k]:
            add("alg1", grid=g)
        return out

    if plan.task == "stream":
        k0 = plan.chunk_rows or plan.dims[0]
        for k in sorted({max(1, k0 // 2), k0, min(plan.dims[0], k0 * 2)}):
            for cand in plan.candidates:
                if cand.executable:
                    add(cand.variant, grid=cand.grid, chunk_rows=k)
        return out[: max(top_k * 2, 3)]

    # P == 1 sketch/nystrom, or distributed nystrom
    for cand in [c for c in plan.candidates if c.executable][:top_k]:
        if cand.variant == "pallas_fused":
            for blocks in BLOCK_SWEEP:
                fit = 4 * (blocks["bm"] * blocks["bk"]
                           + blocks["bk"] * blocks["bn"]
                           + 2 * blocks["bm"] * blocks["bn"])
                if fit <= machine.vmem_bytes:
                    add(cand.variant, blocks=blocks)
        elif cand.variant == "alg2_bound_driven":
            # sweep stage-2 grids: the analytic q plus the next-cheapest
            # executable q factorizations for the same stage-1 grid
            from repro.core.grid import (alg2_two_grid_executable,
                                         factorizations_3d)
            n, r = plan.dims
            scored_q = []
            for qg in factorizations_3d(plan.n_procs):
                if alg2_two_grid_executable(n, r, cand.grid, qg):
                    c = M.alg2_cost(n, r, cand.grid, qg)
                    scored_q.append((c.seconds(machine, isz), qg))
            scored_q.sort(key=lambda t: t[0])
            for _, qg in scored_q[:top_k]:
                add(cand.variant, grid=cand.grid, q_grid=qg)
        else:
            add(cand.variant, grid=cand.grid, q_grid=cand.q_grid)
    return out


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------

def autotune(plan: Plan, *,
             cache: Optional[object] = None,
             timer: Optional[Callable[[Callable[[], object]], float]] = None,
             top_k: int = 3, seed: int = 0, devices=None,
             machine: Optional[M.MachineModel] = None,
             device_kind: Optional[str] = None) -> Plan:
    """Return ``plan`` refined by measurement.

    cache : an :class:`AutotuneCache`, a path (str) to create one at, or
            ``None`` for no persistence.
    timer : callable mapping a nullary executable closure to seconds
            (default: wall clock, median of 3 after warmup).

    A cache hit skips all measurement and rebuilds the plan from the stored
    decision; a miss measures the candidate sweep, stores the winner, and
    returns it with ``measured_seconds`` set.
    """
    if isinstance(cache, str):
        cache = AutotuneCache(cache)
    timer = timer or default_timer
    machine = machine or M.probe_machine()

    key = cache_key(plan, device_kind)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            restored = _plan_from_entry(plan, hit)
            # the key buckets shapes to powers of two, so a stored decision
            # may not divide THIS plan's exact dims — re-validate, and fall
            # through to measuring when it doesn't.
            if restored is not None:
                return _rescore(restored, machine)

    candidates = _measurable_candidates(plan, machine, top_k)
    if not candidates:
        return plan

    A = _synthetic_input(plan)
    best = None
    for cand in candidates:
        secs = timer(lambda c=cand: c.execute(A, seed=seed, devices=devices))
        if best is None or secs < best[0]:
            best = (secs, cand)
    secs, winner = best
    tuned = _rescore(dataclasses.replace(winner, measured_seconds=secs),
                     machine)

    if cache is not None:
        cache.put(key, _entry_from_plan(tuned))
    return tuned


def _rescore(plan: Plan, machine: M.MachineModel) -> Plan:
    """Recompute the analytic cost fields for the plan's (possibly tuned)
    variant/grid, so the bound audit and ``explain`` describe the variant
    that was actually chosen, not the pre-tune analytic favorite."""
    if plan.task == "sketch":
        n1, n2, r = plan.dims
        if plan.variant == "alg1" and plan.grid:
            c = M.alg1_cost(n1, n2, r, plan.grid)
        elif plan.variant == "pallas_fused":
            c = M.pallas_fused_cost(n1, n2, r)
        else:
            c = M.local_cost(n1, n2, r)
    elif plan.task == "nystrom":
        n, r = plan.dims
        if plan.variant in ("alg2_no_redist", "alg2_redist",
                            "alg2_bound_driven") and plan.grid:
            c = M.alg2_cost(n, r, plan.grid, plan.q_grid or plan.grid)
        else:
            c = M.nystrom_local_cost(n, r,
                                     fused=(plan.variant == "pallas_fused"))
    else:  # stream
        n1, n2, r = plan.dims
        k = plan.chunk_rows or n1
        l = plan.sketch_l if plan.sketch_l is not None \
            else min(2 * r + 1, n1)
        grid = plan.grid if plan.variant == "stream_sharded" else (1, 1, 1)
        per = M.stream_update_cost(k, n2, r, l, grid, plan.corange)
        n_upd = math.ceil(n1 / k)
        c = M.Cost(words=per.words * n_upd, messages=per.messages * n_upd,
                   flops=per.flops * n_upd, hbm_words=per.hbm_words * n_upd)
    return dataclasses.replace(
        plan, predicted_words=c.words, predicted_flops=c.flops,
        predicted_hbm_words=c.hbm_words,
        predicted_seconds=c.seconds(machine, _itemsize(plan.dtype)))


def _entry_from_plan(plan: Plan) -> dict:
    return {"variant": plan.variant,
            "grid": list(plan.grid) if plan.grid else None,
            "q_grid": list(plan.q_grid) if plan.q_grid else None,
            "blocks": dict(plan.blocks) if plan.blocks else None,
            "chunk_rows": plan.chunk_rows,
            "seconds": plan.measured_seconds}


def _plan_from_entry(plan: Plan, entry: dict) -> Optional[Plan]:
    """Rebuild a plan from a cache entry; None if the stored decision does
    not apply to this plan's exact dims (pow2 bucket collision)."""
    grid = tuple(entry["grid"]) if entry.get("grid") else None
    variant = entry["variant"]
    if plan.task in ("sketch", "stream"):
        n1, n2, r = plan.dims
        if variant in ("alg1", "stream_sharded"):
            if grid is None or not _alg1_executable(n1, n2, r, grid):
                return None
    elif plan.task == "nystrom":
        n, r = plan.dims
        if variant == "alg2_bound_driven":
            from repro.core.grid import alg2_two_grid_executable
            qg = tuple(entry["q_grid"]) if entry.get("q_grid") else None
            if grid is None or qg is None \
                    or not alg2_two_grid_executable(n, r, grid, qg):
                return None
        elif variant.startswith("alg2"):
            P = plan.n_procs
            if n % P or r % P or P > n:
                return None
    return dataclasses.replace(
        plan,
        variant=variant,
        grid=grid,
        q_grid=tuple(entry["q_grid"]) if entry.get("q_grid") else None,
        blocks=dict(entry["blocks"]) if entry.get("blocks") else None,
        chunk_rows=entry.get("chunk_rows"),
        measured_seconds=entry.get("seconds"),
        executable=True)
