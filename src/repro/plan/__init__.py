"""repro.plan — cost-model-driven execution planner + measured autotuner.

Turns the paper's closed-form communication model (Theorems 2/3, §4.3/§5.3)
into an executable dispatch layer: ``plan_sketch`` / ``plan_nystrom`` /
``plan_stream`` score every variant the repo can run (Alg. 1 grids, Alg. 2
redist/no_redist, the fused Pallas kernel, streaming ingest) on a
:class:`MachineModel`, audit the winner against the lower bounds, and return
a :class:`Plan` whose ``execute`` dispatches to the existing entry points.
``autotune`` refines the analytic ranking with measured timings persisted in
a versioned on-disk cache; ``explain`` renders the decision.

  model.py    — machine presets + analytic per-variant costs
  planner.py  — candidate enumeration, Plan, dispatch
  autotune.py — measured refinement + JSON result cache
  explain.py  — reports (regimes, crossovers, bound gaps)
"""
from .model import (  # noqa: F401
    Cost, MachineModel, PRESETS, choose_bucket_edges, device_kind_tag,
    grad_allreduce_cost, grad_compress_cost, hbm_roofline_words,
    probe_machine, ragged_bucket_cost,
)
from .planner import (  # noqa: F401
    Candidate, LeafDecision, Plan, TrainCompressionPlan, plan_nystrom,
    plan_sketch, plan_stream, plan_train_compression,
)
from .autotune import (  # noqa: F401
    AutotuneCache, PRESET_ENTRIES, autotune, cache_key,
    calibrate_machine_model, default_timer, load_sweep, save_sweep,
    shape_bucket, sweep_records,
)
from .explain import (  # noqa: F401
    explain, explain_train_compression, nystrom_crossover_P, regime_sweep,
    sketch_zero_comm_limit,
)
