"""Human-readable plan reports: chosen regime, crossovers, bound gaps.

``explain(plan)`` renders one plan; ``regime_sweep`` tabulates the chosen
variant across a range of P (the planner's view of the paper's Fig. 7
crossover).  Reuses :class:`repro.core.lower_bounds.BoundReport` for the
"what would a non-random GEMM pay" comparison.
"""
from __future__ import annotations

import math
from typing import Iterable, List

from repro.core.lower_bounds import (
    BoundReport,
    report_matmul,
    report_nystrom,
)

from . import model as M
from .planner import Plan, TrainCompressionPlan


def sketch_zero_comm_limit(n1: int) -> int:
    """Largest P with a zero-communication sketch plan (Thm. 2 regime 1)."""
    return n1


def nystrom_crossover_P(n: int, r: int) -> int:
    """Smallest P where the redist all-to-all (nr/P words) beats the
    no_redist reduce-scatter ((1-1/P)·r² words): P > n/r + 1."""
    return int(math.floor(n / max(r, 1))) + 2


def _fmt(x: float) -> str:
    if x == 0:
        return "0"
    if abs(x) >= 1e4 or 0 < abs(x) < 1e-3:
        return f"{x:.3e}"
    return f"{x:.4g}"


def bound_report(plan: Plan) -> BoundReport:
    if plan.task == "nystrom":
        n, r = plan.dims
        return report_nystrom(n, r, plan.n_procs)
    n1, n2, r = plan.dims
    return report_matmul(n1, n2, r, plan.n_procs)


def explain(plan: Plan) -> str:
    """Multi-line report for one plan."""
    rep = bound_report(plan)
    thm = "Theorem 3" if plan.task == "nystrom" else "Theorem 2"
    lines: List[str] = []
    lines.append(f"Plan[{plan.task}] dims={plan.dims} P={plan.n_procs} "
                 f"dtype={plan.dtype} kind={plan.kind} "
                 f"machine={plan.machine}")
    lines.append(f"  {thm} regime {plan.regime}: lower bound "
                 f"{_fmt(plan.lower_bound_words)} words/proc "
                 f"(non-random GEMM would need {_fmt(rep.gemm_words)}; "
                 f"savings {_fmt(rep.savings_vs_gemm)}x)")
    grid = f" grid={plan.grid}" if plan.grid else ""
    qg = f" q={plan.q_grid}" if plan.q_grid else ""
    blocks = f" blocks={plan.blocks}" if plan.blocks else ""
    chunk = f" chunk_rows={plan.chunk_rows}" if plan.chunk_rows else ""
    be = (f" backend={plan.backend}"
          if getattr(plan, "backend", "jnp") != "jnp" else "")
    lines.append(f"  chosen: {plan.variant}{grid}{qg}{blocks}{chunk}{be}")
    if getattr(plan, "backend", "jnp") == "pallas":
        lines.append("          fused local body: Omega/Psi blocks "
                     "generated in VMEM, never stored in HBM "
                     "(kernels/local.py)")
    if plan.variant in ("local_sparse", "alg1_sparse", "stream_sparse"):
        lines.append(f"          sparse family ({plan.kind}): O(nnz) "
                     "scatter ingest; payload shipped as COO "
                     "(indices+values) = 2*nnz words, not dense tiles "
                     "(plan.model.sparse_payload_words)")
    lines.append(f"          predicted {_fmt(plan.predicted_words)} words/proc"
                 f" (gap over bound {_fmt(plan.bound_gap_words)}, "
                 f"ratio {_fmt(plan.bound_ratio)})")
    lines.append(f"          {_fmt(plan.predicted_flops)} FLOPs/proc, "
                 f"{_fmt(plan.predicted_hbm_words)} HBM words/proc, "
                 f"est {_fmt(plan.predicted_seconds)} s")
    if plan.measured_seconds is not None:
        lines.append(f"          measured {_fmt(plan.measured_seconds)} s "
                     f"(autotuned)")
    if (plan.task == "nystrom" and plan.grid and plan.q_grid
            and tuple(plan.grid) != tuple(plan.q_grid)):
        n, r = plan.dims
        rw = M.redistribute_words(n, r, plan.grid, plan.q_grid)
        how = ("general two-grid (§5.3 approach 1): stage 1 on p, stage 2 "
               "on q" if plan.variant in ("alg2_bound_driven",
                                          "alg2_bound_driven_fused")
               else "B re-laid out between stages")
        if plan.variant == "alg2_bound_driven_fused":
            fw = M.fused_redistribute_words(n, r, plan.grid, plan.q_grid)
            lines.append(f"          {how}; Redistribute of B p->q (§5.2) "
                         f"IN-PROGRAM on the shared mesh: {_fmt(fw)} "
                         f"words/proc min-cut (cross-mesh device_put "
                         f"would move {_fmt(rw)})")
        else:
            from repro.core.grid import two_grid_axis_split
            line = (f"          {how}; Redistribute of B p->q moves "
                    f"{_fmt(rw)} words/proc (§5.2), cross-mesh device_put")
            if (plan.variant == "alg2_bound_driven"
                    and two_grid_axis_split(plan.grid, plan.q_grid)
                    is not None):
                fw = M.fused_redistribute_words(n, r, plan.grid,
                                                plan.q_grid)
                line += (f" (single-jit fused form would move {_fmt(fw)} "
                         f"in-program)")
            lines.append(line)
    if plan.task in ("sketch", "stream"):
        n1 = plan.dims[0]
        lines.append(f"  zero-communication regime up to P <= n1 = {n1}"
                     f" (regenerate-don't-communicate, paper §4.3 case 1)")
    else:
        n, r = plan.dims
        lines.append(f"  redist/no_redist crossover at P ~ n/r = "
                     f"{nystrom_crossover_P(n, r)} (paper Fig. 7)")
    if not plan.executable:
        lines.append("  NOTE: analytic-only plan — no executable grid "
                     "divides this shape")
    lines.append("  candidates (best first; * = chosen):")
    for c in plan.candidates:
        mark = "*" if (c.variant == plan.variant and c.executable
                       and c.grid == plan.grid
                       and getattr(c, "backend", "jnp")
                       == getattr(plan, "backend", "jnp")) else " "
        where = f" grid={c.grid}" if c.grid else ""
        whereq = f" q={c.q_grid}" if c.q_grid else ""
        be = (f" [{c.backend}]"
              if getattr(c, "backend", "jnp") != "jnp" else "")
        tail = f"  [{c.note}]" if c.note else ""
        exe = "" if c.executable else "  (analytic-only)"
        lines.append(f"   {mark} {c.variant:<20}{where}{whereq}{be}"
                     f"  {_fmt(c.cost.words):>10} words"
                     f"  {_fmt(c.cost.hbm_words):>10} hbm"
                     f"  {_fmt(c.seconds):>10} s{exe}{tail}")
    return "\n".join(lines)


def explain_train_compression(plan: TrainCompressionPlan) -> str:
    """Per-layer word table for a DP gradient-exchange plan.

    One row per parameter leaf: the raw all-reduce words (m·n), the
    sketched-exchange words (r·(m+n)), both machine-model second
    estimates, and the decision the planner took — plus the step totals
    the comm ledger audits at runtime (``train.dp_compressed_step``).
    """
    lines: List[str] = []
    lines.append(f"TrainCompressionPlan rank={plan.rank} P={plan.n_procs} "
                 f"dtype={plan.dtype} backend={plan.backend} "
                 f"machine={plan.machine} objective={plan.objective}")
    lines.append("  Theorem 2 regime 1 applied to the DP all-reduce: Omega "
                 "is regenerated per (leaf, step), so only the factors "
                 "P (m·r) and Q (r·n) move — compress iff r < m·n/(m+n)")
    head = ("leaf", "shape", "r", "raw words", "sketch words",
            "raw s", "sketch s", "decision")
    rows = []
    for d in plan.decisions:
        rows.append((d.name, "x".join(map(str, d.shape)) or "()",
                     str(d.r_eff) if d.r_eff else "-",
                     _fmt(d.raw_cost.words), _fmt(d.comp_cost.words),
                     _fmt(d.raw_seconds), _fmt(d.comp_seconds),
                     ("compress" if d.compress else "raw")
                     + (f"  [{d.note}]" if d.note else "")))
    widths = [max(len(head[i]), *(len(r[i]) for r in rows))
              for i in range(len(head))]
    def fmt_row(r):
        return "  " + " | ".join(v.ljust(w) for v, w in zip(r, widths))
    lines.append(fmt_row(head))
    lines.append("  " + "-+-".join("-" * w for w in widths))
    lines.extend(fmt_row(r) for r in rows)
    lines.append(f"  totals: {_fmt(plan.exchange_words)} words/step/worker "
                 f"vs {_fmt(plan.raw_words)} raw "
                 f"({_fmt(plan.savings)}x saving; "
                 f"{plan.n_compressed}/{len(plan.decisions)} leaves "
                 f"compressed)")
    return "\n".join(lines)


def regime_sweep(plan_fn, dims: tuple, Ps: Iterable[int], **kw) -> str:
    """Table of chosen variant/grid/words vs P (e.g. the Fig.-7 view):

        regime_sweep(plan_sketch, (4096, 4096, 256), [1, 8, 64, 512])
    """
    rows = []
    for P in Ps:
        p = plan_fn(*dims, P=P, **kw)
        rows.append((P, p.regime, p.variant,
                     str(p.grid or "-"), _fmt(p.predicted_words),
                     _fmt(p.lower_bound_words)))
    head = ("P", "regime", "variant", "grid", "pred words", "bound words")
    widths = [max(len(head[i]), *(len(str(r[i])) for r in rows))
              for i in range(len(head))]
    fmt_row = lambda r: " | ".join(str(v).ljust(w) for v, w in zip(r, widths))
    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([fmt_row(head), sep] + [fmt_row(r) for r in rows])
