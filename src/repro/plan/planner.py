"""Cost-model-driven execution planner for sketch / Nyström / stream dispatch.

``plan_sketch`` / ``plan_nystrom`` / ``plan_stream`` enumerate every variant
the repo can actually execute for the given (shape, P, dtype), score each
with the analytic costs in :mod:`repro.plan.model`, compare the winner
against the paper's lower bound (Theorems 2/3), and return a :class:`Plan`
whose ``execute`` dispatches to the existing entry points — bitwise
identical to calling them directly, because it *is* the same call.

Planner invariants (pinned by tests/test_plan.py):

  * predicted words are never below the Theorem 2/3 lower bound;
  * when a shard_map variant wins, its words equal the closed forms
    ``alg1_bandwidth_words`` / ``alg2_bandwidth_words`` exactly;
  * in the Theorem-2 regime 1 (P <= n1) the planner picks the
    zero-communication local-regenerate grid (P, 1, 1);
  * the Alg.-1 grid agrees with ``core.grid.select_matmul_grid`` whenever
    that grid is executable (divisibility), and otherwise falls back to the
    cheapest executable factorization of P;
  * every Nyström candidate — including the §5.3 bound-driven general
    two-grid pair run by ``nystrom_two_grid`` — prices at
    ``alg2_bandwidth_words`` on its own (p, q) grids, so no candidate ever
    scores below the Theorem 3 floor.

The analytic ranking is refined by measured timings in ``plan.autotune``.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, Optional, Sequence, Tuple

from repro.core.grid import (
    MatmulGrid,
    factorizations_3d,
    select_matmul_grid,
    select_nystrom_grids,
    select_two_grid_executable,
)
from repro.core.lower_bounds import (
    matmul_lower_bound,
    matmul_regime,
    nystrom_lower_bound,
    nystrom_regime,
)
from repro.core.kinds import SPARSE_KINDS

from . import model as M

# Default Pallas block sizes (MXU-aligned; kernels/sketch_matmul.py).
DEFAULT_BLOCKS = {"bm": 256, "bn": 128, "bk": 512}


def _dtype_name(dtype) -> str:
    import jax.numpy as jnp
    return jnp.dtype(dtype).name


def _itemsize(dtype_name: str) -> int:
    import numpy as np
    return int(np.dtype(dtype_name).itemsize)


# ---------------------------------------------------------------------------
# Candidates and the Plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Candidate:
    """One scored dispatch option; ``executable=False`` entries are kept in
    the report (e.g. the Omega-communicating baseline, infeasible ideal
    grids) but never chosen.  ``backend`` is the local GEMM body
    (kernels/local.py) the shard_map variants would run with — same
    network words, different HBM roofline."""
    variant: str
    cost: M.Cost
    seconds: float
    grid: Optional[Tuple[int, int, int]] = None
    q_grid: Optional[Tuple[int, int, int]] = None
    blocks: Optional[Tuple[Tuple[str, int], ...]] = None
    executable: bool = True
    note: str = ""
    backend: str = "jnp"


@dataclasses.dataclass(frozen=True)
class Plan:
    """An executable dispatch decision plus everything needed to audit it."""
    task: str                       # "sketch" | "nystrom" | "stream"
    variant: str
    dims: Tuple[int, ...]           # sketch: (n1, n2, r); nystrom: (n, r)
    n_procs: int
    dtype: str
    kind: str                       # Omega entry distribution
    grid: Optional[Tuple[int, int, int]]
    q_grid: Optional[Tuple[int, int, int]]
    blocks: Optional[Dict[str, int]]
    predicted_words: float          # per-processor interconnect words
    predicted_flops: float
    predicted_hbm_words: float
    predicted_seconds: float
    lower_bound_words: float
    regime: int
    candidates: Tuple[Candidate, ...]
    machine: str
    executable: bool = True
    chunk_rows: Optional[int] = None
    corange: bool = False                      # stream plans only
    sketch_l: Optional[int] = None             # stream plans only
    measured_seconds: Optional[float] = None   # set by plan.autotune
    backend: str = "jnp"                       # local GEMM body (kernels/)

    # -- audit helpers ------------------------------------------------------

    @property
    def bound_gap_words(self) -> float:
        """Predicted words above the Theorem 2/3 floor (>= 0 by tightness)."""
        return self.predicted_words - self.lower_bound_words

    @property
    def bound_ratio(self) -> float:
        if self.lower_bound_words == 0.0:
            return 1.0 if self.predicted_words == 0.0 else math.inf
        return self.predicted_words / self.lower_bound_words

    # -- execution ----------------------------------------------------------

    def execute(self, A, seed=0, devices=None):
        """Dispatch to the underlying entry point.

        sketch : returns B = A·Omega (layout per the chosen variant)
        nystrom: returns (B, C)
        stream : builds an accumulator, ingests A in ``chunk_rows`` slabs,
                 and returns the accumulator (call .nystrom()/.reconstruct()
                 on it to finalize)

        Bitwise contract: for every variant this performs exactly the same
        call a user would make against core/kernels/stream directly.
        """
        if not self.executable:
            raise ValueError(
                f"plan {self.variant} for dims={self.dims}, P={self.n_procs} "
                f"is analytic-only (no executable grid divides the shape); "
                f"pad the shape or change P")
        from repro.obs import ledger as obs_ledger
        from repro.obs import trace as obs_trace
        led = obs_ledger.get_ledger()
        t0 = time.perf_counter() if led is not None else 0.0
        with obs_trace.span("plan.execute", cat="plan", task=self.task,
                            variant=self.variant, dims=list(self.dims),
                            P=self.n_procs):
            if self.task == "sketch":
                out = self._execute_sketch(A, seed, devices)
            elif self.task == "nystrom":
                out = self._execute_nystrom(A, seed, devices)
            elif self.task == "stream":
                out = self._execute_stream(A, seed, devices)
            else:
                raise ValueError(self.task)
        if led is not None:
            # analytic site: execute dispatches into opaque entry points
            # (the instrumented layers below contribute the HLO-backed
            # sites); the cache_key ties drift flags back to plan.autotune
            from .autotune import cache_key
            import numpy as np
            led.record(f"plan.execute[{self.task}/{self.variant}]",
                       predicted_words=self.predicted_words,
                       lower_bound_words=self.lower_bound_words,
                       itemsize=np.dtype(self.dtype).itemsize,
                       cache_key=cache_key(self),
                       wall_s=time.perf_counter() - t0,
                       detail=(self.dims, self.n_procs))
        return out

    def _mesh_1d(self, devices):
        import jax
        import numpy as np
        from jax.sharding import Mesh
        devices = devices if devices is not None else jax.devices()
        if len(devices) < self.n_procs:
            raise ValueError(f"plan needs {self.n_procs} devices, "
                             f"have {len(devices)}")
        return Mesh(np.asarray(devices[: self.n_procs]), ("x",))

    def _blocks_tuple(self):
        return (tuple(self.blocks[k] for k in ("bm", "bn", "bk"))
                if self.blocks else None)

    def _execute_sketch(self, A, seed, devices):
        import jax
        n1, n2, r = self.dims
        if self.variant == "alg1":
            from repro.core.sketch import (input_sharding, make_grid_mesh,
                                           rand_matmul)
            mesh = make_grid_mesh(*self.grid, devices=devices)
            A = jax.device_put(A, input_sharding(mesh))
            return rand_matmul(A, seed, r, mesh, kind=self.kind,
                               backend=self.backend,
                               blocks=self._blocks_tuple())
        if self.variant == "local_xla":
            from repro.core.sketch import sketch_reference
            return sketch_reference(A, seed, r, kind=self.kind)
        if self.variant == "local_sparse":
            from repro.core.sketch import sketch_sparse_apply
            return sketch_sparse_apply(A, seed, r, kind=self.kind)
        if self.variant == "pallas_fused":
            from repro.kernels.ops import sketch_matmul
            interpret = jax.default_backend() != "tpu"
            return sketch_matmul(A, seed=seed, r=r, kind=self.kind,
                                 interpret=interpret, **(self.blocks or {}))
        raise ValueError(self.variant)

    def _execute_nystrom(self, A, seed, devices):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        n, r = self.dims
        if self.variant in ("alg2_no_redist", "alg2_redist"):
            from repro.core.nystrom import nystrom_no_redist, nystrom_redist
            mesh = self._mesh_1d(devices)
            A = jax.device_put(A, NamedSharding(mesh, P("x", None)))
            fn = (nystrom_no_redist if self.variant == "alg2_no_redist"
                  else nystrom_redist)
            return fn(A, seed, r, mesh, axis="x", kind=self.kind,
                      backend=self.backend, blocks=self._blocks_tuple())
        if self.variant in ("alg2_bound_driven", "alg2_bound_driven_fused"):
            from repro.core.nystrom import (nystrom_two_grid,
                                            nystrom_two_grid_fused)
            devices = devices if devices is not None else jax.devices()
            if len(devices) < self.n_procs:
                raise ValueError(f"plan needs {self.n_procs} devices, "
                                 f"have {len(devices)}")
            fn = (nystrom_two_grid_fused
                  if self.variant == "alg2_bound_driven_fused"
                  else nystrom_two_grid)
            return fn(A, seed, r, p=self.grid, q=self.q_grid,
                      kind=self.kind,
                      devices=list(devices[: self.n_procs]),
                      backend=self.backend,
                      blocks=self._blocks_tuple())
        if self.variant == "local_xla":
            from repro.core.nystrom import nystrom_reference
            return nystrom_reference(A, seed, r, kind=self.kind)
        if self.variant == "pallas_fused":
            from repro.kernels.ops import nystrom_fused
            interpret = jax.default_backend() != "tpu"
            return nystrom_fused(A, seed=seed, r=r, kind=self.kind,
                                 interpret=interpret, **(self.blocks or {}))
        raise ValueError(self.variant)

    def _execute_stream(self, A, seed, devices):
        from repro.stream.state import StreamConfig
        n1, n2, r = self.dims
        cfg = StreamConfig(n1=n1, n2=n2, r=r, seed=seed, kind=self.kind,
                           corange=self.corange, l=self.sketch_l)
        k = self.chunk_rows or n1
        if self.variant == "stream_sparse":
            from repro.stream.state import SparseRows, StreamingSketch
            st = StreamingSketch(cfg, backend="xla")
            for row0 in range(0, n1, k):
                st.update_rows_sparse(
                    row0, SparseRows.from_dense(A[row0: row0 + k]))
            return st
        if self.variant == "stream_local":
            from repro.stream.state import StreamingSketch
            st = StreamingSketch(cfg, backend="xla")
        elif self.variant == "stream_sharded":
            from repro.core.sketch import make_grid_mesh
            from repro.stream.distributed import ShardedStreamingSketch
            mesh = make_grid_mesh(*self.grid, devices=devices)
            st = ShardedStreamingSketch(cfg, mesh, backend=self.backend,
                                        blocks=self._blocks_tuple())
        else:
            raise ValueError(self.variant)
        for row0 in range(0, n1, k):
            st.update_rows(row0, A[row0: row0 + k])
        return st


# ---------------------------------------------------------------------------
# plan_sketch
# ---------------------------------------------------------------------------

def _alg1_executable(n1: int, n2: int, r: int,
                     grid: Tuple[int, int, int]) -> bool:
    # n1 % (p1*p2): B is laid out P((p1, p2), p3) — the reduce-scatter
    # splits each n1/p1 row block p2 ways.
    p1, p2, p3 = grid
    return (n1 % (p1 * p2) == 0 and n2 % (p2 * p3) == 0 and n2 % p2 == 0
            and r % p3 == 0 and p1 <= n1 and p2 <= n2 and p3 <= r)


def _best_executable_alg1_grid(n1: int, n2: int, r: int, P: int):
    """Paper grid if it divides the shape, else the cheapest factorization
    of P that does (what select_matmul_grid does, restricted further to the
    entry point's divisibility contract)."""
    g: MatmulGrid = select_matmul_grid(n1, n2, r, P)
    if _alg1_executable(n1, n2, r, g.shape):
        return g.shape
    best = None
    for cand in factorizations_3d(P):
        if not _alg1_executable(n1, n2, r, cand):
            continue
        c = M.alg1_cost(n1, n2, r, cand)
        key = (c.words, c.messages)
        if best is None or key < best[0]:
            best = (key, cand)
    return best[1] if best else None


def plan_sketch(n1: int, n2: int, r: int, P: Optional[int] = None,
                dtype="float32", kind: str = "normal",
                machine: Optional[M.MachineModel] = None,
                allow_pallas: Optional[bool] = None,
                nnz: Optional[int] = None) -> Plan:
    """Plan B = A·Omega for an (n1 x n2) A on P processors.

    P defaults to ``len(jax.devices())``.  ``allow_pallas`` overrides the
    machine's capability flag (tests force the fused path on CPU, where it
    runs in interpret mode).

    ``nnz`` declares A stored-sparse with that many nonzeros and adds the
    sparse sketch family to the candidate list (``local_sparse`` —
    O(nnz) scatter ingest, COO (indices+values) payload): a sparse
    ``kind`` is kept, a dense ``kind`` is paired with CountSketch (a
    different sketch family — the chosen plan's ``kind`` reports what
    will actually run, and the candidate note says who lost and why).
    Dense candidates stay in the race at their dense cost: the planner
    picks per regime and density, it does not assume sparse wins.
    """
    if P is None:
        import jax
        P = len(jax.devices())
    machine = machine or M.probe_machine()
    if allow_pallas is None:
        allow_pallas = machine.supports_pallas
    dtype = _dtype_name(dtype)
    isz = _itemsize(dtype)
    lb = matmul_lower_bound(n1, n2, r, P)
    regime = matmul_regime(n1, n2, r, P)

    cands = []
    if P == 1:
        c = M.local_cost(n1, n2, r)
        cands.append(Candidate("local_xla", c, c.seconds(machine, isz)))
        cp = M.pallas_fused_cost(n1, n2, r)
        cands.append(Candidate(
            "pallas_fused", cp, cp.seconds(machine, isz),
            blocks=tuple(sorted(DEFAULT_BLOCKS.items())),
            executable=allow_pallas, backend="pallas",
            note="" if allow_pallas else "needs TPU (interpret-only here)"))
    else:
        grid = _best_executable_alg1_grid(n1, n2, r, P)
        if grid is not None:
            c = M.alg1_cost(n1, n2, r, grid)
            cands.append(Candidate("alg1", c, c.seconds(machine, isz),
                                   grid=grid))
            # same grid, fused local body: identical network words,
            # n2·r/(p2·p3) fewer HBM words per device
            cp = M.alg1_cost(n1, n2, r, grid, backend="pallas")
            cands.append(Candidate(
                "alg1", cp, cp.seconds(machine, isz), grid=grid,
                backend="pallas", executable=allow_pallas,
                note="" if allow_pallas else "needs TPU (interpret-only "
                                             "here)"))
            cc = M.alg1_communicating_cost(n1, n2, r, grid)
            cands.append(Candidate(
                "alg1_communicating", cc, cc.seconds(machine, isz),
                grid=grid, executable=False,
                note="Fig.-3 baseline: Omega over the wire, never chosen"))
        else:
            ideal = select_matmul_grid(n1, n2, r, P).shape
            c = M.alg1_cost(n1, n2, r, ideal)
            cands.append(Candidate(
                "alg1", c, c.seconds(machine, isz), grid=ideal,
                executable=False,
                note=f"no factorization of P={P} divides the shape"))

    if nnz is not None:
        skind = kind if kind in SPARSE_KINDS else "countsketch"
        grid = (1, 1, 1) if P == 1 else (_best_executable_alg1_grid(
            n1, n2, r, P) or select_matmul_grid(n1, n2, r, P).shape)
        cs = M.sparse_sketch_cost(n1, n2, r, nnz, grid, skind)
        cands.append(Candidate(
            "local_sparse" if P == 1 else "alg1_sparse",
            cs, cs.seconds(machine, isz),
            grid=None if P == 1 else grid, executable=(P == 1),
            note="" if P == 1 else "distributed sparse shard_map body "
                                   "deferred (ROADMAP item 3)"))
        cands = _note_sparse_losses(cands, kind, skind, nnz, n1 * n2)

    plan = _finish_plan("sketch", (n1, n2, r), P, dtype, kind, machine,
                        cands, lb, regime)
    if nnz is not None and plan.variant in ("local_sparse", "alg1_sparse"):
        plan = dataclasses.replace(plan, kind=skind)
    return plan


def _note_sparse_losses(cands, kind: str, skind: str, nnz: int,
                        dense_entries: int):
    """Honest notes on the sparse-vs-dense race: whoever loses gets told
    why, in words a report reader can check against the cost model."""
    ex = [c for c in cands if c.executable]
    if not ex:
        return cands
    best = min(ex, key=lambda c: c.seconds)
    density = nnz / max(dense_entries, 1)
    out = []
    for c in cands:
        sparse = c.variant in ("local_sparse", "alg1_sparse",
                               "stream_sparse")
        if sparse and c.executable and c is not best:
            note = (f"dense wins at density {density:.3g} "
                    f"({best.seconds:.3g}s vs {c.seconds:.3g}s)")
            if c.note:
                note = f"{c.note}; {note}"
            c = dataclasses.replace(c, note=note)
        elif sparse and c is best and kind not in SPARSE_KINDS:
            note = (f"substitutes {skind} for requested {kind!r} "
                    f"(different sketch family) at density {density:.3g}")
            if c.note:
                note = f"{c.note}; {note}"
            c = dataclasses.replace(c, note=note)
        out.append(c)
    return out


# ---------------------------------------------------------------------------
# plan_nystrom
# ---------------------------------------------------------------------------

def plan_nystrom(n: int, r: int, P: Optional[int] = None,
                 dtype="float32", kind: str = "normal",
                 machine: Optional[M.MachineModel] = None,
                 allow_pallas: Optional[bool] = None,
                 variant: str = "auto") -> Plan:
    """Plan the Nyström pair (B, C) for a symmetric (n x n) A on P procs.

    The redist / no_redist choice falls out of the cost model — redist's
    nr/P all-to-all beats no_redist's (1-1/P)·r² reduce-scatter exactly
    when P > ~n/r, the paper's Fig.-7 crossover.  The §5.3 bound-driven
    general two-grid algorithm is a third executable candidate
    (``alg2_bound_driven``, run by ``core.nystrom.nystrom_two_grid``); it
    wins whenever its (p, q) pair prices below both 1-D variants — in
    particular when P > n and no 1-D grid is runnable at all.

    When the bound-driven (p, q) pair admits a shared mesh
    (``core.grid.two_grid_shared_mesh``), a fourth executable candidate
    ``alg2_bound_driven_fused`` prices the single-jit program
    (``nystrom_two_grid_fused``): identical stage collectives, but the
    §5.2 Redistribute is an in-program min-cut resharding (<= nr/P words,
    one collective hop) instead of the cross-mesh host ``device_put`` —
    so it outranks the cross-mesh form whenever both can run.

    variant: ``"auto"`` lets the cost model choose; ``"no_redist"`` /
    ``"redist"`` / ``"bound_driven"`` / ``"bound_driven_fused"`` force
    that variant (the others stay in ``candidates`` for the audit trail).
    """
    requires = {"auto": None, "no_redist": "alg2_no_redist",
                "redist": "alg2_redist",
                "bound_driven": "alg2_bound_driven",
                "bound_driven_fused": "alg2_bound_driven_fused"}
    if variant not in requires:
        raise ValueError(f"unknown variant {variant!r}")
    require = requires[variant]
    forced = variant != "auto"
    if P is None:
        import jax
        P = len(jax.devices())
    machine = machine or M.probe_machine()
    if allow_pallas is None:
        allow_pallas = machine.supports_pallas
    dtype = _dtype_name(dtype)
    isz = _itemsize(dtype)
    lb = nystrom_lower_bound(n, r, P)
    regime = nystrom_regime(n, r, P)

    cands = []
    if P == 1:
        if forced:
            raise ValueError(f"variant={variant!r} needs P > 1")
        c = M.nystrom_local_cost(n, r, fused=False)
        cands.append(Candidate("local_xla", c, c.seconds(machine, isz)))
        cp = M.nystrom_local_cost(n, r, fused=True)
        cands.append(Candidate(
            "pallas_fused", cp, cp.seconds(machine, isz),
            blocks=tuple(sorted(DEFAULT_BLOCKS.items())),
            executable=allow_pallas, backend="pallas",
            note="" if allow_pallas else "needs TPU (interpret-only here)"))
    else:
        executable_1d = (n % P == 0 and r % P == 0 and P <= n)
        note = "" if executable_1d else f"needs P | n and P | r (P={P})"
        p = (P, 1, 1)
        for vname, q in (("alg2_no_redist", (P, 1, 1)),
                         ("alg2_redist", (1, 1, P))):
            c = M.alg2_cost(n, r, p, q)
            cands.append(Candidate(vname, c, c.seconds(machine, isz),
                                   grid=p, q_grid=q,
                                   executable=executable_1d, note=note))
            cp = M.alg2_cost(n, r, p, q, backend="pallas")
            pnote = note if not executable_1d else (
                "" if allow_pallas else "needs TPU (interpret-only here)")
            cands.append(Candidate(
                vname, cp, cp.seconds(machine, isz), grid=p, q_grid=q,
                backend="pallas",
                executable=executable_1d and allow_pallas, note=pnote))
        # §5.3 approach 1: the bound-driven general two-grid algorithm,
        # executed by core.nystrom.nystrom_two_grid.  When the ideal grids
        # do not divide (n, r), snap to the min-words executable pair of
        # factorizations (same policy as Alg. 1's grid="auto") and report
        # the gap; when no pair divides at all, keep the analytic row.
        ideal = select_nystrom_grids(n, r, P, variant="bound_driven")
        got = select_two_grid_executable(n, r, P)
        if got is not None:
            p_bd, q_bd, exact = got
            cb = M.alg2_cost(n, r, p_bd, q_bd)
            note = "" if exact else (
                f"snapped from ideal p={tuple(ideal.p)} q={tuple(ideal.q)} "
                f"(+{cb.words - M.alg2_cost(n, r, ideal.p, ideal.q).words:g}"
                f" words over the unrunnable ideal)")
            cands.append(Candidate(
                "alg2_bound_driven", cb, cb.seconds(machine, isz),
                grid=p_bd, q_grid=q_bd, executable=True, note=note))
            cbp = M.alg2_cost(n, r, p_bd, q_bd, backend="pallas")
            cands.append(Candidate(
                "alg2_bound_driven", cbp, cbp.seconds(machine, isz),
                grid=p_bd, q_grid=q_bd, backend="pallas",
                executable=allow_pallas,
                note=note if allow_pallas else
                (note + "; " if note else "") + "needs TPU (interpret-only "
                                               "here)"))
            # single-jit fused two-grid (nystrom_two_grid_fused): same
            # stage collectives, but the §5.2 Redistribute is an
            # in-program min-cut resharding instead of a host-mediated
            # cross-mesh device_put — only emitted when one device order
            # serves both grids (core.grid.two_grid_shared_mesh).
            from repro.core.grid import two_grid_axis_split
            if two_grid_axis_split(p_bd, q_bd) is not None:
                fnote = (note + "; " if note else "") + \
                    "in-program Redistribute (shared mesh)"
                cf = M.alg2_fused_cost(n, r, p_bd, q_bd)
                cands.append(Candidate(
                    "alg2_bound_driven_fused", cf, cf.seconds(machine, isz),
                    grid=p_bd, q_grid=q_bd, executable=True, note=fnote))
                cfp = M.alg2_fused_cost(n, r, p_bd, q_bd, backend="pallas")
                cands.append(Candidate(
                    "alg2_bound_driven_fused", cfp,
                    cfp.seconds(machine, isz), grid=p_bd, q_grid=q_bd,
                    backend="pallas", executable=allow_pallas,
                    note=fnote if allow_pallas else
                    fnote + "; needs TPU (interpret-only here)"))
        else:
            cb = M.alg2_cost(n, r, ideal.p, ideal.q)
            cands.append(Candidate(
                "alg2_bound_driven", cb, cb.seconds(machine, isz),
                grid=tuple(ideal.p), q_grid=tuple(ideal.q), executable=False,
                note=f"no (p, q) factorization pair of P={P} divides "
                     f"(n={n}, r={r})"))

    return _finish_plan("nystrom", (n, r), P, dtype, kind, machine,
                        cands, lb, regime, require=require)


# ---------------------------------------------------------------------------
# plan_stream
# ---------------------------------------------------------------------------

def plan_stream(n1: int, n2: int, r: int, P: Optional[int] = None,
                chunk_rows: Optional[int] = None, l: Optional[int] = None,
                corange: bool = False, dtype="float32",
                kind: str = "normal",
                machine: Optional[M.MachineModel] = None,
                allow_pallas: Optional[bool] = None,
                nnz: Optional[int] = None) -> Plan:
    """Plan a full streaming pass over A in row slabs of ``chunk_rows``.

    Scores the local accumulator against the mesh-sharded one; predicted
    cost is the per-update cost times the number of slabs (one full pass).
    Sharded candidates are priced per backend: the fused pallas body drops
    the per-update Omega HBM stream and halves the Y round trips.

    ``nnz`` declares the WHOLE pass stored-sparse with that many nonzeros
    total and adds the COO ingest candidate (``stream_sparse`` —
    ``update_rows_sparse``, (indices+values) payload per slab, O(nnz)
    scatter fold); same kind-substitution and honest-note contract as
    :func:`plan_sketch`.
    """
    if P is None:
        import jax
        P = len(jax.devices())
    machine = machine or M.probe_machine()
    if allow_pallas is None:
        allow_pallas = machine.supports_pallas
    dtype = _dtype_name(dtype)
    isz = _itemsize(dtype)
    chunk_rows = chunk_rows or max(1, n1 // 8)
    n_upd = math.ceil(n1 / chunk_rows)
    l_eff = l if l is not None else min(2 * r + 1, n1)
    lb = matmul_lower_bound(n1, n2, r, P)
    regime = matmul_regime(n1, n2, r, P)

    def scaled(c: M.Cost) -> M.Cost:
        return M.Cost(words=c.words * n_upd, messages=c.messages * n_upd,
                      flops=c.flops * n_upd, hbm_words=c.hbm_words * n_upd)

    cands = []
    c_loc = scaled(M.stream_update_cost(chunk_rows, n2, r, l_eff,
                                        (1, 1, 1), corange))
    cands.append(Candidate("stream_local", c_loc, c_loc.seconds(machine, isz),
                           executable=(P == 1),
                           note="" if P == 1 else "single-device only"))
    if P > 1:
        grid = _best_executable_alg1_grid(n1, n2, r, P)
        if grid is not None:
            c = scaled(M.stream_update_cost(chunk_rows, n2, r, l_eff,
                                            grid, corange))
            cands.append(Candidate("stream_sharded", c,
                                   c.seconds(machine, isz), grid=grid))
            cp = scaled(M.stream_update_cost(chunk_rows, n2, r, l_eff,
                                             grid, corange,
                                             backend="pallas"))
            cands.append(Candidate(
                "stream_sharded", cp, cp.seconds(machine, isz), grid=grid,
                backend="pallas", executable=allow_pallas,
                note="" if allow_pallas else "needs TPU (interpret-only "
                                             "here)"))

    if nnz is not None:
        skind = kind if kind in SPARSE_KINDS else "countsketch"
        nnz_u = nnz / n_upd                      # per-slab payload
        cs = scaled(M.sparse_stream_update_cost(chunk_rows, n2, r, l_eff,
                                                nnz_u, (1, 1, 1), corange,
                                                skind))
        cands.append(Candidate(
            "stream_sparse", cs, cs.seconds(machine, isz),
            executable=(P == 1),
            note="" if P == 1 else "single-device only (distributed "
                                   "sparse bodies: ROADMAP item 3)"))
        cands = _note_sparse_losses(cands, kind, skind, nnz, n1 * n2)

    plan = _finish_plan("stream", (n1, n2, r), P, dtype, kind, machine,
                        cands, lb, regime)
    if nnz is not None and plan.variant == "stream_sparse":
        plan = dataclasses.replace(plan, kind=skind)
    return dataclasses.replace(plan, chunk_rows=chunk_rows, corange=corange,
                               sketch_l=l)


# ---------------------------------------------------------------------------
# plan_train_compression — per-leaf raw-vs-sketched gradient exchange
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LeafDecision:
    """One parameter leaf's priced exchange choice.

    ``m``/``n`` are the leaf folded to a matrix (leading dims merged, the
    same folding ``parallel.grad_compress`` applies); ``r_eff`` is the
    rank clamped to ``min(rank, m, n)``.  Non-matrix leaves (ndim < 2)
    always go raw — there is nothing to sketch.
    """
    name: str
    shape: Tuple[int, ...]
    m: int
    n: int
    r_eff: int
    compress: bool
    raw_cost: M.Cost
    comp_cost: M.Cost
    raw_seconds: float
    comp_seconds: float
    note: str = ""

    @property
    def words(self) -> float:
        """Predicted exchange words for the decision actually taken."""
        return self.comp_cost.words if self.compress else self.raw_cost.words


@dataclasses.dataclass(frozen=True)
class TrainCompressionPlan:
    """Per-leaf decision map for the DP gradient exchange
    (``train.step.make_dp_compressed_step`` consumes it; ``explain.
    explain_train_compression`` renders the word table).

    ``exchange_words`` is the per-step, per-worker prediction the comm
    ledger audits (``train.dp_compressed_step`` site): compressed leaves
    contribute ``r·(m+n)``, raw leaves ``m·n``.  It is also the plan's
    ``lower_bound_words`` — the factor-exchange floor: Omega is
    regenerated (Theorem 2 regime 1, zero words), but the data-dependent
    factors P and Q must move, so a schedule that meets the prediction is
    AT the floor, not above it.
    """
    rank: int
    n_procs: int
    dtype: str
    kind: str
    machine: str
    backend: str
    objective: str
    decisions: Tuple[LeafDecision, ...]
    treedef: object

    def decision_tree(self):
        """Pytree of per-leaf bools matching the params structure."""
        import jax
        return jax.tree_util.tree_unflatten(
            self.treedef, [d.compress for d in self.decisions])

    @property
    def exchange_words(self) -> float:
        return sum(d.words for d in self.decisions)

    @property
    def raw_words(self) -> float:
        return sum(d.raw_cost.words for d in self.decisions)

    @property
    def lower_bound_words(self) -> float:
        return self.exchange_words

    @property
    def savings(self) -> float:
        """Raw-over-compressed word ratio for the whole step (>= 1 when
        any leaf compresses; exactly 1 when none do)."""
        ex = self.exchange_words
        return self.raw_words / ex if ex > 0 else 1.0

    @property
    def n_compressed(self) -> int:
        return sum(1 for d in self.decisions if d.compress)


def _leaf_name(path) -> str:
    parts = []
    for p in path:        # DictKey(.key) / SequenceKey(.idx) / GetAttrKey
        for attr in ("key", "idx", "name"):
            v = getattr(p, attr, None)
            if v is not None:
                parts.append(str(v))
                break
        else:
            parts.append(str(p))
    return ".".join(parts) or "<root>"


def plan_train_compression(params_shapes, rank: int, P: Optional[int] = None,
                           *, dtype="float32", kind: str = "normal",
                           machine: Optional[M.MachineModel] = None,
                           backend: Optional[str] = None,
                           objective: str = "words") -> TrainCompressionPlan:
    """Decide, per parameter leaf, raw all-reduce vs sketched exchange.

    ``params_shapes`` is any pytree of shaped leaves (concrete params or
    ``jax.eval_shape`` output).  For each matrix leaf the planner prices
    ``grad_allreduce_cost`` (m·n words) against ``grad_compress_cost``
    (r·(m+n) words + the rank-r GEMM/QR work) on the measured machine
    model and keeps whichever wins under ``objective``:

      * ``"words"``  (default) — compress iff the predicted exchange
        words strictly drop: ``r_eff·(m+n) < m·n``, i.e. the Theorem-2
        crossover ``r_eff < m·n/(m+n)``.  This is the paper's objective
        (communication is the scarce resource the bounds govern) and the
        contract the decision property test pins.
      * ``"seconds"`` — compress iff predicted seconds drop on
        ``machine`` (the added rank-r FLOPs can outweigh the network
        saving on compute-bound hosts; both estimates are kept on every
        row so ``explain_train_compression`` shows the disagreement).

    ``backend`` prices the local bodies (None: pallas where the machine
    supports it, else jnp).  Dispatch overhead is a per-step constant —
    the whole exchange lives inside ONE jitted step either way — so it
    cancels between the candidates and only the per-leaf resource terms
    decide.
    """
    if objective not in ("words", "seconds"):
        raise ValueError(f"unknown objective {objective!r} "
                         f"(want words|seconds)")
    if P is None:
        import jax
        P = len(jax.devices())
    import jax
    machine = machine or M.probe_machine()
    if backend is None:
        backend = "pallas" if machine.supports_pallas else "jnp"
    dtype = _dtype_name(dtype)
    isz = _itemsize(dtype)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)

    decisions = []
    for path, leaf in flat:
        shape = tuple(leaf.shape)
        if len(shape) < 2:
            m = 1 if not shape else int(shape[0])
            n = 1
            raw = M.grad_allreduce_cost(m, n, P)
            decisions.append(LeafDecision(
                name=_leaf_name(path), shape=shape, m=m, n=n, r_eff=0,
                compress=False, raw_cost=raw, comp_cost=raw,
                raw_seconds=raw.seconds(machine, isz),
                comp_seconds=raw.seconds(machine, isz),
                note="not a matrix"))
            continue
        m = math.prod(shape[:-1])
        n = int(shape[-1])
        r_eff = min(rank, m, n)
        raw = M.grad_allreduce_cost(m, n, P)
        comp = M.grad_compress_cost(m, n, r_eff, P, backend=backend)
        raw_s = raw.seconds(machine, isz)
        comp_s = comp.seconds(machine, isz)
        if objective == "words":
            compress = comp.words < raw.words
        else:
            compress = comp_s < raw_s
        note = ""
        if not compress:
            note = ("below crossover r >= m*n/(m+n)" if objective == "words"
                    else "network saving < added rank-r compute")
        elif objective == "words" and comp_s > raw_s:
            note = "words win; seconds would not on this machine"
        decisions.append(LeafDecision(
            name=_leaf_name(path), shape=shape, m=m, n=n, r_eff=r_eff,
            compress=compress, raw_cost=raw, comp_cost=comp,
            raw_seconds=raw_s, comp_seconds=comp_s, note=note))

    return TrainCompressionPlan(
        rank=rank, n_procs=P, dtype=dtype, kind=kind, machine=machine.name,
        backend=backend, objective=objective,
        decisions=tuple(decisions), treedef=treedef)


# ---------------------------------------------------------------------------
# shared tail
# ---------------------------------------------------------------------------

def _finish_plan(task: str, dims: Tuple[int, ...], P: int, dtype: str,
                 kind: str, machine: M.MachineModel,
                 cands: Sequence[Candidate], lb: float, regime: int,
                 require: Optional[str] = None) -> Plan:
    cands = tuple(sorted(
        cands, key=lambda c: (not c.executable, c.seconds,
                              c.cost.hbm_words, c.cost.words)))
    eligible = [c for c in cands
                if require is None or c.variant == require]
    chosen = next((c for c in eligible if c.executable), None)
    if chosen is None:
        # analytic-only plan; execute() raises
        chosen = eligible[0] if eligible else cands[0]
    return Plan(
        task=task, variant=chosen.variant, dims=tuple(dims), n_procs=P,
        dtype=dtype, kind=kind, grid=chosen.grid, q_grid=chosen.q_grid,
        blocks=dict(chosen.blocks) if chosen.blocks else None,
        predicted_words=chosen.cost.words,
        predicted_flops=chosen.cost.flops,
        predicted_hbm_words=chosen.cost.hbm_words,
        predicted_seconds=chosen.seconds,
        lower_bound_words=lb, regime=regime, candidates=cands,
        machine=machine.name,
        executable=chosen.executable,
        backend=chosen.backend)
