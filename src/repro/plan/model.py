"""Machine model + analytic costs for every executable sketch/Nyström variant.

The paper's cost model (§3) counts *words moved per processor* in the
alpha-beta model; the repo's entry points add two more resources a real
dispatcher must price: local FLOPs and HBM traffic (the fused Pallas kernel
trades HBM words for in-VMEM regeneration the same way Alg. 1 trades network
words for it).  This module turns all of that into one comparable unit —
predicted seconds on a :class:`MachineModel` — while keeping the raw words /
flops / bytes visible so tests can assert the paper's closed forms exactly.

Per-variant analytic costs:

  * ``alg1_cost``        — Alg. 1 on a (p1, p2, p3) grid: words are exactly
                           ``core.grid.alg1_bandwidth_words``.
  * ``alg2_cost``        — Alg. 2 on (p, q) grids: words are exactly
                           ``core.grid.alg2_bandwidth_words``.
  * ``alg2_fused_cost``  — the single-jit two-grid form
                           (``nystrom_two_grid_fused``): same stage terms,
                           but the cross-mesh nr/P Redistribute becomes the
                           in-program layout min-cut
                           (``fused_redistribute_words``).
  * ``local_cost``       — single-device GEMM with Omega materialized in HBM.
  * ``pallas_fused_cost``— the fused kernel: Omega never touches HBM, so the
                           memory term drops by n2·r words (the §6.3 claim
                           applied to the memory hierarchy).
  * ``stream_update_cost``— one row-slab ingest step of the streaming
                           subsystem (local or sharded).

``alg1_cost`` / ``alg2_cost`` / ``stream_update_cost`` take a ``backend``
("jnp" | "pallas") pricing the *local* GEMM body: the pallas backend
(kernels/local.py) generates Omega/Psi blocks in VMEM, zeroing their HBM
streams and halving the accumulate round trips — identical network words,
strictly fewer HBM words, which is how ``plan_*`` picks the backend
analytically (``hbm_roofline_words`` is the single-GEMM table).

Machine presets are deliberately coarse (vendor peaks); the measured
autotuner (``plan.autotune``) exists precisely because these numbers are
only good enough to *rank* candidates, not to predict wall time.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

from repro.core.grid import (
    alg1_bandwidth_words,
    alg1_latency_hops,
    alg2_bandwidth_words,
)
from repro.roofline.analysis import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16


# ---------------------------------------------------------------------------
# Machine model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Alpha-beta-gamma machine: network latency/bandwidth + compute/memory.

    alpha      : per-message latency (seconds)
    byte_bw    : interconnect bandwidth per device (bytes/s) — 1/beta
    flop_rate  : peak FLOP/s per device
    hbm_bw     : HBM bandwidth per device (bytes/s)
    vmem_bytes : per-core fast scratch (VMEM) capacity
    hbm_bytes  : per-device main memory capacity
    supports_pallas : whether the fused Mosaic/Pallas kernels can run
                      natively (TPU); elsewhere they only run in interpret
                      mode, which is a correctness tool, not a fast path.
    dispatch_overhead : host-side cost of launching ONE compiled update
                      (python + runtime + launch latency, seconds).  This
                      is the term shape-bucketed ragged ingest amortizes:
                      N streams fused into one bucket pay it once instead
                      of N times, at the price of padded-lane FLOPs/HBM —
                      :func:`choose_bucket_edges` trades the two.
    """
    name: str
    alpha: float
    byte_bw: float
    flop_rate: float
    hbm_bw: float
    vmem_bytes: int
    hbm_bytes: int
    supports_pallas: bool = False
    dispatch_overhead: float = 5e-5


# Per-chip vendor peaks; the v5e numbers are the roofline module's
# constants, so the planner and the measured roofline agree by construction.
PRESETS = {
    "tpu_v5e": MachineModel(
        name="tpu_v5e", alpha=1e-6, byte_bw=ICI_LINK_BW,
        flop_rate=PEAK_FLOPS_BF16, hbm_bw=HBM_BW,
        vmem_bytes=128 * 2 ** 20, hbm_bytes=16 * 2 ** 30,
        supports_pallas=True),
    "tpu_v4": MachineModel(
        name="tpu_v4", alpha=1e-6, byte_bw=100e9, flop_rate=275e12,
        hbm_bw=1200e9, vmem_bytes=128 * 2 ** 20, hbm_bytes=32 * 2 ** 30,
        supports_pallas=True),
    # Host CPU (also XLA's fake multi-device backend): "network" is shared
    # memory, flops a few-core GEMM rate.  Order-of-magnitude is all the
    # planner needs — candidates are re-ranked by the autotuner anyway.
    "cpu": MachineModel(
        name="cpu", alpha=5e-6, byte_bw=10e9, flop_rate=5e10,
        hbm_bw=20e9, vmem_bytes=32 * 2 ** 20, hbm_bytes=8 * 2 ** 30,
        supports_pallas=False,
        # python + XLA-CPU launch per compiled call (measured order of
        # magnitude); dominates tiny ragged lanes, so the bucket planner
        # fuses aggressively on hosts
        dispatch_overhead=3e-4),
}


def probe_machine(device=None) -> MachineModel:
    """Best-effort preset from ``jax.devices()[0]`` (overridable everywhere).

    Never raises: unknown accelerators fall back to the v5e preset, unknown
    hosts to the cpu preset, and an uninitialized backend to cpu.
    """
    if device is None:
        try:
            import jax
            device = jax.devices()[0]
        except Exception:
            return PRESETS["cpu"]
    platform = getattr(device, "platform", "cpu")
    kind = (getattr(device, "device_kind", "") or "").lower()
    if platform == "tpu":
        if "v4" in kind:
            return PRESETS["tpu_v4"]
        return PRESETS["tpu_v5e"]
    if platform == "cpu":
        return PRESETS["cpu"]
    # gpu / unknown accelerator: v5e-class roofline is the closest preset
    return dataclasses.replace(PRESETS["tpu_v5e"], name=platform,
                               supports_pallas=False)


def device_kind_tag(device=None) -> str:
    """Stable string identifying the device kind (autotune cache key)."""
    if device is None:
        try:
            import jax
            device = jax.devices()[0]
        except Exception:
            return "unknown"
    kind = getattr(device, "device_kind", "") or getattr(device, "platform",
                                                         "unknown")
    return str(kind).replace(" ", "_")


# ---------------------------------------------------------------------------
# Cost breakdown
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Cost:
    """Per-processor resource counts for one variant (paper units: words)."""
    words: float          # interconnect words moved (the paper's W)
    messages: float       # latency hops on the critical path
    flops: float          # local FLOPs
    hbm_words: float      # local HBM words touched (reads + writes)

    def seconds(self, machine: MachineModel, itemsize: int = 4) -> float:
        """Execution estimate: local work overlaps compute with memory
        (max of terms), but the shard_map programs serialize collectives
        with the local GEMM, so network time and latency are added — which
        also keeps variants with identical FLOPs (e.g. redist/no_redist)
        ranked by their word counts rather than by latency noise."""
        t_net = self.words * itemsize / machine.byte_bw
        t_flop = self.flops / machine.flop_rate
        t_mem = self.hbm_words * itemsize / machine.hbm_bw
        return max(t_flop, t_mem) + t_net + self.messages * machine.alpha

    def bottleneck(self, machine: MachineModel, itemsize: int = 4) -> str:
        terms = {
            "network": self.words * itemsize / machine.byte_bw,
            "compute": self.flops / machine.flop_rate,
            "memory": self.hbm_words * itemsize / machine.hbm_bw,
        }
        return max(terms, key=terms.get)


# ---------------------------------------------------------------------------
# Variant costs — sketch  B = A·Omega  (n1 x n2  @  n2 x r)
# ---------------------------------------------------------------------------

def alg1_cost(n1: int, n2: int, r: int,
              grid: Tuple[int, int, int],
              backend: str = "jnp") -> Cost:
    """Alg. 1 on (p1, p2, p3): words is the paper's closed form exactly.

    ``backend`` prices the *local* GEMM body (kernels/local.py): the jnp
    backend materializes the per-shard Omega block in HBM
    (n2·r/(p2·p3) words); the pallas backend generates it in VMEM, so
    that term vanishes — the HBM-roofline analogue of the paper's
    zero-communication claim.  Network words are identical by construction.
    """
    p1, p2, p3 = grid
    P = p1 * p2 * p3
    words = alg1_bandwidth_words(n1, n2, r, p1, p2, p3)
    # per device: read the gathered A panel + regenerated Omega block
    # (write+read through VMEM; zero for the fused backend), write the
    # B shard.
    omega_hbm = 0.0 if backend == "pallas" else n2 * r / (p2 * p3)
    hbm = (n1 * n2 / (p1 * p2) + omega_hbm + n1 * r / P)
    return Cost(words=words, messages=alg1_latency_hops(p2, p3),
                flops=2.0 * n1 * n2 * r / P, hbm_words=hbm)


def alg1_communicating_cost(n1: int, n2: int, r: int,
                            grid: Tuple[int, int, int]) -> Cost:
    """The Fig.-3 anti-pattern: Omega all-gathered instead of regenerated.
    Never chosen; kept in candidate lists so reports can show the margin."""
    base = alg1_cost(n1, n2, r, grid)
    P = grid[0] * grid[1] * grid[2]
    omega_words = (1.0 - 1.0 / P) * n2 * r  # receive the rest of Omega
    return dataclasses.replace(
        base, words=base.words + omega_words,
        messages=base.messages + math.log2(max(P, 1)))


def local_cost(n1: int, n2: int, r: int) -> Cost:
    """Single-device GEMM with Omega materialized in HBM."""
    return Cost(words=0.0, messages=0.0, flops=2.0 * n1 * n2 * r,
                hbm_words=float(n1 * n2 + n2 * r + n1 * r))


def hbm_roofline_words(m: int, k: int, n: int, backend: str,
                       accumulate: bool = False) -> float:
    """Local HBM words of one (m×k)·(k×n) sketch GEMM per backend.

    The words-moved table behind the backend dispatch (see
    docs/COMMUNICATION_MODEL.md "HBM roofline"): jnp streams the operand,
    the materialized Omega block, and the output; pallas generates Omega in
    VMEM so the k·n term vanishes.  ``accumulate=True`` prices ``out += ``
    consumers (the streaming updates): jnp's separate delta + add costs
    4·m·n words (delta write/read + out read/write), the fused kernel's
    aliased accumulator 2·m·n (out read at k==0, write at the flush).
    """
    omega = 0.0 if backend == "pallas" else float(k * n)
    out = (2.0 if backend == "pallas" else 4.0) * m * n if accumulate \
        else float(m * n)
    return m * k + omega + out


def pallas_fused_cost(n1: int, n2: int, r: int) -> Cost:
    """Fused kernel: the n2·r Omega stream never touches HBM (§6.3 applied
    to the memory hierarchy — see kernels/sketch_matmul.py)."""
    return Cost(words=0.0, messages=0.0, flops=2.0 * n1 * n2 * r,
                hbm_words=float(n1 * n2 + n1 * r))


# ---------------------------------------------------------------------------
# Variant costs — Nyström  (B = A·Omega ; C = Omega^T·B)
# ---------------------------------------------------------------------------

def redistribute_words(n: int, r: int, p: Tuple[int, int, int],
                       q: Tuple[int, int, int]) -> float:
    """Per-processor words of the §5.2 ``Redistribute`` of B between the
    stage-1 and stage-2 grids: zero when q == p (B is already in place),
    else the all-to-all re-layout bound nr/P — every processor holds nr/P
    words of B and in the worst case all of them change owner.  This is
    exactly the ``p != q`` term inside ``alg2_bandwidth_words``, broken out
    so plans and reports can show the redistribution separately."""
    if tuple(p) == tuple(q):
        return 0.0
    P = p[0] * p[1] * p[2]
    return n * r / P


def fused_redistribute_words(n: int, r: int, p: Tuple[int, int, int],
                             q: Tuple[int, int, int]) -> float:
    """Per-processor words of the §5.2 ``Redistribute`` when it is expressed
    IN-PROGRAM (``nystrom_two_grid_fused``): the min-cut between B's
    stage-1 layout P((p1, p2), p3) and its stage-2 layout P(q1, (q3, q2))
    over the shared device order.  Each device keeps the overlap between
    its two shards and only receives the rest, so this is at most the
    cross-mesh bound nr/P (``redistribute_words``) and strictly below it
    whenever any device's shards intersect — e.g. the regime-1 pair
    p=(P,1,1), q=(1,1,P) moves nr/P - nr/P^2 words.  Computed exactly as
    the max over devices of (q-shard words) - (overlap words)."""
    p1, p2, p3 = p
    q1, q2, q3 = q
    P = p1 * p2 * p3
    pr, pc = n / (p1 * p2), r / p3            # p-layout shard extents
    qr, qc = n / q1, r / (q2 * q3)            # q-layout shard extents
    worst = 0.0
    for d in range(P):
        rb, cb = divmod(d, p3)                # p-coords of device d
        iq, rem = divmod(d, q2 * q3)          # q-coords of device d
        jq, kq = divmod(rem, q3)
        col_blk = kq * q2 + jq                # cols sharded (q3, q2)-major
        ov_r = max(0.0, min(rb * pr + pr, iq * qr + qr)
                   - max(rb * pr, iq * qr))
        ov_c = max(0.0, min(cb * pc + pc, col_blk * qc + qc)
                   - max(cb * pc, col_blk * qc))
        worst = max(worst, qr * qc - ov_r * ov_c)
    return worst


def alg2_fused_cost(n: int, r: int, p: Tuple[int, int, int],
                    q: Tuple[int, int, int], backend: str = "jnp") -> Cost:
    """Alg. 2 compiled as ONE program (``nystrom_two_grid_fused``): same
    stage collectives as :func:`alg2_cost`, but the cross-mesh nr/P
    Redistribute term is replaced by the in-program min-cut resharding
    (:func:`fused_redistribute_words`) and its log2(P) host-mediated hops
    by one in-program collective.  Words never drop below the Theorem 3
    floor — the stage All-Gather / Reduce-Scatter terms are untouched and
    the min-cut is the traffic a REAL schedule moves (pinned by
    tests/test_two_grid_fused.py across swept (n, r, P))."""
    _, p2, p3 = p
    base = alg2_cost(n, r, p, q, backend=backend)
    cross = redistribute_words(n, r, p, q)
    fused = fused_redistribute_words(n, r, p, q)
    msgs = alg1_latency_hops(p2, p3) + math.log2(max(p[0], 1))
    if fused > 0.0:
        msgs += 1.0                   # one in-program resharding collective
    return dataclasses.replace(base, words=base.words - cross + fused,
                               messages=msgs)


def alg2_cost(n: int, r: int, p: Tuple[int, int, int],
              q: Tuple[int, int, int], backend: str = "jnp") -> Cost:
    """Alg. 2 on grids (p, q): words is ``alg2_bandwidth_words`` exactly
    (which already includes ``redistribute_words`` when p != q), so a
    shard_map winner's predicted words stay equal to the paper's closed
    form and never fall below the Theorem 3 bound.

    ``backend`` prices the local bodies of both stages: pallas drops the
    Omega regeneration HBM streams (stage 1's A·Omega block and stage 2's
    Omega^T·B block) entirely — they live only in VMEM.
    """
    p1, p2, p3 = p
    P = p1 * p2 * p3
    words = alg2_bandwidth_words(n, r, p, q)
    omega_hbm = 0.0 if backend == "pallas" else 2.0 * n * r / P
    hbm = (n * n / (p1 * p2)          # A panel
           + omega_hbm                # Omega regen (stage 1 + stage 2)
           + 2.0 * n * r / P          # B write + B re-read
           + r * r / P)               # C shard
    msgs = alg1_latency_hops(p2, p3) + math.log2(max(p1, 1))
    if tuple(p) != tuple(q):
        msgs += math.log2(max(P, 1))  # the all-to-all redistribution
    return Cost(words=words, messages=msgs,
                flops=(2.0 * n * n * r + 2.0 * n * r * r) / P, hbm_words=hbm)


def nystrom_local_cost(n: int, r: int, fused: bool = False) -> Cost:
    """Single-device Nyström pair; ``fused`` drops both Omega HBM streams."""
    omega_words = 0.0 if fused else 2.0 * n * r
    return Cost(words=0.0, messages=0.0,
                flops=2.0 * n * n * r + 2.0 * n * r * r,
                hbm_words=float(n * n + omega_words + 2 * n * r + r * r))


# ---------------------------------------------------------------------------
# Variant costs — streaming ingest (one row-slab update of k rows)
# ---------------------------------------------------------------------------

def stream_update_cost(k: int, n2: int, r: int, l: int,
                       grid: Tuple[int, int, int] = (1, 1, 1),
                       corange: bool = True,
                       backend: str = "jnp") -> Cost:
    """One ``update_rows`` step folding a (k, n2) slab.

    Local grid (1,1,1): zero network words.  Sharded: the slab (replicated
    over p1, column-sharded over (p2, p3)) pays one All-Gather over p3 and
    one All-Reduce of the dY partial over p2, plus nothing for W (replicated
    over p1, update fully local) — see stream/distributed.py:update_rows.

    HBM accounting per backend, priced for the row-slab ingest this plan
    actually executes (``update_rows``): the jnp body materializes the
    Omega block (n2·r/(p2·p3) words) and, when the co-range sketch is on,
    the Psi slab (k·l words) plus a W read-modify-write through a
    materialized delta (4·l·n2/(p2·p3) accumulate words).  The pallas
    body generates Omega/Psi in VMEM and fuses ``W += Psi·H`` into the
    kernel accumulator (``sketch_t_block(acc=w)``): zero Omega/Psi words
    and one W round trip (2·l·n2/(p2·p3)).  The traced-offset Y fold is
    backend-dispatched too (``kernels.local.fold_rows_block``): the jnp
    body round-trips dY plus the zero-padded frame (4·k·r/p3 accumulate
    words), the pallas body keeps the padded frame in VMEM and aliases
    the Y shard in-place (2·k·r/p3).
    """
    p1, p2, p3 = grid
    words = 0.0
    msgs = 0.0
    if p3 > 1:
        words += (1.0 - 1.0 / p3) * k * n2 / p2
        msgs += math.log2(p3)
    if p2 > 1:
        words += 2.0 * (1.0 - 1.0 / p2) * k * r / p3   # all-reduce of dY
        msgs += 2.0 * math.log2(p2)
    flops = 2.0 * k * n2 * r / (p2 * p3)
    fused = backend == "pallas"
    omega_hbm = 0.0 if fused else n2 * r / (p2 * p3)
    acc_hbm = (2.0 if fused else 4.0) * k * r / p3     # fused Y fold
    hbm = k * n2 / (p2 * p3) + omega_hbm + acc_hbm
    if corange:
        flops += 2.0 * k * n2 * l / (p2 * p3)
        psi_hbm = 0.0 if fused else k * l
        hbm += psi_hbm + (2.0 if fused else 4.0) * l * n2 / (p2 * p3)
    return Cost(words=words, messages=msgs, flops=flops, hbm_words=hbm)


#: Flop-rate penalty of scalar scatter-adds relative to the dense GEMM's
#: vectorized FMAs (no tensor cores, gather/scatter addressing, bank
#: conflicts).  One knob, deliberately pessimistic: the planner should
#: pick sparse only when the O(nnz) arithmetic saving is decisive, not on
#: a coin flip the hardware would lose.
SPARSE_SCATTER_PENALTY = 8.0


def sparse_payload_words(nnz: int) -> float:
    """Wire/storage words of a COO payload: one index + one value per
    stored entry — what a sparse row slab costs to ship instead of its
    dense (k, n2) frame (see docs/COMMUNICATION_MODEL.md)."""
    return 2.0 * float(nnz)


def _sparse_participation(n2: int, r: int, kind: str) -> float:
    """Fraction of input columns a sparse Omega actually touches:
    CountSketch hits every row of Omega; coordinated row sampling keeps a
    row with probability r/n2 (seed-coordinated, so every party agrees on
    the subset without communicating it)."""
    return min(1.0, r / max(n2, 1)) if kind == "rowsample" else 1.0


def sparse_sketch_cost(n1: int, n2: int, r: int, nnz: float,
                       grid: Tuple[int, int, int] = (1, 1, 1),
                       kind: str = "countsketch") -> Cost:
    """B = A·Omega with a SPARSE Omega family (CountSketch / coordinated
    row sampling) on a stored-sparse A with ``nnz`` nonzeros.

    Arithmetic is O(nnz): each stored entry contributes one scatter-add
    into its bucket column (times ``SPARSE_SCATTER_PENALTY`` against the
    dense GEMM's vectorized flop rate).  Communication replaces the dense
    A-panel All-Gather of Alg. 1 with a COO panel — (indices + values) =
    ``2·nnz_eff/(p1·p2)`` words over the p3 axis, where ``nnz_eff`` drops
    to ``nnz·r/n2`` for rowsample because senders filter by the
    seed-coordinated membership before shipping.  The Reduce-Scatter of
    the B partial over p2 is the dense Alg.-1 term unchanged: B is dense
    whatever Omega was.
    """
    p1, p2, p3 = grid
    P = p1 * p2 * p3
    nnz_eff = float(nnz) * _sparse_participation(n2, r, kind)
    words = 0.0
    msgs = 0.0
    if p3 > 1:
        words += (1.0 - 1.0 / p3) * sparse_payload_words(nnz_eff) / (p1 * p2)
        msgs += math.log2(p3)
    if p2 > 1:
        words += (1.0 - 1.0 / p2) * n1 * r / (p1 * p3)
        msgs += math.log2(p2)
    flops = 2.0 * nnz_eff * SPARSE_SCATTER_PENALTY / P
    # read the COO panel; one accumulator read-modify-write per scatter
    # (random buckets — no cache reuse, unlike the GEMM's streaming
    # access); write the (dense) B shard.  The sparse Omega itself is
    # generated from counters — never materialized, zero HBM words.
    hbm = (sparse_payload_words(nnz_eff) + 2.0 * nnz_eff + n1 * r) / P
    return Cost(words=words, messages=msgs, flops=flops, hbm_words=hbm)


def sparse_stream_update_cost(k: int, n2: int, r: int, l: int, nnz: float,
                              grid: Tuple[int, int, int] = (1, 1, 1),
                              corange: bool = True,
                              kind: str = "countsketch") -> Cost:
    """One ``update_rows_sparse`` step folding a (k, n2) COO slab with
    ``nnz`` stored entries (``stream/state.py:_local_sparse_update``).

    Local grid: zero network words — the interesting number is the
    *payload* (priced by :func:`sparse_payload_words` at the service
    ledger site) and the O(nnz) fold.  Sharded grids ship the COO panel
    over p3 instead of the dense slab — same substitution as
    :func:`sparse_sketch_cost`; the dY All-Reduce over p2 is dense.

    A sparse KIND folds one scatter-add per entry into Y (and one into W
    when corange); a dense kind gathers an r-row of the regenerated Omega
    per entry (nnz·r flops) and an l-row of Psi likewise.
    """
    p1, p2, p3 = grid
    nnz_eff = float(nnz) * _sparse_participation(n2, r, kind)
    sparse_om = kind in ("countsketch", "rowsample")
    words = 0.0
    msgs = 0.0
    if p3 > 1:
        words += (1.0 - 1.0 / p3) * sparse_payload_words(nnz_eff) / p2
        msgs += math.log2(p3)
    if p2 > 1:
        words += 2.0 * (1.0 - 1.0 / p2) * k * r / p3   # all-reduce of dY
        msgs += 2.0 * math.log2(p2)
    per_entry = 1.0 if sparse_om else float(r)
    flops = 2.0 * nnz_eff * per_entry * SPARSE_SCATTER_PENALTY / (p2 * p3)
    # COO read + one dY read-modify-write per scatter + the Y fold
    hbm = ((sparse_payload_words(nnz_eff) + 2.0 * nnz_eff) / (p2 * p3)
           + 4.0 * k * r / p3)
    if corange:
        flops += (2.0 * nnz_eff * (1.0 if sparse_om else float(l))
                  * SPARSE_SCATTER_PENALTY / (p2 * p3))
        hbm += (2.0 * nnz_eff + 2.0 * l * n2) / (p2 * p3)
    return Cost(words=words, messages=msgs, flops=flops, hbm_words=hbm)


def stream_reshard_words(n1: int, r: int, p: Tuple[int, int, int],
                         q: Tuple[int, int, int], *, l: int = 0,
                         n2: int = 0, corange: bool = False) -> float:
    """Per-processor words of the one-hop elastic reshard
    (``stream/elastic.py reshard_stream``): re-laying a live accumulator's
    (Y, W) from grid ``p`` onto grid ``q`` in a single resharding hop.

    Exact per-device min-cut over the shared linear device order, the same
    construction as :func:`fused_redistribute_words`: each device keeps the
    overlap between its old and new shards and only receives the rest, so
    the cost is  max over receiving devices of (new-shard words) -
    (overlap words).  Layouts follow stream/distributed.py: Y (n1 x r) is
    P((p1, p2), p3) — device d holds row block d // p3 of p1·p2 and column
    block d % p3 of p3 — and W (l x n2), present when ``corange``, is
    P(None, (p2, p3)) — replicated over p1, column block d % (p2·p3).

    When device counts differ (grow / shrink) the device order is
    prefix-shared (``make_grid_mesh`` takes ``devices[:P]``): the first
    min(P, Q) devices keep their overlap, fresh devices receive their full
    shards, and shed devices only send.  Identical effective layouts —
    e.g. (8,1,1) -> (4,2,1), whose Y row blocks coincide — cost zero: the
    hop is a relabeling, and the compiled relayout emits no collective.

    This min-cut is the hop's *floor* (the ledger's ``lower_bound_words``
    for the ``stream.reshard`` site); what a compiled relayout actually
    moves is :func:`stream_reshard_traffic_words` — XLA round-trips full
    shards, achieving the floor only where the floor is 0 or full-shard.
    """
    p1, p2, p3 = p
    q1, q2, q3 = q
    P, Q = p1 * p2 * p3, q1 * q2 * q3
    pr, pc = n1 / (p1 * p2), r / p3          # old Y shard extents
    qr, qc = n1 / (q1 * q2), r / q3          # new Y shard extents
    worst = 0.0
    for d in range(Q):
        nrb, ncb = divmod(d, q3)
        need = qr * qc
        if d < P:
            rb, cb = divmod(d, p3)
            ov_r = max(0.0, min(rb * pr + pr, nrb * qr + qr)
                       - max(rb * pr, nrb * qr))
            ov_c = max(0.0, min(cb * pc + pc, ncb * qc + qc)
                       - max(cb * pc, ncb * qc))
            need -= ov_r * ov_c
        if corange:
            wp, wq = n2 / (p2 * p3), n2 / (q2 * q3)   # W col extents
            nwb = d % (q2 * q3)
            w_need = l * wq
            if d < P:
                wb = d % (p2 * p3)
                ov_w = max(0.0, min(wb * wp + wp, nwb * wq + wq)
                           - max(wb * wp, nwb * wq))
                w_need -= l * ov_w
            need += w_need
        worst = max(worst, need)
    return worst


def stream_reshard_traffic_words(n1: int, r: int, p: Tuple[int, int, int],
                                 q: Tuple[int, int, int], *, l: int = 0,
                                 n2: int = 0,
                                 corange: bool = False) -> float:
    """Per-processor words the COMPILED one-hop relayout actually moves —
    the ledger's *predicted* words for the ``stream.reshard`` site, next
    to the :func:`stream_reshard_words` min-cut floor.

    XLA's SPMD partitioner implements a layout change as shard-sized
    collective traffic — full shards, not the overlap-aware min-cut — and
    the exact count follows from which axes re-split (calibrated against
    the compiled HLO of every 8-device grid pair, exhaustively pinned by
    tests/test_fault_tolerance.py):

    * **Y** (n1 x r, P((p1,p2), p3); device d -> row block d // p3, col
      block d % p3).  Maps coincide (block counts equal, same device
      count) -> the hop compiles away: 0 words.  Re-splitting an
      already-split column axis (p3 > 1 AND q3 > 1 AND p3 != q3) forces
      TWO full-shard hops — an all-to-all re-splitting the rows plus a
      collective-permute re-routing the columns — so the device pays 2x
      its new shard.  Every other layout change folds into a single
      all-to-all: 1x the new shard.
    * **W** (l x n2, P(None, (p2,p3)); device d -> col block d % (p2·p3),
      replicated over the rest).  Same block count -> 0.  Splitting OUT
      of replicated (p2·p3 == 1) onto the same or fewer devices is a
      local slice of the replica: 0 words (a grown device set still
      ships the new shard to each fresh device).  COARSENING the split
      (q2·q3 < p2·p3) is all-gather traffic counted at its per-device
      operand — the OLD shard: l·n2/(p2·p3) words into replicated, twice
      that (gather + permute hop) when the coarser layout is still split.
      Re-splitting FINER moves 1x the new W shard.
    """
    p1, p2, p3 = p
    q1, q2, q3 = q
    P, Q = p1 * p2 * p3, q1 * q2 * q3
    words = 0.0
    # Y P((p1,p2), p3): the maps coincide iff the block counts do
    same_y = (p1 * p2 == q1 * q2 and p3 == q3 and P == Q)
    if not same_y:
        hops = 2.0 if (p3 > 1 and q3 > 1 and p3 != q3) else 1.0
        words += hops * n1 / (q1 * q2) * (r / q3)  # full new Y shard(s)
    if corange:
        # W P(None, (p2,p3)): device d -> col block d % (p2·p3)
        bp, bq = p2 * p3, q2 * q3
        if bp == bq and P == Q:
            pass                                   # same map: no traffic
        elif bp == 1 and Q <= P:
            pass                                   # slice out of replica
        elif bq < bp:
            # all-gather counted at its operand (the OLD shard); a
            # coarser-but-still-split target adds a permute hop
            words += (2.0 if bq > 1 else 1.0) * l * n2 / bp
        else:
            words += l * n2 / bq                   # full new W shard
    return words


# ---------------------------------------------------------------------------
# Variant costs — data-parallel gradient exchange (parallel/grad_compress.py)
# ---------------------------------------------------------------------------

def grad_allreduce_cost(m: int, n: int, world: int) -> Cost:
    """Raw data-parallel exchange of one (m, n) gradient leaf: a single
    all-reduce (``pmean`` over the data axis) moving the full operand.

    Words follow the repo's HLO-audit convention (``roofline/hlo.py``
    counts an all-reduce at its per-device operand size, the same unit
    the Theorem 2 bounds and the comm ledger use): ``m·n`` words per
    processor, ``log2(P)`` latency hops.  ``world <= 1`` is free — a
    pmean over a singleton axis lowers to no collective at all.
    """
    if world <= 1:
        return Cost(words=0.0, messages=0.0, flops=0.0,
                    hbm_words=2.0 * m * n)
    return Cost(words=float(m * n), messages=math.log2(world),
                flops=float(m * n),            # the reduction adds
                hbm_words=2.0 * m * n)         # leaf read + reduced write


def grad_compress_cost(m: int, n: int, r: int, world: int,
                       backend: str = "jnp") -> Cost:
    """Sketched exchange of one (m, n) gradient leaf at rank ``r``
    (``parallel/grad_compress.py``): the Theorem-2 regime-1 trade applied
    to the DP all-reduce — Omega is regenerated from the counter-based
    seed on every worker (zero words, the paper's central claim), so only
    the two data-dependent factors move:

        P  = pmean((G+E)·Omega)      m·r words
        Qᵀ = pmean(P̂ᵀ·(G+E))         r·n words

    for ``r·(m+n)`` total vs the raw ``m·n`` — the planner's crossover is
    ``r < m·n/(m+n)`` (docs/TRAINING.md works it out).  Local work added:
    four rank-r GEMMs (the two sketch GEMMs above plus the decompression
    ``P̂·Qᵀ`` and the error-feedback update ``E' = M − P̂·Q_locᵀ``),
    ``2·m·r²`` for the thin QR of P, and the ``M = G+E`` add.

    ``backend`` prices the local bodies through ``kernels/local.py``: the
    pallas sketch kernel generates Omega in VMEM (the ``n·r`` HBM stream
    vanishes) and the fused dense kernel (``gemm_block``) aliases the
    error-feedback accumulator in-place, halving its ``4·m·n`` jnp
    read-modify-write to ``2·m·n`` — identical network words either way.
    """
    r = min(r, m, n)
    words = float(r * (m + n)) if world > 1 else 0.0
    msgs = 2.0 * math.log2(world) if world > 1 else 0.0
    flops = 8.0 * m * n * r + 2.0 * m * r * r + float(m * n)
    # M = G+E materialization: read both, write M.
    hbm = 3.0 * m * n
    # sketch GEMM M·Omega (hbm_roofline_words: pallas drops the n·r
    # Omega stream), + QR of the m×r pmean result (round trip).
    hbm += hbm_roofline_words(m, n, r, backend) + 2.0 * m * r
    # dense P̂ᵀ·M: both operands resident in HBM on either backend.
    hbm += m * r + float(m * n) + r * n
    # decompression P̂·Qᵀ writes the g_hat leaf.
    hbm += m * r + r * n + float(m * n)
    # error-feedback update E' = M − P̂·Q_locᵀ: jnp materializes the
    # delta then read-modify-writes (4·m·n); the fused kernel aliases
    # the accumulator (2·m·n) — same halving as the streaming W update.
    acc = (2.0 if backend == "pallas" else 4.0) * m * n
    hbm += m * r + r * n + acc
    return Cost(words=words, messages=msgs, flops=flops, hbm_words=hbm)


# ---------------------------------------------------------------------------
# Ragged-ingest bucket planning (padded-lane waste vs dispatch amortization)
# ---------------------------------------------------------------------------

def ragged_bucket_cost(ks, kb: int, n2: int, r: int, l: int,
                       corange: bool = True, backend: str = "jnp",
                       machine: MachineModel = None,
                       itemsize: int = 4) -> float:
    """Predicted seconds of ONE fused bucket dispatch ingesting ``len(ks)``
    ragged lanes padded to height ``kb`` (each ``k in ks`` must be <= kb).

    One host dispatch, then the vmapped lanes execute back to back on the
    device, each paying the FULL padded-slab work — padded rows are masked,
    not skipped, so their FLOPs and HBM traffic are real.  That waste is
    what the dispatch saving has to beat; :func:`choose_bucket_edges` runs
    the comparison exactly.
    """
    machine = machine or probe_machine()
    lane = stream_update_cost(kb, n2, r, l, corange=corange, backend=backend)
    return (machine.dispatch_overhead
            + len(list(ks)) * lane.seconds(machine, itemsize))


def choose_bucket_edges(ks, n2: int, r: int, l: int = None,
                        corange: bool = True, backend: str = "jnp",
                        machine: MachineModel = None,
                        itemsize: int = 4) -> list:
    """Optimal shape-bucket boundaries for a ragged ingest workload.

    ``ks`` is the observed distribution of lane heights (one entry per
    update).  Returns ascending bucket tops (for
    ``SketchService.update_ragged(bucket_edges=...)`` /
    ``IngestQueue(bucket_edges=...)``); every lane is padded up to the
    smallest edge >= its height.

    Exact DP over the sorted unique heights (buckets are contiguous height
    ranges in an optimal solution — padding a lane past the next-larger
    occupied height is never cheaper than stopping there), minimizing

        sum over buckets [ dispatch_overhead
                           + count(bucket) * lane_seconds(bucket top) ].

    Limits (pinned by tests/test_service_scale.py): zero dispatch overhead
    degenerates to one bucket per distinct height (no padding is ever
    free); a dispatch cost dominating the per-lane work collapses to a
    single bucket at max(ks).

    Height 1, when present, is always its own bucket: ``snap_bucket``
    refuses to pad single-row slabs (XLA's M=1 gemv reduction order
    differs from the packed gemm loop, which would break the bitwise
    lane-vs-solo contract), so the DP plans the remaining heights around
    a mandatory [1] edge.
    """
    machine = machine or probe_machine()
    if l is None:
        l = 2 * r + 1
    ks = sorted(int(k) for k in ks)
    if not ks:
        return []
    if ks[0] <= 1:
        rest = [k for k in ks if k > 1]
        return [1] + choose_bucket_edges(
            rest, n2, r, l, corange=corange, backend=backend,
            machine=machine, itemsize=itemsize)
    uniq = sorted(set(ks))
    counts = [ks.count(u) for u in uniq]
    lane_s = [stream_update_cost(u, n2, r, l, corange=corange,
                                 backend=backend).seconds(machine, itemsize)
              for u in uniq]
    m = len(uniq)
    best = [0.0] * (m + 1)          # best[j]: heights uniq[:j] bucketed
    cut = [0] * (m + 1)
    for j in range(1, m + 1):
        best[j] = math.inf
        tail = 0
        for i in range(j, 0, -1):   # bucket = uniq[i-1 .. j-1], top uniq[j-1]
            tail += counts[i - 1]
            c = best[i - 1] + machine.dispatch_overhead + tail * lane_s[j - 1]
            if c < best[j]:
                best[j], cut[j] = c, i - 1
    edges = []
    j = m
    while j > 0:
        edges.append(uniq[j - 1])
        j = cut[j]
    return edges[::-1]
