"""Production meshes.

Single-pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries cross-pod data parallelism (default) or pipeline stages.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):      # older jax fallback
        return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axes that carry batch parallelism on this mesh."""
    return (("pod", "data") if "pod" in mesh.shape else ("data",))


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
