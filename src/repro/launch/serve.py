"""Serving driver for the repo's two request workloads.

LM decoding (continuous-batching-lite):

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b \
      --requests 6 --max-new 16

Multi-tenant sketch ingest (shape-bucketed ragged batching behind the
bounded async queue):

  PYTHONPATH=src python -m repro.launch.serve --workload sketch \
      --streams 64 --updates 4 --n1 1024 --n2 512 --r 32

Chaos harness (stream/faults.py): inject a named failure scenario into
the serving stack and verify the recovery contract end to end —
kill-worker (WAL replay, bitwise), torn-write (checkpoint quarantine),
shrink-restore (live mesh resize, bitwise finalize), eviction-storm:

  PYTHONPATH=src python -m repro.launch.serve --chaos kill-worker
  PYTHONPATH=src python -m repro.launch.serve --chaos all
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import get_api
from repro.obs import trace as obs_trace
from repro.serve.engine import BatchedServer, Request


def run_lm(args):
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)

    server = BatchedServer(params, cfg, slots=args.slots,
                           max_len=args.max_len, eos=-1)
    for i in range(args.requests):
        server.submit(Request(rid=i, prompt=[2 + i, 5, 7],
                              max_new=args.max_new))
    t0 = time.time()
    server.run()
    dt = time.time() - t0
    print(f"[serve] {args.requests} requests on {args.slots} slots "
          f"in {dt:.1f}s")
    return server


def run_sketch(args):
    """Drive N concurrent sketch streams through the async ingest queue
    and report sustained throughput + tail latency."""
    import numpy as np

    from repro.serve.engine import make_ingest_queue, make_sketch_service
    from repro.stream.state import StreamConfig

    rng = np.random.default_rng(0)
    svc = make_sketch_service(max_resident=args.max_resident or None)
    sids = [svc.open(StreamConfig(n1=args.n1, n2=args.n2, r=args.r, seed=s))
            for s in range(args.streams)]
    ks = [int(rng.integers(1, args.max_rows + 1))
          for _ in range(args.streams * args.updates)]
    q = make_ingest_queue(svc, depth=args.depth, window=args.window,
                          expected_ks=ks)
    # startup warmup on throwaway streams: compile every (bucket height,
    # pow2 lane count) pair live traffic can produce — partial drains give
    # arbitrary per-bucket occupancies, so enumerate counts exactly the
    # way a real server warms its shape set before taking traffic
    from repro.stream import snap_bucket
    tmp = [svc.open(StreamConfig(n1=args.n1, n2=args.n2, r=args.r,
                                 seed=1_000_000 + s))
           for s in range(args.streams)]
    tops = sorted({snap_bucket(k, q.bucket_edges) for k in ks})
    for kb in tops:
        c = 1
        while c <= args.streams:
            svc.update_ragged(
                [(tmp[i], np.zeros((kb, args.n2), np.float32), 0)
                 for i in range(c)], bucket_edges=q.bucket_edges)
            c *= 2
    svc.sync()
    for t in tmp:
        svc.close(t)
    print(f"[serve:sketch] warmed {svc.stats()['compiled_updates']} "
          f"programs over buckets {tops}")
    t0 = time.perf_counter()
    it = iter(ks)
    for u in range(args.updates):
        # submit under a round span: the queue worker's apply spans
        # stitch under it cross-thread in the exported trace
        with obs_trace.span("client.update_round", cat="client", round=u):
            for sid in sids:
                k = next(it)
                H = rng.standard_normal((k, args.n2)).astype(np.float32)
                q.submit(sid, H, int(rng.integers(0, args.n1 - k + 1)))
    q.flush(raise_errors=True)
    dt = time.perf_counter() - t0
    st = q.stats()
    n = args.streams * args.updates
    print(f"[serve:sketch] {n} updates over {args.streams} streams in "
          f"{dt:.2f}s — {n / dt:.1f} updates/s, p50 "
          f"{st['latency_p50_s'] * 1e3:.1f} ms, p99 "
          f"{st['latency_p99_s'] * 1e3:.1f} ms, pad waste "
          f"{st['pad_waste']:.1%}, {st['rounds']} fused rounds")
    q.shutdown()
    return st


def run_chaos(args):
    """Run one (or all) chaos scenarios and report the recovery verdicts.
    Exits non-zero if any scenario failed to recover."""
    from repro.stream import faults

    names = list(faults.SCENARIOS) if args.chaos == "all" else [args.chaos]
    results = {}
    for name in names:
        print(f"[chaos] scenario {name!r} ...")
        res = faults.run_chaos_scenario(
            name, streams=min(args.streams, 8), updates=args.updates)
        results[name] = res
        print(f"[chaos] {name}: "
              f"{'RECOVERED' if res.get('recovered') else 'FAILED'} "
              f"{ {k: v for k, v in res.items() if k != 'recovered'} }")
    if not all(r.get("recovered") for r in results.values()):
        raise SystemExit(1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "sketch"), default="lm")
    ap.add_argument("--chaos", metavar="SCENARIO", default=None,
                    help="run a stream/faults.py chaos scenario instead of "
                         "a workload: kill-worker | torn-write | "
                         "shrink-restore | eviction-storm | all")
    # lm
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    # sketch
    ap.add_argument("--streams", type=int, default=64)
    ap.add_argument("--updates", type=int, default=4,
                    help="updates per stream")
    ap.add_argument("--n1", type=int, default=1024)
    ap.add_argument("--n2", type=int, default=512)
    ap.add_argument("--r", type=int, default=32)
    ap.add_argument("--max-rows", type=int, default=64,
                    help="lane heights drawn from [1, max-rows]")
    ap.add_argument("--depth", type=int, default=256)
    ap.add_argument("--window", type=int, default=64)
    ap.add_argument("--max-resident", type=int, default=0,
                    help="admission budget (0 = unlimited)")
    # observability (repro.obs)
    ap.add_argument("--metrics", action="store_true",
                    help="dump the Prometheus text exposition of the "
                         "process metrics registry after the run")
    ap.add_argument("--trace-out", metavar="FILE", default=None,
                    help="write a Chrome/Perfetto trace (trace_event JSON) "
                         "of the run to FILE; also prints the comm-ledger "
                         "honesty report")
    args = ap.parse_args()
    tracing = args.trace_out is not None
    if tracing:
        from repro import obs
        tracer, ledger, _ = obs.install_observability()
    try:
        if args.chaos is not None:
            out = run_chaos(args)
        else:
            out = (run_sketch(args) if args.workload == "sketch"
                   else run_lm(args))
    finally:
        if tracing:
            tracer.export_chrome(args.trace_out)
            print(f"[serve] trace written to {args.trace_out} "
                  f"({len(tracer.spans)} spans)")
            if len(ledger):
                print(obs.honesty_report(ledger))
            obs.uninstall_observability()
        if args.metrics:
            from repro.obs import get_metrics
            print(get_metrics().prometheus_text(), end="")
    return out


if __name__ == "__main__":
    main()
