"""Serving driver: batched decoding with the continuous-batching-lite
scheduler.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b \
      --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import get_api
from repro.serve.engine import BatchedServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)

    server = BatchedServer(params, cfg, slots=args.slots,
                           max_len=args.max_len, eos=-1)
    for i in range(args.requests):
        server.submit(Request(rid=i, prompt=[2 + i, 5, 7],
                              max_new=args.max_new))
    t0 = time.time()
    server.run()
    dt = time.time() - t0
    done = args.requests
    print(f"[serve] {done} requests on {args.slots} slots in {dt:.1f}s")
    return server


if __name__ == "__main__":
    main()
