"""End-to-end training driver.

CPU-scale by default (reduced config, a few hundred steps on the synthetic
pipeline); pass --full to run an assigned config unchanged (requires real
accelerators).  Demonstrates: config system -> mesh -> sharded state ->
fault-tolerant loop -> checkpointing, with optional sketched gradient
compression (the paper's technique as a first-class training feature).

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
      --steps 200 --batch 8 --seq 128

Sketched gradient compression (docs/TRAINING.md) is one flag away:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
      --steps 60 --batch 8 --grad-compress 8

which builds a 1-D "data" mesh over every device, plans the per-layer
raw-vs-sketch decisions (plan.plan_train_compression, table printed at
startup), and trains through make_dp_compressed_step — the DP all-reduce
pays r·(m+n) words per weight matrix instead of m·n (Theorem 2 regime 1:
Omega is regenerated, never communicated).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data.pipeline import DataConfig
from repro.models import get_api
from repro.models.common import NULL_CTX
from repro.train.loop import train_loop
from repro.train.step import init_state, make_dp_compressed_step, \
    make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true",
                    help="use the full (assigned) config, not the reduced")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grad-compress", type=int, default=0, metavar="RANK",
                    help="sketched gradient compression at this rank over a "
                         "1-D DP mesh of all devices (0 = off; "
                         "docs/TRAINING.md)")
    ap.add_argument("--grad-backend", default="auto",
                    choices=["auto", "jnp", "pallas"],
                    help="local GEMM bodies of the compressed exchange "
                         "(kernels/local.py; auto = pallas on TPU)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    api = get_api(cfg)
    run = RunConfig(steps=args.steps, learning_rate=args.lr,
                    checkpoint_every=args.ckpt_every,
                    checkpoint_dir=args.ckpt_dir, seed=args.seed,
                    remat=True, grad_compress_rank=args.grad_compress,
                    grad_compress_backend=args.grad_backend)

    data_cfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
        frontend=("vision" if cfg.family == "vlm"
                  else "audio" if cfg.family == "encdec" else "none"),
        frontend_dim=cfg.frontend_dim,
        num_frontend_tokens=cfg.num_frontend_tokens,
        enc_seq=cfg.enc_seq if cfg.family == "encdec" else 0,
        d_model=cfg.d_model)

    print(f"[train] arch={cfg.name} family={cfg.family} "
          f"steps={run.steps} batch={args.batch} seq={args.seq}")
    if args.grad_compress:
        # planner-priced sketched DP exchange over a 1-D "data" mesh
        from jax.sharding import Mesh
        from repro.plan import explain_train_compression, \
            plan_train_compression
        devices = jax.devices()
        if args.batch % len(devices):
            raise SystemExit(f"--batch {args.batch} must divide over "
                             f"{len(devices)} DP workers")
        mesh = Mesh(np.asarray(devices), ("data",))
        shapes = jax.eval_shape(lambda k: api.init(k, cfg),
                                jax.random.key(run.seed))
        plan = plan_train_compression(
            shapes, rank=run.grad_compress_rank, P=len(devices),
            backend=None if args.grad_backend == "auto"
            else args.grad_backend)
        print(explain_train_compression(plan))
        state = init_state(api, cfg, run, jax.random.key(run.seed),
                           world=len(devices),
                           decisions=plan.decision_tree())
        step_fn = make_dp_compressed_step(api, cfg, run, mesh,
                                          axis="data", plan=plan,
                                          backend=args.grad_backend)
    else:
        state = init_state(api, cfg, run, jax.random.key(run.seed))
        step_fn = jax.jit(make_train_step(api, cfg, run, NULL_CTX))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"[train] params: {n_params/1e6:.2f}M")

    t0 = time.time()
    result = train_loop(step_fn, state, data_cfg, run)
    dt = time.time() - t0

    first = np.mean(result.losses[:10])
    last = np.mean(result.losses[-10:])
    print(f"[train] done in {dt:.1f}s; loss {first:.4f} -> {last:.4f} "
          f"({len(result.losses)} steps, {result.restarts} restarts, "
          f"{len(result.checkpoints)} checkpoints)")
    assert last < first, "loss did not decrease"
    return result


if __name__ == "__main__":
    main()
