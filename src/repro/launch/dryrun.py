import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ---------------------------------------------------------------------------
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell against
# ShapeDtypeStruct inputs (no allocation), print memory/cost analysis, and
# emit the roofline terms consumed by EXPERIMENTS.md.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --mesh both
#   PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
#       --shape train_4k --mesh single --save-hlo /tmp/hlo
# ---------------------------------------------------------------------------

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, get_config, get_shape, skip_reason,
                           ALL_SHAPES)
from repro.configs.base import RunConfig
from repro.models import get_api, input_specs
from repro.models.api import count_params_split, count_active_params, model_flops
from repro.optim.adamw import AdamWState
from repro.parallel.sharding import (batch_shardings, cache_shardings,
                                     make_shard_ctx, param_shardings)
from repro.roofline.analysis import analyze_compiled
from repro.serve.engine import serve_prefill
from repro.train.state import TrainState
from repro.train.step import make_train_step
from repro.launch.mesh import data_axes, make_production_mesh, mesh_chips


def _replicated(mesh):
    return NamedSharding(mesh, P())


def build_lowered(arch: str, shape_name: str, multi_pod: bool,
                  kv_chunk: int = 1024):
    """Lower one cell; returns (lowered, meta)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    daxes = data_axes(mesh)
    da = daxes if len(daxes) > 1 else daxes[0]
    ctx = make_shard_ctx(mesh, daxes)
    api = get_api(cfg)

    params_shapes = jax.eval_shape(lambda: api.init(jax.random.key(0), cfg))
    p_shard = param_shardings(params_shapes, mesh)
    n_total, _ = count_params_split(cfg, params_shapes)
    n_active = count_active_params(cfg, params_shapes)
    specs = input_specs(cfg, shape)
    meta = dict(arch=arch, shape=shape_name,
                mesh="multi_pod" if multi_pod else "single_pod",
                chips=mesh_chips(mesh), n_params=n_total,
                n_active_params=n_active,
                model_flops=model_flops(cfg, shape, n_total, n_active))

    if shape.kind == "train":
        run = RunConfig(remat=True)
        state_shapes = TrainState(
            params=params_shapes,
            opt=AdamWState(
                m=jax.tree_util.tree_map(
                    lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                    params_shapes),
                v=jax.tree_util.tree_map(
                    lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                    params_shapes),
                count=jax.ShapeDtypeStruct((), jnp.int32)),
            step=jax.ShapeDtypeStruct((), jnp.int32),
            error_fb=None)
        state_shardings = TrainState(
            params=p_shard,
            opt=AdamWState(m=p_shard, v=p_shard, count=_replicated(mesh)),
            step=_replicated(mesh), error_fb=None)
        b_shard = batch_shardings(specs, mesh, da)
        train_step = make_train_step(api, cfg, run, ctx)
        metric_shardings = {k: _replicated(mesh)
                            for k in ("loss", "grad_norm", "lr")}
        fn = jax.jit(train_step,
                     in_shardings=(state_shardings, b_shard),
                     out_shardings=(state_shardings, metric_shardings))
        lowered = fn.lower(state_shapes, specs)
        return lowered, meta

    if shape.kind == "prefill":
        b_shard = batch_shardings(specs, mesh, da)

        def prefill_fn(params, batch):
            return serve_prefill(params, cfg, batch, ctx=ctx,
                                 max_len=shape.seq_len, remat=True)

        fn = jax.jit(prefill_fn, in_shardings=(p_shard, b_shard))
        lowered = fn.lower(params_shapes, specs)
        return lowered, meta

    # decode
    cache_shard = cache_shardings(specs["cache"], mesh, da)
    tok_shard = batch_shardings(specs["token"], mesh, da)

    def decode_fn(params, token, cache, pos):
        return api.decode_step(params, cfg, token, cache, pos, ctx=ctx)

    # the cache is donated: decode updates it in place (without donation
    # every step copies the full multi-GB cache into fresh output buffers)
    fn = jax.jit(decode_fn,
                 in_shardings=(p_shard, tok_shard, cache_shard,
                               _replicated(mesh)),
                 out_shardings=(None, cache_shard),
                 donate_argnums=(2,))
    lowered = fn.lower(params_shapes, specs["token"], specs["cache"],
                       specs["pos"])
    return lowered, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: str = None, verbose: bool = True):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    reason = skip_reason(cfg, shape)
    mesh_name = "multi_pod" if multi_pod else "single_pod"
    cell_id = f"{arch}|{shape_name}|{mesh_name}"
    if reason:
        print(f"[dryrun] {cell_id}: {reason}")
        return {"cell": cell_id, "arch": arch, "shape": shape_name,
                "mesh": mesh_name, "skip": reason}

    t0 = time.time()
    try:
        lowered, meta = build_lowered(arch, shape_name, multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        print(f"[dryrun] {cell_id} memory_analysis: {mem}")
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        print(f"[dryrun] {cell_id} cost_analysis: "
              f"flops={ca.get('flops', 0):.4g} "
              f"bytes={ca.get('bytes accessed', 0):.4g}")

        hlo_text = compiled.as_text()
        if save_hlo:
            os.makedirs(save_hlo, exist_ok=True)
            fname = os.path.join(save_hlo, cell_id.replace("|", "__") + ".hlo")
            with open(fname, "w") as f:
                f.write(hlo_text)

        terms = analyze_compiled(cell_id, compiled, meta["chips"],
                                 model_flops=meta["model_flops"],
                                 hlo_text=hlo_text)
        rec = dict(meta)
        rec.update(terms.to_dict())
        rec["cell"] = cell_id
        rec["t_lower_s"] = round(t_lower, 1)
        rec["t_compile_s"] = round(t_compile, 1)
        try:
            rec["per_device_bytes"] = {
                "args": mem.argument_size_in_bytes,
                "output": mem.output_size_in_bytes,
                "temp": mem.temp_size_in_bytes,
                "alias": mem.alias_size_in_bytes,
            }
        except AttributeError:
            rec["per_device_bytes"] = str(mem)
        if verbose:
            print(f"[dryrun] {cell_id}: OK  "
                  f"t_c={terms.t_compute:.3e}s t_m={terms.t_memory:.3e}s "
                  f"t_l={terms.t_collective:.3e}s "
                  f"bottleneck={terms.bottleneck} "
                  f"useful={terms.useful_ratio} "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        return rec
    except Exception as e:  # noqa: BLE001 — report and continue the sweep
        traceback.print_exc()
        return {"cell": cell_id, "arch": arch, "shape": shape_name,
                "mesh": mesh_name, "error": f"{type(e).__name__}: {e}"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = ([s.name for s in ALL_SHAPES] if args.shape == "all"
              else [args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    with open(args.out, "a") as f:
        for multi in meshes:
            for arch in archs:
                for shape in shapes:
                    rec = run_cell(arch, shape, multi,
                                   save_hlo=args.save_hlo)
                    results.append(rec)
                    f.write(json.dumps(rec, default=str) + "\n")
                    f.flush()

    ok = [r for r in results if "error" not in r and "skip" not in r]
    skipped = [r for r in results if "skip" in r]
    failed = [r for r in results if "error" in r]
    print(f"\n[dryrun] {len(ok)} ok, {len(skipped)} documented skips, "
          f"{len(failed)} FAILED")
    for r in failed:
        print("  FAIL", r["cell"], r["error"])
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
