"""Elastic scaling: resume a checkpoint onto a DIFFERENT device count/mesh.

Checkpoints are mesh-agnostic (logical arrays), so elasticity is:
  1. build the new mesh from the surviving device set,
  2. recompute shardings for the same param pytree on the new mesh,
  3. ``ckpt.restore(..., shardings=new)`` re-places every leaf,
  4. rescale gradient accumulation so the global batch is preserved
     (global_batch = dp_size * per_device_batch * accum_steps).

Exercised by tests/test_fault_tolerance.py on 8->4 fake devices.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import ckpt
from repro.parallel.sharding import param_shardings


def remesh(devices, dp: int, tp: int, axis_names=("data", "model")) -> Mesh:
    devs = np.asarray(devices[: dp * tp]).reshape(dp, tp)
    return Mesh(devs, axis_names)


def elastic_restore(directory: str, state_template, *, mesh: Mesh,
                    model_axis: str = "model",
                    step: Optional[int] = None):
    """Restore a TrainState onto ``mesh`` (any device count)."""
    from repro.optim.adamw import AdamWState
    from repro.train.state import TrainState
    p_shard = param_shardings(state_template.params, mesh, model_axis)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = TrainState(
        params=p_shard,
        opt=AdamWState(m=p_shard, v=p_shard, count=repl),
        step=repl,
        error_fb=(jax.tree_util.tree_map(lambda _: repl,
                                         state_template.error_fb)
                  if state_template.error_fb is not None else None))
    return ckpt.restore(directory, state_template, step=step,
                        shardings=shardings)


def rescale_accum(global_batch: int, per_device_batch: int,
                  dp_size: int) -> Tuple[int, int]:
    """(accum_steps, effective_global_batch) preserving the global batch."""
    denom = per_device_batch * dp_size
    accum = max(1, global_batch // denom)
    return accum, accum * denom
