from .hlo import CollectiveBytes, collective_bytes_of, op_histogram  # noqa: F401
from .analysis import (  # noqa: F401
    RooflineTerms, analyze_compiled, format_table, save_json,
    PEAK_FLOPS_BF16, HBM_BW, ICI_LINK_BW,
)
