"""Trip-count-aware HLO cost analysis.

Feeds the roofline terms of :mod:`repro.roofline.analysis` (the paper's §3
cost model measured on compiled programs; see that module's docstring for
the paper mapping).

``compiled.cost_analysis()`` counts each while-loop body ONCE, which
undercounts scan-over-layers models by ~L× (verified empirically; see
EXPERIMENTS.md §Methodology).  This module re-derives per-device cost from
the optimized HLO text with loop multipliers:

  * computations are parsed into blocks; every ``while`` links to its
    condition/body computations; the trip count is the s32 bound constant
    in the condition computation (all our loops are static-trip scans);
  * FLOPs: 2 * |output| * contraction for every ``dot`` (models are
    GEMM-dominated; elementwise FLOPs are ignored and documented);
  * HBM bytes: operand+output sizes at fusion/op granularity (fusions are
    XLA's unit of HBM traffic); slicing ops count only the moved slice;
    bookkeeping ops (tuple/GTE/bitcast/parameter) count zero;
  * collective bytes: operand sizes of collective ops (degenerate
    single-participant groups count zero), multiplied by loop multipliers.

All results are per-device (the HLO is the SPMD per-device program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "u1": 0.125,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "call", "conditional", "after-all",
               "iota", "rng-bit-generator", "partition-id", "replica-id",
               "opt-barrier"}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,\s]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\((.*)$")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\{\s*$")
_WHILE_LINK_RE = re.compile(r"condition=(%[\w.\-]+),\s*body=(%[\w.\-]+)")
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_S32_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_EXPLICIT = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_elems_bytes(type_text: str) -> Tuple[int, float]:
    n_total, b_total = 0, 0.0
    for dt, dims in _SHAPE_RE.findall(type_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            d = d.strip()
            if d:
                n *= int(d)
        n_total += n
        b_total += n * _DTYPE_BYTES[dt]
    return n_total, b_total


def _first_shape_dims(type_text: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_text)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d.strip()]
    return dims


@dataclass
class Instr:
    name: str
    type_text: str
    op: str
    rest: str      # text after the open paren (operands + attrs)


@dataclass
class HloCost:
    flops: float = 0.0                  # per device, trip-corrected
    hbm_bytes: float = 0.0              # per device, estimate
    collective_bytes: float = 0.0       # per device
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)
    while_trips: Dict[str, int] = field(default_factory=dict)
    num_partitions: int = 1


def _operand_names(rest: str) -> List[str]:
    depth = 1
    end = len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%[\w.\-]+", rest[:end])


def _group_size(rest: str) -> Optional[int]:
    m = _GROUPS_EXPLICIT.search(rest)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_IOTA.search(rest)
    if m:
        return int(m.group(2))
    return None


def parse_computations(text: str) -> Tuple[Dict[str, List[Instr]], str]:
    comps: Dict[str, List[Instr]] = {}
    entry = ""
    cur: Optional[str] = None
    for line in text.splitlines():
        if not line:
            continue
        if not line[0].isspace():
            hm = _COMP_HEADER_RE.match(line.strip())
            if hm:
                cur = hm.group(2)
                comps[cur] = []
                if hm.group(1):
                    entry = cur
            else:
                cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if im:
            comps[cur].append(Instr(*im.groups()))
    return comps, entry


def analyze(text: str) -> HloCost:
    comps, entry = parse_computations(text)
    out = HloCost()
    m = re.search(r"num_partitions\s*=\s*(\d+)", text)
    if m:
        out.num_partitions = int(m.group(1))

    # symbol table: result sizes/dims by name (names are unique module-wide
    # in printed HLO)
    sizes: Dict[str, float] = {}
    dims_of: Dict[str, List[int]] = {}
    for instrs in comps.values():
        for ins in instrs:
            sizes[ins.name] = _shape_elems_bytes(ins.type_text)[1]
            d = _first_shape_dims(ins.type_text)
            if d is not None:
                dims_of[ins.name] = d

    # effective read size per (fused computation, operand index): a fusion
    # parameter that reaches ONLY slice/dynamic-slice/gather ops (possibly
    # through unary pass-throughs: convert/bitcast/copy/reshape) reads just
    # the sliced region — e.g. python-unrolled decode slicing one layer out
    # of stacked (L, ...) params, where counting the full stacked operand
    # overstated decode HBM traffic ~40x.
    fusion_param_eff: Dict[str, Dict[int, float]] = {}
    _SLICE_OPS = ("slice", "dynamic-slice", "gather")
    _PASS_OPS = ("convert", "bitcast", "copy", "reshape", "transpose")
    for cname, instrs in comps.items():
        pidx: Dict[str, int] = {}
        for ins in instrs:
            if ins.op == "parameter":
                m_p = re.match(r"\s*(\d+)", ins.rest)
                if m_p:
                    pidx[ins.name] = int(m_p.group(1))
        if not pidx:
            continue
        users: Dict[str, list] = {}
        for ins in instrs:
            for o in _operand_names(ins.rest):
                users.setdefault(o, []).append(ins)
        eff: Dict[int, float] = {}
        for pname, i in pidx.items():
            per_elem = 0.0
            n_el, b_tot = 0, 0.0
            for ins in instrs:
                if ins.name == pname:
                    n_el, b_tot = _shape_elems_bytes(ins.type_text)
            per_elem = (b_tot / n_el) if n_el else 4.0
            # BFS through pass-through ops
            frontier = [pname]
            sliced_elems = 0
            ok = True
            hops = 0
            while frontier and ok and hops < 64:
                hops += 1
                nxt = []
                for name in frontier:
                    for u in users.get(name, []):
                        if u.op in _SLICE_OPS:
                            sliced_elems += _shape_elems_bytes(
                                u.type_text)[0]
                        elif u.op in _PASS_OPS:
                            nxt.append(u.name)
                        else:
                            ok = False
                frontier = nxt
            if ok and sliced_elems:
                eff[i] = sliced_elems * per_elem
        if eff:
            fusion_param_eff[cname] = eff

    # dot FLOPs inside fused computations (decode lowers dots into kLoop
    # fusions): attributed at the call site with the caller's multiplier
    fusion_dot_flops: Dict[str, float] = {}
    for cname, instrs in comps.items():
        local_dims = {ins.name: _first_shape_dims(ins.type_text)
                      for ins in instrs}
        fl = 0.0
        for ins in instrs:
            if ins.op != "dot":
                continue
            out_dims = _first_shape_dims(ins.type_text) or []
            n_out = 1
            for d in out_dims:
                n_out *= d
            contraction = 1
            cd = _CDIMS_RE.search(ins.rest)
            ops_names = _operand_names(ins.rest)
            if cd and ops_names:
                ld = local_dims.get(ops_names[0]) or dims_of.get(ops_names[0])
                if ld:
                    for ci in cd.group(1).split(","):
                        ci = ci.strip()
                        if ci and int(ci) < len(ld):
                            contraction *= ld[int(ci)]
            fl += 2.0 * n_out * contraction
        if fl:
            fusion_dot_flops[cname] = fl

    # while links + trip counts
    links: Dict[str, List[Tuple[str, str]]] = {}   # comp -> [(cond, body)]
    trips: Dict[str, int] = {}                     # body comp -> trip
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "while":
                lm = _WHILE_LINK_RE.search(ins.rest)
                if not lm:
                    continue
                cond, body = lm.group(1), lm.group(2)
                links.setdefault(cname, []).append((cond, body))
                bound = 1
                for c in comps.get(cond, []):
                    for mm in _S32_CONST_RE.finditer(
                            f"{c.type_text} {c.op}({c.rest}"):
                        bound = max(bound, int(mm.group(1)))
                trips[body] = bound
                trips[cond] = bound
            elif ins.op in ("call", "conditional"):
                # NOT fusion: fused-computation internals are VMEM/register
                # traffic, counted once at the fusion boundary.
                for cm in _CALLS_RE.finditer(ins.rest):
                    links.setdefault(cname, []).append((None, cm.group(1)))

    # multipliers via BFS from ENTRY
    mult: Dict[str, float] = {entry: 1.0}
    work = [entry]
    seen = set()
    while work:
        cname = work.pop()
        if cname in seen:
            continue
        seen.add(cname)
        m0 = mult.get(cname, 1.0)
        for cond, body in links.get(cname, []):
            t = trips.get(body, 1)
            for sub in ((cond, body) if cond else (body,)):
                if sub is None:
                    continue
                mult[sub] = mult.get(sub, 0.0) + m0 * t
                if sub not in seen:
                    work.append(sub)

    # cost walk
    for cname, instrs in comps.items():
        m0 = mult.get(cname)
        if m0 is None:
            continue   # fusion internals et al.: counted at the call site
        for ins in instrs:
            op = ins.op
            if op in _COLLECTIVES or (op.endswith("-start")
                                      and op[:-6] in _COLLECTIVES):
                base = op[:-6] if op.endswith("-start") else op
                if _group_size(ins.rest) == 1:
                    continue
                nbytes = sum(sizes.get(o, 0.0)
                             for o in _operand_names(ins.rest))
                if nbytes == 0.0:
                    nbytes = _shape_elems_bytes(ins.type_text)[1]
                out.collective_by_kind[base] = \
                    out.collective_by_kind.get(base, 0.0) + nbytes * m0
                out.collective_counts[base] = \
                    out.collective_counts.get(base, 0) + int(m0)
                out.collective_bytes += nbytes * m0
                # collectives also read+write HBM
                out.hbm_bytes += 2 * nbytes * m0
                continue
            if op.endswith("-done"):
                continue
            if op == "dot":
                out_dims = _first_shape_dims(ins.type_text) or []
                n_out = 1
                for d in out_dims:
                    n_out *= d
                cdims = _CDIMS_RE.search(ins.rest)
                contraction = 1
                ops_names = _operand_names(ins.rest)
                if cdims and ops_names:
                    lhs_dims = dims_of.get(ops_names[0])
                    if lhs_dims:
                        for ci in cdims.group(1).split(","):
                            ci = ci.strip()
                            if ci:
                                idx = int(ci)
                                if idx < len(lhs_dims):
                                    contraction *= lhs_dims[idx]
                out.flops += 2.0 * n_out * contraction * m0
                _, ob = _shape_elems_bytes(ins.type_text)
                ib = sum(sizes.get(o, 0.0) for o in _operand_names(ins.rest))
                out.hbm_bytes += (ib + ob) * m0
                continue
            if op in _SKIP_BYTES:
                continue
            if op in ("dynamic-update-slice",):
                # traffic = the updated slice (2nd operand), read+write
                names = _operand_names(ins.rest)
                upd = sizes.get(names[1], 0.0) if len(names) > 1 else 0.0
                out.hbm_bytes += 2 * upd * m0
                continue
            if op in ("dynamic-slice", "slice"):
                _, ob = _shape_elems_bytes(ins.type_text)
                out.hbm_bytes += 2 * ob * m0
                continue
            if op == "broadcast":
                _, ob = _shape_elems_bytes(ins.type_text)
                out.hbm_bytes += ob * m0
                continue
            # default: fusions, copies, converts, elementwise, reduce, etc.
            _, ob = _shape_elems_bytes(ins.type_text)
            operands = _operand_names(ins.rest)
            eff = None
            if op == "fusion":
                cm = _CALLS_RE.search(ins.rest)
                if cm:
                    eff = fusion_param_eff.get(cm.group(1))
                    out.flops += fusion_dot_flops.get(cm.group(1), 0.0) * m0
            if eff:
                ib = sum(min(sizes.get(o, 0.0), eff.get(i, float("inf")))
                         for i, o in enumerate(operands))
            else:
                ib = sum(sizes.get(o, 0.0) for o in operands)
            out.hbm_bytes += (ib + ob) * m0

    out.while_trips = {b: t for b, t in trips.items()}
    return out
