"""HLO-text parsing: collective-communication byte accounting.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not collective
traffic, so we parse the (post-SPMD-partitioning) HLO text and sum the
operand sizes of every collective op — all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (+ their async -start
forms).  The HLO is the per-device SPMD program, so sums here are
*per-device* bytes; multiply by the partition count for fleet totals.

XLA prints collective operands by %name only (no inline shapes), so parsing
is two-pass: build a symbol table of instruction result shapes, then resolve
each collective's operand names against it.

Relation to the paper (PAPER.md): this parser is how the repo turns the
paper's bandwidth cost W (§3, Theorems 2/3) from a model into an
*assertion* — tests compile Alg. 1/2 (§4.2, §5.3) and the streaming update
step (repro.stream) and check the summed collective operand bytes equal the
closed forms in ``core/grid.py`` exactly (zero in regime 1).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

# collective op kinds we account, normalized (async -start folded in)
_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute", "ragged-all-to-all")

# definition site:  %name = <type> op(...)   where <type> is a shape or tuple
_DEF = re.compile(
    r"(%[\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\(")

_SHAPE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,\s]*)\]")

_OPERAND = re.compile(r"%[\w.\-]+")

_GROUPS_EXPLICIT = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

# collective-permute routing: source_target_pairs={{0,1},{1,2},...}
_ST_PAIRS = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")
_ST_PAIR = re.compile(r"\{(\d+),(\d+)\}")

#: collective kinds that implement a LAYOUT CHANGE (each device sends its
#: shard to a different owner) rather than a reduction/broadcast — the
#: §5.2 Redistribute of the fused two-grid path is emitted as these.
REDISTRIBUTE_KINDS = ("collective-permute", "all-to-all",
                      "ragged-all-to-all")


def _group_size(line: str):
    """Participants per replica group of a collective (None if unknown)."""
    m = _GROUPS_EXPLICIT.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_IOTA.search(line)
    if m:  # [G,S]<=[N]: G groups of size S
        return int(m.group(2))
    return None


def _permute_pairs(line: str):
    """(moving, identity) source->target pair counts of a
    collective-permute, or None when the attribute is absent."""
    m = _ST_PAIRS.search(line)
    if m is None:
        return None
    moving = identity = 0
    for src, dst in _ST_PAIR.findall(m.group(1)):
        if src == dst:
            identity += 1
        else:
            moving += 1
    return moving, identity


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    dims = dims.strip()
    if dims:
        for d in dims.split(","):
            d = d.strip()
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _type_bytes(type_text: str) -> float:
    """Bytes of a shape or tuple-of-shapes type string."""
    return sum(_shape_bytes(d, s) for d, s in _SHAPE.findall(type_text))


def _operand_span(text: str) -> str:
    """The operand list of an op call: text up to the matching close-paren."""
    depth = 1
    for i, ch in enumerate(text):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return text[:i]
    return text


@dataclass
class CollectiveBytes:
    """Per-device collective traffic of one compiled HLO module.

    ``permute_pairs`` / ``permute_identity_pairs`` classify the
    collective-permute routing tables: moving (src != dst) vs identity
    pairs summed over all counted permutes.  Permutes whose routing table
    is entirely identity pairs move nothing and are skipped outright (like
    group-size-1 collectives) — the partitioner emits them as layout
    no-ops and counting their operand would overstate the traffic.
    """
    by_kind: Dict[str, float] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)
    num_partitions: int = 1
    permute_pairs: int = 0
    permute_identity_pairs: int = 0

    @property
    def total(self) -> float:
        """Per-device bytes summed over all collective ops."""
        return float(sum(self.by_kind.values()))

    @property
    def fleet_total(self) -> float:
        """Across all participating devices."""
        return self.total * self.num_partitions

    @property
    def redistribute_total(self) -> float:
        """Per-device bytes of the layout-change collectives
        (collective-permute + all-to-all + ragged-all-to-all) — the §5.2
        Redistribute traffic of the fused two-grid path, separated from
        the reduction/broadcast collectives of the Alg.-1/2 stages."""
        return float(sum(self.by_kind.get(k, 0.0)
                         for k in REDISTRIBUTE_KINDS))

    def __repr__(self):
        kinds = ", ".join(f"{k}:{v:.4g}B x{self.counts.get(k, 0)}"
                          for k, v in sorted(self.by_kind.items()))
        return (f"CollectiveBytes(per_device_total={self.total:.6g}, "
                f"partitions={self.num_partitions}, {kinds or 'none'})")


def collective_bytes_of(hlo_text: str) -> CollectiveBytes:
    out = CollectiveBytes()
    m = re.search(r"num_partitions\s*=\s*(\d+)", hlo_text)
    if m:
        out.num_partitions = int(m.group(1))

    # pass 1: symbol table  %name -> result bytes
    sizes: Dict[str, float] = {}
    pending = []  # (kind, operand names, def line) for pass 2
    for line in hlo_text.splitlines():
        dm = _DEF.search(line)
        if not dm:
            continue
        name, type_text, op = dm.group(1), dm.group(2), dm.group(3)
        sizes[name] = _type_bytes(type_text)
        base = op[:-6] if op.endswith("-start") else op
        if base in _KINDS and not op.endswith("-done"):
            if _group_size(line) == 1:
                continue  # degenerate collective: no traffic
            pairs = None
            if base == "collective-permute":
                pairs = _permute_pairs(line)
                if pairs is not None and pairs[0] == 0:
                    continue  # identity-only routing: a layout no-op
            rest = line[dm.end():]
            operands = _OPERAND.findall(_operand_span(rest))
            pending.append((base, operands, type_text, pairs))

    # pass 2: resolve operand sizes
    for kind, operands, type_text, pairs in pending:
        nbytes = sum(sizes.get(o, 0.0) for o in operands)
        if nbytes == 0.0:
            # fall back to result size (conservative, e.g. params as operands)
            nbytes = _type_bytes(type_text)
        out.by_kind[kind] = out.by_kind.get(kind, 0.0) + nbytes
        out.counts[kind] = out.counts.get(kind, 0) + 1
        if pairs is not None:
            out.permute_pairs += pairs[0]
            out.permute_identity_pairs += pairs[1]
    return out


def op_histogram(hlo_text: str, ops=("fusion", "dot", "convolution",
                                     "transpose", "reshape", "copy",
                                     "dynamic-slice", "scatter")) -> Dict[str, int]:
    """Rough HLO op histogram — used in the perf loop to spot layout
    mismatches (transpose/copy storms) and remat recompute."""
    hist: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        dm = _DEF.search(line)
        if not dm:
            continue
        op = dm.group(3)
        if op in ops:
            hist[op] = hist.get(op, 0) + 1
    return hist
