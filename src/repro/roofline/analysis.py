"""Three-term roofline model for TPU v5e from compiled (AOT) artifacts.

    compute term    = HLO_FLOPs        / (chips * peak_FLOP/s)
    memory term     = HLO_bytes        / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (per-device SPMD
program — multiplied by chip count for the global figures), collective bytes
from the HLO-text parser in :mod:`repro.roofline.hlo`.

Relation to the paper (PAPER.md): the collective term is the W of the
paper's α-β model (§3) measured on real compiled programs; the tests use it
to assert Alg. 1 (§4.2) moves exactly its modeled bytes and zero in the
Theorem-2 regime-1 range, and that streaming updates (repro.stream) add no
Omega/Psi traffic.  The memory term plays the same role for the Pallas
kernel path: ``kernels/sketch_matmul.py`` removes the n2·r Omega stream
from HBM exactly as §6.3's regeneration removes it from the network.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, asdict, field
from typing import Dict, Optional

from .hlo import collective_bytes_of, op_histogram
from . import hlo_cost

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_LINK_BW = 50e9            # bytes/s per link (spec constant)


@dataclass
class RooflineTerms:
    name: str
    chips: int
    # global (fleet) quantities — trip-count-corrected HLO walk
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    # derived times (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    # usefulness
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None
    # raw (uncorrected) cost_analysis numbers, for reference: XLA counts
    # while bodies once, so these undercount scanned models by ~L x.
    raw_flops: Optional[float] = None
    raw_bytes: Optional[float] = None
    # extras
    per_device_peak_memory: Optional[float] = None
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    notes: str = ""

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step that is *useful* compute at peak, under the
        max-of-terms execution model: (model_flops/peak/chips) / t_bound."""
        if not self.model_flops or self.t_bound <= 0:
            return 0.0
        ideal = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        return ideal / self.t_bound

    def to_dict(self):
        d = asdict(self)
        d["t_bound"] = self.t_bound
        d["roofline_fraction"] = self.roofline_fraction
        return d


def analyze_compiled(name: str, compiled, chips: int,
                     model_flops: Optional[float] = None,
                     hlo_text: Optional[str] = None,
                     notes: str = "") -> RooflineTerms:
    """Build roofline terms from a ``jax.stages.Compiled`` artifact.

    FLOPs/bytes/collective-bytes come from the trip-count-corrected HLO walk
    (``hlo_cost.analyze``); raw ``cost_analysis()`` numbers (which count
    while bodies once) are recorded alongside."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):           # older jax returns [dict]
        ca = ca[0]
    raw_flops_dev = float(ca.get("flops", 0.0))
    raw_bytes_dev = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = hlo_cost.analyze(text)

    flops = hc.flops * chips
    mem_bytes = hc.hbm_bytes * chips
    coll_bytes = hc.collective_bytes * chips    # sum of operand sizes

    t_c = flops / (chips * PEAK_FLOPS_BF16)
    t_m = mem_bytes / (chips * HBM_BW)
    t_l = coll_bytes / (chips * ICI_LINK_BW)
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    bottleneck = max(terms, key=terms.get)

    peak_mem = None
    try:
        ma = compiled.memory_analysis()
        peak_mem = (getattr(ma, "temp_size_in_bytes", 0)
                    + getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0)
                    - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass

    return RooflineTerms(
        name=name, chips=chips,
        hlo_flops=flops, hlo_bytes=mem_bytes, collective_bytes=coll_bytes,
        t_compute=t_c, t_memory=t_m, t_collective=t_l,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / flops) if (model_flops and flops) else None,
        raw_flops=raw_flops_dev * chips,
        raw_bytes=raw_bytes_dev * chips,
        per_device_peak_memory=peak_mem,
        collective_counts=dict(hc.collective_counts),
        collective_by_kind={k: v * chips
                            for k, v in hc.collective_by_kind.items()},
        notes=notes,
    )


def format_table(rows, keys=("name", "chips", "hlo_flops", "hlo_bytes",
                             "collective_bytes", "t_compute", "t_memory",
                             "t_collective", "bottleneck", "useful_ratio",
                             "roofline_fraction")) -> str:
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.3e}" if (abs(v) >= 1e4 or 0 < abs(v) < 1e-3) else f"{v:.4f}"
        return str(v)
    dicts = [r.to_dict() if hasattr(r, "to_dict") else dict(r) for r in rows]
    widths = {k: max(len(k), *(len(fmt(d.get(k, ""))) for d in dicts))
              for k in keys}
    head = " | ".join(k.ljust(widths[k]) for k in keys)
    sep = "-+-".join("-" * widths[k] for k in keys)
    body = "\n".join(" | ".join(fmt(d.get(k, "")).ljust(widths[k]) for k in keys)
                     for d in dicts)
    return f"{head}\n{sep}\n{body}"


def save_json(rows, path: str):
    data = [r.to_dict() if hasattr(r, "to_dict") else dict(r) for r in rows]
    with open(path, "w") as f:
        json.dump(data, f, indent=2, default=str)


__all__ = ["RooflineTerms", "analyze_compiled", "collective_bytes_of",
           "op_histogram", "format_table", "save_json",
           "PEAK_FLOPS_BF16", "HBM_BW", "ICI_LINK_BW"]
