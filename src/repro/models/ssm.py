"""State-space sequence layers: Mamba-1 (diagonal selective scan) and
Mamba-2 (SSD, chunked scalar-decay form).

Both use a chunked formulation: the sequence is processed in chunks with an
O(1)-size carried state, so the (B, S, d_inner, N) tensor of a naive
associative scan never materializes — necessary for the 4k-train and
32k-prefill cells (d_inner up to 8192).  The channel/head dimension is
sharded over the model axis (Mamba TP).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ShardCtx, NULL_CTX, dense_init, matmul, rmsnorm


# ---------------------------------------------------------------------------
# causal depthwise conv1d
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, b, state=None):
    """x: (B, S, C); w: (K, C) depthwise; left-causal.
    If ``state`` (B, K-1, C) is given, it is prepended (decode/chunk carry);
    returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else state
    return out + b[None, None, :], new_state


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------

class Mamba1Params(NamedTuple):
    in_proj: jnp.ndarray    # (d, 2*dI)
    conv_w: jnp.ndarray     # (K, dI)
    conv_b: jnp.ndarray     # (dI,)
    x_proj: jnp.ndarray     # (dI, dt_rank + 2N)
    dt_proj: jnp.ndarray    # (dt_rank, dI)
    dt_bias: jnp.ndarray    # (dI,)
    A_log: jnp.ndarray      # (dI, N)
    D: jnp.ndarray          # (dI,)
    out_proj: jnp.ndarray   # (dI, d)


def mamba1_init(key, d: int, d_inner: int, d_state: int, dt_rank: int,
                d_conv: int, dtype) -> Mamba1Params:
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return Mamba1Params(
        in_proj=dense_init(ks[0], d, 2 * d_inner, dtype),
        conv_w=(jax.random.normal(ks[1], (d_conv, d_inner), jnp.float32)
                / math.sqrt(d_conv)).astype(dtype),
        conv_b=jnp.zeros((d_inner,), dtype),
        x_proj=dense_init(ks[2], d_inner, dt_rank + 2 * d_state, dtype),
        dt_proj=dense_init(ks[3], dt_rank, d_inner, dtype),
        dt_bias=jnp.full((d_inner,), -4.6, jnp.float32),  # softplus^-1(0.01)
        A_log=jnp.log(A),
        D=jnp.ones((d_inner,), jnp.float32),
        out_proj=dense_init(ks[4], d_inner, d, dtype,
                            scale=1.0 / math.sqrt(d_inner)),
    )


def _scan_chunk_diag(h0, a, bx):
    """h_t = a_t * h_{t-1} + bx_t within one chunk via associative scan.
    a, bx: (B, c, C, N) f32; h0: (B, C, N). Returns (h_all, h_last)."""
    def comb(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2
    A_, Bv = jax.lax.associative_scan(comb, (a, bx), axis=1)
    h = Bv + A_ * h0[:, None]
    return h, h[:, -1]


def mamba1(params: Mamba1Params, x, *, d_state: int, dt_rank: int,
           chunk: int = 256, ctx: ShardCtx = NULL_CTX,
           conv_state=None, ssm_state=None, return_state: bool = False):
    """Mamba-1 block. x: (B, S, d) -> (B, S, d).

    For decode, pass S=1 with ``conv_state``/``ssm_state`` and
    ``return_state=True``.
    """
    B, S, d = x.shape
    dI = params.conv_w.shape[1]
    N = d_state

    xz = matmul(x, params.in_proj)
    xs, z = jnp.split(xz, 2, axis=-1)
    if ctx.mesh is not None:
        xs = ctx.constrain(xs, P(ctx.data, None, ctx.model))
        z = ctx.constrain(z, P(ctx.data, None, ctx.model))
    xs, new_conv_state = causal_conv1d(xs, params.conv_w, params.conv_b,
                                       conv_state)
    xs = jax.nn.silu(xs)

    dbc = matmul(xs, params.x_proj)
    dt_r = dbc[..., :dt_rank]
    Bm = dbc[..., dt_rank:dt_rank + N].astype(jnp.float32)        # (B,S,N)
    Cm = dbc[..., dt_rank + N:].astype(jnp.float32)               # (B,S,N)
    dt = jax.nn.softplus(
        matmul(dt_r, params.dt_proj).astype(jnp.float32)
        + params.dt_bias)                                          # (B,S,dI)
    A = -jnp.exp(params.A_log)                                     # (dI,N)
    xf = xs.astype(jnp.float32)

    nc = max(1, S // chunk)
    c = S // nc
    assert nc * c == S, (S, chunk)

    def chunk_step(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * c, c, axis=1)
        dt_c, B_c, C_c, x_c = sl(dt), sl(Bm), sl(Cm), sl(xf)
        a = jnp.exp(dt_c[..., None] * A[None, None])               # (B,c,dI,N)
        bx = (dt_c * x_c)[..., None] * B_c[:, :, None, :]          # (B,c,dI,N)
        h_all, h_last = _scan_chunk_diag(h, a, bx)
        y_c = jnp.einsum("bcdn,bcn->bcd", h_all, C_c)
        return h_last, y_c

    h0 = (ssm_state if ssm_state is not None
          else jnp.zeros((B, dI, N), jnp.float32))
    h_last, ys = jax.lax.scan(chunk_step, h0, jnp.arange(nc))
    y = ys.swapaxes(0, 1).reshape(B, S, dI)
    y = y + params.D[None, None] * xf
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = matmul(y, params.out_proj)
    out = ctx.act_btd(out)
    if return_state:
        return out, new_conv_state, h_last
    return out


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------

class Mamba2Params(NamedTuple):
    in_proj: jnp.ndarray    # (d, 2*dI + 2N + H)
    conv_w: jnp.ndarray     # (K, dI + 2N)
    conv_b: jnp.ndarray     # (dI + 2N,)
    A_log: jnp.ndarray      # (H,)
    D: jnp.ndarray          # (H,)
    dt_bias: jnp.ndarray    # (H,)
    norm_scale: jnp.ndarray # (dI,)
    out_proj: jnp.ndarray   # (dI, d)


def mamba2_init(key, d: int, d_inner: int, d_state: int, n_heads: int,
                d_conv: int, dtype) -> Mamba2Params:
    ks = jax.random.split(key, 3)
    conv_dim = d_inner + 2 * d_state
    return Mamba2Params(
        in_proj=dense_init(ks[0], d, 2 * d_inner + 2 * d_state + n_heads,
                           dtype),
        conv_w=(jax.random.normal(ks[1], (d_conv, conv_dim), jnp.float32)
                / math.sqrt(d_conv)).astype(dtype),
        conv_b=jnp.zeros((conv_dim,), dtype),
        A_log=jnp.log(jnp.linspace(1.0, 16.0, n_heads)),
        D=jnp.ones((n_heads,), jnp.float32),
        dt_bias=jnp.full((n_heads,), -4.6, jnp.float32),
        norm_scale=jnp.zeros((d_inner,), dtype),
        out_proj=dense_init(ks[2], d_inner, d, dtype,
                            scale=1.0 / math.sqrt(d_inner)),
    )


def mamba2(params: Mamba2Params, x, *, d_state: int, n_heads: int,
           chunk: int = 256, ctx: ShardCtx = NULL_CTX,
           conv_state=None, ssm_state=None, return_state: bool = False):
    """Mamba-2 / SSD block (scalar per-head decay, n_groups=1).

    x: (B, S, d) -> (B, S, d).  Chunked: intra-chunk is an attention-like
    (c x c) masked product per head; inter-chunk passes the (Pd x N) state.
    """
    B, S, d = x.shape
    H = n_heads
    N = d_state
    dI = params.out_proj.shape[0]
    Pd = dI // H                                        # head dim

    zxbcdt = matmul(x, params.in_proj)
    z = zxbcdt[..., :dI]
    xbc = zxbcdt[..., dI:dI + dI + 2 * N]
    dt_in = zxbcdt[..., -H:].astype(jnp.float32)
    if ctx.mesh is not None:
        z = ctx.constrain(z, P(ctx.data, None, ctx.model))
    xbc, new_conv_state = causal_conv1d(xbc, params.conv_w, params.conv_b,
                                        conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :dI]
    Bm = xbc[..., dI:dI + N].astype(jnp.float32)        # (B,S,N)
    Cm = xbc[..., dI + N:].astype(jnp.float32)          # (B,S,N)

    dt = jax.nn.softplus(dt_in + params.dt_bias)        # (B,S,H)
    A = -jnp.exp(params.A_log)                          # (H,)
    xh = xs.astype(jnp.float32).reshape(B, S, H, Pd)

    nc = max(1, S // chunk)
    c = S // nc
    assert nc * c == S, (S, chunk)

    def chunk_step(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * c, c, axis=1)
        dt_c, B_c, C_c, x_c = sl(dt), sl(Bm), sl(Cm), sl(xh)
        a = dt_c * A[None, None]                         # (B,c,H) log-decay
        cum = jnp.cumsum(a, axis=1)                      # (B,c,H)
        # intra-chunk: y_t += sum_{tau<=t} exp(cum_t - cum_tau) dt_tau
        #              (C_t . B_tau) x_tau
        Lmask = jnp.tril(jnp.ones((c, c), bool))
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # (B,t,s,H)
        decay = jnp.where(Lmask[None, :, :, None], decay, 0.0)
        cb = jnp.einsum("btn,bsn->bts", C_c, B_c)                  # (B,t,s)
        w = cb[..., None] * decay * dt_c[:, None, :, :]            # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", w, x_c)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", C_c, h,
                             jnp.exp(cum))
        # state update: h' = exp(cum_c) h + sum_tau exp(cum_c - cum_tau)
        #               dt_tau B_tau (x) x_tau
        tail = jnp.exp(cum[:, -1:, :] - cum) * dt_c                # (B,c,H)
        dh = jnp.einsum("bsh,bsn,bshp->bhpn", tail, B_c, x_c)
        h_new = jnp.exp(cum[:, -1])[:, :, None, None] * h + dh
        return h_new, y_intra + y_inter

    h0 = (ssm_state if ssm_state is not None
          else jnp.zeros((B, H, Pd, N), jnp.float32))
    h_last, ys = jax.lax.scan(chunk_step, h0, jnp.arange(nc))
    y = ys.swapaxes(0, 1).reshape(B, S, H, Pd)
    y = y + params.D[None, None, :, None] * xh
    y = y.reshape(B, S, dI).astype(x.dtype)
    # gated RMSNorm then out-projection
    y = rmsnorm({"scale": params.norm_scale}, y * jax.nn.silu(z))
    out = matmul(y, params.out_proj)
    out = ctx.act_btd(out)
    if return_state:
        return out, new_conv_state, h_last
    return out
