"""Attention-free Mamba-1 LM (falcon-mamba family)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import (NULL_CTX, ShardCtx, cross_entropy_chunked, embed_init,
                     matmul, rmsnorm, rmsnorm_init)
from .ssm import Mamba1Params, mamba1, mamba1_init


def mamba_lm_init(key, cfg: ModelConfig):
    dtype = cfg.jnp_dtype
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = [{
        "mamba": mamba1_init(keys[i], cfg.d_model, cfg.d_inner,
                             cfg.ssm_state, cfg.dt_rank, cfg.d_conv,
                             dtype)._asdict(),
        "ln": rmsnorm_init(cfg.d_model, dtype),
    } for i in range(cfg.n_layers)]
    return {
        "embed": embed_init(keys[-1], cfg.vocab, cfg.d_model, dtype),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "ln_final": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": embed_init(keys[-2], cfg.vocab, cfg.d_model, dtype),
    }


def mamba_lm_hidden(params, cfg: ModelConfig, tokens, *,
                    ctx: ShardCtx = NULL_CTX, remat: bool = True):
    h = params["embed"][tokens]
    h = ctx.act_btd(h)

    def body(h, blk):
        x = rmsnorm(blk["ln"], h, cfg.norm_eps)
        y = mamba1(Mamba1Params(**blk["mamba"]), x, d_state=cfg.ssm_state,
                   dt_rank=cfg.dt_rank, chunk=cfg.ssm_chunk, ctx=ctx)
        return h + y, None

    body_fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body_fn, h, params["blocks"])
    return rmsnorm(params["ln_final"], h, cfg.norm_eps)


def mamba_lm_loss(params, cfg: ModelConfig, batch, *,
                  ctx: ShardCtx = NULL_CTX, remat: bool = True):
    h = mamba_lm_hidden(params, cfg, batch["tokens"], ctx=ctx, remat=remat)
    logits_fn = lambda hc: matmul(hc, params["lm_head"].T)
    return cross_entropy_chunked(logits_fn, h, batch["labels"], cfg.vocab,
                                 chunk=cfg.loss_chunk, ctx=ctx)


def mamba_lm_init_cache(cfg: ModelConfig, batch: int, max_len: int = 0,
                        dtype=None) -> Dict[str, Any]:
    """SSM decode state is O(1) in sequence length — max_len is ignored
    (that is the whole point of the long_500k cell for this family)."""
    dtype = dtype or cfg.jnp_dtype
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1,
                           cfg.d_inner), dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, cfg.d_inner, cfg.ssm_state),
                         jnp.float32),
    }


def mamba_lm_decode_step(params, cfg: ModelConfig, token, cache, pos, *,
                         ctx: ShardCtx = NULL_CTX):
    """Position-independent O(1) decode (pos kept for API uniformity)."""
    del pos
    h = params["embed"][token]
    h = ctx.act_btd(h)

    def body(h, xs):
        blk, conv_s, ssm_s = xs
        x = rmsnorm(blk["ln"], h, cfg.norm_eps)
        y, cs, ss = mamba1(Mamba1Params(**blk["mamba"]), x,
                           d_state=cfg.ssm_state, dt_rank=cfg.dt_rank,
                           chunk=1, ctx=ctx, conv_state=conv_s,
                           ssm_state=ssm_s, return_state=True)
        return h + y, (cs, ss)

    h, (new_conv, new_ssm) = jax.lax.scan(
        body, h, (params["blocks"], cache["conv"], cache["ssm"]))
    h = rmsnorm(params["ln_final"], h, cfg.norm_eps)
    logits = matmul(h, params["lm_head"].T)
    return ctx.logits(logits), {"conv": new_conv, "ssm": new_ssm}
