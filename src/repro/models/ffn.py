"""Feed-forward layers: gated-GLU dense FFN and top-k MoE with
capacity-based dispatch (einsum form — expert axis shardable for EP)."""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ShardCtx, NULL_CTX, dense_init, matmul


# ---------------------------------------------------------------------------
# dense gated FFN (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

class FFNParams(NamedTuple):
    w_gate: jnp.ndarray   # (d, f)
    w_up: jnp.ndarray     # (d, f)
    w_down: jnp.ndarray   # (f, d)


def ffn_init(key, d: int, f: int, dtype) -> FFNParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return FFNParams(
        w_gate=dense_init(k1, d, f, dtype),
        w_up=dense_init(k2, d, f, dtype),
        w_down=dense_init(k3, f, d, dtype, scale=1.0 / math.sqrt(f)),
    )


def ffn(params: FFNParams, x, activation: str = "silu",
        ctx: ShardCtx = NULL_CTX):
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    g = matmul(x, params.w_gate)
    u = matmul(x, params.w_up)
    h = act(g) * u
    h = ctx.act_btf(h)
    return ctx.act_btd(matmul(h, params.w_down))


# plain 2-layer MLP (whisper)
class MLPParams(NamedTuple):
    w1: jnp.ndarray
    b1: jnp.ndarray
    w2: jnp.ndarray
    b2: jnp.ndarray


def mlp_init(key, d: int, f: int, dtype) -> MLPParams:
    k1, k2 = jax.random.split(key)
    return MLPParams(
        w1=dense_init(k1, d, f, dtype), b1=jnp.zeros((f,), dtype),
        w2=dense_init(k2, f, d, dtype, scale=1.0 / math.sqrt(f)),
        b2=jnp.zeros((d,), dtype))


def mlp(params: MLPParams, x, ctx: ShardCtx = NULL_CTX):
    h = jax.nn.gelu(matmul(x, params.w1) + params.b1.astype(x.dtype))
    h = ctx.act_btf(h)
    return ctx.act_btd(matmul(h, params.w2) + params.b2.astype(x.dtype))


# ---------------------------------------------------------------------------
# top-k MoE with capacity-based dispatch (GShard/Switch einsum form)
# ---------------------------------------------------------------------------

class MoEParams(NamedTuple):
    router: jnp.ndarray    # (d, E)
    w_gate: jnp.ndarray    # (E, d, f)
    w_up: jnp.ndarray      # (E, d, f)
    w_down: jnp.ndarray    # (E, f, d)


def moe_init(key, d: int, f: int, n_experts: int, dtype) -> MoEParams:
    k0, k1, k2, k3 = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d)
    scd = 1.0 / math.sqrt(f)
    return MoEParams(
        router=dense_init(k0, d, n_experts, jnp.float32),  # router in f32
        w_gate=(jax.random.normal(k1, (n_experts, d, f), jnp.float32) * sc).astype(dtype),
        w_up=(jax.random.normal(k2, (n_experts, d, f), jnp.float32) * sc).astype(dtype),
        w_down=(jax.random.normal(k3, (n_experts, f, d), jnp.float32) * scd).astype(dtype),
    )


def moe(params: MoEParams, x, *, top_k: int, capacity_factor: float = 1.25,
        ctx: ShardCtx = NULL_CTX, return_aux: bool = False,
        dispatch: str = "scatter"):
    """Token-choice top-k routing with per-expert capacity.

    x: (B, S, d) -> (B, S, d).  Two dispatch paths:

      * ``scatter`` (default, beyond-paper optimized): tokens are scattered
        into the (E, cap, d) expert buffers and gathered back — O(N·k·d)
        data movement, no token-count-quadratic FLOPs.
      * ``einsum`` (GShard-style baseline): one-hot dispatch/combine
        einsums — O(N·E·cap·d) FLOPs, which at 1M-token batches dominates
        the entire step (see EXPERIMENTS.md §Perf, dbrx hillclimb).

    The expert (E) axis shards over the model/EP mesh axis in both paths.
    Tokens overflowing an expert's capacity are dropped (standard
    capacity-based semantics); the aux loss balances load to keep drops low.
    """
    B, S, d = x.shape
    E = params.router.shape[1]
    N = B * S
    xt = x.reshape(N, d)

    logits = jnp.asarray(xt, jnp.float32) @ params.router          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)              # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    if dispatch == "scatter":
        # ---- grouped gather/scatter dispatch.  Tokens are grouped by DP
        # shard (G groups); capacity/positions are PER (group, expert)
        # bucket, so the scatter/gather are shard-local and the only
        # cross-device movement is the canonical EP all-to-all when the
        # (G, E, cap, d) buffer re-shards from G@data to E@model.
        G = ctx.data_size if N % max(ctx.data_size, 1) == 0 else 1
        n_loc = N // G
        NK = n_loc * top_k
        cap = max(1, int(capacity_factor * top_k * n_loc / E))
        idx_g = gate_idx.reshape(G, NK)                            # (G,NK)
        oh = jax.nn.one_hot(idx_g, E, dtype=jnp.int32)             # (G,NK,E)
        pos = jnp.cumsum(oh, axis=1) - oh
        pos = (pos * oh).sum(-1)                                   # (G,NK)
        keep = pos < cap
        p_flat = jnp.where(keep, pos, cap)
        tok_id = jnp.repeat(jnp.arange(n_loc), top_k)              # (NK,)
        xg = xt.reshape(G, n_loc, d)

        # vmap over groups => gather/scatter carry explicit batch dims that
        # GSPMD partitions trivially along the (data-sharded) G axis
        def disp_one(xg_g, idx_1, p_1):
            buf = jnp.zeros((E, cap + 1, d), x.dtype)
            return buf.at[idx_1, p_1].set(xg_g[tok_id], mode="drop")

        xe = jax.vmap(disp_one)(xg, idx_g, p_flat)[:, :, :cap]
        if ctx.mesh is not None:
            # groups over DATA axes, experts over the MODEL axis (EP)
            xe = ctx.constrain(xe, P(ctx.data, ctx.model, None, None))
        gg = jnp.einsum("gecd,edf->gecf", xe, params.w_gate,
                        preferred_element_type=jnp.float32).astype(x.dtype)
        uu = jnp.einsum("gecd,edf->gecf", xe, params.w_up,
                        preferred_element_type=jnp.float32).astype(x.dtype)
        h = jax.nn.silu(gg) * uu
        ye = jnp.einsum("gecf,efd->gecd", h, params.w_down,
                        preferred_element_type=jnp.float32).astype(x.dtype)
        if ctx.mesh is not None:
            # bring each group's expert outputs home in ONE collective (an
            # all-gather over the model axis); the element gather below is
            # then shard-local.  Leaving ye expert-sharded makes XLA emit
            # per-element masked all-reduces of the full (G,NK,d) tensor —
            # the 686s-collective pathology of §Perf round 3.
            ye = ctx.constrain(ye, P(ctx.data, None, None, None))
        # gather combine: y_n = sum_k gate_{nk} * ye[g, e_{nk}, p_{nk}]
        w = (gate_vals.reshape(G, NK) * keep).astype(x.dtype)
        p_safe = jnp.where(keep, pos, 0)

        def comb_one(ye_g, idx_1, p_1, w_1):
            picked = ye_g[idx_1, p_1]                              # (NK, d)
            buf = jnp.zeros((n_loc, d), jnp.float32)
            return buf.at[tok_id].add(
                (picked * w_1[:, None]).astype(jnp.float32))

        y = jax.vmap(comb_one)(ye, idx_g, p_safe, w)
        y = y.astype(x.dtype).reshape(B, S, d)
        onehot = oh.reshape(N, top_k, E)
    else:
        # ---- GShard-style one-hot einsum dispatch (baseline)
        cap = max(1, int(capacity_factor * top_k * N / E))
        onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)      # (N,k,E)
        flat = onehot.reshape(N * top_k, E)
        pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(
            N, top_k, E)
        pos_in_expert = (pos_in_expert * onehot).sum(-1)           # (N, k)
        keep = pos_in_expert < cap
        disp = jnp.einsum(
            "nke,nkc->nec",
            jax.nn.one_hot(gate_idx, E, dtype=x.dtype)
            * keep[..., None].astype(x.dtype),
            jax.nn.one_hot(pos_in_expert, cap, dtype=x.dtype))
        xe = jnp.einsum("nd,nec->ecd", xt, disp)                   # (E,cap,d)
        if ctx.mesh is not None:
            xe = ctx.constrain(xe, P(ctx.model, ctx.data, None))
        g = jnp.einsum("ecd,edf->ecf", xe, params.w_gate,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        u = jnp.einsum("ecd,edf->ecf", xe, params.w_up,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        h = jax.nn.silu(g) * u
        ye = jnp.einsum("ecf,efd->ecd", h, params.w_down,
                        preferred_element_type=jnp.float32).astype(x.dtype)
        if ctx.mesh is not None:
            ye = ctx.constrain(ye, P(ctx.model, ctx.data, None))
        comb = jnp.einsum(
            "nke,nkc,nk->nec",
            jax.nn.one_hot(gate_idx, E, dtype=jnp.float32),
            jax.nn.one_hot(pos_in_expert, cap, dtype=jnp.float32),
            gate_vals * keep.astype(jnp.float32)).astype(x.dtype)
        y = jnp.einsum("ecd,nec->nd", ye, comb).reshape(B, S, d)
    y = ctx.act_btd(y)

    if return_aux:
        # Switch-style load-balancing loss
        me = probs.mean(0)                                          # (E,)
        ce = (onehot.sum(1) > 0).astype(jnp.float32).mean(0)        # (E,)
        aux = E * jnp.sum(me * ce)
        return y, aux
    return y
