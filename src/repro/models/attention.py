"""Attention: GQA/MQA/MHA with chunked (flash-style) softmax, sliding
windows, gemma-2 softcaps, KV-cache decode, and Nyström landmark attention
(the paper's two-product structure applied to the attention kernel matrix).

The chunked implementation scans over KV chunks with an online softmax, so
the (S x S) score matrix never materializes — required for the 32k-prefill
dry-run cells to fit HBM.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import ShardCtx, NULL_CTX, dense_init, matmul, apply_rope


class AttnParams(NamedTuple):
    wq: jnp.ndarray   # (d, Hq*D)
    wk: jnp.ndarray   # (d, Hk*D)
    wv: jnp.ndarray   # (d, Hk*D)
    wo: jnp.ndarray   # (Hq*D, d)


def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int,
              head_dim: int, dtype) -> AttnParams:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return AttnParams(
        wq=dense_init(k1, d_model, n_heads * head_dim, dtype),
        wk=dense_init(k2, d_model, n_kv_heads * head_dim, dtype),
        wv=dense_init(k3, d_model, n_kv_heads * head_dim, dtype),
        wo=dense_init(k4, n_heads * head_dim, d_model,
                      dtype, scale=1.0 / math.sqrt(n_heads * head_dim)),
    )


# ---------------------------------------------------------------------------
# chunked softmax attention core
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, q_pos, k_pos, *, causal: bool = True,
                      window=None, attn_softcap: float = 0.0,
                      kv_chunk: int = 1024, scale: Optional[float] = None,
                      remat_chunks: bool = True):
    """Online-softmax attention.

    q: (B, S, Hk, G, D) — grouped query heads; k, v: (B, T, Hk, D).
    q_pos: (S,), k_pos: (T,) absolute positions for masking.
    window: None for full attention, or a python/traced int — key j is
    visible to query i iff  0 <= pos_i - pos_j < window  (plus causality).
    Returns (B, S, Hk, G, D).
    """
    B, S, Hk, G, D = q.shape
    T = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    kv_chunk = min(kv_chunk, T)
    n_chunks = (T + kv_chunk - 1) // kv_chunk
    Tp = n_chunks * kv_chunk
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, Tp - T), constant_values=jnp.iinfo(jnp.int32).max // 2)

    kc = k.reshape(B, n_chunks, kv_chunk, Hk, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, Hk, D).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, kv_chunk)

    # HBM-traffic optimization (EXPERIMENTS.md §Perf): for bf16 models the
    # (B,S,H,G,c) score/probability tensors — the dominant HBM traffic of
    # this lowering — are STORED in bf16 (softmax statistics m/l and the
    # output accumulator stay f32).  f32 inputs keep the exact f32 path.
    store_dt = jnp.bfloat16 if q.dtype == jnp.bfloat16 else jnp.float32
    qf = (q.astype(jnp.float32) * scale).astype(store_dt)

    NEG = jnp.float32(-3e9)      # additive mask bias; see note below

    def step(carry, xs):
        m, l, acc = carry                     # m,l: (B,S,Hk,G); acc: +D
        k_c, v_c, p_c = xs                    # (B,c,Hk,D), (B,c,Hk,D), (c,)
        s = jnp.einsum("bshgd,bchd->bshgc", qf, k_c.astype(store_dt),
                       preferred_element_type=store_dt)
        sf = s.astype(jnp.float32)
        if attn_softcap:
            sf = jnp.tanh(sf / attn_softcap) * attn_softcap
        mask = jnp.ones((S, kv_chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= p_c[None, :]
        if window is not None:
            dist = q_pos[:, None] - p_c[None, :]
            mask &= (dist < window) & (dist >= 0 if not causal else True)
        # masking as an ADDITIVE bias folded into the exp: masked entries
        # get s-3e9 while m_safe is clamped to >= -1e9, so exp underflows
        # to exactly 0 — no score-sized where/select passes (two fewer
        # full-tensor HBM streams per chunk than the where() formulation).
        bias = jnp.where(mask, 0.0, NEG)[None, :, None, None, :]
        sf = sf + bias
        m_new = jnp.maximum(m, sf.max(axis=-1))
        m_safe = jnp.maximum(m_new, -1e9)
        p = jnp.exp(sf - m_safe[..., None])
        corr = jnp.exp(m - m_safe)            # m0 = -inf -> corr = 0
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bshgc,bchd->bshgd", p.astype(store_dt), v_c.astype(store_dt),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, Hk, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, S, Hk, G), jnp.float32)
    a0 = jnp.zeros((B, S, Hk, G, D), jnp.float32)
    # remat each kv-chunk step: without it, AD saves the per-chunk f32
    # score/probability tensors stacked over chunks — the single largest
    # HBM stream of the train lowering (EXPERIMENTS.md §Perf, llama3).
    step_fn = jax.checkpoint(step) if remat_chunks else step
    (m, l, acc), _ = jax.lax.scan(step_fn, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# full attention layer (train / prefill)
# ---------------------------------------------------------------------------

def attention(params: AttnParams, x, *, n_heads: int, n_kv_heads: int,
              head_dim: int, positions=None, causal: bool = True,
              window=None, attn_softcap: float = 0.0,
              rope_theta: float = 1e4, use_rope: bool = True,
              kv_chunk: int = 1024, ctx: ShardCtx = NULL_CTX,
              xkv=None, kv_positions=None):
    """Standard attention layer over (B, S, d). ``xkv`` enables
    cross-attention (keys/values from the encoder stream)."""
    B, S, d = x.shape
    Hq, Hk, D = n_heads, n_kv_heads, head_dim
    G = Hq // Hk
    src = x if xkv is None else xkv
    T = src.shape[1]
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if kv_positions is None:
        kv_positions = (positions if xkv is None
                        else jnp.arange(T, dtype=jnp.int32))

    q = matmul(x, params.wq).reshape(B, S, Hk, G, D)
    k = matmul(src, params.wk).reshape(B, T, Hk, D)
    v = matmul(src, params.wv).reshape(B, T, Hk, D)
    if use_rope:
        qr = q.reshape(B, S, Hk * G, D)
        qr = apply_rope(qr, positions[None, :], rope_theta)
        q = qr.reshape(B, S, Hk, G, D)
        k = apply_rope(k, kv_positions[None, :], rope_theta)
    if ctx.mesh is not None:
        # kv-heads over the model axis; grouped q heads follow their kv head
        q = ctx.constrain(q, jax.sharding.PartitionSpec(
            ctx.data, None, ctx.model, None, None))
        k = ctx.act_bthd(k)
        v = ctx.act_bthd(v)

    out = chunked_attention(q, k, v, positions, kv_positions, causal=causal,
                            window=window, attn_softcap=attn_softcap,
                            kv_chunk=kv_chunk)
    out = out.reshape(B, S, Hq * D)
    y = matmul(out, params.wo)
    return ctx.act_btd(y)


# ---------------------------------------------------------------------------
# decode step against a KV cache
# ---------------------------------------------------------------------------

def attention_decode(params: AttnParams, x, cache_k, cache_v, pos, *,
                     n_heads: int, n_kv_heads: int, head_dim: int,
                     window=None, attn_softcap: float = 0.0,
                     rope_theta: float = 1e4, use_rope: bool = True,
                     ctx: ShardCtx = NULL_CTX):
    """One-token decode. x: (B, 1, d); cache_k/v: (B, T, Hk, D) with a ring
    layout when ``window`` is set (cache length == window).  ``pos``:
    scalar int32, absolute position of the new token.
    Returns (y, new_cache_k, new_cache_v)."""
    B, _, d = x.shape
    Hq, Hk, D = n_heads, n_kv_heads, head_dim
    G = Hq // Hk
    T = cache_k.shape[1]

    q = matmul(x, params.wq).reshape(B, 1, Hk, G, D)
    k = matmul(x, params.wk).reshape(B, 1, Hk, D)
    v = matmul(x, params.wv).reshape(B, 1, Hk, D)
    posv = jnp.full((1,), pos, jnp.int32)
    if use_rope:
        qr = apply_rope(q.reshape(B, 1, Hq, D), posv[None, :], rope_theta)
        q = qr.reshape(B, 1, Hk, G, D)
        k = apply_rope(k, posv[None, :], rope_theta)

    slot = pos % T if window is not None else pos
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))

    # absolute positions stored in each cache slot
    idx = jnp.arange(T, dtype=jnp.int32)
    if window is not None:
        # ring: slot i holds position  i + T*floor((pos-i)/T) pattern;
        # equivalently the largest value <= pos congruent to i mod T
        k_pos = pos - ((pos - idx) % T)
    else:
        k_pos = idx
    valid = (k_pos <= pos) & (k_pos >= 0)
    if window is not None:
        valid &= (pos - k_pos) < window

    qf = q.astype(jnp.float32) / math.sqrt(D)
    s = jnp.einsum("bshgd,bchd->bshgc", qf, cache_k.astype(jnp.float32))
    if attn_softcap:
        s = jnp.tanh(s / attn_softcap) * attn_softcap
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bshgc,bchd->bshgd", p, cache_v.astype(jnp.float32))
    out = out.reshape(B, 1, Hq * D).astype(x.dtype)
    y = matmul(out, params.wo)
    return ctx.act_btd(y), cache_k, cache_v


# ---------------------------------------------------------------------------
# Nyström landmark attention (paper technique -> sub-quadratic attention)
# ---------------------------------------------------------------------------

def nystrom_attention(params: AttnParams, x, *, n_heads: int,
                      n_kv_heads: int, head_dim: int, n_landmarks: int = 64,
                      rope_theta: float = 1e4, use_rope: bool = True,
                      ctx: ShardCtx = NULL_CTX, pinv_iters: int = 6):
    """Nyströmformer-style attention: the softmax kernel matrix
    K = softmax(QK^T) is approximated as  F · A† · Bm  — structurally the
    paper's Nyström pair (two sketched products + a small core inverse),
    with landmark means playing the role of the sketch.  O(S·m) time/memory.

    Non-causal (used for the hybrid arch's shared attention blocks on
    long-context cells; see DESIGN.md §Arch-applicability)."""
    B, S, d = x.shape
    Hq, Hk, D = n_heads, n_kv_heads, head_dim
    G = Hq // Hk
    m = min(n_landmarks, S)
    assert S % m == 0, (S, m)

    q = matmul(x, params.wq).reshape(B, S, Hq, D)
    k = matmul(x, params.wk).reshape(B, S, Hk, D)
    v = matmul(x, params.wv).reshape(B, S, Hk, D)
    if use_rope:
        pos = jnp.arange(S, dtype=jnp.int32)
        q = apply_rope(q, pos[None, :], rope_theta)
        k = apply_rope(k, pos[None, :], rope_theta)
    # expand kv heads to query heads
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)

    qf = q.astype(jnp.float32) / math.sqrt(D)
    kf = k.astype(jnp.float32)
    # landmarks: segment means (sketching Q and K with a fixed averaging
    # matrix — a structured Omega)
    q_l = qf.reshape(B, m, S // m, Hq, D).mean(axis=2)
    k_l = kf.reshape(B, m, S // m, Hq, D).mean(axis=2)

    F = jax.nn.softmax(jnp.einsum("bshd,bmhd->bhsm", qf, k_l), axis=-1)
    A = jax.nn.softmax(jnp.einsum("bmhd,bnhd->bhmn", q_l, k_l), axis=-1)
    Bm = jax.nn.softmax(jnp.einsum("bmhd,bshd->bhms", q_l, kf), axis=-1)

    # iterative Moore-Penrose pseudoinverse of the (m x m) core
    I = jnp.eye(m, dtype=jnp.float32)
    a1 = A.sum(-1).max(-1)[..., None, None]
    a2 = A.sum(-2).max(-1)[..., None, None]
    Z = A.swapaxes(-1, -2) / (a1 * a2)
    def mp(Z, _):
        AZ = A @ Z
        Z = 0.25 * Z @ (13 * I - AZ @ (15 * I - AZ @ (7 * I - AZ)))
        return Z, None
    Z, _ = jax.lax.scan(mp, Z, None, length=pinv_iters)

    out = F @ Z @ jnp.einsum("bhms,bshd->bhmd", Bm, v.astype(jnp.float32))
    # out: (B, H, S, D)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, Hq * D).astype(x.dtype)
    y = matmul(out, params.wo)
    return ctx.act_btd(y)
