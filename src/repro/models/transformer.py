"""Decoder-only LM covering the dense and MoE families (llama3, internlm2,
h2o-danube3, gemma2, granite-moe, dbrx) plus the text backbone of the VLM.

Train/prefill run the layer stack under ``jax.lax.scan`` over stacked
per-layer params (bounded HLO for 48-layer models) with optional remat;
decode unrolls a Python loop over layers so heterogeneous per-layer caches
(ring buffers for local layers, full caches for global layers) stay exact.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from .attention import (AttnParams, attn_init, attention, attention_decode,
                        nystrom_attention)
from .common import (NULL_CTX, ShardCtx, apply_rope, cross_entropy_chunked,
                     embed_init, matmul, rmsnorm, rmsnorm_init, layernorm,
                     layernorm_init, softcap)
from .ffn import FFNParams, MoEParams, ffn, ffn_init, moe, moe_init

FULL_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_init(cfg: ModelConfig, d: int, dtype):
    return (rmsnorm_init(d, dtype) if cfg.norm == "rmsnorm"
            else layernorm_init(d, dtype))


def _norm_apply(cfg: ModelConfig, p, x):
    return (rmsnorm(p, x, cfg.norm_eps) if cfg.norm == "rmsnorm"
            else layernorm(p, x, cfg.norm_eps))


def _block_init(key, cfg: ModelConfig):
    dtype = cfg.jnp_dtype
    k1, k2 = jax.random.split(key)
    blk: Dict[str, Any] = {
        "attn": attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, dtype)._asdict(),
        "ln_attn": _norm_init(cfg, cfg.d_model, dtype),
        "ln_ffn": _norm_init(cfg, cfg.d_model, dtype),
    }
    if cfg.use_post_norms:
        blk["ln_attn_post"] = _norm_init(cfg, cfg.d_model, dtype)
        blk["ln_ffn_post"] = _norm_init(cfg, cfg.d_model, dtype)
    if cfg.n_experts:
        blk["moe"] = moe_init(k2, cfg.d_model, cfg.d_ff, cfg.n_experts,
                              dtype)._asdict()
    else:
        blk["ffn"] = ffn_init(k2, cfg.d_model, cfg.d_ff, dtype)._asdict()
    return blk


def lm_init(key, cfg: ModelConfig):
    dtype = cfg.jnp_dtype
    keys = jax.random.split(key, cfg.n_layers + 3)
    blocks = [_block_init(keys[i], cfg) for i in range(cfg.n_layers)]
    # stack per-layer params along leading L axis for scan
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    params = {
        "embed": embed_init(keys[-1], cfg.vocab, cfg.d_model, dtype),
        "blocks": stacked,
        "ln_final": _norm_init(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[-2], cfg.vocab, cfg.d_model,
                                       dtype)
    if cfg.frontend != "none":
        # modality projector (frontend itself is a stub per assignment)
        params["projector"] = {
            "w": embed_init(keys[-3], cfg.frontend_dim, cfg.d_model, dtype),
            "ln": _norm_init(cfg, cfg.d_model, dtype),
        }
    return params


def param_sharding_rules(cfg: ModelConfig, mesh, data_axes, model_axis):
    """NamedSharding pytree for the params (used by jit in_shardings)."""
    def spec_for(path: str, x):
        d = {
            "embed": P(model_axis, None),
            "lm_head": P(model_axis, None),
            "wq": P(None, None, model_axis),
            "wk": P(None, None, model_axis),
            "wv": P(None, None, model_axis),
            "wo": P(None, model_axis, None),
            "w_gate": P(None, None, model_axis),
            "w_up": P(None, None, model_axis),
            "w_down": P(None, model_axis, None),
            "router": P(None, None, None),
        }
        return d.get(path.split("/")[-1])
    return spec_for  # resolved fully in parallel/sharding.py


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_tokens(params, cfg: ModelConfig, tokens, ctx: ShardCtx):
    h = params["embed"][tokens]                         # gather (B,S,d)
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    return ctx.act_btd(h)


def _project_frontend(params, cfg: ModelConfig, feats, ctx: ShardCtx):
    p = params["projector"]
    h = matmul(feats.astype(cfg.jnp_dtype), p["w"])
    return _norm_apply(cfg, p["ln"], h)


def _block_apply(cfg: ModelConfig, blk, h, *, window, positions,
                 ctx: ShardCtx, kv_chunk: int, use_nystrom: bool = False):
    attn_p = AttnParams(**blk["attn"])
    a_in = _norm_apply(cfg, blk["ln_attn"], h)
    if use_nystrom:
        a = nystrom_attention(attn_p, a_in, n_heads=cfg.n_heads,
                              n_kv_heads=cfg.n_kv_heads,
                              head_dim=cfg.head_dim,
                              n_landmarks=cfg.nystrom_landmarks,
                              rope_theta=cfg.rope_theta, ctx=ctx)
    else:
        a = attention(attn_p, a_in, n_heads=cfg.n_heads,
                      n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                      positions=positions, causal=True, window=window,
                      attn_softcap=cfg.attn_softcap,
                      rope_theta=cfg.rope_theta, kv_chunk=kv_chunk, ctx=ctx)
    if cfg.use_post_norms:
        a = _norm_apply(cfg, blk["ln_attn_post"], a)
    h = h + a

    f_in = _norm_apply(cfg, blk["ln_ffn"], h)
    aux = jnp.float32(0)
    if cfg.n_experts:
        f, aux = moe(MoEParams(**blk["moe"]), f_in, top_k=cfg.top_k,
                     capacity_factor=cfg.capacity_factor, ctx=ctx,
                     return_aux=True, dispatch=cfg.moe_dispatch)
    else:
        f = ffn(FFNParams(**blk["ffn"]), f_in, activation=cfg.activation,
                ctx=ctx)
    if cfg.use_post_norms:
        f = _norm_apply(cfg, blk["ln_ffn_post"], f)
    return h + f, aux


def lm_hidden(params, cfg: ModelConfig, tokens, *, ctx: ShardCtx = NULL_CTX,
              frontend_feats=None, remat: bool = True,
              kv_chunk: int = 1024):
    """Token ids (+ optional frontend features, prepended) -> final hidden.

    Returns (h, aux_loss)."""
    B, S_tok = tokens.shape
    h = _embed_tokens(params, cfg, tokens, ctx)
    if frontend_feats is not None:
        fe = _project_frontend(params, cfg, frontend_feats, ctx)
        h = jnp.concatenate([fe, h], axis=1)
        h = ctx.act_btd(h)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    windows = jnp.asarray(cfg.layer_windows(S), jnp.int32)
    use_nystrom = bool(cfg.nystrom_attn_above) and S >= cfg.nystrom_attn_above

    def body(carry, xs):
        h, aux = carry
        blk, window_l = xs
        h, a = _block_apply(cfg, blk, h, window=window_l,
                            positions=positions, ctx=ctx, kv_chunk=kv_chunk,
                            use_nystrom=use_nystrom)
        return (h, aux + a), None

    body_fn = jax.checkpoint(body) if remat else body
    (h, aux), _ = jax.lax.scan(body_fn, (h, jnp.float32(0)),
                               (params["blocks"], windows))
    h = _norm_apply(cfg, params["ln_final"], h)
    return h, aux


def _lm_head_weight(params, cfg: ModelConfig):
    return (params["embed"] if cfg.tie_embeddings else params["lm_head"])


def lm_loss(params, cfg: ModelConfig, batch, *, ctx: ShardCtx = NULL_CTX,
            remat: bool = True):
    """batch: {"tokens": (B,S), "labels": (B,S)} (+ "frontend_feats")."""
    h, aux = lm_hidden(params, cfg, batch["tokens"], ctx=ctx,
                       frontend_feats=batch.get("frontend_feats"),
                       remat=remat)
    labels = batch["labels"]
    if h.shape[1] != labels.shape[1]:   # frontend tokens prepended: no loss
        pad = h.shape[1] - labels.shape[1]
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], pad), -100, labels.dtype), labels],
            axis=1)
    W = _lm_head_weight(params, cfg)
    logits_fn = lambda hc: matmul(hc, W.T)
    nll = cross_entropy_chunked(logits_fn, h, labels, cfg.vocab,
                                chunk=cfg.loss_chunk,
                                final_softcap=cfg.final_softcap, ctx=ctx)
    return nll + cfg.router_aux_weight * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode with per-layer caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> List[Dict[str, Any]]:
    """Per-layer KV caches; local (windowed) layers get ring buffers."""
    dtype = dtype or cfg.jnp_dtype
    caches = []
    for w in cfg.layer_windows(max_len):
        L = min(w, max_len)
        caches.append({
            "k": jnp.zeros((batch, L, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, L, cfg.n_kv_heads, cfg.head_dim), dtype),
        })
    return caches


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """ShapeDtypeStruct pytree of ``init_cache`` (dry-run input specs)."""
    dtype = dtype or cfg.jnp_dtype
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def decode_step(params, cfg: ModelConfig, token, caches, pos, *,
                ctx: ShardCtx = NULL_CTX):
    """One decode step. token: (B, 1) int32; pos: scalar int32 (absolute).

    Returns (logits (B, 1, vocab), new_caches). Python-unrolled over layers
    so windowed ring caches and full caches coexist."""
    h = _embed_tokens(params, cfg, token, ctx)
    windows = cfg.layer_windows(FULL_WINDOW)
    new_caches = []
    for l in range(cfg.n_layers):
        blk = jax.tree.map(lambda a: a[l], params["blocks"])
        attn_p = AttnParams(**blk["attn"])
        a_in = _norm_apply(cfg, blk["ln_attn"], h)
        w = windows[l]
        a, ck, cv = attention_decode(
            attn_p, a_in, caches[l]["k"], caches[l]["v"], pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            window=(w if w < FULL_WINDOW else None),
            attn_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            ctx=ctx)
        new_caches.append({"k": ck, "v": cv})
        if cfg.use_post_norms:
            a = _norm_apply(cfg, blk["ln_attn_post"], a)
        h = h + a
        f_in = _norm_apply(cfg, blk["ln_ffn"], h)
        if cfg.n_experts:
            f = moe(MoEParams(**blk["moe"]), f_in, top_k=cfg.top_k,
                    capacity_factor=cfg.capacity_factor, ctx=ctx,
                    dispatch=cfg.moe_dispatch)
        else:
            f = ffn(FFNParams(**blk["ffn"]), f_in,
                    activation=cfg.activation, ctx=ctx)
        if cfg.use_post_norms:
            f = _norm_apply(cfg, blk["ln_ffn_post"], f)
        h = h + f
    h = _norm_apply(cfg, params["ln_final"], h)
    logits = matmul(h, _lm_head_weight(params, cfg).T)
    logits = softcap(logits, cfg.final_softcap)
    return ctx.logits(logits), new_caches


def prefill(params, cfg: ModelConfig, tokens, *, ctx: ShardCtx = NULL_CTX,
            remat: bool = True, kv_chunk: int = 1024,
            max_len: Optional[int] = None):
    """Process a full prompt; returns (last-position logits, caches).

    The cache is built by re-projecting K/V per layer (scan output), then
    re-laid out into the per-layer list used by decode: full layers pad to
    ``max_len`` (slot == absolute position); windowed layers become ring
    buffers (slot == position mod ring length)."""
    B, S = tokens.shape
    max_len = max_len or S
    h = _embed_tokens(params, cfg, tokens, ctx)
    positions = jnp.arange(S, dtype=jnp.int32)
    windows = jnp.asarray(cfg.layer_windows(S), jnp.int32)

    def body(carry, xs):
        h, aux = carry
        blk, window_l = xs
        a_in = _norm_apply(cfg, blk["ln_attn"], h)
        attn_p = AttnParams(**blk["attn"])
        k = matmul(a_in, attn_p.wk).reshape(B, S, cfg.n_kv_heads,
                                            cfg.head_dim)
        v = matmul(a_in, attn_p.wv).reshape(B, S, cfg.n_kv_heads,
                                            cfg.head_dim)
        k = apply_rope(k, positions[None, :], cfg.rope_theta)
        h, a = _block_apply(cfg, blk, h, window=window_l,
                            positions=positions, ctx=ctx, kv_chunk=kv_chunk)
        return (h, aux + a), (k, v)

    body_fn = jax.checkpoint(body) if remat else body
    (h, _), (ks, vs) = jax.lax.scan(body_fn, (h, jnp.float32(0)),
                                    (params["blocks"], windows))
    h = _norm_apply(cfg, params["ln_final"], h)
    logits = matmul(h[:, -1:], _lm_head_weight(params, cfg).T)
    logits = softcap(logits, cfg.final_softcap)

    caches = []
    for l, w in enumerate(cfg.layer_windows(S)):
        L = min(w, max_len)
        k_l, v_l = ks[l], vs[l]
        if L >= S:
            # slot == absolute position; pad tail for future tokens
            pad = ((0, 0), (0, L - S), (0, 0), (0, 0))
            caches.append({"k": jnp.pad(k_l, pad), "v": jnp.pad(v_l, pad)})
        else:
            # ring: keep last L positions, place position p at slot p % L
            caches.append({"k": jnp.roll(k_l[:, -L:], S, axis=1),
                           "v": jnp.roll(v_l[:, -L:], S, axis=1)})
    return ctx.logits(logits), caches
