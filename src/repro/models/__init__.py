"""Model zoo: all assigned architecture families in raw JAX."""
from . import (api, attention, common, ffn, mamba_lm, ssm, transformer,
               whisper, zamba)  # noqa: F401
from .api import ModelAPI, get_api, input_specs, model_flops  # noqa: F401
from .common import ShardCtx, NULL_CTX, count_params  # noqa: F401
