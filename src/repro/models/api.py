"""Uniform model API across families + ShapeDtypeStruct input specs.

Every family exposes:
  init(key, cfg) -> params
  loss(params, cfg, batch, ctx, remat) -> scalar
  init_cache(cfg, batch, max_len) -> cache pytree
  decode_step(params, cfg, token, cache, pos, ctx) -> (logits, cache)
  (dense/moe/vlm also expose prefill)

``input_specs(cfg, shape)`` returns the ShapeDtypeStruct stand-ins for every
model input of that (arch x shape) cell — weak-type-correct, shardable, no
device allocation — exactly what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from . import mamba_lm, transformer, whisper, zamba
from .common import NULL_CTX


@dataclass(frozen=True)
class ModelAPI:
    init: Callable
    loss: Callable
    init_cache: Callable
    decode_step: Callable
    prefill: Optional[Callable] = None


def _vlm_loss(params, cfg, batch, *, ctx=NULL_CTX, remat=True):
    return transformer.lm_loss(params, cfg, batch, ctx=ctx, remat=remat)


_FAMILIES: Dict[str, ModelAPI] = {
    "dense": ModelAPI(transformer.lm_init, transformer.lm_loss,
                      transformer.init_cache, transformer.decode_step,
                      transformer.prefill),
    "moe": ModelAPI(transformer.lm_init, transformer.lm_loss,
                    transformer.init_cache, transformer.decode_step,
                    transformer.prefill),
    "vlm": ModelAPI(transformer.lm_init, _vlm_loss,
                    transformer.init_cache, transformer.decode_step,
                    transformer.prefill),
    "ssm": ModelAPI(mamba_lm.mamba_lm_init, mamba_lm.mamba_lm_loss,
                    mamba_lm.mamba_lm_init_cache,
                    mamba_lm.mamba_lm_decode_step),
    "hybrid": ModelAPI(zamba.hybrid_init, zamba.hybrid_loss,
                       zamba.hybrid_init_cache, zamba.hybrid_decode_step),
    "encdec": ModelAPI(whisper.encdec_init, whisper.encdec_loss,
                       whisper.encdec_init_cache, whisper.encdec_decode_step),
}


def get_api(cfg: ModelConfig) -> ModelAPI:
    return _FAMILIES[cfg.family]


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell.

    train/prefill: the token batch (+ stub frontend features);
    decode: one new token + the KV/state cache at seq_len + position.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            nf = cfg.num_frontend_tokens
            batch = {
                "tokens": _sds((B, S - nf), i32),
                "labels": _sds((B, S - nf), i32),
                "frontend_feats": _sds((B, nf, cfg.frontend_dim),
                                       jnp.float32),
            }
        elif cfg.family == "encdec":
            batch = {
                "frames": _sds((B, cfg.enc_seq, cfg.d_model), jnp.float32),
                "tokens": _sds((B, S), i32),
                "labels": _sds((B, S), i32),
            }
        else:
            batch = {"tokens": _sds((B, S), i32),
                     "labels": _sds((B, S), i32)}
        if shape.kind == "prefill":
            batch.pop("labels", None)
        return batch

    # decode: one token against a cache of length S
    api = get_api(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(cfg, B, S))
    return {
        "token": _sds((B, 1), i32),
        "cache": cache,
        "pos": _sds((), i32),
    }


def model_flops(cfg: ModelConfig, shape: ShapeConfig,
                n_params: Optional[int] = None,
                n_active_params: Optional[int] = None) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for train;
    2·N·D for inference-type shapes (forward only)."""
    N = n_active_params or n_params or 0
    D = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * N * D


def count_params_split(cfg: ModelConfig, params_shapes):
    """(total, expert) param counts from a shape pytree (no allocation)."""
    total = 0
    expert = 0
    flat = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    for path, leaf in flat:
        name = "/".join(str(p) for p in path)
        sz = 1
        for s in leaf.shape:
            sz *= int(s)
        if cfg.n_experts and "moe" in name and any(
                w in name for w in ("w_gate", "w_up", "w_down")):
            expert += sz
        else:
            total += sz
    return total + expert, expert


def count_active_params(cfg: ModelConfig, params_shapes) -> int:
    """Active params per token: MoE experts count at top_k/E weight."""
    total, expert = count_params_split(cfg, params_shapes)
    if cfg.n_experts:
        return int(total - expert + expert * cfg.top_k / cfg.n_experts)
    return int(total)
