"""Whisper-style encoder-decoder (audio backbone only; the conv/mel
frontend is a stub per the assignment — ``input_specs`` supplies precomputed
frame embeddings (B, enc_seq, d_model))."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import AttnParams, attn_init, attention, attention_decode
from .common import (NULL_CTX, ShardCtx, cross_entropy_chunked, embed_init,
                     layernorm, layernorm_init, matmul)
from .ffn import MLPParams, mlp, mlp_init


def _enc_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    d = cfg.jnp_dtype
    return {
        "attn": attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, d)._asdict(),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, d)._asdict(),
        "ln1": layernorm_init(cfg.d_model, d),
        "ln2": layernorm_init(cfg.d_model, d),
    }


def _dec_block_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.jnp_dtype
    return {
        "self_attn": attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, d)._asdict(),
        "cross_attn": attn_init(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, d)._asdict(),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, d)._asdict(),
        "ln1": layernorm_init(cfg.d_model, d),
        "ln2": layernorm_init(cfg.d_model, d),
        "ln3": layernorm_init(cfg.d_model, d),
    }


def encdec_init(key, cfg: ModelConfig):
    d = cfg.jnp_dtype
    keys = jax.random.split(key, cfg.n_enc_layers + cfg.n_layers + 5)
    enc = [_enc_block_init(keys[i], cfg) for i in range(cfg.n_enc_layers)]
    dec = [_dec_block_init(keys[cfg.n_enc_layers + i], cfg)
           for i in range(cfg.n_layers)]
    return {
        "enc_pos": embed_init(keys[-1], cfg.enc_seq, cfg.d_model, d),
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "enc_ln": layernorm_init(cfg.d_model, d),
        "embed": embed_init(keys[-2], cfg.vocab, cfg.d_model, d),
        "dec_pos": embed_init(keys[-3], max(cfg.max_pos, 4096), cfg.d_model, d),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "dec_ln": layernorm_init(cfg.d_model, d),
    }


def encode(params, cfg: ModelConfig, frames, *, ctx: ShardCtx = NULL_CTX,
           remat: bool = True):
    """frames: (B, enc_seq, d_model) precomputed conv-frontend output."""
    T = frames.shape[1]
    h = frames.astype(cfg.jnp_dtype) + params["enc_pos"][None, :T]
    h = ctx.act_btd(h)

    def body(h, blk):
        a = attention(AttnParams(**blk["attn"]),
                      layernorm(blk["ln1"], h, cfg.norm_eps),
                      n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                      head_dim=cfg.head_dim, causal=False, use_rope=False,
                      ctx=ctx)
        h = h + a
        f = mlp(MLPParams(**blk["mlp"]),
                layernorm(blk["ln2"], h, cfg.norm_eps), ctx=ctx)
        return h + f, None

    body_fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body_fn, h, params["enc_blocks"])
    return layernorm(params["enc_ln"], h, cfg.norm_eps)


def decode_hidden(params, cfg: ModelConfig, tokens, enc_out, *,
                  ctx: ShardCtx = NULL_CTX, remat: bool = True):
    B, S = tokens.shape
    h = params["embed"][tokens] + params["dec_pos"][None, :S]
    h = ctx.act_btd(h)

    def body(h, blk):
        a = attention(AttnParams(**blk["self_attn"]),
                      layernorm(blk["ln1"], h, cfg.norm_eps),
                      n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                      head_dim=cfg.head_dim, causal=True, use_rope=False,
                      ctx=ctx)
        h = h + a
        c = attention(AttnParams(**blk["cross_attn"]),
                      layernorm(blk["ln2"], h, cfg.norm_eps),
                      n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                      head_dim=cfg.head_dim, causal=False, use_rope=False,
                      xkv=enc_out, ctx=ctx)
        h = h + c
        f = mlp(MLPParams(**blk["mlp"]),
                layernorm(blk["ln3"], h, cfg.norm_eps), ctx=ctx)
        return h + f, None

    body_fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body_fn, h, params["dec_blocks"])
    return layernorm(params["dec_ln"], h, cfg.norm_eps)


def encdec_loss(params, cfg: ModelConfig, batch, *,
                ctx: ShardCtx = NULL_CTX, remat: bool = True):
    """batch: {"frames": (B,T,d), "tokens": (B,S), "labels": (B,S)}."""
    enc_out = encode(params, cfg, batch["frames"], ctx=ctx, remat=remat)
    h = decode_hidden(params, cfg, batch["tokens"], enc_out, ctx=ctx,
                      remat=remat)
    logits_fn = lambda hc: matmul(hc, params["embed"].T)
    return cross_entropy_chunked(logits_fn, h, batch["labels"], cfg.vocab,
                                 chunk=cfg.loss_chunk, ctx=ctx)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=None) -> Dict[str, Any]:
    d = dtype or cfg.jnp_dtype
    L = cfg.n_layers
    return {
        "self_k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads,
                             cfg.head_dim), d),
        "self_v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads,
                             cfg.head_dim), d),
        # cross-attention K/V computed once from enc_out at prefill
        "cross_k": jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv_heads,
                              cfg.head_dim), d),
        "cross_v": jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv_heads,
                              cfg.head_dim), d),
    }


def encdec_prepare_cross(params, cfg: ModelConfig, enc_out):
    """Precompute per-layer cross-attention K/V from encoder output."""
    B, T, _ = enc_out.shape
    ks, vs = [], []
    for l in range(cfg.n_layers):
        blk = jax.tree.map(lambda a: a[l], params["dec_blocks"])
        ap = AttnParams(**blk["cross_attn"])
        ks.append(matmul(enc_out, ap.wk).reshape(B, T, cfg.n_kv_heads,
                                                 cfg.head_dim))
        vs.append(matmul(enc_out, ap.wv).reshape(B, T, cfg.n_kv_heads,
                                                 cfg.head_dim))
    return jnp.stack(ks), jnp.stack(vs)


def encdec_decode_step(params, cfg: ModelConfig, token, cache, pos, *,
                       ctx: ShardCtx = NULL_CTX):
    import math as _m
    B = token.shape[0]
    pos_emb = jnp.take(params["dec_pos"],
                       jnp.full((1,), pos, jnp.int32), axis=0)
    h = params["embed"][token] + pos_emb[None]
    h = ctx.act_btd(h)
    sk, sv = cache["self_k"], cache["self_v"]
    for l in range(cfg.n_layers):
        blk = jax.tree.map(lambda a: a[l], params["dec_blocks"])
        a, ck, cv = attention_decode(
            AttnParams(**blk["self_attn"]),
            layernorm(blk["ln1"], h, cfg.norm_eps), sk[l], sv[l], pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, use_rope=False, ctx=ctx)
        sk = sk.at[l].set(ck)
        sv = sv.at[l].set(cv)
        h = h + a
        # cross-attn against fixed K/V
        q_in = layernorm(blk["ln2"], h, cfg.norm_eps)
        ap = AttnParams(**blk["cross_attn"])
        Hq, Hk, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        G = Hq // Hk
        q = matmul(q_in, ap.wq).reshape(B, 1, Hk, G, D)
        s = jnp.einsum("bshgd,bchd->bshgc",
                       q.astype(jnp.float32) / _m.sqrt(D),
                       cache["cross_k"][l].astype(jnp.float32))
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bshgc,bchd->bshgd", p,
                       cache["cross_v"][l].astype(jnp.float32))
        o = o.reshape(B, 1, Hq * D).astype(h.dtype)
        h = h + matmul(o, ap.wo)
        f = mlp(MLPParams(**blk["mlp"]),
                layernorm(blk["ln3"], h, cfg.norm_eps), ctx=ctx)
        h = h + f
    h = layernorm(params["dec_ln"], h, cfg.norm_eps)
    logits = matmul(h, params["embed"].T)
    new_cache = dict(cache, self_k=sk, self_v=sv)
    return ctx.logits(logits), new_cache
