"""Zamba2-style hybrid: a Mamba-2 backbone with ONE shared
attention+FFN block applied every ``shared_attn_every`` layers (weight
sharing across applications, as in Zamba/Zamba2).

Long-context note (paper tie-in): when ``cfg.nystrom_attn_above`` is set and
the sequence is long, the shared block's softmax attention is replaced by
Nyström landmark attention — the paper's two-product sketch structure — so
the hybrid arch stays sub-quadratic on the long_500k cell.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import (AttnParams, attn_init, attention, attention_decode,
                        nystrom_attention)
from .common import (NULL_CTX, ShardCtx, cross_entropy_chunked, embed_init,
                     matmul, rmsnorm, rmsnorm_init)
from .ffn import FFNParams, ffn, ffn_init
from .ssm import (Mamba2Params, mamba2, mamba2_init)

FULL_WINDOW = 1 << 30


def _shared_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    dtype = cfg.jnp_dtype
    return {
        "attn": attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, dtype)._asdict(),
        "ffn": ffn_init(k2, cfg.d_model, cfg.d_ff, dtype)._asdict(),
        "ln_attn": rmsnorm_init(cfg.d_model, dtype),
        "ln_ffn": rmsnorm_init(cfg.d_model, dtype),
    }


def hybrid_init(key, cfg: ModelConfig):
    dtype = cfg.jnp_dtype
    keys = jax.random.split(key, cfg.n_layers + 4)
    blocks = []
    for i in range(cfg.n_layers):
        blocks.append({
            "mamba": mamba2_init(keys[i], cfg.d_model, cfg.d_inner,
                                 cfg.ssm_state, cfg.ssm_heads, cfg.d_conv,
                                 dtype)._asdict(),
            "ln": rmsnorm_init(cfg.d_model, dtype),
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return {
        "embed": embed_init(keys[-1], cfg.vocab, cfg.d_model, dtype),
        "blocks": stacked,
        "shared": _shared_block_init(keys[-2], cfg),
        "ln_final": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": embed_init(keys[-3], cfg.vocab, cfg.d_model, dtype),
    }


def _apply_shared(params, cfg: ModelConfig, h, *, ctx: ShardCtx,
                  use_nystrom: bool, kv_chunk: int = 1024):
    sb = params["shared"]
    attn_p = AttnParams(**sb["attn"])
    a_in = rmsnorm(sb["ln_attn"], h, cfg.norm_eps)
    if use_nystrom:
        a = nystrom_attention(attn_p, a_in, n_heads=cfg.n_heads,
                              n_kv_heads=cfg.n_kv_heads,
                              head_dim=cfg.head_dim,
                              n_landmarks=cfg.nystrom_landmarks,
                              rope_theta=cfg.rope_theta, ctx=ctx)
    else:
        a = attention(attn_p, a_in, n_heads=cfg.n_heads,
                      n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                      causal=True, rope_theta=cfg.rope_theta,
                      kv_chunk=kv_chunk, ctx=ctx)
    h = h + a
    f = ffn(FFNParams(**sb["ffn"]), rmsnorm(sb["ln_ffn"], h, cfg.norm_eps),
            ctx=ctx)
    return h + f


def _mamba_segment(params, cfg: ModelConfig, h, lo: int, hi: int,
                   ctx: ShardCtx, remat: bool):
    """Scan mamba layers [lo, hi) over the stacked params."""
    seg = jax.tree.map(lambda a: a[lo:hi], params["blocks"])

    def body(h, blk):
        x = rmsnorm(blk["ln"], h, cfg.norm_eps)
        y = mamba2(Mamba2Params(**blk["mamba"]), x, d_state=cfg.ssm_state,
                   n_heads=cfg.ssm_heads, chunk=cfg.ssm_chunk, ctx=ctx)
        return h + y, None

    body_fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body_fn, h, seg)
    return h


def hybrid_hidden(params, cfg: ModelConfig, tokens, *,
                  ctx: ShardCtx = NULL_CTX, remat: bool = True):
    h = params["embed"][tokens]
    h = ctx.act_btd(h)
    S = h.shape[1]
    use_ny = bool(cfg.nystrom_attn_above) and S >= cfg.nystrom_attn_above
    every = cfg.shared_attn_every or (cfg.n_layers + 1)
    lo = 0
    while lo < cfg.n_layers:
        hi = min(lo + every, cfg.n_layers)
        h = _mamba_segment(params, cfg, h, lo, hi, ctx, remat)
        if hi < cfg.n_layers or cfg.n_layers % every == 0:
            h = _apply_shared(params, cfg, h, ctx=ctx, use_nystrom=use_ny)
        lo = hi
    return rmsnorm(params["ln_final"], h, cfg.norm_eps)


def hybrid_loss(params, cfg: ModelConfig, batch, *,
                ctx: ShardCtx = NULL_CTX, remat: bool = True):
    h = hybrid_hidden(params, cfg, batch["tokens"], ctx=ctx, remat=remat)
    logits_fn = lambda hc: matmul(hc, params["lm_head"].T)
    return cross_entropy_chunked(logits_fn, h, batch["labels"], cfg.vocab,
                                 chunk=cfg.loss_chunk, ctx=ctx)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def hybrid_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=None) -> Dict[str, Any]:
    """SSM states are stacked (scanned homogeneously); the shared-attention
    KV caches are a per-application LIST — a stacked (n_shared, B, T, H, D)
    array forces full-cache dynamic-update-slices on every decode step
    (2 x 2.1 GB x 6 of pure copy traffic at 500k context; §Perf round 1 of
    the zamba hillclimb), while list entries update in place."""
    dtype = dtype or cfg.jnp_dtype
    Pd = cfg.d_inner // cfg.ssm_heads
    n_shared = _n_shared_applications(cfg)
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads, Pd,
                          cfg.ssm_state), jnp.float32),
        "shared": [
            {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads,
                             cfg.head_dim), dtype),
             "v": jnp.zeros((batch, max_len, cfg.n_kv_heads,
                             cfg.head_dim), dtype)}
            for _ in range(n_shared)],
    }


def _n_shared_applications(cfg: ModelConfig) -> int:
    every = cfg.shared_attn_every or (cfg.n_layers + 1)
    n = 0
    lo = 0
    while lo < cfg.n_layers:
        hi = min(lo + every, cfg.n_layers)
        if hi < cfg.n_layers or cfg.n_layers % every == 0:
            n += 1
        lo = hi
    return n


def hybrid_decode_step(params, cfg: ModelConfig, token, cache, pos, *,
                       ctx: ShardCtx = NULL_CTX):
    """One-token decode: SSM layers update O(1) state; shared attention
    blocks append to their (per-application) KV caches."""
    h = params["embed"][token]
    h = ctx.act_btd(h)
    every = cfg.shared_attn_every or (cfg.n_layers + 1)
    new_conv, new_ssm = [], []
    new_shared = []
    s_idx = 0
    lo = 0
    while lo < cfg.n_layers:
        hi = min(lo + every, cfg.n_layers)
        for l in range(lo, hi):
            blk = jax.tree.map(lambda a: a[l], params["blocks"])
            x = rmsnorm(blk["ln"], h, cfg.norm_eps)
            y, cs, ss = mamba2(Mamba2Params(**blk["mamba"]), x,
                               d_state=cfg.ssm_state, n_heads=cfg.ssm_heads,
                               chunk=1, ctx=ctx,
                               conv_state=cache["conv"][l],
                               ssm_state=cache["ssm"][l], return_state=True)
            new_conv.append(cs)
            new_ssm.append(ss)
            h = h + y
        if hi < cfg.n_layers or cfg.n_layers % every == 0:
            sb = params["shared"]
            attn_p = AttnParams(**sb["attn"])
            a_in = rmsnorm(sb["ln_attn"], h, cfg.norm_eps)
            entry = cache["shared"][s_idx]
            a, ck, cv = attention_decode(
                attn_p, a_in, entry["k"], entry["v"], pos,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, rope_theta=cfg.rope_theta, ctx=ctx)
            new_shared.append({"k": ck, "v": cv})
            h = h + a
            f = ffn(FFNParams(**sb["ffn"]),
                    rmsnorm(sb["ln_ffn"], h, cfg.norm_eps), ctx=ctx)
            h = h + f
            s_idx += 1
        lo = hi
    h = rmsnorm(params["ln_final"], h, cfg.norm_eps)
    logits = matmul(h, params["lm_head"].T)
    new_cache = {
        "conv": jnp.stack(new_conv), "ssm": jnp.stack(new_ssm),
        "shared": new_shared,
    }
    return ctx.logits(logits), new_cache
