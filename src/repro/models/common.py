"""Shared model building blocks (raw JAX pytrees, no framework deps).

Conventions:
  * params are nested dicts of jnp arrays; per-layer params are stacked
    along a leading L axis so the layer stack runs under ``jax.lax.scan``
    (one trace per unique block — keeps dry-run compile time and HLO size
    bounded for 48-layer models).
  * compute dtype is the param dtype (bf16 on the TPU target); all matmuls
    accumulate in f32 via ``preferred_element_type``.
  * sharding is injected via a ``ShardCtx`` of logical-axis constraints; on
    a single device all constraints are no-ops.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Sharding context
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Logical→mesh axis mapping used by with_sharding_constraint calls.

    data  : batch-like dims          (mesh axes, e.g. ("pod", "data"))
    model : tensor-parallel dims     (e.g. "model")
    seq   : sequence-parallel dim    (usually == model axis, exclusive with
                                      head sharding at any given point)
    """
    mesh: Optional[object] = None
    data: Optional[object] = None
    model: Optional[object] = None
    use_sp: bool = True

    def constrain(self, x, spec: P):
        """Apply a sharding constraint, dropping spec entries that do not
        divide the corresponding dim (production meshes are fixed powers of
        two; models with e.g. 8 kv heads on a 16-way model axis fall back to
        replication on that dim instead of GSPMD padding)."""
        if self.mesh is None:
            return x
        fixed = []
        for dim, entry in enumerate(spec):
            if entry is None:
                fixed.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= self.mesh.shape[a]
            fixed.append(entry if x.shape[dim] % size == 0 else None)
        fixed += [None] * (x.ndim - len(fixed))
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, P(*fixed)))

    @property
    def data_size(self) -> int:
        """Number of data-parallel shards (1 without a mesh)."""
        if self.mesh is None or self.data is None:
            return 1
        axes = self.data if isinstance(self.data, tuple) else (self.data,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    # canonical activation layouts ------------------------------------
    def act_btd(self, x):
        """(batch, seq, d_model) residual stream: batch over data axes and,
        if SP is on, seq over the model axis (Megatron-SP layout)."""
        if self.mesh is None:
            return x
        seq_ax = self.model if self.use_sp else None
        return self.constrain(x, P(self.data, seq_ax, None))

    def act_bthd(self, x):
        """(batch, seq, heads, head_dim): heads over the model axis."""
        if self.mesh is None:
            return x
        return self.constrain(x, P(self.data, None, self.model, None))

    def act_btf(self, x):
        """(batch, seq, d_ff): ff dim over the model axis."""
        if self.mesh is None:
            return x
        return self.constrain(x, P(self.data, None, self.model))

    def logits(self, x):
        """(batch, seq, vocab): vocab over the model axis."""
        if self.mesh is None:
            return x
        return self.constrain(x, P(self.data, None, self.model))


NULL_CTX = ShardCtx()


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def matmul(x, w):
    """bf16-safe matmul with f32 accumulation."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.zeros((d,), dtype)}     # (1 + scale) convention

def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}

def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))

def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(ang)[..., None, :]                     # (..., s, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Softcap (gemma-2)
# ---------------------------------------------------------------------------

def softcap(x, cap: float):
    if not cap:
        return x
    xf = x.astype(jnp.float32)
    return (jnp.tanh(xf / cap) * cap).astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy_chunked(logits_fn: Callable, h, labels, vocab: int,
                          chunk: int = 1024,
                          final_softcap: float = 0.0,
                          ctx: ShardCtx = NULL_CTX):
    """Memory-bounded LM loss: computes logits per sequence chunk inside a
    scan so the (B, S, vocab) tensor never materializes (vital for 256k
    vocabularies at 4k seq).

    ``logits_fn(h_chunk) -> (B, c, vocab)``; labels: (B, S) int32, -100 pads.
    Returns mean NLL over non-pad tokens.
    """
    B, S, _ = h.shape
    chunk = min(chunk, S)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk

    def one(h_c, y_c):
        logits = logits_fn(h_c)
        logits = softcap(logits, final_softcap)
        logits = ctx.logits(logits)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        valid = y_c >= 0
        y_safe = jnp.where(valid, y_c, 0)
        picked = jnp.take_along_axis(lf, y_safe[..., None], axis=-1)[..., 0]
        nll = jnp.where(valid, lse - picked, 0.0)
        return nll.sum(), valid.sum()

    if n_chunks > 0:
        hs = h[:, :n_chunks * chunk].reshape(B, n_chunks, chunk, -1)
        ys = labels[:, :n_chunks * chunk].reshape(B, n_chunks, chunk)
        def body(carry, xs):
            h_c, y_c = xs
            s, c = one(h_c.swapaxes(0, 0), y_c)
            return (carry[0] + s, carry[1] + c), None
        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.float32(0), jnp.int32(0)),
            (hs.swapaxes(0, 1), ys.swapaxes(0, 1)))
    else:
        tot, cnt = jnp.float32(0), jnp.int32(0)
    if rem:
        s, c = one(h[:, n_chunks * chunk:], labels[:, n_chunks * chunk:])
        tot, cnt = tot + s, cnt + c
    return tot / jnp.maximum(cnt, 1)


# ---------------------------------------------------------------------------
# Param counting
# ---------------------------------------------------------------------------

def count_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(x.size for x in leaves))
