"""The Omega/Psi kind registry — deliberately jax-free.

``plan/`` consults the registry when scoring sparse-vs-dense candidates,
and the plan layer imports no jax at module scope (costs are closed-form
floats); keeping the registry here lets every layer agree on the valid
kinds without dragging the runtime in.  ``core/sketch.py`` re-exports
these names, so executable code keeps importing them from there.

Dense kinds draw every entry of Omega i.i.d. (Philox counter grids,
``core/rng.py``).  Sparse kinds place ONE nonzero per row:

  countsketch — Clarkson-Woodruff: Omega[g, h(g)] = s(g) with h uniform
                over the r columns and s a random sign, both drawn from
                the row's Philox counter.
  rowsample   — coordinated sampling (Daliri-Freire-Li-Musco,
                arXiv:2501.17836): row g participates iff its uniform
                draw u_g < p = min(1, r/n); a kept row scatters
                s(g)/sqrt(p) into column h(g), so E[Omega·Omega^T] = I
                and every party derives the SAME subset from the seed
                without communicating it.
"""

DENSE_KINDS = ("normal", "uniform", "rademacher")
SPARSE_KINDS = ("countsketch", "rowsample")
VALID_KINDS = DENSE_KINDS + SPARSE_KINDS


def validate_kind(kind: str) -> None:
    """Eager kind check shared by every public entry point: a typo'd kind
    fails HERE, with the valid list, not as a shape error three layers
    down a traced program."""
    if kind not in VALID_KINDS:
        raise ValueError(f"unknown omega kind {kind!r}; valid kinds: "
                         f"{', '.join(VALID_KINDS)}")
