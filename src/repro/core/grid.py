"""Optimal processor-grid selection (paper §4.3 and §5.3) + cost models.

``select_matmul_grid``   — the paper's per-regime optimal (p1, p2, p3) for
                           Algorithm 1, exact when divisibility allows, else
                           snapped to the nearest feasible factorization.
``select_nystrom_grids`` — §5.3's two approaches: ``redist`` (bound-driven
                           grids, B re-laid out with an all-to-all) and
                           ``no_redist`` (q == p, pays an O(r^2)
                           reduce-scatter instead).
``alg1_bandwidth_words`` / ``alg2_bandwidth_words`` — the paper's closed-form
costs for the chosen grids; tests assert alg-cost == lower bound in every
regime of Theorem 2 (tightness), and within the paper's stated gap for
Theorem 3.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable, Tuple

from .lower_bounds import matmul_regime, nystrom_regime


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _divisors(P: int) -> list:
    out = []
    i = 1
    while i * i <= P:
        if P % i == 0:
            out.append(i)
            if i != P // i:
                out.append(P // i)
        i += 1
    return sorted(out)


def factorizations_3d(P: int) -> Iterable[Tuple[int, int, int]]:
    """All (p1, p2, p3) with p1*p2*p3 == P."""
    for p1 in _divisors(P):
        rem = P // p1
        for p2 in _divisors(rem):
            yield (p1, p2, rem // p2)


def alg1_bandwidth_words(n1: int, n2: int, r: int,
                         p1: int, p2: int, p3: int) -> float:
    """Algorithm 1 bandwidth cost (paper §4.2.1):

        (1 - 1/p3) * n1*n2/(p1*p2)   [All-Gather of A over Pi_ij*]
      + (1 - 1/p2) * n1*r/(p1*p3)    [Reduce-Scatter of B over Pi_i*k]
    """
    P = p1 * p2 * p3
    ag = (1.0 - 1.0 / p3) * (n1 * n2) / (p1 * p2)
    rs = (1.0 - 1.0 / p2) * (n1 * r) / (p1 * p3)
    assert P > 0
    return ag + rs


def alg1_latency_hops(p2: int, p3: int) -> float:
    """log(p3) + log(p2) messages on the critical path (§4.2.1)."""
    return math.log2(max(p3, 1)) + math.log2(max(p2, 1))


# ---------------------------------------------------------------------------
# §4.3 — optimal grid for Algorithm 1
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MatmulGrid:
    p1: int
    p2: int
    p3: int
    regime: int
    bandwidth_words: float
    latency_hops: float

    @property
    def shape(self):
        return (self.p1, self.p2, self.p3)


def select_matmul_grid(n1: int, n2: int, r: int, P: int,
                       exhaustive_fallback: bool = True) -> MatmulGrid:
    """The paper's optimal grid, snapped to integer factorizations of P.

    Case 1 (P <= n1):        (P, 1, 1)          -> zero communication
    Case 2 (n1 < P <= n1n2/r):(n1, P/n1, 1)
    Case 3 (else):           (n1, sqrt(Pn2/(r n1)), sqrt(Pr/(n1 n2)))

    When the paper's ideal dims don't divide P (or exceed matrix dims), we
    pick the factorization of P minimizing the Alg. 1 cost model, restricted
    to p1 <= n1, p2 <= n2, p3 <= r — this is exactly what a production
    launcher must do on a fixed mesh.
    """
    regime = matmul_regime(n1, n2, r, P)
    ideal: Tuple[int, int, int]
    if regime == 1:
        ideal = (P, 1, 1)
    elif regime == 2:
        ideal = (n1, max(1, P // n1), 1)
    else:
        p2 = math.sqrt(P * n2 / (r * n1))
        p3 = math.sqrt(P * r / (n1 * n2))
        ideal = (n1, max(1, round(p2)), max(1, round(p3)))

    p1, p2, p3 = ideal
    if p1 * p2 * p3 == P and p1 <= n1 and p2 <= n2 and p3 <= r:
        return MatmulGrid(p1, p2, p3, regime,
                          alg1_bandwidth_words(n1, n2, r, p1, p2, p3),
                          alg1_latency_hops(p2, p3))

    if not exhaustive_fallback:
        raise ValueError(f"ideal grid {ideal} infeasible for P={P}")

    best = None
    for (a, b, c) in factorizations_3d(P):
        if a > n1 or b > n2 or c > r:
            continue
        cost = alg1_bandwidth_words(n1, n2, r, a, b, c)
        key = (cost, alg1_latency_hops(b, c))
        if best is None or key < best[0]:
            best = (key, (a, b, c))
    if best is None:
        # degenerate matrices; fall back to 1D over rows
        a = min(P, n1)
        return MatmulGrid(a, 1, 1, regime,
                          alg1_bandwidth_words(n1, n2, r, a, 1, 1),
                          0.0)
    (cost, lat), (a, b, c) = best
    return MatmulGrid(a, b, c, regime, cost, lat)


# ---------------------------------------------------------------------------
# §5.3 — Nystrom grids
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class NystromGrids:
    p: Tuple[int, int, int]
    q: Tuple[int, int, int]
    variant: str           # "redist" | "no_redist" | "bound_driven"
    regime: int
    bandwidth_words: float
    redistributes_B: bool


def alg2_bandwidth_words(n: int, r: int,
                         p: Tuple[int, int, int],
                         q: Tuple[int, int, int]) -> float:
    """Algorithm 2 bandwidth cost (§5.2.1), including redistribution.

        (1-1/p3) n^2/(p1 p2)   AG of A
      + (1-1/p2) nr/(p1 p3)    RS of B-hat
      + (1-1/q2) nr/(q1 q3)    AG of B
      + (1-1/q1) r^2/(q2 q3)   RS of C
      + nr/P if p != q         all-to-all redistribution of B
    """
    p1, p2, p3 = p
    q1, q2, q3 = q
    P = p1 * p2 * p3
    cost = ((1 - 1 / p3) * n * n / (p1 * p2)
            + (1 - 1 / p2) * n * r / (p1 * p3)
            + (1 - 1 / q2) * n * r / (q1 * q3)
            + (1 - 1 / q1) * r * r / (q2 * q3))
    if tuple(p) != tuple(q):
        cost += n * r / P
    return cost


def select_nystrom_grids(n: int, r: int, P: int,
                         variant: str = "auto") -> NystromGrids:
    """§5.3 grid selection.

    variant:
      * ``redist``     — 1D Case-1 grids p=(P,1,1), q=(1,1,P); all-to-all
                         re-layout of B; comm O(nr/P). Scales with P.
      * ``no_redist``  — p=q=(P,1,1); B never moves; comm O(r^2) from the
                         C reduce-scatter. Better when P < n/r.
      * ``bound_driven``— the per-regime grids of §5.3 approach 1.
      * ``auto``       — paper's empirical rule: redist iff P > n/r.
    """
    regime = nystrom_regime(n, r, P)
    if variant == "auto":
        variant = "redist" if P > max(1, n // max(r, 1)) else "no_redist"

    if variant == "no_redist":
        p = q = (min(P, n), 1, 1)
        if p[0] != P:
            p = q = _snap_1d(n, P)
        return NystromGrids(p, q, "no_redist", regime,
                            alg2_bandwidth_words(n, r, p, q), False)

    if variant == "redist":
        p = (min(P, n), 1, 1)
        q = (1, 1, min(P, r)) if P <= r else _snap_q_redist(n, r, P)
        if p[0] != P:
            p = _snap_1d(n, P)
        return NystromGrids(p, q, "redist", regime,
                            alg2_bandwidth_words(n, r, p, q), True)

    if variant == "bound_driven":
        if regime == 1:
            p, q = (P, 1, 1), (1, 1, P)
        elif regime == 2:
            p, q = (P, 1, 1), (max(1, P // r), 1, min(r, P))
        elif regime == 3:
            p = (min(n, P), max(1, P // n), 1)
            q = (max(1, n // r), max(1, P // n), min(r, P))
            p, q = _fix_product(p, P), _fix_product(q, P)
        else:
            p2 = max(1, round(math.sqrt((n + r) * P / (n * r))))
            p3 = max(1, P // (min(n, P) * p2))
            p = _fix_product((min(n, P), p2, p3), P)
            q = _fix_product((max(1, P // (p2 * min(r, P))), p2, min(r, P)), P)
        return NystromGrids(tuple(p), tuple(q), "bound_driven", regime,
                            alg2_bandwidth_words(n, r, p, q),
                            tuple(p) != tuple(q))

    raise ValueError(f"unknown variant {variant!r}")


def alg2_two_grid_executable(n: int, r: int,
                             p: Tuple[int, int, int],
                             q: Tuple[int, int, int]) -> bool:
    """Whether ``core.nystrom.nystrom_two_grid`` can run (p, q) on (n, r).

    Stage 1 is Alg. 1 with n1 = n2 = n, so it inherits the entry point's
    divisibility contract (the B layout P((p1, p2), p3) reduce-scatters each
    n/p1 row block p2 ways).  Stage 2 lays B out P(q1, (q3, q2)) and
    reduce-scatters each r/q2 row block of C q1 ways, hence r % (q1*q2).
    """
    p1, p2, p3 = p
    q1, q2, q3 = q
    stage1 = (n % (p1 * p2) == 0 and n % (p2 * p3) == 0 and r % p3 == 0
              and p1 <= n and p2 <= n and p3 <= r)
    stage2 = (n % q1 == 0 and r % (q1 * q2) == 0 and r % (q2 * q3) == 0
              and q1 <= n and q2 <= r and q3 <= r)
    return stage1 and stage2


def select_two_grid_executable(n: int, r: int, P: int, p=None):
    """The §5.3 bound-driven (p, q) pair, snapped to what can execute.

    Returns ``(p, q, exact)`` where ``exact`` says the ideal bound-driven
    grids themselves divide (n, r); otherwise (p, q) is the pair of
    factorizations of P minimizing ``alg2_bandwidth_words`` among all
    executable pairs (the same min-words snap ``grid="auto"`` applies to
    Alg. 1), and the caller should report the bound gap.  Returns ``None``
    when no factorization pair divides the shape.  ``p`` fixes the stage-1
    grid (e.g. a streamed accumulator already laid out on (P, 1, 1)) and
    restricts the search to q.
    """
    ideal = select_nystrom_grids(n, r, P, variant="bound_driven")
    if (p is None or tuple(p) == tuple(ideal.p)) \
            and alg2_two_grid_executable(n, r, ideal.p, ideal.q):
        return tuple(ideal.p), tuple(ideal.q), True
    facs = list(factorizations_3d(P))
    p_cands = [tuple(p)] if p is not None else facs
    best = None
    for pc in p_cands:
        for qc in facs:
            if not alg2_two_grid_executable(n, r, pc, qc):
                continue
            w = alg2_bandwidth_words(n, r, pc, qc)
            lat = (alg1_latency_hops(pc[1], pc[2])
                   + math.log2(max(qc[0], 1))
                   + (math.log2(max(P, 1)) if pc != qc else 0.0))
            key = (w, lat)
            if best is None or key < best[0]:
                best = (key, pc, qc)
    if best is None:
        return None
    return best[1], best[2], False


# ---------------------------------------------------------------------------
# §5.2 Redistribute, in-program: device-order reconciliation of two grids.
#
# ``nystrom_two_grid`` runs its two stages on two meshes over the same flat
# device list and pays a host-mediated ``device_put`` between them.  When
# one mesh can express BOTH grids — its axes refine both factorizations in
# row-major order, so the device at p-coordinate (i, j, k) and the device
# at q-coordinate (i', j', k') are the SAME physical assignment the two
# separate meshes would use — the Redistribute becomes an in-program
# resharding (``with_sharding_constraint``) that XLA compiles into the one
# executable (``core.nystrom.nystrom_two_grid_fused``).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TwoGridSharedMesh:
    """One mesh serving both grids of a two-grid Alg. 2 run.

    ``p_axes`` / ``q_axes`` are 3-tuples of (possibly empty) tuples of mesh
    axis names whose size products are (p1, p2, p3) / (q1, q2, q3); grouped
    row-major, so sharding a dim over a group reproduces the device
    assignment of the standalone ``make_grid_mesh(p...)`` / ``(q...)``
    meshes exactly.
    """
    mesh: object                       # jax.sharding.Mesh
    p: Tuple[int, int, int]
    q: Tuple[int, int, int]
    p_axes: Tuple[Tuple[str, ...], Tuple[str, ...], Tuple[str, ...]]
    q_axes: Tuple[Tuple[str, ...], Tuple[str, ...], Tuple[str, ...]]


def two_grid_axis_split(p: Tuple[int, int, int],
                        q: Tuple[int, int, int]):
    """Common row-major refinement of two factorizations of the same P.

    Returns ``(sizes, p_groups, q_groups)`` — mesh axis sizes plus, per
    grid, three tuples of axis indices whose size products are the grid
    dims — or ``None`` when no single row-major device assignment serves
    both grids (the prefix products of p and q do not chain under
    divisibility, e.g. p=(2,3,1) vs q=(3,2,1) over P=6).
    """
    p = tuple(int(x) for x in p)
    q = tuple(int(x) for x in q)
    P = p[0] * p[1] * p[2]
    if q[0] * q[1] * q[2] != P:
        raise ValueError(f"grids must factor the same P: {p} vs {q}")
    if P == 1:
        return (1,), ((0,), (), ()), ((0,), (), ())
    cuts = sorted({1, P, p[0], p[0] * p[1], q[0], q[0] * q[1]})
    for a, b in zip(cuts, cuts[1:]):
        if b % a:
            return None
    sizes = tuple(b // a for a, b in zip(cuts, cuts[1:]))

    def groups(g):
        bounds = (1, g[0], g[0] * g[1], P)
        return tuple(
            tuple(i for i, (a, b) in enumerate(zip(cuts, cuts[1:]))
                  if a >= bounds[bi] and b <= bounds[bi + 1])
            for bi in range(3))

    return sizes, groups(p), groups(q)


def two_grid_shared_mesh(p: Tuple[int, int, int],
                         q: Tuple[int, int, int],
                         devices=None):
    """A mesh whose device order serves BOTH grids, or ``None``.

    When the refinement exists, the returned mesh assigns devices exactly
    as ``make_grid_mesh(*p)`` and ``make_grid_mesh(*q)`` over the same
    flat device list would — so stage 1 sharded over ``p_axes`` is
    bitwise the p-grid mesh program, and the §5.2 Redistribute to the
    ``q_axes`` layout can be expressed in-program (no cross-mesh
    ``device_put``).  ``None`` means no single device assignment serves
    both factorizations; callers fall back to the cross-mesh path.
    """
    split = two_grid_axis_split(p, q)
    if split is None:
        return None
    import jax
    import numpy as np
    from jax.sharding import Mesh
    sizes, pg, qg = split
    if devices is None:
        devices = jax.devices()
    P = p[0] * p[1] * p[2]
    if len(devices) < P:
        raise ValueError(f"grids {p}/{q} need {P} devices, "
                         f"have {len(devices)}")
    names = tuple(f"g{i}" for i in range(len(sizes)))
    devs = np.asarray(list(devices[:P])).reshape(sizes)
    mesh = Mesh(devs, names)
    to_names = lambda idxs: tuple(tuple(names[i] for i in grp)
                                  for grp in idxs)
    return TwoGridSharedMesh(mesh=mesh, p=tuple(p), q=tuple(q),
                             p_axes=to_names(pg), q_axes=to_names(qg))


def _snap_1d(n: int, P: int) -> Tuple[int, int, int]:
    """Largest p1 | P with p1 <= n, rest into p2."""
    for d in sorted(_divisors(P), reverse=True):
        if d <= n:
            return (d, P // d, 1)
    return (1, P, 1)


def _snap_q_redist(n: int, r: int, P: int) -> Tuple[int, int, int]:
    for d in sorted(_divisors(P), reverse=True):
        if d <= r:
            return (P // d, 1, d)
    return (P, 1, 1)


def _fix_product(p: Tuple[int, int, int], P: int) -> Tuple[int, int, int]:
    """Adjust a rounded grid so the product is exactly P (greedy)."""
    p1, p2, p3 = (max(1, int(x)) for x in p)
    prod = p1 * p2 * p3
    if prod == P:
        return (p1, p2, p3)
    # greedy: fix p1 to a divisor, then p2, then p3 absorbs the rest
    d1 = max(d for d in _divisors(P) if d <= max(p1, 1))
    rem = P // d1
    d2 = max(d for d in _divisors(rem) if d <= max(p2, 1))
    return (d1, d2, rem // d2)
