"""Communication lower bounds from the paper (Theorems 2 and 3).

Closed forms for:
  * ``matmul_lower_bound``  — Theorem 2: B = A·Omega, A: n1 x n2, Omega: n2 x r
    random (regenerable), r < n2.  Three regimes in P.
  * ``nystrom_lower_bound`` — Theorem 3: B = A·Omega then C = Omega^T·B,
    A: n x n, Omega: n x r random, r < n.  Four regimes in P.
  * ``gemm_lower_bound``    — the classical non-random GEMM bound
    [Al Daas et al., SPAA'22] used by the paper as the comparison point
    ("random input needs strictly less communication").

Each closed form is paired with a *numeric* optimizer
(``minimize_access_matmul`` / ``minimize_access_nystrom``) that solves the
paper's constrained optimization (Lemma 5 / Lemma 6) directly; the property
tests assert closed-form == numeric optimum across the whole (n, r, P) space,
which is an executable re-proof of the KKT case analysis.

All returns are in *words* (element counts), matching the paper's model.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


# ---------------------------------------------------------------------------
# Theorem 2 — B = A * Omega
# ---------------------------------------------------------------------------

def matmul_regime(n1: int, n2: int, r: int, P: int) -> int:
    """Which case of Theorem 2 applies (1, 2, or 3)."""
    if P <= n1:
        return 1
    if P <= n1 * n2 / r:
        return 2
    return 3


def matmul_access_lower_bound(n1: int, n2: int, r: int, P: int) -> float:
    """Lemma 5 optimum: min words a 1/P-load processor must *access*."""
    case = matmul_regime(n1, n2, r, P)
    if case == 1:
        return n1 * n2 / P + n1 * r / P
    if case == 2:
        return n1 * n2 / P + r
    return 2.0 * math.sqrt(n1 * n2 * r / P)


def matmul_lower_bound(n1: int, n2: int, r: int, P: int) -> float:
    """Theorem 2: minimum words *communicated* by some processor.

    W = (min access) - (data the processor may own) with ownership
    (n1*n2 + n1*r)/P under the one-copy input/output assumption.
    """
    if not (r < n2):
        raise ValueError(f"paper assumes r < n2, got r={r}, n2={n2}")
    own = (n1 * n2 + n1 * r) / P
    W = matmul_access_lower_bound(n1, n2, r, P) - own
    return max(0.0, W)


def gemm_lower_bound(n1: int, n2: int, n3: int, P: int) -> float:
    """Classical memory-independent GEMM bound (both operands must move).

    From Al Daas et al. SPAA'22 (paper's ref [4]); used for the
    "sketching needs less communication than GEMM" comparison.  Three
    regimes for n1 >= n2 >= n3 (we sort dims to canonical order):
        P <= n1/n2              : (n2 n3) - lower-order
        n1/n2 < P <= n1 n2/n3^2 : 2 (n1 n2 n3 / P)^(1/2) ... (2D regime)
        else                    : 3 (n1 n2 n3 / P)^(2/3) / ... (3D regime)
    We implement the standard access form and subtract ownership.
    """
    d = sorted((n1, n2, n3), reverse=True)
    m1, m2, m3 = d  # m1 >= m2 >= m3
    own = (n1 * n2 + n2 * n3 + n1 * n3) / P
    if P <= m1 / m2:
        access = m2 * m3 + (m1 * m2 + m1 * m3) / P
    elif P <= m1 * m2 / (m3 * m3):
        access = 2.0 * math.sqrt(m1 * m2 * m3 * m3 / P) + m1 * m2 / P
    else:
        access = 3.0 * (m1 * m2 * m3 / P) ** (2.0 / 3.0)
    return max(0.0, access - own)


# ---------------------------------------------------------------------------
# Theorem 3 — Nystrom pair B = A*Omega ; C = Omega^T*B
# ---------------------------------------------------------------------------

def nystrom_regime(n: int, r: int, P: int) -> int:
    """Which case of Theorem 3 / Lemma 6 applies (1..4)."""
    if P <= r:
        return 1
    if P <= n:
        return 2
    if P <= n * (n + r) / r:
        return 3
    return 4


def nystrom_access_lower_bound(n: int, r: int, P: int) -> float:
    """Lemma 6 optimum: min words accessed (x1 + x2 + x3)."""
    case = nystrom_regime(n, r, P)
    if case == 1:
        return (n * n + n * r + r * r) / P
    if case == 2:
        return (n * n + n * r) / P + r
    if case == 3:
        return n * n / P + r + n * r / P
    return 2.0 * math.sqrt(n * r * (n + r) / P)


def nystrom_lower_bound(n: int, r: int, P: int) -> float:
    """Theorem 3: W_access - (n^2 + nr + r^2)/P, in words."""
    if not (r < n):
        raise ValueError(f"paper assumes r < n, got r={r}, n={n}")
    own = (n * n + n * r + r * r) / P
    return max(0.0, nystrom_access_lower_bound(n, r, P) - own)


# ---------------------------------------------------------------------------
# Numeric optimizers (executable re-proof of Lemmas 5 and 6)
# ---------------------------------------------------------------------------

def minimize_access_matmul(n1: int, n2: int, r: int, P: int,
                           iters: int = 200) -> float:
    """Numerically solve Lemma 5:

        min x1 + x2  s.t.  x1 x2 >= n1 n2 r / P,
                           x1 >= n1 n2 / P,  x2 >= n1 r / P.

    One-dimensional: on the optimum either the product constraint is tight
    or both box constraints bind, so sweep x1 over [lb1, hi] with
    x2 = max(lb2, K/x1) and take the min; golden-section refine.
    """
    K = n1 * n2 * r / P
    lb1 = n1 * n2 / P
    lb2 = n1 * r / P

    def obj(x1):
        x2 = max(lb2, K / x1)
        return x1 + x2

    hi = max(lb1, K / lb2) * 4.0 + 1.0
    lo = lb1
    # coarse log sweep then golden section
    best_x, best_v = lo, obj(lo)
    steps = 4096
    for i in range(steps + 1):
        x = lo * (hi / lo) ** (i / steps) if lo > 0 else lo + (hi - lo) * i / steps
        v = obj(x)
        if v < best_v:
            best_v, best_x = v, x
    gl, gr = max(lo, best_x / 1.1), min(hi, best_x * 1.1)
    phi = (math.sqrt(5) - 1) / 2
    a, b = gl, gr
    c, d = b - phi * (b - a), a + phi * (b - a)
    for _ in range(iters):
        if obj(c) < obj(d):
            b, d = d, c
            c = b - phi * (b - a)
        else:
            a, c = c, d
            d = a + phi * (b - a)
    return min(best_v, obj((a + b) / 2))


def minimize_access_nystrom(n: int, r: int, P: int,
                            grid: int = 256, refine: int = 60) -> float:
    """Numerically solve Lemma 6:

        min x1+x2+x3  s.t.  x1 x2 >= n^2 r/P,  x2 x3 >= n r^2/P,
                            x1 >= n^2/P, x2 >= nr/P, x3 >= r^2/P.

    For fixed x2, the optimum is x1 = max(n^2/P, n^2 r/(P x2)),
    x3 = max(r^2/P, n r^2 /(P x2)) — so sweep x2 (1-D) and refine.
    """
    K1 = n * n * r / P
    K2 = n * r * r / P
    lb1 = n * n / P
    lb2 = n * r / P
    lb3 = r * r / P

    def obj(x2):
        x1 = max(lb1, K1 / x2)
        x3 = max(lb3, K2 / x2)
        return x1 + x2 + x3

    lo = lb2
    hi = max(lb2 * 4, math.sqrt(K1) * 4, math.sqrt(K2) * 4, 4.0)
    best_x, best_v = lo, obj(lo)
    for i in range(grid * 16 + 1):
        x = lo * (hi / lo) ** (i / (grid * 16))
        v = obj(x)
        if v < best_v:
            best_v, best_x = v, x
    phi = (math.sqrt(5) - 1) / 2
    a, b = max(lo, best_x / 1.1), best_x * 1.1
    c, d = b - phi * (b - a), a + phi * (b - a)
    for _ in range(refine):
        if obj(c) < obj(d):
            b, d = d, c
            c = b - phi * (b - a)
        else:
            a, c = c, d
            d = a + phi * (b - a)
    return min(best_v, obj((a + b) / 2))


# ---------------------------------------------------------------------------
# Convenience report
# ---------------------------------------------------------------------------

@dataclass
class BoundReport:
    kind: str
    dims: tuple
    P: int
    regime: int
    words_lower_bound: float
    access_lower_bound: float
    gemm_words: float  # what a non-random GEMM would require

    @property
    def savings_vs_gemm(self) -> float:
        if self.words_lower_bound == 0:
            return float("inf") if self.gemm_words > 0 else 1.0
        return self.gemm_words / self.words_lower_bound


def report_matmul(n1: int, n2: int, r: int, P: int) -> BoundReport:
    return BoundReport(
        kind="sketch-matmul", dims=(n1, n2, r), P=P,
        regime=matmul_regime(n1, n2, r, P),
        words_lower_bound=matmul_lower_bound(n1, n2, r, P),
        access_lower_bound=matmul_access_lower_bound(n1, n2, r, P),
        gemm_words=gemm_lower_bound(n1, n2, r, P),
    )


def report_nystrom(n: int, r: int, P: int) -> BoundReport:
    return BoundReport(
        kind="nystrom", dims=(n, r), P=P,
        regime=nystrom_regime(n, r, P),
        words_lower_bound=nystrom_lower_bound(n, r, P),
        access_lower_bound=nystrom_access_lower_bound(n, r, P),
        gemm_words=(gemm_lower_bound(n, n, r, P)
                    + gemm_lower_bound(r, n, r, P)),
    )
