"""Algorithm 1 — communication-optimal parallel B = A·Omega (paper §4.2).

The processor grid is a JAX mesh with three named axes (p1, p2, p3).  The
algorithm is *exactly* the paper's: one All-Gather of A over the p3 fibers,
local regeneration of the Omega block (zero communication — the paper's
point), one local GEMM, one Reduce-Scatter of B over the p2 fibers.

Data layout contract (paper §4.2):
  in : A is evenly divided into a (p1 x p2) grid of blocks; each block A_ij
       is split column-wise across the p3 fiber -> in_specs P(p1, (p2, p3)).
  out: B is evenly divided into a (p1 x p3) grid of blocks; each block B_ik
       is split row-wise across the p2 fiber -> out_specs P((p1, p2), p3).

Omega entries are generated with the Philox-4x32-10 counter-based generator
keyed by *global* coordinates, so every processor-grid decomposition of the
same (seed, n2, r) produces bitwise-identical sketches — the distributed
result equals the single-device reference exactly, which is the executable
form of the paper's regenerate-don't-communicate claim.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import rng
from .compat import shard_map
from .grid import MatmulGrid, select_matmul_grid

DEFAULT_AXES = ("p1", "p2", "p3")

# The kind registry (DENSE_KINDS dense entry distributions applied by
# GEMM; SPARSE_KINDS one-nonzero-per-row families applied in O(nnz) by
# scatter-add) lives in the jax-free core/kinds.py so the plan layer can
# consult it without importing the runtime; re-exported here because this
# module is where executable code looks for it.
from .kinds import (DENSE_KINDS, SPARSE_KINDS,  # noqa: F401,E402
                    VALID_KINDS, validate_kind)


# ---------------------------------------------------------------------------
# Omega tile generation (shared by local + distributed paths)
# ---------------------------------------------------------------------------

def seed_keys(seed):
    """The Philox (key0, key1) pair for a seed.

    ``seed`` may be a Python int (split into two uint32 halves, as the
    one-shot APIs have always done) or a JAX value — a scalar or a shape-(2,)
    uint32 array — so the streaming sketch service can trace the seed and
    share one compiled update executable across every concurrent stream.
    A Python int < 2**32 and the equivalent traced uint32 scalar produce
    bitwise-identical Omega entries.
    """
    if isinstance(seed, (int, np.integer)):
        seed = int(seed)
        return (jnp.uint32(seed & 0xFFFFFFFF),
                jnp.uint32((seed >> 32) & 0xFFFFFFFF))
    seed = jnp.asarray(seed)
    if seed.shape == (2,):
        return seed[0].astype(jnp.uint32), seed[1].astype(jnp.uint32)
    if seed.shape == ():
        return seed.astype(jnp.uint32), jnp.zeros((), jnp.uint32)
    raise ValueError(f"seed must be an int, a scalar, or a (2,) key pair; "
                     f"got shape {seed.shape}")


def omega_tile(seed, row0, col0, rows: int, cols: int,
               kind: str = "normal", dtype=jnp.float32, salt: int = 0,
               r_total: Optional[int] = None,
               n_total: Optional[int] = None):
    """Tile [row0:row0+rows, col0:col0+cols] of the global Omega.

    Entry values depend only on global coordinates + seed, never on the
    tiling, so this is safe to call from any shard with traced offsets.
    ``seed`` may be traced (see :func:`seed_keys`).

    The sparse kinds need the GLOBAL Omega shape, which a tile call does
    not otherwise carry: ``r_total`` is the global column count (the
    bucket modulus; defaults to ``cols``, i.e. a full-width tile — pass
    it explicitly for column sub-tiles) and ``n_total`` the global row
    count (the ``rowsample`` membership probability r_total/n_total;
    defaults to ``rows``, i.e. a full-height tile — row-sliced callers
    like ``stream.state.psi_cols`` pass the stream's n1).  Dense kinds
    ignore both.
    """
    validate_kind(kind)
    key0, key1 = seed_keys(seed)
    row0 = jnp.asarray(row0, jnp.uint32)
    col0 = jnp.asarray(col0, jnp.uint32)
    if kind == "normal":
        t = rng.philox_normal_grid(key0, key1, row0, col0, rows, cols, salt)
    elif kind == "uniform":
        t = rng.philox_uniform_grid(key0, key1, row0, col0, rows, cols, salt)
    elif kind == "rademacher":
        u = rng.philox_uniform_grid(key0, key1, row0, col0, rows, cols, salt)
        t = jnp.where(u < 0.5, -1.0, 1.0)
    elif kind == "countsketch":
        t = rng.philox_countsketch_grid(key0, key1, row0, col0, rows, cols,
                                        r_total if r_total is not None
                                        else cols, salt)
    else:  # rowsample
        t = rng.philox_rowsample_grid(key0, key1, row0, col0, rows, cols,
                                      r_total if r_total is not None
                                      else cols,
                                      n_total if n_total is not None
                                      else rows, salt)
    return t.astype(dtype)


def sparse_omega_map(seed, n_rows: int, width: int, kind: str,
                     dtype=jnp.float32, salt: int = 0, row0=0,
                     n_total: Optional[int] = None):
    """Per-row (bucket, value) arrays defining a sparse Omega row range:
    ``Omega[row0 + i, bucket[i]] = value[i]`` for i < n_rows (every other
    entry 0; value 0 means the row was not sampled).  ``width`` is the
    GLOBAL column count of Omega; ``n_total`` its global row count (the
    ``rowsample`` membership denominator — defaults to ``n_rows``, i.e. a
    full-height call; row-sliced callers must pass it); ``row0`` offsets
    the returned range (may be traced).  This is the O(n) form the
    scatter-add apply paths consume — materializing the dense tile is
    :func:`omega_tile`'s job.
    """
    validate_kind(kind)
    if kind not in SPARSE_KINDS:
        raise ValueError(f"kind {kind!r} is dense; sparse_omega_map serves "
                         f"{', '.join(SPARSE_KINDS)}")
    g = (jnp.asarray(row0, jnp.uint32)
         + jax.lax.broadcasted_iota(jnp.uint32, (n_rows,), 0))
    return sparse_omega_rows(seed, g, width, kind, dtype, salt,
                             n_total if n_total is not None else n_rows)


def sparse_omega_rows(seed, g, width: int, kind: str, dtype=jnp.float32,
                      salt: int = 0, n_total: Optional[int] = None):
    """Gather form of :func:`sparse_omega_map`: (bucket, value) draws at an
    arbitrary (possibly repeated, possibly traced) array ``g`` of global
    row indices.  Counter-based, so ``bucket[i]``/``value[i]`` depend only
    on ``g[i]`` — gathering draws per stored entry of a sparse operand is
    bitwise-identical to slicing them out of the full map.  ``n_total`` is
    the global row count of Omega (the rowsample membership denominator;
    required for ``rowsample``).
    """
    validate_kind(kind)
    if kind not in SPARSE_KINDS:
        raise ValueError(f"kind {kind!r} is dense; sparse_omega_rows serves "
                         f"{', '.join(SPARSE_KINDS)}")
    key0, key1 = seed_keys(seed)
    g = jnp.asarray(g, jnp.uint32)
    bucket, sign = rng.philox_countsketch_rows(key0, key1, g, width, salt)
    if kind == "countsketch":
        value = sign
    else:
        import math
        if n_total is None:
            raise ValueError("rowsample draws need n_total (global rows)")
        p = min(1.0, float(width) / float(n_total))
        u = rng.philox_rowsample_uniform(key0, key1, g, salt)
        value = jnp.where(u < np.float32(p),
                          sign * np.float32(1.0 / math.sqrt(p)),
                          jnp.float32(0.0))
    return bucket.astype(jnp.int32), value.astype(dtype)


def sketch_sparse_apply(A, seed, r: int, kind: str = "countsketch",
                        salt: int = 0):
    """B = A @ Omega for a sparse-structured Omega, WITHOUT materializing
    it: one scatter-add per stored entry of A (O(nnz) work — the
    Clarkson-Woodruff property; 2 flops per entry instead of the dense
    GEMM's 2·r).  Bitwise-equal to ``A @ omega_tile(...)`` up to
    summation order (the draws themselves are bitwise; the accumulation
    order differs from a GEMM's), pinned to tolerance by
    tests/test_sparse.py.
    """
    validate_kind(kind)
    if kind not in SPARSE_KINDS:
        raise ValueError(f"kind {kind!r} is dense; use sketch_reference "
                         f"or rand_matmul")
    n2 = A.shape[-1]
    bucket, value = sparse_omega_map(seed, n2, r, kind, A.dtype, salt)
    out = jnp.zeros((*A.shape[:-1], r), A.dtype)
    return out.at[..., bucket].add(A * value)


def sketch_reference(A, seed, r: int, kind: str = "normal",
                     scale: Optional[float] = None):
    """Single-device oracle: B = A @ Omega with the full Omega materialized."""
    validate_kind(kind)
    n2 = A.shape[-1]
    om = omega_tile(seed, 0, 0, n2, r, kind, A.dtype)
    if scale is not None:
        om = om * jnp.asarray(scale, A.dtype)
    return A @ om


# ---------------------------------------------------------------------------
# Mesh helpers
# ---------------------------------------------------------------------------

def make_grid_mesh(p1: int, p2: int, p3: int,
                   axis_names: Tuple[str, str, str] = DEFAULT_AXES,
                   devices=None) -> Mesh:
    """A (p1, p2, p3) mesh for the paper's processor grid."""
    if devices is None:
        devices = jax.devices()
    n = p1 * p2 * p3
    if len(devices) < n:
        raise ValueError(f"grid {p1}x{p2}x{p3} needs {n} devices, "
                         f"have {len(devices)}")
    devs = np.asarray(devices[:n]).reshape(p1, p2, p3)
    return Mesh(devs, axis_names)


def input_sharding(mesh: Mesh, axes=DEFAULT_AXES) -> NamedSharding:
    """Sharding of A per the Alg. 1 layout contract."""
    return NamedSharding(mesh, P(axes[0], (axes[1], axes[2])))


def output_sharding(mesh: Mesh, axes=DEFAULT_AXES) -> NamedSharding:
    """Sharding of B per the Alg. 1 layout contract."""
    return NamedSharding(mesh, P((axes[0], axes[1]), axes[2]))


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

def rand_matmul(A, seed, r: int, mesh: Mesh,
                axes: Tuple[str, str, str] = DEFAULT_AXES,
                kind: str = "normal",
                scale: Optional[float] = None,
                precision=None, salt: int = 0,
                backend: str = "auto", blocks=None):
    """B = A @ Omega on the (p1, p2, p3) grid ``mesh`` (paper Alg. 1).

    A must be shardable as P(p1, (p2, p3)); the result is sharded
    P((p1, p2), p3).  Communication: one tiled All-Gather over p3 and one
    tiled Reduce-Scatter over p2 — matching the paper's optimal bandwidth
    ``(1-1/p3)·n1n2/(p1p2) + (1-1/p2)·n1r/(p1p3)`` exactly.

    ``backend`` selects the *local* GEMM body (``repro.kernels.local``):
    ``"jnp"`` materializes the per-shard Omega block in HBM; ``"pallas"``
    generates it in VMEM inside the fused kernel, dropping the n2·r/(p2·p3)
    HBM stream — the memory-roofline analogue of the zero-communication
    claim; ``"auto"`` picks pallas on TPU.  Both backends are bitwise-
    identical wherever the local contraction is not tiled (the interpret-
    mode default — see kernels/local.py).  ``blocks`` optionally fixes the
    Pallas (bm, bn, bk) tile shape (autotunable via plan.autotune).

    The compiled program is cached per (r, mesh, axes, kind, scale,
    precision, backend, blocks) with the seed *traced* as a Philox key
    pair, so repeated calls — any seed, any A of the same shape — reuse
    one executable.  (Eager ``shard_map`` would otherwise pay a
    per-primitive SPMD dispatch on every call, which is minutes for the
    Philox graph.)
    """
    from repro.kernels.local import resolve_backend
    validate_kind(kind)
    if kind in SPARSE_KINDS:
        raise NotImplementedError(
            f"kind {kind!r}: distributed sparse shard_map bodies are "
            f"deferred (ROADMAP item 3) — use sketch_sparse_apply / the "
            f"local streaming paths, or a dense kind here")
    ax1, ax2, ax3 = axes
    p1, p2, p3 = (mesh.shape[a] for a in axes)
    n1, n2 = A.shape
    # n1 % (p1*p2): the output layout P((p1, p2), p3) reduce-scatters each
    # n1/p1 row block p2 ways (previously surfaced as an opaque XLA
    # reduce_scatter divisibility error).
    if n1 % (p1 * p2) or n2 % (p2 * p3) or n2 % p2 or r % p3:
        raise ValueError(f"shape ({n1},{n2},r={r}) not divisible by grid "
                         f"({p1},{p2},{p3})")
    keys = jnp.stack(seed_keys(seed))
    fn = _rand_matmul_prog(r, mesh, tuple(axes), kind,
                           None if scale is None else float(scale),
                           precision, salt, resolve_backend(backend),
                           None if blocks is None else tuple(blocks))
    return fn(A, keys)


# Bounded caches: a long-lived serving process may construct meshes
# dynamically; evicting a program merely costs a recompile on next use.
_PROG_CACHE_SIZE = 64


@functools.lru_cache(maxsize=_PROG_CACHE_SIZE)
def _rand_matmul_prog(r: int, mesh: Mesh, axes: Tuple[str, str, str],
                      kind: str, scale, precision, salt: int,
                      backend: str = "jnp", blocks=None):
    from repro.kernels.local import sketch_block
    ax1, ax2, ax3 = axes
    p2 = mesh.shape[ax2]
    p3 = mesh.shape[ax3]

    def impl(A, keys):
        n2 = A.shape[1]
        blk_rows = n2 // p2   # Omega block rows  (contraction dim)
        blk_cols = r // p3    # Omega block cols

        def body(a_blk):
            j = jax.lax.axis_index(ax2)
            k = jax.lax.axis_index(ax3)
            # All-Gather A_ij over the p3 fiber (tiled along columns).
            if p3 == 1:
                a_ij = a_blk                  # regime-1 grids: no collective
            else:
                a_ij = jax.lax.all_gather(a_blk, ax3, axis=1, tiled=True)
            # Regenerate Omega_jk locally — zero communication.  The
            # backend decides whether the block lives in HBM (jnp) or only
            # in VMEM inside the fused kernel (pallas).
            b_partial = sketch_block(
                a_ij, keys, blk_cols, row0=j * blk_rows, col0=k * blk_cols,
                kind=kind, salt=salt, scale=scale, precision=precision,
                backend=backend, blocks=blocks)
            # Reduce-Scatter B_ik over the p2 fiber (tiled along rows).
            if p2 == 1:
                return b_partial
            return jax.lax.psum_scatter(b_partial, ax2, scatter_dimension=0,
                                        tiled=True)

        kw = {} if backend == "jnp" else {"check_rep": False}
        return shard_map(
            body, mesh=mesh,
            in_specs=P(ax1, (ax2, ax3)),
            out_specs=P((ax1, ax2), ax3), **kw)(A)

    return jax.jit(impl)


def rand_matmul_auto(A, seed: int, r: int, P_procs: Optional[int] = None,
                     kind: str = "normal", devices=None, grid="auto",
                     plan=None, backend: str = "auto", blocks=None):
    """Alg. 1 with the grid chosen automatically.

    grid:
      * ``"auto"`` — the paper's §4.3 optimal grid (``select_matmul_grid``),
        snapped to an executable factorization by the planner when the ideal
        grid does not divide the shape;
      * ``"plan"`` — full cost-model dispatch via :mod:`repro.plan`
        (equivalent to passing ``plan=plan_sketch(...)``);
      * an explicit ``(p1, p2, p3)`` tuple.
    plan: a precomputed :class:`repro.plan.Plan` (wins over ``grid``; its
    backend/blocks decision also wins over the ``backend``/``blocks`` args).
    backend: local GEMM backend (see :func:`rand_matmul`).

    Returns (B, MatmulGrid, mesh).
    """
    from .grid import alg1_bandwidth_words, alg1_latency_hops
    from .lower_bounds import matmul_regime
    validate_kind(kind)
    devices = devices if devices is not None else jax.devices()
    P_procs = P_procs or len(devices)
    n1, n2 = A.shape
    if plan is not None or grid == "plan":
        if plan is None:
            from repro.plan import plan_sketch
            plan = plan_sketch(n1, n2, r, P=P_procs, kind=kind)
        if not plan.executable:
            raise ValueError(
                f"plan {plan.variant!r} for dims={plan.dims}, "
                f"P={plan.n_procs} is analytic-only (no executable grid "
                f"divides the shape)")
        if plan.variant == "alg1" and plan.grid is not None:
            grid = plan.grid
            backend = getattr(plan, "backend", backend) or backend
            if plan.blocks:
                blocks = tuple(plan.blocks[k] for k in ("bm", "bn", "bk"))
        elif plan.variant == "local_xla":
            grid = (1, 1, 1)          # degenerate Alg.-1 grid, same GEMM
        else:
            # kernel variants (pallas_fused) are not mesh programs and are
            # documented as non-bitwise vs the XLA GEMM — don't silently
            # substitute one for the other.
            raise ValueError(f"plan variant {plan.variant!r} is not an "
                             f"Alg.-1 grid plan; call plan.execute instead")
    if grid == "auto":
        g: MatmulGrid = select_matmul_grid(n1, n2, r, P_procs)
        if n1 % (g.p1 * g.p2) or n2 % (g.p2 * g.p3) or n2 % g.p2 or r % g.p3:
            # the §4.3 grid satisfies p_i <= dim_i but not necessarily the
            # entry point's divisibility contract — snap to the cheapest
            # executable factorization (same fallback the planner uses)
            from repro.plan.planner import _best_executable_alg1_grid
            shape = _best_executable_alg1_grid(n1, n2, r, P_procs)
            if shape is None:
                raise ValueError(
                    f"no factorization of P={P_procs} divides "
                    f"({n1}, {n2}, r={r}); pad the shape or change P")
            g = MatmulGrid(*shape, g.regime,
                           alg1_bandwidth_words(n1, n2, r, *shape),
                           alg1_latency_hops(shape[1], shape[2]))
    else:
        p1, p2, p3 = grid
        g = MatmulGrid(p1, p2, p3, matmul_regime(n1, n2, r, P_procs),
                       alg1_bandwidth_words(n1, n2, r, p1, p2, p3),
                       alg1_latency_hops(p2, p3))
    mesh = make_grid_mesh(g.p1, g.p2, g.p3, devices=devices)
    A = jax.device_put(A, input_sharding(mesh))
    return rand_matmul(A, seed, r, mesh, kind=kind, backend=backend,
                       blocks=blocks), g, mesh


# ---------------------------------------------------------------------------
# The anti-pattern, for the Fig.-3 comparison: communicate Omega instead of
# regenerating it.  Only rank (j==0, k==0) "owns" Omega; everyone else
# receives it via All-Gather over (p2, p3) fibers.
# ---------------------------------------------------------------------------

def rand_matmul_communicating(A, seed, r: int, mesh: Mesh,
                              axes: Tuple[str, str, str] = DEFAULT_AXES,
                              kind: str = "normal"):
    """Baseline that COMMUNICATES Omega (paper Fig. 3's losing strategy).

    Omega starts distributed over the full mesh (one copy in the system) and
    is all-gathered by every processor before the local GEMM.  Same result,
    strictly more communication; used by benchmarks/bench_comm_vs_gen.py.
    """
    keys = jnp.stack(seed_keys(seed))
    return _rand_matmul_communicating_prog(r, mesh, tuple(axes), kind)(A, keys)


@functools.lru_cache(maxsize=_PROG_CACHE_SIZE)
def _rand_matmul_communicating_prog(r: int, mesh: Mesh,
                                    axes: Tuple[str, str, str], kind: str):
    ax1, ax2, ax3 = axes
    p2 = mesh.shape[ax2]
    p3 = mesh.shape[ax3]

    def impl(A, keys):
        n2 = A.shape[1]
        # Build Omega once, sharded across the whole mesh (the "one copy").
        om_global = omega_tile(keys, 0, 0, n2, r, kind, A.dtype)
        om_sharding = NamedSharding(mesh, P((ax1, ax2, ax3), None))
        om_global = jax.lax.with_sharding_constraint(om_global, om_sharding)

        blk_rows = n2 // p2
        blk_cols = r // p3

        def body(a_blk, om_blk):
            j = jax.lax.axis_index(ax2)
            k = jax.lax.axis_index(ax3)
            a_ij = jax.lax.all_gather(a_blk, ax3, axis=1, tiled=True)
            # Omega arrives over the network instead of being regenerated:
            om_full = jax.lax.all_gather(om_blk, (ax1, ax2, ax3), axis=0,
                                         tiled=True)
            om = jax.lax.dynamic_slice(
                om_full, (j * blk_rows, k * blk_cols), (blk_rows, blk_cols))
            b_partial = a_ij @ om
            if p2 == 1:
                return b_partial
            return jax.lax.psum_scatter(b_partial, ax2, scatter_dimension=0,
                                        tiled=True)

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(ax1, (ax2, ax3)), P((ax1, ax2, ax3), None)),
            out_specs=P((ax1, ax2), ax3))(A, om_global)

    return jax.jit(impl)
