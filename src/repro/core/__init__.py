"""Core: the paper's contribution — communication-optimal distributed
sketching with random dense matrices, and Nyström approximation."""
from . import rng, lower_bounds, grid, sketch, nystrom  # noqa: F401

from .lower_bounds import (  # noqa: F401
    matmul_lower_bound, matmul_access_lower_bound, matmul_regime,
    nystrom_lower_bound, nystrom_access_lower_bound, nystrom_regime,
    gemm_lower_bound, report_matmul, report_nystrom,
)
from .grid import (  # noqa: F401
    select_matmul_grid, select_nystrom_grids,
    alg1_bandwidth_words, alg2_bandwidth_words,
    alg2_two_grid_executable, select_two_grid_executable,
    two_grid_axis_split, two_grid_shared_mesh,
)
from .sketch import (  # noqa: F401
    DENSE_KINDS, SPARSE_KINDS, VALID_KINDS,
    rand_matmul, rand_matmul_auto, rand_matmul_communicating,
    sketch_reference, sketch_sparse_apply, sparse_omega_map,
    sparse_omega_rows, omega_tile, seed_keys, make_grid_mesh,
    validate_kind,
)
from .nystrom import (  # noqa: F401
    nystrom_reference, nystrom_no_redist, nystrom_redist, nystrom_general,
    nystrom_two_grid, nystrom_two_grid_fused, nystrom_auto,
    nystrom_second_stage_no_redist, nystrom_second_stage_redist,
    nystrom_second_stage_two_grid, nystrom_second_stage_two_grid_fused,
    reconstruct, relative_error,
)
