"""Counter-based pseudorandom generation for communication-free sketching.

The paper's central systems insight (§6.3) is that a dense random sketching
matrix Omega never needs to be *communicated*: any processor can regenerate
exactly the block it consumes from a shared seed using a counter-based PRNG
(they use Philox-4x32-10 via MKL/cuRAND).  This module provides two
realizations of that insight:

1. ``block_omega`` / ``omega_full`` — JAX-native. JAX's threefry PRNG is
   itself counter-based, so ``fold_in(key, linear_block_index)`` gives a
   deterministic, device-local, communication-free block of Omega.  The block
   grid is defined *globally* (independent of the mesh), so any processor
   grid regenerates bit-identical entries — this is what makes the
   distributed algorithms bitwise-equal to the single-device reference.

2. ``philox_4x32`` / ``philox_uniform`` / ``philox_normal`` — a pure-jnp
   Philox-4x32-10 (the paper's exact generator, Salmon et al. SC'11),
   written only with uint32 ops and 16-bit-limb multiplies so the identical
   bitstream is reproducible inside a Pallas TPU kernel (no 64-bit multiply
   on the TPU VPU).  ``kernels/sketch_matmul.py`` consumes these helpers to
   generate Omega tiles in VMEM, and ``kernels/ref.py`` uses them as the
   bitwise oracle.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Philox-4x32-10 in pure jnp uint32 ops (TPU-VPU compatible: no 64-bit mult)
# ---------------------------------------------------------------------------

PHILOX_M0 = np.uint32(0xD2511F53)
PHILOX_M1 = np.uint32(0xCD9E8D57)
PHILOX_W0 = np.uint32(0x9E3779B9)  # golden ratio
PHILOX_W1 = np.uint32(0xBB67AE85)  # sqrt(3) - 1
PHILOX_ROUNDS = 10


def _u32(x):
    return jnp.asarray(x, jnp.uint32)


def _mulhilo32(a, b):
    """(hi, lo) of the 32x32->64 bit product using 16-bit limbs.

    TPU VPU has no 64-bit integer multiply; CUDA's ``mulhi.u32`` must be
    re-derived via schoolbook 16-bit limbs so the same code runs in a Pallas
    kernel body and in plain jnp.
    """
    a = _u32(a)
    b = _u32(b)
    a_lo = a & 0xFFFF
    a_hi = a >> 16
    b_lo = b & 0xFFFF
    b_hi = b >> 16

    ll = a_lo * b_lo                     # <= (2^16-1)^2 < 2^32, exact in u32
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    hh = a_hi * b_hi

    # low 32 bits: ll + ((lh + hl) << 16)  (mod 2^32)
    mid = lh + hl                         # may wrap; handle carry manually
    mid_carry = _u32(mid < lh)            # wrapped iff result < an addend
    lo = ll + (mid << 16)
    lo_carry = _u32(lo < ll)
    # high 32 bits: hh + (mid >> 16) + (mid_carry << 16) + carry from lo
    hi = hh + (mid >> 16) + (mid_carry << 16) + lo_carry
    return hi, lo


def _philox_round(c0, c1, c2, c3, k0, k1):
    hi0, lo0 = _mulhilo32(PHILOX_M0, c0)
    hi1, lo1 = _mulhilo32(PHILOX_M1, c2)
    n0 = hi1 ^ c1 ^ k0
    n1 = lo1
    n2 = hi0 ^ c3 ^ k1
    n3 = lo0
    return n0, n1, n2, n3


def philox_4x32(counter: Tuple[jnp.ndarray, ...], key: Tuple[jnp.ndarray, jnp.ndarray],
                rounds: int = PHILOX_ROUNDS):
    """Philox-4x32 with ``rounds`` rounds (default 10, the standard).

    ``counter`` is a 4-tuple and ``key`` a 2-tuple of uint32 arrays of any
    broadcastable shape. Returns 4 uint32 arrays of the broadcast shape.
    """
    c0, c1, c2, c3 = (_u32(c) for c in counter)
    k0, k1 = _u32(key[0]), _u32(key[1])
    for _ in range(rounds):
        c0, c1, c2, c3 = _philox_round(c0, c1, c2, c3, k0, k1)
        k0 = k0 + PHILOX_W0
        k1 = k1 + PHILOX_W1
    return c0, c1, c2, c3


def _uniform_from_u32(bits):
    """uint32 -> float32 uniform in [0, 1) with 24-bit mantissa usage."""
    return (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


# ---------------------------------------------------------------------------
# Bit-exact normal generation (Irwin-Hall / CLT-12).
#
# Why not Box-Muller: jnp.log / jnp.cos lower to backend libm or SIMD
# approximations whose rounding differs between vector widths — the same
# input value can yield different low bits depending on the *shape* of the
# array it sits in (vector body vs. scalar remainder lane).  And any
# hand-rolled polynomial replacement is context-dependent instead: inside a
# jit fusion XLA's CPU backend contracts mul+add chains into FMAs, so even
# plain `a*b + c` rounds differently eager vs. jitted.  Either way the tile
# shape or the consumer's compilation context leaks into Omega's bits,
# breaking the regenerate-don't-communicate determinism contract.
#
# The Irwin-Hall transform has NO roundable float arithmetic at all:
#
#     z = (sum of 12 uniform 24-bit integers - 6*2^24) * 2^-24
#
# Integer adds are exact; the int->float convert is correctly rounded by
# IEEE on every backend; the final scale is a power of two (exponent shift,
# exact).  The entry bits therefore depend on nothing but (seed, salt,
# global coordinate) — invariant to tiling, fusion, vectorization, and
# backend.  Statistically: mean 0, variance 12 * (1/12) = 1, support
# [-6, 6] (subgaussian), which preserves every sketching guarantee used
# here (JL-type embeddings need only subgaussian entries).  Costs 3 Philox
# invocations per entry (12 lanes) instead of Box-Muller's 1.
# ---------------------------------------------------------------------------


def philox_uniform_grid(key0: jnp.ndarray, key1: jnp.ndarray,
                        row0: jnp.ndarray, col0: jnp.ndarray,
                        rows: int, cols: int,
                        salt: int = 0) -> jnp.ndarray:
    """A (rows, cols) float32 uniform[0,1) tile.

    Entry (i, j) depends only on the *global* coordinates
    (row0 + i, col0 + j) and the key — independent of the tiling — so any
    tile decomposition regenerates identical values (the paper's
    regenerate-don't-communicate invariant at tile granularity).
    """
    gi = row0 + jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
    gj = col0 + jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    r0, r1, r2, r3 = philox_4x32(
        (gi, gj, _u32(salt) + jnp.zeros_like(gi), jnp.zeros_like(gi)),
        (key0, key1))
    del r1, r2, r3
    return _uniform_from_u32(r0)


def philox_normal_grid(key0: jnp.ndarray, key1: jnp.ndarray,
                       row0: jnp.ndarray, col0: jnp.ndarray,
                       rows: int, cols: int,
                       salt: int = 0) -> jnp.ndarray:
    """A (rows, cols) float32 ~N(0,1) tile, bit-exact on every backend.

    Irwin-Hall: the sum of 12 uniform 24-bit lanes, centered and scaled —
    see the block comment above for why this beats Box-Muller here (zero
    roundable float ops => entry bits depend only on seed/salt/global
    coordinate, never on tile shape or fusion context).  Three Philox
    invocations per entry; the sub-counter lives in counter lane c3
    (offset by 1 so the normal stream never aliases the uniform stream's
    c3 = 0 block).
    """
    gi = row0 + jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
    gj = col0 + jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    salt_c = _u32(salt) + jnp.zeros_like(gi)
    total = jnp.zeros_like(gi)                         # uint32; max 12*2^24
    for sub in range(3):
        r0, r1, r2, r3 = philox_4x32(
            (gi, gj, salt_c, _u32(sub + 1) + jnp.zeros_like(gi)),
            (key0, key1))
        total = total + (r0 >> 8) + (r1 >> 8) + (r2 >> 8) + (r3 >> 8)
    d = total.astype(jnp.int32) - jnp.int32(6 * (1 << 24))   # exact
    return d.astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


# ---------------------------------------------------------------------------
# Sparse sketch family draws (CountSketch buckets/signs + coordinated
# sampling membership).  Same determinism contract as the grids above:
# pure uint32 Philox on GLOBAL coordinates, zero roundable float ops, so
# every draw is bitwise invariant to tiling, shard offsets, and fusion
# context.  Counter-lane budget under one salt: c3 = 0 is the uniform
# grid, c3 in {1, 2, 3} the Irwin-Hall sub-draws, c3 = 4 the bucket/sign
# stream, c3 = 5 the sampling-membership stream — the five streams never
# alias.  Draws are PER ROW (counter (g, 0, salt, c3) with g the global
# row index), which is what makes a sparse Omega tile-decomposable: any
# column slice of row g sees the same (bucket, sign, membership).
# ---------------------------------------------------------------------------

COUNTSKETCH_LANE = 4   # c3 lane of the bucket/sign stream
ROWSAMPLE_LANE = 5     # c3 lane of the coordinated-membership stream


def philox_countsketch_rows(key0: jnp.ndarray, key1: jnp.ndarray,
                            g, r: int, salt: int = 0):
    """(bucket, sign) draws for global Omega rows ``g`` (uint32 array or a
    scalar offset; any shape).

    One Philox invocation per row at counter ``(g, 0, salt, 4)``: bucket
    is ``r0 mod r`` (uint32 — the ~r/2^32 modulo bias is negligible and
    deterministic, the same convention scipy's Clarkson-Woodruff transform
    uses), sign is the low bit of ``r1`` mapped to float32 +-1.  Row g's
    draw depends only on (key, salt, g) — never on which tile asked.
    """
    g = _u32(g)
    z = jnp.zeros_like(g)
    r0, r1, r2, r3 = philox_4x32(
        (g, z, _u32(salt) + z, _u32(COUNTSKETCH_LANE) + z), (key0, key1))
    del r2, r3
    bucket = r0 % _u32(r)
    sign = jnp.where((r1 & 1) == 1, jnp.float32(1.0), jnp.float32(-1.0))
    return bucket, sign


def philox_rowsample_uniform(key0: jnp.ndarray, key1: jnp.ndarray,
                             g, salt: int = 0) -> jnp.ndarray:
    """Coordinated membership draw u in [0, 1) for global rows ``g``.

    Counter ``(g, 0, salt, 5)``.  "Coordinated" (Daliri-Freire-Li-Musco,
    arXiv 2501.17836): u depends only on (key, salt, g), so two parties
    sketching DIFFERENT matrices under the same seed keep exactly the
    same row subset ``{g : u_g < p}`` — the property their inner-product
    estimators need — without exchanging a byte.
    """
    g = _u32(g)
    z = jnp.zeros_like(g)
    r0, r1, r2, r3 = philox_4x32(
        (g, z, _u32(salt) + z, _u32(ROWSAMPLE_LANE) + z), (key0, key1))
    del r1, r2, r3
    return _uniform_from_u32(r0)


def philox_countsketch_grid(key0: jnp.ndarray, key1: jnp.ndarray,
                            row0, col0, rows: int, cols: int,
                            r_total: int, salt: int = 0) -> jnp.ndarray:
    """Materialized (rows, cols) tile of the CountSketch Omega
    (Clarkson-Woodruff): row g carries a single +-1 at column bucket(g)
    of the GLOBAL width ``r_total``; this tile sees the part of it that
    lands in [col0, col0+cols)."""
    g = _u32(row0) + jax.lax.broadcasted_iota(jnp.uint32, (rows,), 0)
    bucket, sign = philox_countsketch_rows(key0, key1, g, r_total, salt)
    gj = _u32(col0) + jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    return jnp.where(bucket[:, None] == gj, sign[:, None], jnp.float32(0.0))


def philox_rowsample_grid(key0: jnp.ndarray, key1: jnp.ndarray,
                          row0, col0, rows: int, cols: int,
                          r_total: int, n_total: int,
                          salt: int = 0) -> jnp.ndarray:
    """Materialized (rows, cols) tile of the coordinated row-sampling
    Omega: row g participates iff its coordinated uniform u_g < p with
    p = min(1, r_total / n_total) (expected r_total sampled rows out of
    the global n_total), and a participating row carries
    sign(g) / sqrt(p) at column bucket(g) — an unbiased sampled
    CountSketch (E[Omega Omega^T] = I) whose row subset is seed-
    coordinated across matrices.  p and 1/sqrt(p) are Python-side
    constants of (r_total, n_total): no traced float op depends on tile
    shape, so entry bits stay tile/context invariant.
    """
    import math
    p = min(1.0, float(r_total) / float(n_total))
    scale = np.float32(1.0 / math.sqrt(p))
    g = _u32(row0) + jax.lax.broadcasted_iota(jnp.uint32, (rows,), 0)
    bucket, sign = philox_countsketch_rows(key0, key1, g, r_total, salt)
    u = philox_rowsample_uniform(key0, key1, g, salt)
    val = jnp.where(u < np.float32(p), sign * scale, jnp.float32(0.0))
    gj = _u32(col0) + jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    return jnp.where(bucket[:, None] == gj, val[:, None], jnp.float32(0.0))


# ---------------------------------------------------------------------------
# JAX-threefry block Omega (used by the distributed shard_map algorithms)
# ---------------------------------------------------------------------------

def _as_key(seed_or_key):
    if isinstance(seed_or_key, (int, np.integer)):
        return jax.random.key(seed_or_key)
    return seed_or_key


@functools.partial(jax.jit, static_argnums=(3, 4, 5, 6, 7))
def block_omega(key, j, k, block_rows: int, block_cols: int,
                n_block_cols: int, dtype=jnp.float32, kind: str = "normal"):
    """Block (j, k) of the global random matrix Omega.

    The (j, k) indexing is over a *global* block grid of
    ``block_rows x block_cols`` tiles covering Omega (n2 x r).  Any processor
    calls this with its own (j, k) — zero communication, deterministic in
    ``key``.  Different (mesh, grid) decompositions must use the *same*
    (block_rows, block_cols) to be bitwise-consistent; `omega_full`
    reassembles the same matrix on one device.
    """
    key = _as_key(key)
    kk = jax.random.fold_in(key, j * n_block_cols + k)
    if kind == "normal":
        return jax.random.normal(kk, (block_rows, block_cols), dtype)
    elif kind == "uniform":
        return jax.random.uniform(kk, (block_rows, block_cols), dtype)
    elif kind == "rademacher":
        return jax.random.rademacher(kk, (block_rows, block_cols), dtype)
    raise ValueError(f"unknown omega kind: {kind}")


def omega_full(key, n2: int, r: int, p2: int, p3: int,
               dtype=jnp.float32, kind: str = "normal"):
    """Assemble the full Omega from its (p2 x p3) block grid on one device.

    Reference/oracle path: must equal the concatenation of every processor's
    ``block_omega`` outputs.
    """
    assert n2 % p2 == 0 and r % p3 == 0, (n2, r, p2, p3)
    br, bc = n2 // p2, r // p3
    rows = []
    for j in range(p2):
        cols = [block_omega(key, j, k, br, bc, p3, dtype, kind)
                for k in range(p3)]
        rows.append(jnp.concatenate(cols, axis=1))
    return jnp.concatenate(rows, axis=0)


def philox_omega_full(seed: int, n2: int, r: int, dtype=jnp.float32,
                      salt: int = 0):
    """Full Omega from the Philox path (tile-decomposition independent)."""
    key0 = _u32(seed & 0xFFFFFFFF)
    key1 = _u32((seed >> 32) & 0xFFFFFFFF)
    return philox_normal_grid(key0, key1, _u32(0), _u32(0), n2, r,
                              salt=salt).astype(dtype)
