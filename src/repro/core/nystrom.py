"""Algorithm 2 — parallel Nyström approximation (paper §5).

Computes the pair  B = A·Omega  (n x r)  and  C = Omega^T·B  (r x r)  for a
symmetric A (n x n), then reconstructs  Ã = B · C† · B^T.

Two 1-D variants exactly as implemented in the paper (§5.3, Fig. 1):

  * ``no_redist`` — p = q = (P, 1, 1).  A is row-sharded; every processor
    regenerates the full Omega; B_i = A_i·Omega needs no communication; the
    second product is a partial-sum C_i = Omega_i^T·B_i reduced with one
    Reduce-Scatter of O(r^2) words.  Best when P < n/r.

  * ``redist`` — p = (P, 1, 1), q = (1, 1, P).  Same first stage, then B is
    re-laid out row-sharded -> column-sharded with one All-to-All of
    O(nr/P) words per processor, and the second product is entirely local.
    Best when P > n/r (the paper's empirical crossover, Fig. 7).

Plus two general two-grid forms of §5.3:

  * ``nystrom_general`` — one mesh: the (q1,q2,q3) grid is a permutation of
    the mesh axes, with XLA inserting the B redistribution (§5.2's
    ``Redistribute``) via a sharding constraint.
  * ``nystrom_two_grid`` — two independent factorizations of the same P
    devices (the form Theorem 3's bound-driven grids take): Alg. 1 on a
    p-grid mesh, an explicit cross-grid redistribution of B (<= nr/P words
    per processor), then the second multiply on a q-grid mesh.  This is the
    executable form of §5.3 approach 1, dispatched by the planner's
    ``alg2_bound_driven`` plans.
  * ``nystrom_two_grid_fused`` — the same algorithm compiled into ONE
    executable: both stages plus the §5.2 ``Redistribute`` (expressed as an
    in-program resharding) over one mesh whose device order serves both
    grids (``core.grid.two_grid_shared_mesh``), so XLA can schedule and
    overlap the redistribution instead of paying ``nystrom_two_grid``'s
    host-mediated ``device_put``.  Dispatched by ``alg2_bound_driven_fused``
    plans; falls back to the cross-mesh path when no shared mesh exists.

The second stages are factored out (``nystrom_second_stage_no_redist`` /
``nystrom_second_stage_redist``) so they can consume any row-sharded B —
the one-shot variants above produce B with the zero-communication first
stage, and the streaming subsystem (``repro.stream``) feeds its accumulated
Y straight into the same code at finalize time.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.obs import ledger as obs_ledger
from repro.obs import trace as obs_trace

from .compat import shard_map
from .sketch import (DEFAULT_AXES, _PROG_CACHE_SIZE, SPARSE_KINDS,
                     input_sharding, make_grid_mesh, omega_tile, rand_matmul,
                     seed_keys, validate_kind)

X_AXIS = "x"


def _check_dense_kind(kind: str) -> None:
    """Eagerly reject bad/sparse kinds before any tracing or device work."""
    validate_kind(kind)
    if kind in SPARSE_KINDS:
        raise NotImplementedError(
            f"omega kind {kind!r}: distributed sparse shard_map bodies are "
            "deferred (ROADMAP item 3); use nystrom_reference, "
            "sketch_sparse_apply, or the local streaming path")


def _fused_audit(n: int, r: int, p, q, backend: str):
    """(predicted words, Theorem-3 floor) of the fused two-grid program —
    the ledger's reference numbers.  The prediction is
    ``plan.model.alg2_fused_cost``: stage collectives plus the in-program
    §5.2 Redistribute min-cut (stage 1 contributes zero words on the
    streamed-finalize (P, 1, 1) p-grid)."""
    from repro.plan import model as M
    from .lower_bounds import nystrom_lower_bound
    try:
        floor = nystrom_lower_bound(n, r, p[0] * p[1] * p[2])
    except ValueError:                  # paper assumes r < n
        floor = 0.0
    return float(M.alg2_fused_cost(n, r, tuple(p), tuple(q),
                                   backend=backend).words), float(floor)


# ---------------------------------------------------------------------------
# Reference (single device)
# ---------------------------------------------------------------------------

def nystrom_reference(A, seed: int, r: int, kind: str = "normal"):
    """(B, C) on one device with the same Philox Omega as distributed runs."""
    validate_kind(kind)
    n = A.shape[0]
    om = omega_tile(seed, 0, 0, n, r, kind, A.dtype)
    B = A @ om
    C = om.T @ B
    return B, C


def _default_rcond(dtype) -> float:
    """Paper §6.2 uses 1e-12 — appropriate for their FP64 runs.  In reduced
    precision the cutoff must sit above the noise floor of the dtype."""
    if dtype == jnp.float64:
        return 1e-12
    return 1e-6


def reconstruct(B, C, rcond: Optional[float] = None):
    """Ã = B C† B^T with a numerically-tolerant pseudoinverse.

    C = Omega^T A Omega is symmetric (A symmetric), so the pseudoinverse is
    computed by eigendecomposition with a relative eigenvalue cutoff —
    cheaper and more stable than SVD-based pinv for the PSD-dominated case.
    """
    rcond = _default_rcond(C.dtype) if rcond is None else rcond
    Cs = (C + C.T) / 2
    w, V = jnp.linalg.eigh(Cs)
    cutoff = rcond * jnp.max(jnp.abs(w))
    w_inv = jnp.where(jnp.abs(w) > cutoff, 1.0 / w, 0.0)
    Cd = (V * w_inv[None, :]) @ V.T
    return B @ Cd @ B.T


def relative_error(A, B, C, rcond: Optional[float] = None):
    """|| A - Ã ||_F / || A ||_F  (the paper's Tab. 2 metric)."""
    At = reconstruct(B, C, rcond)
    return jnp.linalg.norm(A - At) / jnp.linalg.norm(A)


# ---------------------------------------------------------------------------
# First stage (shared): B_i = A_i·Omega on a 1-D row-sharded layout
# ---------------------------------------------------------------------------

def _sketch_rows_1d(A, seed, r: int, mesh: Mesh, axis: str, kind: str,
                    backend: str = "jnp", blocks=None):
    """B = A·Omega with A row-sharded; every rank regenerates the full Omega
    (zero communication — the Case-1 grid p=(P,1,1) of Alg. 1)."""
    keys = jnp.stack(seed_keys(seed))
    return _sketch_rows_1d_prog(r, mesh, axis, kind, backend, blocks)(A, keys)


@functools.lru_cache(maxsize=_PROG_CACHE_SIZE)
def _sketch_rows_1d_prog(r: int, mesh: Mesh, axis: str, kind: str,
                         backend: str = "jnp", blocks=None):
    from repro.kernels.local import sketch_block

    def impl(A, keys):
        def body(a_i):                            # a_i: (n/P, n2)
            # full Omega consumed locally; the pallas backend never
            # materializes it in HBM (kernels/local.py)
            return sketch_block(a_i, keys, r, kind=kind, backend=backend,
                                blocks=blocks)    # (n/P, r) — no comm

        kw = {} if backend == "jnp" else {"check_rep": False}
        return shard_map(body, mesh=mesh,
                         in_specs=P(axis, None), out_specs=P(axis, None),
                         **kw)(A)

    return jax.jit(impl)


# ---------------------------------------------------------------------------
# Second stages (shared with the streaming subsystem, repro.stream):
# C = Omega^T·B from a row-sharded B.  The streaming accumulator finalizes
# its Nyström pair by feeding the accumulated Y (= B) straight into these.
# ---------------------------------------------------------------------------

def nystrom_second_stage_no_redist(B, seed, r: int, mesh: Mesh,
                                   axis: str = X_AXIS, kind: str = "normal",
                                   salt: int = 0, backend: str = "jnp",
                                   blocks=None):
    """No-Redist second stage: C = Omega^T·B with B row-sharded (§5.3).

    Each rank forms the partial product Omega_i^T·B_i against its local row
    block and one Reduce-Scatter of r^2 words produces C row-sharded —
    B never moves.  Omega_i is regenerated from global coordinates, so this
    composes bitwise with any producer of B (one-shot or streamed).
    ``backend``: local GEMM body (kernels/local.py) — the pallas backend
    keeps Omega_i out of HBM too.
    """
    _check_dense_kind(kind)
    from repro.kernels.local import resolve_backend
    Pn = mesh.shape[axis]
    n = B.shape[0]
    if n % Pn or r % Pn:
        raise ValueError(f"n={n}, r={r} must divide P={Pn}")
    keys = jnp.stack(seed_keys(seed))
    return _second_stage_no_redist_prog(
        r, mesh, axis, kind, salt, resolve_backend(backend),
        None if blocks is None else tuple(blocks))(B, keys)


@functools.lru_cache(maxsize=_PROG_CACHE_SIZE)
def _second_stage_no_redist_prog(r: int, mesh: Mesh, axis: str, kind: str,
                                 salt: int, backend: str = "jnp",
                                 blocks=None):
    from repro.kernels.local import sketch_t_block
    Pn = mesh.shape[axis]

    def impl(B, keys):
        rows = B.shape[0] // Pn

        def body(b_i):                            # b_i: (n/P, r2)
            i = jax.lax.axis_index(axis)
            c_part = sketch_t_block(b_i, keys, r, row0=i * rows, kind=kind,
                                    salt=salt, backend=backend,
                                    blocks=blocks)    # (r, r2) partial sum
            return jax.lax.psum_scatter(c_part, axis, scatter_dimension=0,
                                        tiled=True)   # (r/P, r2)

        kw = {} if backend == "jnp" else {"check_rep": False}
        return shard_map(body, mesh=mesh,
                         in_specs=P(axis, None), out_specs=P(axis, None),
                         **kw)(B)

    return jax.jit(impl)


def nystrom_second_stage_redist(B, seed, r: int, mesh: Mesh,
                                axis: str = X_AXIS, kind: str = "normal",
                                salt: int = 0, backend: str = "jnp",
                                blocks=None):
    """Redist second stage: re-lay out B and finish locally (§5.3).

    One All-to-All moves nr/P words per processor (row-shard -> column-shard
    re-layout of B); the product C = Omega^T·B is then entirely local.
    Returns (B column-sharded, C column-sharded).
    """
    _check_dense_kind(kind)
    from repro.kernels.local import resolve_backend
    Pn = mesh.shape[axis]
    n = B.shape[0]
    if n % Pn or r % Pn:
        raise ValueError(f"n={n}, r={r} must divide P={Pn}")
    keys = jnp.stack(seed_keys(seed))
    return _second_stage_redist_prog(
        r, mesh, axis, kind, salt, resolve_backend(backend),
        None if blocks is None else tuple(blocks))(B, keys)


@functools.lru_cache(maxsize=_PROG_CACHE_SIZE)
def _second_stage_redist_prog(r: int, mesh: Mesh, axis: str, kind: str,
                              salt: int, backend: str = "jnp", blocks=None):
    from repro.kernels.local import sketch_t_block

    def impl(B, keys):
        def body(b_i):                            # b_i: (n/P, r)
            # Redistribute B: rows-sharded -> cols-sharded (All-to-All).
            b_k = jax.lax.all_to_all(b_i, axis, split_axis=1, concat_axis=0,
                                     tiled=True)  # (n, r/P)
            c_k = sketch_t_block(b_k, keys, r, kind=kind, salt=salt,
                                 backend=backend, blocks=blocks)
            return b_k, c_k                       # (r, r/P) — local

        kw = {} if backend == "jnp" else {"check_rep": False}
        return shard_map(body, mesh=mesh,
                         in_specs=P(axis, None),
                         out_specs=(P(None, axis), P(None, axis)), **kw)(B)

    return jax.jit(impl)


# ---------------------------------------------------------------------------
# 1-D No-Redist  (p = q = (P,1,1))
# ---------------------------------------------------------------------------

def nystrom_no_redist(A, seed, r: int, mesh: Mesh,
                      axis: str = X_AXIS, kind: str = "normal",
                      backend: str = "auto", blocks=None):
    """Paper's No-Redist variant.

    in : A row-sharded P(x, None)
    out: B row-sharded P(x, None); C row-sharded P(x, None)
    comm: one Reduce-Scatter of r^2 words (the (1-1/P)·r^2 term).
    backend: local GEMM body for both stages (kernels/local.py).
    """
    _check_dense_kind(kind)
    from repro.kernels.local import resolve_backend
    backend = resolve_backend(backend)
    blocks = None if blocks is None else tuple(blocks)
    Pn = mesh.shape[axis]
    n = A.shape[0]
    if n % Pn or r % Pn:
        raise ValueError(f"n={n}, r={r} must divide P={Pn}")
    B = _sketch_rows_1d(A, seed, r, mesh, axis, kind, backend, blocks)
    C = nystrom_second_stage_no_redist(B, seed, r, mesh, axis, kind,
                                       backend=backend, blocks=blocks)
    return B, C


# ---------------------------------------------------------------------------
# 1-D Redist  (p = (P,1,1), q = (1,1,P))
# ---------------------------------------------------------------------------

def nystrom_redist(A, seed, r: int, mesh: Mesh,
                   axis: str = X_AXIS, kind: str = "normal",
                   backend: str = "auto", blocks=None):
    """Paper's Redist variant.

    in : A row-sharded P(x, None)
    out: B column-sharded P(None, x); C column-sharded P(None, x)
    comm: one All-to-All moving nr/P words per processor (B row-shard ->
    column-shard re-layout), second multiply fully local.
    backend: local GEMM body for both stages (kernels/local.py).
    """
    _check_dense_kind(kind)
    from repro.kernels.local import resolve_backend
    backend = resolve_backend(backend)
    blocks = None if blocks is None else tuple(blocks)
    Pn = mesh.shape[axis]
    n = A.shape[0]
    if n % Pn or r % Pn:
        raise ValueError(f"n={n}, r={r} must divide P={Pn}")
    B = _sketch_rows_1d(A, seed, r, mesh, axis, kind, backend, blocks)
    return nystrom_second_stage_redist(B, seed, r, mesh, axis, kind,
                                       backend=backend, blocks=blocks)


# ---------------------------------------------------------------------------
# General two-grid Alg. 2
# ---------------------------------------------------------------------------

def nystrom_general(A, seed: int, r: int, mesh: Mesh,
                    p_axes: Tuple[str, str, str] = DEFAULT_AXES,
                    q_axes: Optional[Tuple[str, str, str]] = None,
                    kind: str = "normal", backend: str = "auto",
                    blocks=None):
    """Alg. 2 on arbitrary (p1,p2,p3) / (q1,q2,q3) grids over one mesh.

    Stage 1 is Alg. 1 (``rand_matmul``).  The ``Redistribute`` of §5.2 is
    expressed as a sharding constraint — XLA emits the all-to-all /
    collective-permute exactly where the paper's algorithm places it.
    Stage 2 (C = Omega^T B) mirrors Alg. 1 with the roles of the grid axes
    shifted: all-gather B over q2, generate Omega_{i'j'}, local GEMM,
    reduce-scatter C over q1.  ``backend`` selects the local GEMM body for
    both stages (kernels/local.py).
    """
    _check_dense_kind(kind)
    from repro.kernels.local import resolve_backend
    q_axes = tuple(q_axes or p_axes)
    p_axes = tuple(p_axes)
    q1, q2, q3 = (mesh.shape[a] for a in q_axes)
    n = A.shape[0]
    if n % q1 or r % (q2 * q3) or r % q2 or r % q3:
        raise ValueError(f"(n={n}, r={r}) not divisible by q-grid "
                         f"({q1},{q2},{q3})")
    keys = jnp.stack(seed_keys(seed))
    return _nystrom_general_prog(
        r, mesh, p_axes, q_axes, kind, resolve_backend(backend),
        None if blocks is None else tuple(blocks))(A, keys)


@functools.lru_cache(maxsize=_PROG_CACHE_SIZE)
def _nystrom_general_prog(r: int, mesh: Mesh,
                          p_axes: Tuple[str, str, str],
                          q_axes: Tuple[str, str, str], kind: str,
                          backend: str = "jnp", blocks=None):
    from repro.kernels.local import sketch_t_block
    a1, a2, a3 = q_axes
    q1, q2, q3 = (mesh.shape[a] for a in q_axes)

    def impl(A, keys):
        n = A.shape[0]
        B = rand_matmul(A, keys, r, mesh, axes=p_axes, kind=kind,
                        backend=backend, blocks=blocks)

        # Redistribute B into the stage-2 layout: rows over q1, cols over
        # (q3, q2) — each block B_{i'k'} split column-wise across q2.
        B = jax.lax.with_sharding_constraint(
            B, NamedSharding(mesh, P(a1, (a3, a2))))
        om_rows = n // q1
        om_cols = r // q2

        def stage2(b_blk):                        # (n/q1, r/(q3 q2))
            i = jax.lax.axis_index(a1)
            j = jax.lax.axis_index(a2)
            b_ik = jax.lax.all_gather(b_blk, a2, axis=1, tiled=True)
            c_part = sketch_t_block(b_ik, keys, om_cols, row0=i * om_rows,
                                    col0=j * om_cols, kind=kind,
                                    backend=backend, blocks=blocks)
            if q1 == 1:                           # (r/q2, r/q3) partial
                return c_part
            return jax.lax.psum_scatter(c_part, a1, scatter_dimension=0,
                                        tiled=True)

        kw = {} if backend == "jnp" else {"check_rep": False}
        C = shard_map(stage2, mesh=mesh,
                      in_specs=P(a1, (a3, a2)),
                      out_specs=P((a2, a1), a3), **kw)(B)
        return B, C

    return jax.jit(impl)


# ---------------------------------------------------------------------------
# Bound-driven general two-grid Alg. 2 (§5.3 approach 1): stage 1 on a
# (p1,p2,p3) grid, stage 2 on an arbitrary (q1,q2,q3) grid over the SAME
# devices, with the §5.2 ``Redistribute`` of B made explicit between them.
# Unlike ``nystrom_general`` (one mesh, q a permutation of p's axes), the two
# grids here are independent factorizations of P — the form Theorem 3's
# bound-driven grids actually take.
# ---------------------------------------------------------------------------

Q_AXES = ("q1", "q2", "q3")


def _two_grid_devices(mesh, devices):
    if devices is not None:
        return list(devices)
    if mesh is not None:
        return list(mesh.devices.flat)
    return jax.devices()


def nystrom_second_stage_two_grid(B, seed, r: int, q: Tuple[int, int, int],
                                  mesh: Optional[Mesh] = None, devices=None,
                                  kind: str = "normal", salt: int = 0,
                                  backend: str = "auto", blocks=None):
    """Stage 2 of Alg. 2 on an arbitrary (q1, q2, q3) grid (§5.3).

    Accepts B = A·Omega in ANY sharding (one-shot stage-1 output or a
    streamed accumulator's Y) and re-lays it out P(q1, (q3, q2)) — the
    cross-grid ``Redistribute`` of §5.2, at most nr/P words per processor.
    Then, mirroring Alg. 1 with the grid roles shifted: All-Gather B over
    q2, regenerate Omega_{i'j'} from global coordinates (zero
    communication), local GEMM, Reduce-Scatter C over q1.

    Returns (B sharded P(q1, (q3, q2)), C sharded P((q2, q1), q3)) on the
    q-grid mesh.  Bitwise note: with q1 == 1 the stage-2 contraction is
    never split, so C is blockwise-bitwise against the single-device
    reference (given a bitwise B).  ``backend`` selects the local GEMM
    body (kernels/local.py) — both backends honor the bitwise note.
    """
    _check_dense_kind(kind)
    from repro.kernels.local import resolve_backend
    q1, q2, q3 = (int(x) for x in q)
    n = B.shape[0]
    if B.shape[1] != r:
        raise ValueError(f"B must be (n, r); got {B.shape} with r={r}")
    if n % q1 or r % (q1 * q2) or r % (q2 * q3):
        raise ValueError(f"(n={n}, r={r}) not divisible by q-grid "
                         f"({q1},{q2},{q3}): needs q1 | n, q1*q2 | r, "
                         f"q2*q3 | r")
    devices = _two_grid_devices(mesh, devices)
    mesh_q = make_grid_mesh(q1, q2, q3, axis_names=Q_AXES, devices=devices)
    # Redistribute: whatever layout B arrives in -> the stage-2 layout.
    B = jax.device_put(
        B, NamedSharding(mesh_q, P(Q_AXES[0], (Q_AXES[2], Q_AXES[1]))))
    keys = jnp.stack(seed_keys(seed))
    C = _two_grid_stage2_prog(
        r, mesh_q, kind, salt, resolve_backend(backend),
        None if blocks is None else tuple(blocks))(B, keys)
    return B, C


@functools.lru_cache(maxsize=_PROG_CACHE_SIZE)
def _two_grid_stage2_prog(r: int, mesh: Mesh, kind: str, salt: int,
                          backend: str = "jnp", blocks=None):
    from repro.kernels.local import sketch_t_block
    a1, a2, a3 = Q_AXES
    q1, q2, q3 = (mesh.shape[a] for a in Q_AXES)

    def impl(B, keys):
        n = B.shape[0]
        om_rows = n // q1
        om_cols = r // q2

        def body(b_blk):                          # (n/q1, r/(q3 q2))
            i = jax.lax.axis_index(a1)
            j = jax.lax.axis_index(a2)
            if q2 == 1:
                b_ik = b_blk
            else:
                b_ik = jax.lax.all_gather(b_blk, a2, axis=1, tiled=True)
            c_part = sketch_t_block(b_ik, keys, om_cols, row0=i * om_rows,
                                    col0=j * om_cols, kind=kind, salt=salt,
                                    backend=backend, blocks=blocks)
            if q1 == 1:                           # (r/q2, r/q3) partial
                return c_part
            return jax.lax.psum_scatter(c_part, a1, scatter_dimension=0,
                                        tiled=True)

        kw = {} if backend == "jnp" else {"check_rep": False}
        return shard_map(body, mesh=mesh,
                         in_specs=P(a1, (a3, a2)),
                         out_specs=P((a2, a1), a3), **kw)(B)

    return jax.jit(impl)


def nystrom_two_grid(A, seed, r: int, mesh: Optional[Mesh] = None,
                     p: Tuple[int, int, int] = None,
                     q: Tuple[int, int, int] = None,
                     kind: str = "normal", devices=None,
                     backend: str = "auto", blocks=None):
    """Alg. 2 with stage 1 on grid ``p`` and stage 2 on grid ``q`` (§5.3).

    The grids are independent factorizations of the same P devices (taken
    from ``mesh``, ``devices``, or ``jax.devices()``), so this executes the
    bound-driven (p, q) pairs of Theorem 3 that ``nystrom_general`` — one
    mesh, shared axis sizes — cannot express.  Stage 1 is Alg. 1 on the
    p-grid mesh; B is then redistributed to the q-grid layout (the §5.2
    ``Redistribute``, <= nr/P words per processor, zero when the layouts
    coincide); stage 2 runs on the q-grid mesh.

    in : A (n x n) in any sharding (re-laid out to the Alg. 1 contract)
    out: B sharded P(q1, (q3, q2)); C sharded P((q2, q1), q3), both on the
         q-grid mesh.
    Bitwise note: with p2 == 1 and q1 == 1 neither contraction is split, so
    (B, C) are bitwise-identical to ``nystrom_reference`` on this backend.
    """
    if p is None or q is None:
        raise ValueError("nystrom_two_grid needs explicit p and q grids "
                         "(use nystrom_auto(variant='bound_driven') to pick "
                         "them from the bound)")
    _check_dense_kind(kind)
    from .grid import alg2_two_grid_executable
    p = tuple(int(x) for x in p)
    q = tuple(int(x) for x in q)
    if p[0] * p[1] * p[2] != q[0] * q[1] * q[2]:
        raise ValueError(f"grids must factor the same P: {p} vs {q}")
    n = A.shape[0]
    if A.shape[1] != n:
        raise ValueError(f"Nyström needs a square A; got {A.shape}")
    if not alg2_two_grid_executable(n, r, p, q):
        raise ValueError(f"(n={n}, r={r}) not divisible by grids p={p}, "
                         f"q={q} (see alg2_two_grid_executable)")
    devices = _two_grid_devices(mesh, devices)
    mesh_p = make_grid_mesh(*p, devices=devices)
    A = jax.device_put(A, input_sharding(mesh_p))
    B = rand_matmul(A, seed, r, mesh_p, kind=kind, backend=backend,
                    blocks=blocks)
    return nystrom_second_stage_two_grid(B, seed, r, q, devices=devices,
                                         kind=kind, backend=backend,
                                         blocks=blocks)


# ---------------------------------------------------------------------------
# Fused single-jit two-grid Alg. 2: stage 1, the §5.2 ``Redistribute``, and
# stage 2 compiled into ONE executable over ONE mesh whose device order
# serves both grids (``core.grid.two_grid_shared_mesh``).  The cross-mesh
# ``device_put`` of ``nystrom_two_grid`` is a host-mediated transfer XLA
# cannot overlap or fuse; here the Redistribute is an in-program
# ``with_sharding_constraint`` the SPMD partitioner lowers to a
# collective-permute / all-to-all inside the compiled program.
# ---------------------------------------------------------------------------

def _spec_entry(names: Tuple[str, ...]):
    """PartitionSpec entry for an axis-name group (None when empty)."""
    if not names:
        return None
    return names[0] if len(names) == 1 else tuple(names)


def _axes_index(mesh: Mesh, names: Tuple[str, ...]):
    """Row-major linear index over an axis-name group (0 when empty) —
    the grouped-axes analogue of ``jax.lax.axis_index`` on a fused axis."""
    if not names:
        return jnp.int32(0)
    idx = None
    for nm in names:
        i = jax.lax.axis_index(nm)
        idx = i if idx is None else idx * mesh.shape[nm] + i
    return idx


def _two_grid_stage2_body(shared, r: int, n: int, kind: str, salt: int,
                          backend: str, blocks, keys):
    """Stage-2 shard_map body + specs on a shared mesh's q-axis groups.

    Mirrors ``_two_grid_stage2_prog`` with every single-axis collective /
    axis_index generalized to the q group; grouped collectives concatenate
    and reduce in the same row-major participant order as the standalone
    q-grid mesh, preserving the bitwise contract.
    """
    from repro.kernels.local import sketch_t_block
    mesh = shared.mesh
    qa1, qa2, qa3 = shared.q_axes
    q1, q2, q3 = shared.q
    om_rows = n // q1
    om_cols = r // q2

    def body(b_blk):                              # (n/q1, r/(q3 q2))
        i = _axes_index(mesh, qa1)
        j = _axes_index(mesh, qa2)
        if q2 == 1:
            b_ik = b_blk
        else:
            b_ik = jax.lax.all_gather(b_blk, qa2, axis=1, tiled=True)
        c_part = sketch_t_block(b_ik, keys, om_cols, row0=i * om_rows,
                                col0=j * om_cols, kind=kind, salt=salt,
                                backend=backend, blocks=blocks)
        if q1 == 1:                               # (r/q2, r/q3) partial
            return c_part
        return jax.lax.psum_scatter(c_part, qa1, scatter_dimension=0,
                                    tiled=True)

    in_spec = P(_spec_entry(qa1), _spec_entry(qa3 + qa2))
    out_spec = P(_spec_entry(qa2 + qa1), _spec_entry(qa3))
    return body, in_spec, out_spec


@functools.lru_cache(maxsize=_PROG_CACHE_SIZE)
def _nystrom_two_grid_fused_prog(r: int, shared, kind: str,
                                 backend: str = "jnp", blocks=None):
    """One jitted program: Alg. 1 on the p-axis groups, the in-program
    Redistribute of B, and stage 2 on the q-axis groups."""
    from repro.kernels.local import sketch_block
    mesh = shared.mesh
    pa1, pa2, pa3 = shared.p_axes
    p1, p2, p3 = shared.p
    in_spec = P(_spec_entry(pa1), _spec_entry(pa2 + pa3))
    b_p_spec = P(_spec_entry(pa1 + pa2), _spec_entry(pa3))
    kw = {} if backend == "jnp" else {"check_rep": False}

    def impl(A, keys):
        n = A.shape[0]
        blk_rows = n // p2
        blk_cols = r // p3

        def stage1(a_blk):
            j = _axes_index(mesh, pa2)
            k = _axes_index(mesh, pa3)
            if p3 == 1:
                a_ij = a_blk
            else:
                a_ij = jax.lax.all_gather(a_blk, pa3, axis=1, tiled=True)
            b_partial = sketch_block(a_ij, keys, blk_cols,
                                     row0=j * blk_rows, col0=k * blk_cols,
                                     kind=kind, backend=backend,
                                     blocks=blocks)
            if p2 == 1:
                return b_partial
            return jax.lax.psum_scatter(b_partial, pa2,
                                        scatter_dimension=0, tiled=True)

        B = shard_map(stage1, mesh=mesh, in_specs=in_spec,
                      out_specs=b_p_spec, **kw)(A)

        body, s2_in, s2_out = _two_grid_stage2_body(
            shared, r, n, kind, 0, backend, blocks, keys)
        # §5.2 Redistribute, in-program: p-layout of B -> q-layout, one
        # resharding the partitioner compiles into this executable (no
        # host-mediated device_put between the stages).
        B = jax.lax.with_sharding_constraint(
            B, NamedSharding(mesh, s2_in))
        C = shard_map(body, mesh=mesh, in_specs=s2_in, out_specs=s2_out,
                      **kw)(B)
        return B, C

    return jax.jit(impl)


@functools.lru_cache(maxsize=_PROG_CACHE_SIZE)
def _two_grid_stage2_fused_prog(r: int, n: int, shared, kind: str,
                                salt: int, backend: str = "jnp",
                                blocks=None):
    """Redistribute + stage 2 in one jit (streamed-Y finalize: stage 1's B
    is the accumulated Y, already resident on the p-grid layout)."""
    mesh = shared.mesh
    pa1, pa2, pa3 = shared.p_axes
    b_p_spec = P(_spec_entry(pa1 + pa2), _spec_entry(pa3))
    kw = {} if backend == "jnp" else {"check_rep": False}

    def impl(B, keys):
        body, s2_in, s2_out = _two_grid_stage2_body(
            shared, r, n, kind, salt, backend, blocks, keys)
        B = jax.lax.with_sharding_constraint(B, NamedSharding(mesh, s2_in))
        C = shard_map(body, mesh=mesh, in_specs=s2_in, out_specs=s2_out,
                      **kw)(B)
        return B, C

    return jax.jit(impl), b_p_spec


def nystrom_second_stage_two_grid_fused(B, seed, r: int,
                                        q: Tuple[int, int, int],
                                        p: Optional[Tuple[int, int, int]]
                                        = None,
                                        mesh: Optional[Mesh] = None,
                                        devices=None, kind: str = "normal",
                                        salt: int = 0,
                                        backend: str = "auto", blocks=None):
    """Stage 2 of Alg. 2 on the q-grid with the Redistribute in-program.

    Like :func:`nystrom_second_stage_two_grid` but the §5.2 re-layout of B
    and the stage-2 collectives compile into ONE executable on the shared
    mesh of (p, q) — ``p`` names the layout B arrives in (default the
    streamed accumulator's (P, 1, 1) row-sharded grid, for which the
    shared mesh always exists).  Falls back to the cross-mesh path when no
    single device assignment serves both grids.
    """
    _check_dense_kind(kind)
    from repro.kernels.local import resolve_backend
    from .grid import two_grid_shared_mesh
    q = tuple(int(x) for x in q)
    n = B.shape[0]
    if B.shape[1] != r:
        raise ValueError(f"B must be (n, r); got {B.shape} with r={r}")
    q1, q2, q3 = q
    if n % q1 or r % (q1 * q2) or r % (q2 * q3):
        raise ValueError(f"(n={n}, r={r}) not divisible by q-grid "
                         f"({q1},{q2},{q3}): needs q1 | n, q1*q2 | r, "
                         f"q2*q3 | r")
    devices = _two_grid_devices(mesh, devices)
    Pn = q1 * q2 * q3
    p = (Pn, 1, 1) if p is None else tuple(int(x) for x in p)
    shared = two_grid_shared_mesh(p, q, devices=devices)
    if shared is None:
        return nystrom_second_stage_two_grid(B, seed, r, q, devices=devices,
                                             kind=kind, salt=salt,
                                             backend=backend, blocks=blocks)
    backend = resolve_backend(backend)
    blocks = None if blocks is None else tuple(blocks)
    fn, b_p_spec = _two_grid_stage2_fused_prog(r, n, shared, kind, salt,
                                               backend, blocks)
    # placement onto the shared mesh in the p-grid layout.  When B already
    # lives in that layout — the streamed-finalize case: nystrom_finalize
    # gates on a (P,1,1) accumulator grid, whose Y layout P((p1,p2),p3)
    # IS b_p_spec — the shared mesh assigns devices exactly as the p-grid
    # mesh does, so this moves no bytes between devices and the actual
    # re-layout happens inside the compiled program.  A B arriving in some
    # other sharding gets re-laid out by this device_put first (same
    # host-mediated cost the cross-mesh path pays on every call).
    B = jax.device_put(B, NamedSharding(shared.mesh, b_p_spec))
    keys = jnp.stack(seed_keys(seed))
    led = obs_ledger.get_ledger()
    if led is not None:
        pred, floor = _fused_audit(n, r, p, q, backend)
        led.observe("nystrom.stage2_two_grid_fused", fn, (B, keys),
                    predicted_words=pred, lower_bound_words=floor,
                    itemsize=jnp.dtype(B.dtype).itemsize)
    with obs_trace.span("nystrom.stage2_two_grid_fused", cat="nystrom",
                        n=n, r=r, p=list(p), q=list(q)):
        return fn(B, keys)


def nystrom_two_grid_fused(A, seed, r: int, mesh: Optional[Mesh] = None,
                           p: Tuple[int, int, int] = None,
                           q: Tuple[int, int, int] = None,
                           kind: str = "normal", devices=None,
                           backend: str = "auto", blocks=None):
    """Alg. 2 with both stages AND the §5.2 Redistribute in one jit (§5.3).

    Same contract as :func:`nystrom_two_grid` — independent (p, q)
    factorizations of P, B returned in the q layout, bitwise
    ``nystrom_reference`` when p2 == 1 and q1 == 1 — but compiled as a
    single executable over the shared mesh of
    :func:`repro.core.grid.two_grid_shared_mesh`: the cross-grid
    redistribution of B is an in-program resharding (still <= nr/P words
    per processor, emitted as an all-to-all / collective-permute the
    compiler can overlap) instead of a host-mediated ``device_put``.
    Falls back to :func:`nystrom_two_grid` when no single device
    assignment serves both grids (``two_grid_shared_mesh`` returns None).
    """
    if p is None or q is None:
        raise ValueError("nystrom_two_grid_fused needs explicit p and q "
                         "grids (use nystrom_auto(variant='bound_driven') "
                         "to pick them from the bound)")
    _check_dense_kind(kind)
    from repro.kernels.local import resolve_backend
    from .grid import alg2_two_grid_executable, two_grid_shared_mesh
    p = tuple(int(x) for x in p)
    q = tuple(int(x) for x in q)
    if p[0] * p[1] * p[2] != q[0] * q[1] * q[2]:
        raise ValueError(f"grids must factor the same P: {p} vs {q}")
    n = A.shape[0]
    if A.shape[1] != n:
        raise ValueError(f"Nyström needs a square A; got {A.shape}")
    if not alg2_two_grid_executable(n, r, p, q):
        raise ValueError(f"(n={n}, r={r}) not divisible by grids p={p}, "
                         f"q={q} (see alg2_two_grid_executable)")
    devices = _two_grid_devices(mesh, devices)
    shared = two_grid_shared_mesh(p, q, devices=devices)
    if shared is None:
        # no device-order reconciliation: the two-mesh path with its
        # explicit cross-mesh Redistribute is the only executable form
        return nystrom_two_grid(A, seed, r, p=p, q=q, kind=kind,
                                devices=devices, backend=backend,
                                blocks=blocks)
    backend = resolve_backend(backend)
    blocks = None if blocks is None else tuple(blocks)
    pa1, pa2, pa3 = shared.p_axes
    A = jax.device_put(
        A, NamedSharding(shared.mesh,
                         P(_spec_entry(pa1), _spec_entry(pa2 + pa3))))
    keys = jnp.stack(seed_keys(seed))
    fn = _nystrom_two_grid_fused_prog(r, shared, kind, backend, blocks)
    led = obs_ledger.get_ledger()
    if led is not None:
        pred, floor = _fused_audit(n, r, p, q, backend)
        led.observe("nystrom.two_grid_fused", fn, (A, keys),
                    predicted_words=pred, lower_bound_words=floor,
                    itemsize=jnp.dtype(A.dtype).itemsize)
    with obs_trace.span("nystrom.two_grid_fused", cat="nystrom",
                        n=n, r=r, p=list(p), q=list(q)):
        return fn(A, keys)


# ---------------------------------------------------------------------------
# Convenience driver
# ---------------------------------------------------------------------------

def nystrom_auto(A, seed: int, r: int, variant: str = "auto", devices=None,
                 kind: str = "normal", plan=None, backend: str = "auto",
                 blocks=None):
    """Run the paper-preferred variant on a 1-D mesh over all devices.

    variant:
      * ``"auto"``   — the paper's empirical rule (redist iff P > n/r);
      * ``"plan"``   — cost-model dispatch via :mod:`repro.plan` (prices the
        redist all-to-all against the no_redist reduce-scatter on the
        machine model, so latency-dominated small problems may legitimately
        deviate from the bandwidth-only rule);
      * ``"bound_driven"`` — the §5.3 general two-grid algorithm on the
        Theorem-3 bound-driven (p, q) pair, snapped to the min-words
        executable factorization pair when the ideal grids do not divide
        (``core.grid.select_two_grid_executable``); runs the single-jit
        fused program (``nystrom_two_grid_fused`` — in-program §5.2
        Redistribute) whenever the pair admits a shared mesh, else the
        cross-mesh two-grid path;
      * ``"redist"`` / ``"no_redist"`` — explicit.
    plan: a precomputed :class:`repro.plan.Plan` (wins over ``variant``;
    its backend decision also wins over the ``backend`` arg).
    backend: local GEMM body for every stage (kernels/local.py).
    """
    _check_dense_kind(kind)
    devices = devices if devices is not None else jax.devices()
    Pn = len(devices)
    n = A.shape[0]
    if plan is not None or variant == "plan":
        if plan is None:
            from repro.plan import plan_nystrom
            plan = plan_nystrom(n, r, P=Pn, kind=kind)
        if not plan.executable:
            raise ValueError(
                f"plan {plan.variant!r} for dims={plan.dims}, "
                f"P={plan.n_procs} is analytic-only (no executable grid "
                f"pair divides the shape)")
        backend = getattr(plan, "backend", backend) or backend
        if plan.blocks and plan.variant != "pallas_fused":
            blocks = tuple(plan.blocks[k] for k in ("bm", "bn", "bk"))
        if plan.variant in ("alg2_bound_driven", "alg2_bound_driven_fused"):
            fn = (nystrom_two_grid_fused
                  if plan.variant == "alg2_bound_driven_fused"
                  else nystrom_two_grid)
            B, C = fn(A, seed, r, p=plan.grid, q=plan.q_grid, kind=kind,
                      devices=list(devices[: plan.n_procs]),
                      backend=backend, blocks=blocks)
            mesh_q = make_grid_mesh(*plan.q_grid, axis_names=Q_AXES,
                                    devices=list(devices[: plan.n_procs]))
            return B, C, mesh_q, "bound_driven"
        variant = {"alg2_no_redist": "no_redist", "alg2_redist": "redist",
                   "local_xla": "no_redist"}.get(plan.variant)
        if variant is None:
            # pallas_fused is a kernel variant (non-bitwise vs the XLA
            # path), not a 1-D mesh program — dispatch it via the plan.
            raise ValueError(f"plan variant {plan.variant!r} has no 1-D "
                             f"mesh execution here; call plan.execute "
                             f"instead (or pass variant='auto' to force "
                             f"the mesh path)")
    if variant == "bound_driven":
        from .grid import select_two_grid_executable
        got = select_two_grid_executable(n, r, Pn)
        if got is None:
            raise ValueError(f"no (p, q) factorization pair of P={Pn} "
                             f"divides (n={n}, r={r}); pad the shape or "
                             f"change P")
        p, q, _exact = got
        # prefer the single-jit fused program; it falls back to the
        # cross-mesh two-grid path itself when no shared mesh exists
        B, C = nystrom_two_grid_fused(A, seed, r, p=p, q=q, kind=kind,
                                      devices=list(devices), backend=backend,
                                      blocks=blocks)
        mesh_q = make_grid_mesh(*q, axis_names=Q_AXES, devices=list(devices))
        return B, C, mesh_q, "bound_driven"
    if variant == "auto":
        variant = "redist" if Pn > max(1, n // max(r, 1)) else "no_redist"
    mesh = Mesh(np.asarray(devices), (X_AXIS,))
    A = jax.device_put(A, NamedSharding(mesh, P(X_AXIS, None)))
    if variant == "no_redist":
        B, C = nystrom_no_redist(A, seed, r, mesh, kind=kind,
                                 backend=backend, blocks=blocks)
    elif variant == "redist":
        B, C = nystrom_redist(A, seed, r, mesh, kind=kind,
                              backend=backend, blocks=blocks)
    else:
        raise ValueError(variant)
    return B, C, mesh, variant
