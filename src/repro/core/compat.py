"""Version compatibility shims for the JAX APIs the core algorithms need.

``shard_map`` moved from ``jax.experimental.shard_map`` into the top-level
``jax`` namespace around jax 0.5, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma`` along the way; this repo must run on both (the
pinned CI environment ships 0.4.x).  Everything in ``core/``, ``stream/``,
and ``train/`` imports ``shard_map`` from here instead of reaching into
``jax`` directly.

This module also owns the pallas-TPU VMEM probe (``vmem_scratch``): the
fused kernels allocate their accumulators via ``pltpu.VMEM``, whose import
path is stable across the entire supported jax range (floor 0.4.30, pinned
by the ``jax-floor`` CI job).  The probe runs at import time with an
explicit version check — no blind try/except hiding a dead fallback — so
the jax-floor job exercises it on every PR simply by importing ``repro.core``
(the distributed shard it runs imports this module transitively).
"""
from __future__ import annotations

import inspect
import re

import jax

try:                                                   # jax >= 0.5
    _shard_map = jax.shard_map
except AttributeError:                                 # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = set(inspect.signature(_shard_map).parameters)

# Leading digits only: pre-release suffixes ("0.8.0rc1", "...dev2025")
# must not crash the import-time parse.
JAX_VERSION = tuple(
    int(re.match(r"\d+", x).group()) if re.match(r"\d+", x) else 0
    for x in jax.__version__.split(".")[:3])

# Import-time probe: on every supported jax (>= 0.4.30) the pallas TPU
# namespace is importable on all backends, CPU-only hosts included — the
# interpret-mode kernel tests depend on it.  Below the floor we record the
# reason and fail loudly at *use* time instead of shipping a wrong API call.
if JAX_VERSION >= (0, 4, 30):
    from jax.experimental.pallas import tpu as _pltpu
else:                                                  # pragma: no cover
    _pltpu = None


def vmem_scratch(shape, dtype):
    """A pallas VMEM scratch allocation (the fused kernels' accumulator).

    Single spelling (``pltpu.VMEM``) across the supported range; raises a
    clear error rather than guessing an API below the jax floor.
    """
    if _pltpu is None:                                 # pragma: no cover
        raise RuntimeError(
            f"pallas VMEM scratch needs jax >= 0.4.30; have {jax.__version__}")
    return _pltpu.VMEM(shape, dtype)


def shard_map(f, **kwargs):
    """``jax.shard_map`` with the replication-check kwarg translated to
    whatever name the installed jax understands."""
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, **kwargs)


__all__ = ["shard_map", "vmem_scratch", "JAX_VERSION"]
