"""Version compatibility shims for the JAX APIs the core algorithms need.

``shard_map`` moved from ``jax.experimental.shard_map`` into the top-level
``jax`` namespace around jax 0.5, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma`` along the way; this repo must run on both (the
pinned CI environment ships 0.4.x).  Everything in ``core/``, ``stream/``,
and ``train/`` imports ``shard_map`` from here instead of reaching into
``jax`` directly.
"""
from __future__ import annotations

import inspect

import jax

try:                                                   # jax >= 0.5
    _shard_map = jax.shard_map
except AttributeError:                                 # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(f, **kwargs):
    """``jax.shard_map`` with the replication-check kwarg translated to
    whatever name the installed jax understands."""
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, **kwargs)


__all__ = ["shard_map"]
