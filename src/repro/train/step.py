"""jit-compiled train step factory: loss -> grads -> clip -> AdamW.

Two variants:
  * ``make_train_step``     — GSPMD path (TP/SP/EP via sharding constraints,
    DP reduction emitted by XLA).  Supports gradient accumulation.
  * ``make_dp_compressed_step`` — pure-DP shard_map path where the gradient
    all-reduce is replaced by the paper's sketched compression
    (parallel/grad_compress.py).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.core.compat import shard_map
from repro.models.api import ModelAPI
from repro.models.common import NULL_CTX, ShardCtx
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine
from repro.parallel.grad_compress import (compress_and_allreduce,
                                          init_error_fb)
from .state import TrainState


def init_state(api: ModelAPI, cfg: ModelConfig, run: RunConfig,
               key) -> TrainState:
    params = api.init(key, cfg)
    st = TrainState(params=params, opt=adamw.init(params),
                    step=jnp.zeros((), jnp.int32))
    if run.grad_compress_rank:
        st = st.replace(error_fb=init_error_fb(
            params, run.grad_compress_rank, run.grad_compress_min_dim))
    return st


def make_train_step(api: ModelAPI, cfg: ModelConfig, run: RunConfig,
                    ctx: ShardCtx = NULL_CTX, accum_steps: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return api.loss(params, cfg, batch, ctx=ctx, remat=run.remat)

    def grads_of(params, batch):
        if accum_steps == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        # gradient accumulation over leading microbatch splits
        def micro(carry, mb):
            acc, tot = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            acc = jax.tree_util.tree_map(jnp.add, acc, g)
            return (acc, tot + l), None
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch)
        (g, tot), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)), mbs)
        scale = 1.0 / accum_steps
        g = jax.tree_util.tree_map(lambda x: x * scale, g)
        return tot * scale, g

    def train_step(state: TrainState, batch):
        loss, grads = grads_of(state.params, batch)
        grads, gnorm = adamw.clip_by_global_norm(grads, run.grad_clip)
        lr = warmup_cosine(state.step, peak_lr=run.learning_rate,
                           warmup_steps=run.warmup_steps,
                           total_steps=run.steps)
        new_params, new_opt = adamw.update(
            grads, state.opt, state.params, lr,
            weight_decay=run.weight_decay)
        new_state = TrainState(new_params, new_opt, state.step + 1,
                               state.error_fb)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def make_dp_compressed_step(api: ModelAPI, cfg: ModelConfig, run: RunConfig,
                            mesh, axis: str = "data"):
    """Pure-DP training with the paper's sketched gradient all-reduce.

    Batch is sharded over ``axis``; params/opt replicated.  Inside the
    shard_map body each worker computes grads on its local shard, then the
    cross-replica reduction is the compressed exchange (Omega regenerated
    per (leaf, step) — zero communication for the random operand).
    """
    from repro.parallel.grad_compress import local_fb, stack_fb

    def body(state: TrainState, batch):
        def loss_fn(params):
            return api.loss(params, cfg, batch, ctx=NULL_CTX,
                            remat=run.remat)
        loss, grads = jax.value_and_grad(loss_fn)(state.params)
        loss = jax.lax.pmean(loss, axis)
        # error-feedback buffers are PER-WORKER (sharded over the DP axis)
        grads, fb = compress_and_allreduce(
            grads, local_fb(state.error_fb), step=state.step,
            rank=run.grad_compress_rank,
            min_dim=run.grad_compress_min_dim, axis_name=axis)
        grads, gnorm = adamw.clip_by_global_norm(grads, run.grad_clip)
        lr = warmup_cosine(state.step, peak_lr=run.learning_rate,
                           warmup_steps=run.warmup_steps,
                           total_steps=run.steps)
        new_params, new_opt = adamw.update(
            grads, state.opt, state.params, lr,
            weight_decay=run.weight_decay)
        new_state = TrainState(new_params, new_opt, state.step + 1,
                               stack_fb(fb))
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    def step(state, batch):
        fb_spec = jax.tree_util.tree_map(lambda _: P(axis), state.error_fb)
        in_specs = (
            TrainState(
                params=jax.tree_util.tree_map(lambda _: P(), state.params),
                opt=jax.tree_util.tree_map(lambda _: P(), state.opt),
                step=P(), error_fb=fb_spec),
            jax.tree_util.tree_map(lambda _: P(axis), batch),
        )
        out_specs = (
            TrainState(
                params=jax.tree_util.tree_map(lambda _: P(), state.params),
                opt=jax.tree_util.tree_map(lambda _: P(), state.opt),
                step=P(), error_fb=fb_spec),
            {"loss": P(), "grad_norm": P(), "lr": P()},
        )
        fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
        return fn(state, batch)

    return step
