"""jit-compiled train step factory: loss -> grads -> clip -> AdamW.

Two variants:
  * ``make_train_step``     — GSPMD path (TP/SP/EP via sharding constraints,
    DP reduction emitted by XLA).  Supports gradient accumulation.
  * ``make_dp_compressed_step`` — pure-DP shard_map path where the gradient
    all-reduce is replaced by the paper's sketched compression
    (parallel/grad_compress.py): Theorem 2 regime 1 at the DP axis —
    Omega is regenerated from the counter-based seed (§6.3, zero words),
    only the r·(m+n) factor words move.  Per-leaf raw-vs-sketch is the
    planner's priced decision (plan.plan_train_compression) and every
    dispatch is audited by the comm ledger.  docs/TRAINING.md is the
    user-facing guide.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.core.compat import shard_map
from repro.models.api import ModelAPI
from repro.models.common import NULL_CTX, ShardCtx
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine
from repro.parallel.grad_compress import (compress_and_allreduce,
                                          init_error_fb)
from .state import TrainState


def init_state(api: ModelAPI, cfg: ModelConfig, run: RunConfig,
               key, world: int = 1, decisions=None) -> TrainState:
    """Fresh TrainState; with ``run.grad_compress_rank`` set, zero
    error-feedback buffers ride along (``parallel/grad_compress.py``).

    ``world`` — DP worker count: error-feedback is PER-WORKER state, so
    sharded runs get a leading world axis (sharded P(axis) by
    ``make_dp_compressed_step``).  ``decisions`` — the planner's per-leaf
    compress map (``plan.plan_train_compression(...).decision_tree()``);
    None falls back to the ``run.grad_compress_min_dim`` heuristic.
    """
    params = api.init(key, cfg)
    st = TrainState(params=params, opt=adamw.init(params),
                    step=jnp.zeros((), jnp.int32))
    if run.grad_compress_rank:
        st = st.replace(error_fb=init_error_fb(
            params, run.grad_compress_rank, run.grad_compress_min_dim,
            world=world, decisions=decisions))
    return st


def make_train_step(api: ModelAPI, cfg: ModelConfig, run: RunConfig,
                    ctx: ShardCtx = NULL_CTX, accum_steps: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    The GSPMD baseline: XLA emits the DP gradient all-reduce at the full
    m·n words per weight matrix — the raw side of the Theorem-2 regime-1
    comparison ``make_dp_compressed_step`` wins by r·(m+n) < m·n.
    """

    def loss_fn(params, batch):
        return api.loss(params, cfg, batch, ctx=ctx, remat=run.remat)

    def grads_of(params, batch):
        if accum_steps == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        # gradient accumulation over leading microbatch splits
        def micro(carry, mb):
            acc, tot = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            acc = jax.tree_util.tree_map(jnp.add, acc, g)
            return (acc, tot + l), None
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch)
        (g, tot), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)), mbs)
        scale = 1.0 / accum_steps
        g = jax.tree_util.tree_map(lambda x: x * scale, g)
        return tot * scale, g

    def train_step(state: TrainState, batch):
        loss, grads = grads_of(state.params, batch)
        grads, gnorm = adamw.clip_by_global_norm(grads, run.grad_clip)
        lr = warmup_cosine(state.step, peak_lr=run.learning_rate,
                           warmup_steps=run.warmup_steps,
                           total_steps=run.steps)
        new_params, new_opt = adamw.update(
            grads, state.opt, state.params, lr,
            weight_decay=run.weight_decay)
        new_state = TrainState(new_params, new_opt, state.step + 1,
                               state.error_fb)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def make_dp_compressed_step(api: ModelAPI, cfg: ModelConfig, run: RunConfig,
                            mesh, axis: str = "data", plan=None,
                            backend: str = None):
    """Pure-DP training with the paper's sketched gradient all-reduce
    (§6.3 regenerate-don't-communicate at the DP axis; docs/TRAINING.md).

    Batch is sharded over ``axis``; params/opt replicated.  Inside the
    shard_map body each worker computes grads on its local shard, then the
    cross-replica reduction is the compressed exchange (Omega regenerated
    per (leaf, step) — zero communication for the random operand, r·(m+n)
    words for the data-dependent factors vs the raw m·n).

    Which leaves compress is the PLANNER's per-leaf priced decision:
    ``plan`` is a ``plan.TrainCompressionPlan`` (computed lazily from the
    first state's param shapes when None) whose ``decision_tree()`` the
    body consumes instead of the blanket ``min_dim`` heuristic.  The
    resolved plan is exposed as ``step.plan`` (feed it to
    ``plan.explain_train_compression`` for the per-layer word table).

    The shard_map program is built and jitted ONCE (first call) over the
    flattened arg leaves; each dispatch is observed in the comm ledger
    (site ``train.dp_compressed_step``) against the plan's exchange-word
    prediction — the factor-exchange floor, so drift ≈ 0 certifies the
    schedule moves exactly the words the planner priced.
    """
    from repro.kernels.local import resolve_backend
    from repro.obs import ledger as obs_ledger
    from repro.obs import trace as obs_trace
    from repro.parallel.grad_compress import local_fb, stack_fb
    from repro.plan.planner import plan_train_compression

    backend = resolve_backend(
        backend if backend is not None
        else getattr(run, "grad_compress_backend", "auto"))
    cache = {"plan": plan, "fn": None, "argdef": None}

    def body(state: TrainState, batch):
        def loss_fn(params):
            return api.loss(params, cfg, batch, ctx=NULL_CTX,
                            remat=run.remat)
        loss, grads = jax.value_and_grad(loss_fn)(state.params)
        loss = jax.lax.pmean(loss, axis)              # +1 word (the scalar)
        # error-feedback buffers are PER-WORKER (sharded over the DP axis)
        grads, fb = compress_and_allreduce(
            grads, local_fb(state.error_fb), step=state.step,
            rank=run.grad_compress_rank, axis_name=axis,
            decisions=cache["plan"].decision_tree(), backend=backend)
        grads, gnorm = adamw.clip_by_global_norm(grads, run.grad_clip)
        lr = warmup_cosine(state.step, peak_lr=run.learning_rate,
                           warmup_steps=run.warmup_steps,
                           total_steps=run.steps)
        new_params, new_opt = adamw.update(
            grads, state.opt, state.params, lr,
            weight_decay=run.weight_decay)
        new_state = TrainState(new_params, new_opt, state.step + 1,
                               stack_fb(fb))
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    def _build(state, batch):
        fb_spec = jax.tree_util.tree_map(lambda _: P(axis), state.error_fb)
        state_spec = TrainState(
            params=jax.tree_util.tree_map(lambda _: P(), state.params),
            opt=jax.tree_util.tree_map(lambda _: P(), state.opt),
            step=P(), error_fb=fb_spec)
        in_specs = (state_spec,
                    jax.tree_util.tree_map(lambda _: P(axis), batch))
        out_specs = (state_spec,
                     {"loss": P(), "grad_norm": P(), "lr": P()})
        mapped = shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)
        _, argdef = jax.tree_util.tree_flatten((state, batch))

        # jit over FLAT leaves: compiled once, and the leaf tuple is what
        # the ledger can signature/abstractify (pytrees are unhashable)
        @jax.jit
        def flat_fn(*leaves):
            st, b = jax.tree_util.tree_unflatten(argdef, leaves)
            return mapped(st, b)
        cache["fn"], cache["argdef"] = flat_fn, argdef

    def step(state, batch):
        if cache["plan"] is None:
            cache["plan"] = plan_train_compression(
                state.params, rank=run.grad_compress_rank,
                P=mesh.shape[axis], backend=backend)
        step.plan = cache["plan"]
        if cache["fn"] is None:
            _build(state, batch)
        leaves = jax.tree_util.tree_leaves((state, batch))
        led = obs_ledger.get_ledger()
        site = None
        t0 = time.perf_counter() if led is not None else 0.0
        if led is not None:
            # observe BEFORE dispatch (donation-safe); predicted = the
            # per-leaf exchange words + the loss-scalar pmean, which is
            # also the factor-exchange floor: Omega is free (Thm 2
            # regime 1), the factors and the loss must move
            pred = cache["plan"].exchange_words + 1.0
            site = led.observe("train.dp_compressed_step", cache["fn"],
                               tuple(leaves), predicted_words=pred,
                               lower_bound_words=pred, itemsize=4)
        with obs_trace.span("train.dp_compressed_step", cat="train",
                            axis=axis, rank=run.grad_compress_rank):
            out = cache["fn"](*leaves)
        if site is not None:
            site.wall_s += time.perf_counter() - t0
        return out

    step.plan = plan
    return step
