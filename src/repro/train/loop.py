"""Fault-tolerant training loop.

Features exercised by tests and the end-to-end example:
  * periodic atomic checkpoints (params + optimizer + step + data stream
    position — the stream is step-indexed so restore is bit-exact);
  * crash recovery: any exception (or injected failure) falls back to the
    last checkpoint and resumes; a retry budget bounds crash loops;
  * straggler monitor: EWMA step-time tracker flags > k-sigma outliers
    (on real fleets this feeds preemption/replacement; here it records and
    can trigger a simulated mitigation callback);
  * NaN/overflow guard: non-finite loss skips the update (step is retried
    with the next batch) — the cheap insurance against loss spikes at
    scale.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import RunConfig
from repro.data.pipeline import DataConfig, Pipeline
from .state import TrainState


class StragglerMonitor:
    """EWMA mean/var of step time; flags outliers beyond k sigma."""

    def __init__(self, alpha: float = 0.9, k: float = 3.0):
        self.alpha, self.k = alpha, k
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.flagged: List[Dict] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.mean is None:
            self.mean = dt
            return False
        sigma = max(self.var ** 0.5, 1e-6)
        slow = dt > self.mean + self.k * sigma and dt > 1.5 * self.mean
        if slow:
            self.flagged.append({"step": step, "dt": dt, "mean": self.mean})
        d = dt - self.mean
        self.mean = self.alpha * self.mean + (1 - self.alpha) * dt
        self.var = self.alpha * self.var + (1 - self.alpha) * d * d
        return slow


@dataclasses.dataclass
class LoopResult:
    state: TrainState
    losses: List[float]
    restarts: int
    stragglers: List[Dict]
    checkpoints: List[int]


def train_loop(train_step: Callable, state: TrainState, data_cfg: DataConfig,
               run: RunConfig, *,
               failure_injector: Optional[Callable[[int], None]] = None,
               on_straggler: Optional[Callable[[int], None]] = None,
               state_template=None) -> LoopResult:
    """Run ``run.steps`` steps with checkpoint/restart fault tolerance.

    ``failure_injector(step)`` may raise to simulate a node failure; the
    loop restores the last checkpoint and continues (up to 10 restarts).
    """
    monitor = StragglerMonitor(run.straggler_ewma, run.straggler_sigma)
    losses: List[float] = []
    ckpts: List[int] = []
    restarts = 0
    template = state_template if state_template is not None else state

    start = int(state.step)
    pipe = Pipeline(data_cfg, start_step=start)
    step_i = start
    while step_i < run.steps:
        try:
            batch = next(pipe)
            if failure_injector is not None:
                failure_injector(step_i)
            t0 = time.perf_counter()
            new_state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if monitor.observe(step_i, dt) and on_straggler is not None:
                on_straggler(step_i)
            if not np.isfinite(loss):
                # skip the poisoned update, keep the old state
                step_i += 1
                continue
            state = new_state
            losses.append(loss)
            step_i += 1
            if run.checkpoint_every and step_i % run.checkpoint_every == 0:
                ckpt.save(run.checkpoint_dir, step_i, state,
                          extra={"data": pipe.state()},
                          keep=run.keep_checkpoints)
                ckpts.append(step_i)
        except (KeyboardInterrupt,):
            raise
        except Exception:  # noqa: BLE001 — node-failure recovery path
            restarts += 1
            if restarts > 10:
                raise
            last = ckpt.latest_step(run.checkpoint_dir)
            if last is None:
                # no checkpoint yet: restart from the initial state
                step_i = start
                pipe = Pipeline(data_cfg, start_step=start)
                continue
            state, step_i, extra = ckpt.restore(run.checkpoint_dir,
                                                template)
            pipe = Pipeline.from_state(
                data_cfg, extra.get("data", {"step": step_i,
                                             "seed": data_cfg.seed}))
    return LoopResult(state, losses, restarts, monitor.flagged, ckpts)
