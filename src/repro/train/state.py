"""Train state pytree."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax.numpy as jnp

from repro.optim.adamw import AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jnp.ndarray                 # int32 scalar
    error_fb: Optional[Any] = None    # sketched-grad-compression feedback

    def replace(self, **kw) -> "TrainState":
        return self._replace(**kw)
