from .loop import LoopResult, StragglerMonitor, train_loop  # noqa: F401
from .state import TrainState  # noqa: F401
from .step import init_state, make_dp_compressed_step, make_train_step  # noqa: F401
