"""repro.obs — runtime observability: spans, metrics, and the comm ledger.

Three instruments, one install pattern:

  * **metrics** (:mod:`.metrics`) — always-on process-global registry;
    counters/gauges/histograms with Prometheus text exposition.  The
    serving layer publishes into it unconditionally (the publish path is
    a dict hit + float add).
  * **tracer** (:mod:`.trace`) — span timeline with Chrome/Perfetto
    export; off by default (``span()`` is a shared no-op until
    ``install_tracer``).
  * **ledger** (:mod:`.ledger`) — per-call-site measured collective bytes
    vs planner prediction vs the Theorem-2/3 floor; off by default
    (``install_ledger``).  ``report.honesty_report`` renders the audit;
    ``report.revalidate_autotune`` feeds drift back into the tuner cache.

``install_observability()`` turns everything on at once (the serve/bench
drivers use it behind ``--trace-out`` / ``--trace``).
"""
from .ledger import (CommLedger, LedgerSite, get_ledger, install_ledger,
                     uninstall_ledger)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_metrics, set_metrics)
from .report import (drift_flags, honesty_report, report_rows,
                     revalidate_autotune)
from .trace import (SpanRecord, Tracer, current_span_id, get_tracer,
                    install_tracer, span, uninstall_tracer)

__all__ = [
    "CommLedger", "LedgerSite", "get_ledger", "install_ledger",
    "uninstall_ledger",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_metrics",
    "set_metrics",
    "drift_flags", "honesty_report", "report_rows", "revalidate_autotune",
    "SpanRecord", "Tracer", "current_span_id", "get_tracer",
    "install_tracer", "span", "uninstall_tracer",
    "install_observability", "uninstall_observability",
]


def install_observability(max_spans: int = 100_000):
    """Install a fresh tracer + ledger (metrics are always on); returns
    ``(tracer, ledger, metrics)``."""
    return (install_tracer(Tracer(max_spans=max_spans)), install_ledger(),
            get_metrics())


def uninstall_observability():
    """Uninstall tracer and ledger; returns the previous ``(tracer,
    ledger)`` pair (the metrics registry stays installed)."""
    return uninstall_tracer(), uninstall_ledger()
