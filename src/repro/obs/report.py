"""The honesty report: predicted vs measured vs the paper's floor, per site.

``honesty_report`` renders one table row per ledger site — predicted
interconnect words (``plan/model.py``), measured per-device HLO collective
bytes (``roofline/hlo.py`` via the ledger's lazy parse), the Theorem-2/3
floor, accumulated wall time, and the two audit ratios (``bound_fraction``,
``drift``).  Column meanings are documented in
``docs/COMMUNICATION_MODEL.md``.

``drift_flags`` + ``revalidate_autotune`` close the measurement loop with
the planner: a site whose measured words diverged from its prediction past
the threshold names the autotune cache entry that decision came from, and
revalidation pops it — the next ``plan.autotune`` call at that key
re-measures instead of trusting the stale decision.
"""
from __future__ import annotations

import math
from typing import List, Optional, Tuple

from .ledger import CommLedger, LedgerSite


def report_rows(ledger: CommLedger) -> List[dict]:
    """One plain dict per site, report-ready."""
    rows = []
    for s in sorted(ledger.sites(), key=lambda s: s.name):
        rows.append({
            "site": s.name,
            "calls": s.calls,
            "predicted_words": s.predicted_words,
            "measured_bytes_per_call": s.measured_bytes_per_call,
            "measured_words_per_call": s.measured_words_per_call,
            "lower_bound_words": s.lower_bound_words,
            "bound_fraction": s.bound_fraction,
            "drift": s.drift,
            "wall_s": s.wall_s,
            "cache_key": s.cache_key,
        })
    return rows


def _fmt(v, unit: str = "") -> str:
    if v is None:
        return "-"
    if isinstance(v, float) and math.isinf(v):
        return "inf"
    if isinstance(v, float):
        return f"{v:.4g}{unit}"
    return f"{v}{unit}"


def honesty_report(ledger: CommLedger,
                   machine_words_per_s: Optional[float] = None) -> str:
    """Fixed-width table of every site's predicted/measured/floor audit.

    ``machine_words_per_s`` (e.g. ``MachineModel.byte_bw / itemsize``)
    adds a roofline-fraction column: the share of each site's wall time
    the measured traffic would need at peak interconnect bandwidth.
    """
    cols = ["site", "calls", "pred_words", "meas_words", "thm_floor",
            "bound_frac", "drift", "wall_s"]
    if machine_words_per_s:
        cols.append("roofline_frac")
    table = [cols]
    for r in report_rows(ledger):
        row = [r["site"], str(r["calls"]),
               _fmt(r["predicted_words"]),
               _fmt(r["measured_words_per_call"]),
               _fmt(r["lower_bound_words"]),
               _fmt(r["bound_fraction"]),
               _fmt(r["drift"]),
               _fmt(r["wall_s"])]
        if machine_words_per_s:
            mw = r["measured_words_per_call"]
            if mw is None or r["wall_s"] <= 0 or r["calls"] == 0:
                row.append("-")
            else:
                need = mw * r["calls"] / machine_words_per_s
                row.append(_fmt(need / r["wall_s"]))
        table.append(row)
    widths = [max(len(row[i]) for row in table) for i in range(len(cols))]
    lines = []
    for j, row in enumerate(table):
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
        if j == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


# -- drift hook: feed plan/autotune revalidation -----------------------------

def drift_flags(ledger: CommLedger,
                threshold: float = 0.25) -> List[Tuple[LedgerSite, float]]:
    """Sites whose measured words diverged from the planner prediction by
    more than ``threshold`` (relative) — ``(site, drift)`` pairs, worst
    first.  Analytic-only sites (no measured bytes) never flag."""
    if threshold < 0:
        raise ValueError("threshold must be >= 0")
    out = []
    for s in ledger.sites():
        d = s.drift
        if d is not None and abs(d) > threshold:
            out.append((s, d))
    out.sort(key=lambda t: -abs(t[1]))
    return out


def revalidate_autotune(ledger: CommLedger, cache,
                        threshold: float = 0.25) -> List[str]:
    """Pop every autotune cache entry named by a drift-flagged site.

    ``cache`` is a :class:`repro.plan.autotune.AutotuneCache` (anything
    with ``pop(key)``).  Returns the popped keys; the next ``autotune``
    call at each key misses the cache and re-measures."""
    popped = []
    for site, _ in drift_flags(ledger, threshold):
        if site.cache_key and site.cache_key not in popped:
            if cache.pop(site.cache_key) is not None:
                popped.append(site.cache_key)
    return popped
