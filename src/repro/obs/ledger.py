"""CommLedger: runtime collective-byte accounting against the paper bounds.

PR 4/5 could only audit communication inside tests — compile a program,
parse its HLO with ``roofline/hlo.collective_bytes_of``, assert the bytes
equal the closed forms.  The ledger makes that audit a *runtime* property
of every instrumented call-site: each site accumulates call counts and
(lazily, parsed once per compiled executable) the measured per-device
collective bytes of the executable it dispatches, next to the planner's
predicted words and the Theorem-2/3 floor.

Two site flavors:

  * :meth:`CommLedger.observe` — HLO-backed.  The call-site passes its
    jitted ``fn`` and the concrete call args; the ledger abstractifies the
    args into ``ShapeDtypeStruct``s (sharding preserved — shard_map byte
    counts depend on it) BEFORE the dispatch touches donated buffers, and
    stores a lazy thunk.  ``fn.lower(...).compile().as_text()`` runs only
    at first byte query (report time), hits XLA's compilation cache (the
    hot path already compiled this executable), and the parse is cached
    per (executable, signature) fingerprint — the hot-path cost after the
    first call at a signature is a tuple build + dict hit + counter bump.
  * :meth:`CommLedger.record` — analytic-only (no fn handle available,
    e.g. ``Plan.execute`` dispatching into opaque entry points): predicted
    words, floor and wall time accumulate; measured bytes stay None.

Per-site audit figures (mirroring ``plan.Plan.bound_ratio``):

  * ``bound_fraction`` — measured words/call over the Theorem-2/3 floor
    (1.0 when both are zero: a regime-1 schedule meeting a zero floor
    with zero traffic is *at* the bound, not off the scale);
  * ``drift``        — (measured - predicted) / predicted words: how far
    reality diverged from ``plan/model.py``.  Sites opened with an
    autotune ``cache_key`` feed ``obs.report.revalidate_autotune``.
"""
from __future__ import annotations

import math
import threading
from typing import Any, Dict, Optional, Tuple


def _sig_of(args: Tuple) -> Tuple:
    """Cheap structural signature of a call's args (shape/dtype per array;
    scalars and None verbatim) — the per-(site, executable) ledger key.
    Dtype objects are kept verbatim (hashable); stringifying them is ~2us
    of numpy machinery per array, which the hot path cannot afford."""
    out = []
    for a in args:
        shape = getattr(a, "shape", None)
        if shape is not None:
            out.append((shape if type(shape) is tuple else tuple(shape),
                        getattr(a, "dtype", None)))
        else:
            out.append(a)
    return tuple(out)


def _abstractify(args: Tuple) -> Tuple:
    """ShapeDtypeStructs (sharding preserved) for lazy re-lowering without
    holding or donating the concrete buffers."""
    import jax
    out = []
    for a in args:
        if getattr(a, "shape", None) is not None and hasattr(a, "dtype"):
            sharding = getattr(a, "sharding", None)
            # Only mesh shardings constrain the lowering; a scalar operand
            # committed to one device (e.g. a jnp.int32 row offset) would
            # otherwise pin lower() to that device and conflict with the
            # mesh-sharded operands — jit replicates it at dispatch anyway.
            if not isinstance(sharding,
                              getattr(jax.sharding, "NamedSharding", ())):
                sharding = None
            try:
                out.append(jax.ShapeDtypeStruct(a.shape, a.dtype,
                                                sharding=sharding))
            except TypeError:       # older jax: no sharding kwarg
                out.append(jax.ShapeDtypeStruct(a.shape, a.dtype))
        else:
            out.append(a)
    return tuple(out)


class LedgerSite:
    """One (call-site name, executable signature) accumulator."""

    def __init__(self, name: str, sig: Tuple, *,
                 predicted_words: float = 0.0,
                 lower_bound_words: float = 0.0,
                 itemsize: int = 4,
                 cache_key: Optional[str] = None,
                 hlo_thunk=None):
        self.name = name
        self.sig = sig
        self.predicted_words = float(predicted_words)
        self.lower_bound_words = float(lower_bound_words)
        self.itemsize = int(itemsize)
        self.cache_key = cache_key
        self.calls = 0
        self.wall_s = 0.0
        self._hlo_thunk = hlo_thunk
        self._cb = None             # cached CollectiveBytes (or False: n/a)

    # -- measured bytes (lazy, parsed once) ---------------------------------

    def collectives(self):
        """The executable's parsed :class:`CollectiveBytes` (None for
        analytic-only sites); lowers + parses on first call, then cached."""
        if self._cb is None:
            if self._hlo_thunk is None:
                self._cb = False
            else:
                from repro.roofline.hlo import collective_bytes_of
                self._cb = collective_bytes_of(self._hlo_thunk())
        return None if self._cb is False else self._cb

    @property
    def measured_bytes_per_call(self) -> Optional[float]:
        cb = self.collectives()
        return None if cb is None else cb.total

    @property
    def measured_bytes(self) -> Optional[float]:
        per = self.measured_bytes_per_call
        return None if per is None else per * self.calls

    @property
    def measured_words_per_call(self) -> Optional[float]:
        per = self.measured_bytes_per_call
        return None if per is None else per / self.itemsize

    # -- audit figures ------------------------------------------------------

    @property
    def bound_fraction(self) -> Optional[float]:
        """Measured words/call over the Theorem-2/3 floor; the zero/zero
        convention matches ``plan.Plan.bound_ratio``."""
        m = self.measured_words_per_call
        if m is None:
            return None
        if self.lower_bound_words == 0.0:
            return 1.0 if m == 0.0 else math.inf
        return m / self.lower_bound_words

    @property
    def drift(self) -> Optional[float]:
        """(measured - predicted) / predicted words per call."""
        m = self.measured_words_per_call
        if m is None:
            return None
        if self.predicted_words == 0.0:
            return 0.0 if m == 0.0 else math.inf
        return (m - self.predicted_words) / self.predicted_words

    def __repr__(self):
        m = self.measured_bytes_per_call
        return (f"LedgerSite({self.name!r}, calls={self.calls}, "
                f"bytes/call={'n/a' if m is None else f'{m:.6g}'}, "
                f"predicted_words={self.predicted_words:.6g}, "
                f"floor={self.lower_bound_words:.6g})")


class CommLedger:
    """Accumulates :class:`LedgerSite`s across every instrumented path."""

    def __init__(self):
        self._lock = threading.Lock()
        self._sites: Dict[Tuple, LedgerSite] = {}

    # -- hot-path API -------------------------------------------------------

    def observe(self, name: str, fn, args: Tuple, *,
                predicted_words: float = 0.0,
                lower_bound_words: float = 0.0,
                itemsize: int = 4,
                cache_key: Optional[str] = None,
                wall_s: Optional[float] = None,
                count: int = 1) -> LedgerSite:
        """Account one dispatch of jitted ``fn`` called with ``args``.

        Call BEFORE the dispatch when any arg is donated — the ledger
        abstractifies immediately and never touches the buffers again.
        """
        sig = _sig_of(args)
        key = (name, sig)
        site = self._sites.get(key)
        if site is None:
            abs_args = _abstractify(args)
            site = LedgerSite(
                name, sig, predicted_words=predicted_words,
                lower_bound_words=lower_bound_words, itemsize=itemsize,
                cache_key=cache_key,
                hlo_thunk=lambda: fn.lower(*abs_args).compile().as_text())
            with self._lock:
                site = self._sites.setdefault(key, site)
        site.calls += count
        if wall_s is not None:
            site.wall_s += wall_s
        return site

    def record(self, name: str, *,
               predicted_words: float = 0.0,
               lower_bound_words: float = 0.0,
               itemsize: int = 4,
               cache_key: Optional[str] = None,
               wall_s: Optional[float] = None,
               detail: Any = None,
               count: int = 1) -> LedgerSite:
        """Analytic-only site (no executable handle): predictions, floor
        and wall time accumulate; measured bytes stay unavailable."""
        key = (name, ("analytic", detail))
        site = self._sites.get(key)
        if site is None:
            site = LedgerSite(name, key[1],
                              predicted_words=predicted_words,
                              lower_bound_words=lower_bound_words,
                              itemsize=itemsize, cache_key=cache_key)
            with self._lock:
                site = self._sites.setdefault(key, site)
        site.calls += count
        if wall_s is not None:
            site.wall_s += wall_s
        return site

    # -- queries ------------------------------------------------------------

    def sites(self):
        with self._lock:
            return list(self._sites.values())

    def site(self, name: str) -> Optional[LedgerSite]:
        """The single site registered under ``name`` (first match)."""
        for s in self.sites():
            if s.name == name:
                return s
        return None

    def total_measured_bytes(self, name: Optional[str] = None) -> float:
        """Measured bytes summed over calls (and, with ``name``, restricted
        to that site name) — analytic-only sites contribute nothing."""
        tot = 0.0
        for s in self.sites():
            if name is not None and s.name != name:
                continue
            b = s.measured_bytes
            if b is not None:
                tot += b
        return tot

    def clear(self) -> None:
        with self._lock:
            self._sites.clear()

    def __len__(self):
        return len(self._sites)


# -- module-level install point ----------------------------------------------

_ledger: Optional[CommLedger] = None


def get_ledger() -> Optional[CommLedger]:
    return _ledger


def install_ledger(ledger: Optional[CommLedger] = None) -> CommLedger:
    global _ledger
    _ledger = ledger if ledger is not None else CommLedger()
    return _ledger


def uninstall_ledger() -> Optional[CommLedger]:
    global _ledger
    prev, _ledger = _ledger, None
    return prev
