"""Thread-safe span tracer with Chrome/Perfetto ``trace_event`` export.

One serving run produces a timeline of ingest -> bucket -> fused update ->
finalize: every instrumented path opens spans through the module-level
:func:`span` helper, which is a shared no-op context manager while no
tracer is installed — the uninstrumented hot path pays one global read.

Cross-thread parenting: spans nest per-thread via a ``threading.local``
stack, and a span may be opened with an explicit ``parent=`` id — the
``IngestQueue`` worker stitches its apply spans under the submitting
request's span this way (capture ``current_span_id()`` at submit, pass it
through the queue).

Export: :meth:`Tracer.export_chrome` writes the Chrome ``trace_event``
JSON array format (complete "X" events, microsecond timestamps), loadable
in ``chrome://tracing`` / Perfetto; :meth:`Tracer.to_chrome_events`
returns the event dicts for tests.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import itertools
import json
import threading
import time
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class SpanRecord:
    """One closed span (monotonic clock, ns)."""
    name: str
    cat: str
    start_ns: int
    dur_ns: int
    tid: int
    span_id: int
    parent_id: Optional[int]
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)


class _SpanCtx:
    __slots__ = ("_tracer", "name", "cat", "args", "parent",
                 "span_id", "_t0", "_explicit_parent")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 parent: Optional[int], args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._explicit_parent = parent
        self.parent = None
        self.span_id = None
        self._t0 = 0

    def __enter__(self):
        t = self._tracer
        self.span_id = next(t._ids)
        stack = t._stack()
        self.parent = (self._explicit_parent
                       if self._explicit_parent is not None
                       else (stack[-1] if stack else None))
        stack.append(self.span_id)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self._t0
        t = self._tracer
        stack = t._stack()
        if stack and stack[-1] == self.span_id:
            stack.pop()
        t._record(SpanRecord(
            name=self.name, cat=self.cat, start_ns=self._t0, dur_ns=dur,
            tid=threading.get_ident(), span_id=self.span_id,
            parent_id=self.parent, args=self.args))
        return False


class Tracer:
    """Collects :class:`SpanRecord`s; bounded, thread-safe."""

    def __init__(self, max_spans: int = 100_000):
        self.max_spans = int(max_spans)
        self._spans: List[SpanRecord] = []
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._local = threading.local()
        self.dropped = 0

    # -- recording ----------------------------------------------------------

    def _stack(self) -> List[int]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self._spans) >= self.max_spans:
                self.dropped += 1
                return
            self._spans.append(rec)

    def span(self, name: str, cat: str = "", parent: Optional[int] = None,
             **args) -> _SpanCtx:
        """Context manager opening a span; nests under the thread's current
        span unless ``parent=`` pins it explicitly (cross-thread)."""
        return _SpanCtx(self, name, cat, parent, args)

    def trace(self, name: Optional[str] = None, cat: str = ""):
        """Decorator form: ``@tracer.trace("my.op")``."""
        def deco(fn):
            label = name or fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                with self.span(label, cat=cat):
                    return fn(*a, **kw)
            return wrapper
        return deco

    def current_span_id(self) -> Optional[int]:
        """Id of this thread's innermost open span (None outside spans) —
        capture at submit time to parent work done on another thread."""
        st = self._stack()
        return st[-1] if st else None

    # -- introspection / export ---------------------------------------------

    @property
    def spans(self) -> List[SpanRecord]:
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0

    def to_chrome_events(self) -> List[dict]:
        """Chrome ``trace_event`` complete ("X") events, microseconds."""
        events = []
        for s in self.spans:
            args = dict(s.args)
            args["span_id"] = s.span_id
            if s.parent_id is not None:
                args["parent_id"] = s.parent_id
            events.append({
                "name": s.name, "cat": s.cat or "repro", "ph": "X",
                "ts": s.start_ns / 1e3, "dur": s.dur_ns / 1e3,
                "pid": 0, "tid": s.tid, "args": args})
        return events

    def export_chrome(self, path: str) -> str:
        """Write the Chrome/Perfetto JSON trace; returns ``path``."""
        with open(path, "w") as f:
            json.dump({"traceEvents": self.to_chrome_events(),
                       "displayTimeUnit": "ms"}, f)
        return path


# -- module-level install point (the hot-path fast path) ---------------------

_tracer: Optional[Tracer] = None

# one shared reusable no-op context manager: `with span(...)` costs a
# global read + a function call when tracing is off
_NULL = contextlib.nullcontext()


def get_tracer() -> Optional[Tracer]:
    return _tracer


def install_tracer(tracer: Optional[Tracer] = None) -> Tracer:
    """Install (and return) the process-global tracer; ``None`` makes a
    fresh one."""
    global _tracer
    _tracer = tracer if tracer is not None else Tracer()
    return _tracer


def uninstall_tracer() -> Optional[Tracer]:
    """Remove the global tracer (spans become no-ops); returns it."""
    global _tracer
    prev, _tracer = _tracer, None
    return prev


def span(name: str, cat: str = "", parent: Optional[int] = None, **args):
    """Module-level span helper: a real span when a tracer is installed,
    the shared no-op context manager otherwise."""
    t = _tracer
    if t is None:
        return _NULL
    return t.span(name, cat=cat, parent=parent, **args)


def current_span_id() -> Optional[int]:
    t = _tracer
    return None if t is None else t.current_span_id()
