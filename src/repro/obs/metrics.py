"""Dependency-free counter / gauge / histogram registry.

The serving layer (``stream/service.py``, ``stream/ingest.py``,
``serve/engine.py``) publishes into a process-global default registry —
always on, because the publish path is a dict lookup plus a float add and
the registry never allocates on the hot path after the first observation
of a (metric, labelset).  ``prometheus_text`` renders the standard text
exposition (``launch/serve.py --metrics`` dumps it); ``snapshot`` returns
plain dicts for tests and dashboards.

No prometheus_client, no numpy: histograms keep cumulative bucket counts
(Prometheus ``le`` semantics) plus a bounded window of raw values so the
queue's p50/p99 tail latencies stay exact, not bucket-quantized.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterable, Optional, Sequence, Tuple

# default latency-ish buckets (seconds); callers pass their own for counts
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_RAW_WINDOW = 8192          # raw-value window cap per (histogram, labelset)


def _labelkey(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _labelstr(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()

    def _header(self) -> str:
        out = []
        if self.help:
            out.append(f"# HELP {self.name} {self.help}")
        out.append(f"# TYPE {self.name} {self.kind}")
        return "\n".join(out)


class Counter(_Metric):
    """Monotonically increasing count, optionally labelled."""
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._vals: Dict[Tuple, float] = {}

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError("counters only go up")
        k = _labelkey(labels)
        with self._lock:
            self._vals[k] = self._vals.get(k, 0.0) + value

    def value(self, **labels) -> float:
        return self._vals.get(_labelkey(labels), 0.0)

    def snapshot(self):
        return {_labelstr(k) or "": v for k, v in self._vals.items()}

    def expose(self) -> str:
        lines = [self._header()]
        for k, v in sorted(self._vals.items()):
            lines.append(f"{self.name}{_labelstr(k)} {_fmt(v)}")
        if not self._vals:
            lines.append(f"{self.name} 0")
        return "\n".join(lines)


class Gauge(_Metric):
    """A value that can go up and down (queue depth, resident streams)."""
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._vals: Dict[Tuple, float] = {}

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._vals[_labelkey(labels)] = float(value)

    def inc(self, value: float = 1.0, **labels) -> None:
        k = _labelkey(labels)
        with self._lock:
            self._vals[k] = self._vals.get(k, 0.0) + value

    def dec(self, value: float = 1.0, **labels) -> None:
        self.inc(-value, **labels)

    def value(self, **labels) -> float:
        return self._vals.get(_labelkey(labels), 0.0)

    def snapshot(self):
        return {_labelstr(k) or "": v for k, v in self._vals.items()}

    def expose(self) -> str:
        lines = [self._header()]
        for k, v in sorted(self._vals.items()):
            lines.append(f"{self.name}{_labelstr(k)} {_fmt(v)}")
        if not self._vals:
            lines.append(f"{self.name} 0")
        return "\n".join(lines)


class _HistState:
    __slots__ = ("bucket_counts", "count", "total", "window")

    def __init__(self, nbuckets: int):
        self.bucket_counts = [0] * nbuckets
        self.count = 0
        self.total = 0.0
        self.window = []            # bounded raw values for exact quantiles


class Histogram(_Metric):
    """Cumulative-bucket histogram plus an exact bounded quantile window."""
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self._states: Dict[Tuple, _HistState] = {}

    def observe(self, value: float, **labels) -> None:
        k = _labelkey(labels)
        with self._lock:
            st = self._states.get(k)
            if st is None:
                st = self._states[k] = _HistState(len(self.buckets))
            i = bisect.bisect_left(self.buckets, value)
            if i < len(self.buckets):
                st.bucket_counts[i] += 1
            st.count += 1
            st.total += value
            st.window.append(value)
            if len(st.window) > _RAW_WINDOW:
                del st.window[: _RAW_WINDOW // 2]

    def count(self, **labels) -> int:
        st = self._states.get(_labelkey(labels))
        return 0 if st is None else st.count

    def percentile(self, q: float, **labels) -> float:
        """Exact q-th percentile over the retained raw-value window
        (0.0 on an empty window — never an exception)."""
        st = self._states.get(_labelkey(labels))
        if st is None or not st.window:
            return 0.0
        xs = sorted(st.window)
        if len(xs) == 1:
            return xs[0]
        # linear interpolation, numpy.percentile's default method
        pos = (len(xs) - 1) * min(max(q, 0.0), 100.0) / 100.0
        lo = int(pos)
        frac = pos - lo
        hi = min(lo + 1, len(xs) - 1)
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def reset_window(self, **labels) -> None:
        st = self._states.get(_labelkey(labels))
        if st is not None:
            st.window.clear()

    def snapshot(self):
        out = {}
        for k, st in self._states.items():
            out[_labelstr(k) or ""] = {
                "count": st.count, "sum": st.total,
                "p50": self.percentile(50, **dict(k)),
                "p99": self.percentile(99, **dict(k))}
        return out

    def expose(self) -> str:
        lines = [self._header()]
        for k, st in sorted(self._states.items()):
            cum = 0
            for b, c in zip(self.buckets, st.bucket_counts):
                cum += c
                lk = dict(k)
                lk["le"] = _fmt(b)
                lines.append(f"{self.name}_bucket{_labelstr(_labelkey(lk))} "
                             f"{cum}")
            lk = dict(k)
            lk["le"] = "+Inf"
            lines.append(f"{self.name}_bucket{_labelstr(_labelkey(lk))} "
                         f"{st.count}")
            lines.append(f"{self.name}_sum{_labelstr(k)} {_fmt(st.total)}")
            lines.append(f"{self.name}_count{_labelstr(k)} {st.count}")
        if not self._states:
            lines.append(f"{self.name}_count 0")
        return "\n".join(lines)


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class MetricsRegistry:
    """Named metrics, create-on-first-use; thread-safe."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, name: str, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        m = self._get(name, lambda: Counter(name, help))
        if not isinstance(m, Counter):
            raise TypeError(f"{name!r} is a {m.kind}, not a counter")
        return m

    def gauge(self, name: str, help: str = "") -> Gauge:
        m = self._get(name, lambda: Gauge(name, help))
        if not isinstance(m, Gauge):
            raise TypeError(f"{name!r} is a {m.kind}, not a gauge")
        return m

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        m = self._get(name, lambda: Histogram(name, help, buckets))
        if not isinstance(m, Histogram):
            raise TypeError(f"{name!r} is a {m.kind}, not a histogram")
        return m

    def names(self) -> Iterable[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, object]:
        return {name: self._metrics[name].snapshot()
                for name in self.names()}

    def prometheus_text(self) -> str:
        """Standard Prometheus text exposition of every metric."""
        blocks = [self._metrics[name].expose() for name in self.names()]
        return "\n".join(blocks) + ("\n" if blocks else "")

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


# -- process-global default registry ----------------------------------------

_default = MetricsRegistry()


def get_metrics() -> MetricsRegistry:
    """The process-global registry every instrumented path publishes to."""
    return _default


def set_metrics(registry: Optional[MetricsRegistry]) -> MetricsRegistry:
    """Swap the global registry (tests isolate by installing a fresh one);
    returns the previous registry.  ``None`` installs a fresh empty one."""
    global _default
    prev = _default
    _default = registry if registry is not None else MetricsRegistry()
    return prev
