"""Async multi-tenant ingest queue: the request-facing front half of the
serving story (ROADMAP item 1), hardened into the fault-tolerance layer
(ISSUE 9).

``IngestQueue`` sits between request handlers and a
:class:`~repro.stream.service.SketchService`.  Handlers call
:meth:`submit` (cheap: validate + journal + enqueue); a single worker
thread drains the queue in windows, splits each window into rounds with at
most one update per stream (per-stream FIFO order is preserved — sketch
updates commute across streams but not within one), and applies every
round through ONE fused :meth:`SketchService.update_ragged` dispatch
(local mode) or per-lane sharded updates (distributed mode, which enables
the drain -> reshard -> resume arc of ``stream/elastic.py``).

Overlap model (double buffering): JAX dispatch is asynchronous, so while
the device executes round R's fused update the worker is already draining,
bucketing and padding round R+1 on the host — host-side request handling,
H staging and device compute overlap without any explicit stream
management.  The queue is BOUNDED: when the device falls behind, ``submit``
blocks (backpressure) rather than dropping updates, and raises
``queue.Full`` only when the caller's timeout expires.

Fault model (pinned by tests/test_service_scale.py and
tests/test_fault_tolerance.py; taxonomy in docs/FAULT_MODEL.md):

  * non-finite payloads are rejected at submit time, before anything can
    touch (Y, W);
  * with a :class:`~repro.stream.wal.WriteAheadLog` attached (``wal=``),
    every accepted submit is journaled (fsynced) before it is enqueued —
    a crash between accept and apply is recoverable by ``wal.replay``
    onto a fresh service, BITWISE (update determinism);
  * an unexpected worker-thread death (a real crash, or the chaos
    harness's ``WorkerKilled``) fails fast: ``submit`` / ``flush`` /
    ``close_stream`` raise :class:`WorkerDied` carrying the original
    traceback instead of blocking forever, and ``shutdown`` stays
    idempotent;
  * transient round failures are retried with exponential backoff under a
    deadline (``ingest_retries_total``); when retries exhaust, the round
    falls back to per-lane application and only the poison lane is
    excised from the cohort (``ingest_quarantined_total``) — the other
    tenants' updates land; retries and the fallback touch only the
    not-yet-applied lanes, so a distributed round that failed partway
    through its sequential per-lane dispatch never re-applies the lanes
    that already landed (exactly-once per lane);
  * worker-side failures are recorded per-request and surfaced by
    ``flush(raise_errors=True)`` / ``stats()``, never silently swallowed.
"""
from __future__ import annotations

import queue
import threading
import time
import traceback
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from . import faults
from .state import snap_bucket


class WorkerDied(RuntimeError):
    """The ingest worker thread died unexpectedly.  Raised (fast) by
    ``submit`` / ``flush`` / ``close_stream`` instead of blocking on a
    queue nobody will ever drain.  ``traceback_text`` carries the worker's
    original traceback; it is also appended to ``str(exc)``."""

    def __init__(self, msg: str, traceback_text: str = ""):
        self.traceback_text = traceback_text
        if traceback_text:
            msg = f"{msg}\n--- worker traceback ---\n{traceback_text}"
        super().__init__(msg)


def _percentile(xs: Sequence[float], q: float) -> float:
    """Percentile of a latency window; 0.0 on an empty or all-non-finite
    window (sustained dashboards poll stats() between drains, so the
    window is legitimately empty/short at any moment — never raise)."""
    if xs is None or len(xs) == 0:
        return 0.0
    a = np.asarray(xs, np.float64)
    a = a[np.isfinite(a)]
    if a.size == 0:
        return 0.0
    return float(np.percentile(a, q))


class IngestQueue:
    """Bounded async ingest front-end for a SketchService.

    Parameters
    ----------
    service : SketchService.  Local mode gets the fused ragged hot path;
        distributed mode applies lanes through the sharded per-stream
        update (full-shape additive, ``row0=0`` only).
    depth : int — queue capacity; a full queue blocks ``submit``
        (backpressure)
    window : int — max requests fused per drain (one or more rounds)
    bucket_edges : optional ascending bucket tops forwarded to
        ``update_ragged`` (e.g. from ``repro.plan.choose_bucket_edges``)
    validate_payloads : bool — reject non-finite H at submit time
    wal : optional :class:`~repro.stream.wal.WriteAheadLog` — journal
        every accepted submit before enqueue (crash-safe ingest); the
        applied watermark advances as rounds land and the journal is
        truncated every ``wal_truncate_every`` drained batches
    max_retries : int — whole-round retries on transient failure before
        the per-lane poison-excision fallback
    backoff_base : float — first retry sleeps ``backoff_base`` seconds,
        doubling per attempt (exponential backoff)
    retry_deadline : optional float — wall-clock budget (seconds) for one
        round's retries; when exceeded, remaining retries are forfeited
        and the fallback runs immediately
    """

    def __init__(self, service, depth: int = 256, window: int = 64,
                 bucket_edges: Optional[Sequence[int]] = None,
                 validate_payloads: bool = True,
                 wal=None, max_retries: int = 2,
                 backoff_base: float = 0.05,
                 retry_deadline: Optional[float] = None,
                 wal_truncate_every: int = 16):
        if depth < 1 or window < 1:
            raise ValueError("depth and window must be >= 1")
        if max_retries < 0 or backoff_base < 0:
            raise ValueError("max_retries and backoff_base must be >= 0")
        self.service = service
        self.window = int(window)
        self.bucket_edges = (None if bucket_edges is None
                             else tuple(sorted(int(e) for e in bucket_edges)))
        self.validate_payloads = validate_payloads
        self.wal = wal
        self.max_retries = int(max_retries)
        self.backoff_base = float(backoff_base)
        self.retry_deadline = retry_deadline
        self.wal_truncate_every = max(1, int(wal_truncate_every))
        # published metrics (process-global registry, repro.obs.metrics)
        m = obs_metrics.get_metrics()
        self._m_depth = m.gauge(
            "ingest_queue_depth", "requests waiting in the bounded queue")
        self._m_backpressure = m.counter(
            "ingest_backpressure_total",
            "submits that hit a full queue (queue.Full raised)")
        self._m_submitted = m.counter(
            "ingest_submitted_total", "accepted submits")
        self._m_rejected = m.counter(
            "ingest_rejected_total", "submits rejected at validation")
        self._m_applied = m.counter(
            "ingest_applied_total", "updates applied to the service")
        self._m_errors = m.counter(
            "ingest_errors_total", "per-request worker-side failures")
        self._m_retries = m.counter(
            "ingest_retries_total",
            "whole-round retries after a transient apply failure")
        self._m_quarantined = m.counter(
            "ingest_quarantined_total",
            "poison lanes excised from their cohort (error recorded, "
            "round survived)")
        self._m_latency = m.histogram(
            "ingest_drain_latency_seconds",
            "submit -> applied latency through the queue")
        self._q: "queue.Queue[Tuple]" = queue.Queue(maxsize=depth)
        self._lock = threading.Lock()
        self._done = threading.Condition(self._lock)
        self._inflight: Dict[int, int] = {}
        self._closed_sids: set = set()
        self._errors: List[Tuple[int, Exception]] = []
        self._lat: List[float] = []         # submit->applied seconds
        self._submitted = 0
        self._applied = 0
        self._rejected = 0
        self._rounds = 0
        self._round_index = 0               # monotone, fault-point context
        self._retries = 0
        self._quarantined = 0
        self._real_rows = 0
        self._padded_rows = 0
        self._batches = 0
        # WAL bookkeeping: resolved-but-not-yet-contiguous seqnos
        self._wal_done: Set[int] = set()
        self._gate = threading.Event()      # test hook: hold() stalls drain
        self._gate.set()
        self._stop = False
        self._death: Optional[str] = None   # worker traceback after a crash
        self._heartbeat = time.monotonic()
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="sketch-ingest")
        self._worker.start()

    # -- failure detection ---------------------------------------------------

    @property
    def worker_alive(self) -> bool:
        return self._worker.is_alive()

    def heartbeat_age(self) -> float:
        """Seconds since the worker last reported progress (liveness
        signal for external watchdogs; grows unboundedly after a death)."""
        return time.monotonic() - self._heartbeat

    def _check_worker(self) -> None:
        """Fail fast when the worker died unexpectedly: nobody will ever
        drain the queue, so blocking would hang the caller forever."""
        if self._death is not None or (not self._worker.is_alive()
                                       and not self._stop):
            raise WorkerDied("ingest worker thread died unexpectedly "
                             "(queue will never drain; accepted updates "
                             "are recoverable from the WAL — see "
                             "repro.stream.wal.replay)",
                             self._death or "")

    # -- producer side -----------------------------------------------------

    def submit(self, sid: int, H, row0: int = 0,
               timeout: Optional[float] = None) -> Optional[int]:
        """Enqueue one update.  Blocks while the queue is full
        (backpressure); raises ``queue.Full`` only if ``timeout`` expires.
        Non-finite payloads raise ValueError HERE — before the request can
        ever reach the service's (Y, W) accumulators.  With a WAL
        attached, the update is journaled (fsynced — durable) before it is
        enqueued, and the journal seqno is returned."""
        if self._stop:
            raise RuntimeError("ingest queue is shut down")
        self._check_worker()
        H = np.asarray(H)
        row0 = int(row0)
        if self.service.mesh is not None and row0 != 0:
            # distributed streams take full-shape additive updates only:
            # reject HERE, with service.update's semantics, instead of
            # silently applying the slab at row 0
            with self._lock:
                self._rejected += 1
            self._m_rejected.inc()
            raise ValueError(
                f"stream {sid}: distributed streams take full-shape "
                f"additive updates only (row0 must be 0, got {row0})")
        if self.validate_payloads and not np.all(np.isfinite(
                H.astype(np.float32, copy=False))):
            with self._lock:
                self._rejected += 1
            self._m_rejected.inc()
            raise ValueError(
                f"non-finite update payload for stream {sid} rejected at "
                f"submit (accumulators untouched)")
        with self._lock:
            if sid in self._closed_sids:
                raise ValueError(f"stream {sid} was closed via this queue")
            self._inflight[sid] = self._inflight.get(sid, 0) + 1
            self._submitted += 1
        # parent span id captured on the SUBMITTING thread: the worker's
        # apply span re-parents under it across the thread boundary
        parent = obs_trace.current_span_id()
        seq = None
        try:
            if self.wal is not None:
                # journal-before-enqueue: once submit returns, the update
                # is durable.  A crash between the fsync here and the
                # round landing is exactly what wal.replay recovers.
                seq = self.wal.append(sid, row0, H)
            item = (sid, H, row0, time.perf_counter(), parent, seq)
            # bounded put as a loop of short-timeout puts, re-checking
            # worker liveness between attempts: a worker that dies while
            # the queue is full can never drain it, and its death cannot
            # wake a blocked ``queue.Queue.put`` — a single indefinitely
            # blocking put would hang the producer forever
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            while True:
                self._check_worker()
                step = (0.05 if deadline is None else
                        min(0.05, max(0.0, deadline - time.monotonic())))
                try:
                    self._q.put(item, timeout=step)
                    break
                except queue.Full:
                    if deadline is not None and time.monotonic() >= deadline:
                        raise
        except (queue.Full, WorkerDied) as e:
            with self._lock:
                self._inflight[sid] -= 1
                self._submitted -= 1
                if seq is not None:
                    # journaled but never accepted: resolve the seqno so
                    # the watermark keeps moving (the caller saw the
                    # rejection; semantics of a timed-out submit are
                    # "maybe applied" across a crash, as for any timeout)
                    self._wal_resolve([seq])
                self._done.notify_all()
            if isinstance(e, queue.Full):
                self._m_backpressure.inc()
            raise
        self._m_submitted.inc()
        self._m_depth.set(self._q.qsize())
        return seq

    # -- worker side -------------------------------------------------------

    def _drain(self) -> List[Tuple]:
        if not self._gate.is_set():         # held: park without consuming
            return []
        try:
            first = self._q.get(timeout=0.02)
        except queue.Empty:
            return []
        batch = [first]
        while len(batch) < self.window:
            try:
                batch.append(self._q.get_nowait())
            except queue.Empty:
                break
        return batch

    def _run(self) -> None:
        try:
            while True:
                self._heartbeat = time.monotonic()
                self._gate.wait()
                if self._stop and self._q.empty():
                    return
                batch = self._drain()
                if not batch:
                    if self._stop:
                        return
                    continue
                # rounds: the i-th request for a given sid lands in round
                # i, so per-stream FIFO order survives the fusion
                rounds: List[List[Tuple]] = []
                seen: Dict[int, int] = {}
                for req in batch:
                    i = seen.get(req[0], 0)
                    seen[req[0]] = i + 1
                    if i == len(rounds):
                        rounds.append([])
                    rounds[i].append(req)
                for rnd in rounds:
                    self._apply(rnd)
                self._batches += 1
                if (self.wal is not None
                        and self._batches % self.wal_truncate_every == 0):
                    self.wal.truncate()
        except BaseException:   # a real crash (incl. chaos WorkerKilled):
            # record the corpse's traceback and wake every waiter so
            # submit/flush/close_stream fail fast instead of hanging
            self._death = traceback.format_exc()
            with self._lock:
                self._done.notify_all()

    def _dispatch(self, pending: List[Tuple[int, Any, int]]) -> None:
        """One round's service dispatch: fused ragged (local mode) or
        per-lane sharded updates (distributed mode).  ``pending`` is
        consumed IN PLACE — a lane is removed the moment it has landed —
        so a mid-dispatch failure leaves exactly the not-yet-applied
        lanes behind for the retry / poison-excision paths and no lane
        is ever applied twice.  Local mode is all-or-nothing by
        construction (``update_ragged`` validates every lane before
        mutating any stream); distributed mode applies lanes
        sequentially, so the explicit bookkeeping here is what makes a
        whole-round retry safe."""
        if self.service.mesh is None:
            self.service.update_ragged(list(pending),
                                       bucket_edges=self.bucket_edges)
            pending.clear()
        else:
            while pending:
                sid, H, _row0 = pending[0]
                # chaos hook: fail ONE lane mid-dispatch — exercises the
                # partial-round bookkeeping above
                faults.fire("ingest.dispatch_lane", sid=sid)
                self.service.update(sid, H)
                pending.pop(0)

    def _apply(self, rnd: List[Tuple]) -> None:
        items = [(sid, H, row0) for sid, H, row0, _, _, _ in rnd]
        # parent under the earliest submitter's span (cross-thread): the
        # timeline shows which request pulled this fused round in
        parent = next((p for *_, p, _ in rnd if p is not None), None)
        self._round_index += 1
        round_index = self._round_index
        err = None
        attempt = 0
        t_start = time.monotonic()
        pending = list(items)       # lanes not yet applied (exactly-once)
        while True:
            try:
                # chaos hook: WorkerKilled here simulates the worker dying
                # mid-round (BaseException — escapes this handler and
                # kills the thread); a transient exc exercises retry
                faults.fire("ingest.apply_round", round_index=round_index,
                            lanes=len(items))
                with obs_trace.span("ingest.apply_round", cat="ingest",
                                    parent=parent, lanes=len(items),
                                    attempt=attempt):
                    self._dispatch(pending)
                err = None
                break
            except Exception as e:        # transient? retry with backoff
                err = e
                budget_left = (self.retry_deadline is None
                               or time.monotonic() - t_start
                               < self.retry_deadline)
                if attempt >= self.max_retries or not budget_left:
                    break
                attempt += 1
                with self._lock:
                    self._retries += 1
                self._m_retries.inc()
                time.sleep(self.backoff_base * (2.0 ** (attempt - 1)))
        lane_err: Dict[int, Exception] = {}
        if err is not None:
            # poison excision: the round failed even after retries — fall
            # back to per-lane application so one bad tenant cannot kill
            # its cohort.  Only the NOT-YET-APPLIED lanes are attempted:
            # a partially applied distributed round keeps its landed
            # prefix (removed from ``pending`` by _dispatch), and a
            # failed local fused round left no partial state behind
            # (validate-then-mutate), so every lane applies exactly once.
            for sid, H, row0 in pending:
                try:
                    faults.fire("ingest.apply_lane", sid=sid)
                    with obs_trace.span("ingest.apply_lane", cat="ingest",
                                        parent=parent, sid=sid):
                        if self.service.mesh is None:
                            self.service.update(sid, H, row0=row0)
                        else:
                            self.service.update(sid, H)
                except Exception as e2:
                    lane_err[sid] = e2
                    with self._lock:
                        self._quarantined += 1
                    self._m_quarantined.inc()
        now = time.perf_counter()
        resolved: List[int] = []
        with self._lock:
            self._rounds += 1
            for sid, H, _, t0, _, seq in rnd:
                self._inflight[sid] -= 1
                failed = err is not None and sid in lane_err
                if not failed:
                    self._applied += 1
                    self._lat.append(now - t0)
                    self._m_applied.inc()
                    self._m_latency.observe(now - t0)
                    k = H.shape[0]
                    kb = snap_bucket(k, self.bucket_edges)
                    self._real_rows += k
                    self._padded_rows += max(kb, k) - k
                else:
                    self._errors.append((sid, lane_err[sid]))
                    self._m_errors.inc()
                if seq is not None:
                    # a quarantined lane resolves its seqno too: its error
                    # is recorded and surfaced — replay must not silently
                    # re-fail it forever
                    resolved.append(seq)
            if resolved:
                self._wal_resolve(resolved)
            if len(self._lat) > 8192:
                del self._lat[:4096]
            self._done.notify_all()
        self._m_depth.set(self._q.qsize())

    def _wal_resolve(self, seqnos: Sequence[int]) -> None:
        """Advance the WAL's applied watermark over the contiguous prefix
        of resolved seqnos (callers hold ``self._lock`` or are
        single-threaded with respect to it)."""
        self._wal_done.update(seqnos)
        w = self.wal.watermark
        while w + 1 in self._wal_done:
            w += 1
            self._wal_done.discard(w)
        self.wal.mark_applied(w)

    # -- control plane -----------------------------------------------------

    def hold(self) -> None:
        """Test hook: stall the worker (queue keeps filling — lets tests
        exercise backpressure deterministically)."""
        self._gate.clear()

    def release(self) -> None:
        self._gate.set()

    def flush(self, raise_errors: bool = False,
              timeout: Optional[float] = None) -> int:
        """Block until every accepted update has been applied (or failed).
        Raises :class:`WorkerDied` (not TimeoutError-after-forever) if the
        worker crashed.  Returns the lifetime applied count."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._done:
            while any(v for v in self._inflight.values()):
                self._check_worker()
                left = (None if deadline is None
                        else max(0.0, deadline - time.monotonic()))
                if left == 0.0 or not self._done.wait(
                        timeout=min(left or 1.0, 1.0)):
                    if deadline is not None and time.monotonic() >= deadline:
                        raise TimeoutError("flush timed out")
            self._check_worker()
            if raise_errors and self._errors:
                sid, err = self._errors[0]
                raise RuntimeError(
                    f"{len(self._errors)} ingest failure(s); first: "
                    f"stream {sid}: {err!r}") from err
            return self._applied

    def close_stream(self, sid: int, timeout: Optional[float] = None):
        """Drain the stream's in-flight updates, then close it on the
        service — every update accepted before this call lands in the
        returned (Y, W)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._done:
            self._closed_sids.add(sid)   # no new submits for this sid
            while self._inflight.get(sid, 0) > 0:
                self._check_worker()
                left = (None if deadline is None
                        else max(0.0, deadline - time.monotonic()))
                if left == 0.0 or not self._done.wait(
                        timeout=min(left or 1.0, 1.0)):
                    if deadline is not None and time.monotonic() >= deadline:
                        raise TimeoutError(
                            f"close_stream({sid}) timed out draining")
        return self.service.close(sid)

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting work; drain what was accepted, then stop the
        worker.  Idempotent — including after a worker crash (joining a
        corpse is a no-op; the WAL keeps the unapplied tail)."""
        self._stop = True
        self._gate.set()
        if wait and self._worker.is_alive():
            self._worker.join(timeout=30.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False

    # -- introspection -----------------------------------------------------

    def stats(self, reset: bool = False) -> Dict[str, Any]:
        """Queue statistics.  ``reset=True`` additionally clears the
        WINDOW stats — the latency window and the real/padded row tallies
        behind ``pad_waste`` — after snapshotting, so a sustained-serving
        dashboard polling ``stats(reset=True)`` sees per-interval figures
        instead of an aggregate over the process lifetime.  The lifetime
        counters (submitted/applied/rejected/errors/rounds) are never
        reset."""
        with self._lock:
            lat = list(self._lat)
            real, padded = self._real_rows, self._padded_rows
            out = {
                "submitted": self._submitted,
                "applied": self._applied,
                "rejected": self._rejected,
                "errors": len(self._errors),
                "inflight": sum(self._inflight.values()),
                "rounds": self._rounds,
                "retries": self._retries,
                "quarantined": self._quarantined,
                "worker_alive": self._worker.is_alive(),
                "heartbeat_age_s": self.heartbeat_age(),
                "wal_depth": 0 if self.wal is None else self.wal.depth,
                "latency_p50_s": _percentile(lat, 50),
                "latency_p99_s": _percentile(lat, 99),
                "real_rows": real,
                "padded_rows": padded,
                "pad_waste": padded / max(1, real + padded),
            }
            if reset:
                self._lat.clear()
                self._real_rows = 0
                self._padded_rows = 0
            return out
