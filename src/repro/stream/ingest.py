"""Async multi-tenant ingest queue: the request-facing front half of the
serving story (ROADMAP item 1).

``IngestQueue`` sits between request handlers and a local-mode
:class:`~repro.stream.service.SketchService`.  Handlers call
:meth:`submit` (cheap: validate + enqueue); a single worker thread drains
the queue in windows, splits each window into rounds with at most one
update per stream (per-stream FIFO order is preserved — sketch updates
commute across streams but not within one), and applies every round
through ONE fused :meth:`SketchService.update_ragged` dispatch.

Overlap model (double buffering): JAX dispatch is asynchronous, so while
the device executes round R's fused update the worker is already draining,
bucketing and padding round R+1 on the host — host-side request handling,
H staging and device compute overlap without any explicit stream
management.  The queue is BOUNDED: when the device falls behind, ``submit``
blocks (backpressure) rather than dropping updates, and raises
``queue.Full`` only when the caller's timeout expires.

Fault model (pinned by tests/test_service_scale.py):

  * non-finite payloads are rejected at submit time, before anything can
    touch (Y, W);
  * closing a stream with updates in flight drains them first —
    ``close_stream`` returns the final state with every accepted update
    applied;
  * worker-side failures (e.g. racing an already-closed sid) are recorded
    per-request and surfaced by ``flush(raise_errors=True)`` / ``stats()``,
    never silently swallowed — and never abort the rest of the round.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from .state import snap_bucket


def _percentile(xs: Sequence[float], q: float) -> float:
    """Percentile of a latency window; 0.0 on an empty or all-non-finite
    window (sustained dashboards poll stats() between drains, so the
    window is legitimately empty/short at any moment — never raise)."""
    if xs is None or len(xs) == 0:
        return 0.0
    a = np.asarray(xs, np.float64)
    a = a[np.isfinite(a)]
    if a.size == 0:
        return 0.0
    return float(np.percentile(a, q))


class IngestQueue:
    """Bounded async ingest front-end for a local-mode SketchService.

    Parameters
    ----------
    service : SketchService (local mode)
    depth : int — queue capacity; a full queue blocks ``submit`` (backpressure)
    window : int — max requests fused per drain (one or more rounds)
    bucket_edges : optional ascending bucket tops forwarded to
        ``update_ragged`` (e.g. from ``repro.plan.choose_bucket_edges``)
    validate_payloads : bool — reject non-finite H at submit time
    """

    def __init__(self, service, depth: int = 256, window: int = 64,
                 bucket_edges: Optional[Sequence[int]] = None,
                 validate_payloads: bool = True):
        if service.mesh is not None:
            raise ValueError("IngestQueue fronts local-mode services only")
        if depth < 1 or window < 1:
            raise ValueError("depth and window must be >= 1")
        self.service = service
        self.window = int(window)
        self.bucket_edges = (None if bucket_edges is None
                             else tuple(sorted(int(e) for e in bucket_edges)))
        self.validate_payloads = validate_payloads
        # published metrics (process-global registry, repro.obs.metrics)
        m = obs_metrics.get_metrics()
        self._m_depth = m.gauge(
            "ingest_queue_depth", "requests waiting in the bounded queue")
        self._m_backpressure = m.counter(
            "ingest_backpressure_total",
            "submits that hit a full queue (queue.Full raised)")
        self._m_submitted = m.counter(
            "ingest_submitted_total", "accepted submits")
        self._m_rejected = m.counter(
            "ingest_rejected_total", "submits rejected at validation")
        self._m_applied = m.counter(
            "ingest_applied_total", "updates applied to the service")
        self._m_errors = m.counter(
            "ingest_errors_total", "per-request worker-side failures")
        self._m_latency = m.histogram(
            "ingest_drain_latency_seconds",
            "submit -> applied latency through the queue")
        self._q: "queue.Queue[Tuple]" = queue.Queue(maxsize=depth)
        self._lock = threading.Lock()
        self._done = threading.Condition(self._lock)
        self._inflight: Dict[int, int] = {}
        self._closed_sids: set = set()
        self._errors: List[Tuple[int, Exception]] = []
        self._lat: List[float] = []         # submit->applied seconds
        self._submitted = 0
        self._applied = 0
        self._rejected = 0
        self._rounds = 0
        self._real_rows = 0
        self._padded_rows = 0
        self._gate = threading.Event()      # test hook: hold() stalls drain
        self._gate.set()
        self._stop = False
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="sketch-ingest")
        self._worker.start()

    # -- producer side -----------------------------------------------------

    def submit(self, sid: int, H, row0: int = 0,
               timeout: Optional[float] = None) -> None:
        """Enqueue one row-slab update.  Blocks while the queue is full
        (backpressure); raises ``queue.Full`` only if ``timeout`` expires.
        Non-finite payloads raise ValueError HERE — before the request can
        ever reach the service's (Y, W) accumulators."""
        if self._stop:
            raise RuntimeError("ingest queue is shut down")
        H = np.asarray(H)
        if self.validate_payloads and not np.all(np.isfinite(
                H.astype(np.float32, copy=False))):
            with self._lock:
                self._rejected += 1
            self._m_rejected.inc()
            raise ValueError(
                f"non-finite update payload for stream {sid} rejected at "
                f"submit (accumulators untouched)")
        with self._lock:
            if sid in self._closed_sids:
                raise ValueError(f"stream {sid} was closed via this queue")
            self._inflight[sid] = self._inflight.get(sid, 0) + 1
            self._submitted += 1
        # parent span id captured on the SUBMITTING thread: the worker's
        # apply span re-parents under it across the thread boundary
        parent = obs_trace.current_span_id()
        try:
            self._q.put((sid, H, int(row0), time.perf_counter(), parent),
                        timeout=timeout)
        except queue.Full:
            with self._lock:
                self._inflight[sid] -= 1
                self._submitted -= 1
                self._done.notify_all()
            self._m_backpressure.inc()
            raise
        self._m_submitted.inc()
        self._m_depth.set(self._q.qsize())

    # -- worker side -------------------------------------------------------

    def _drain(self) -> List[Tuple]:
        if not self._gate.is_set():         # held: park without consuming
            return []
        try:
            first = self._q.get(timeout=0.02)
        except queue.Empty:
            return []
        batch = [first]
        while len(batch) < self.window:
            try:
                batch.append(self._q.get_nowait())
            except queue.Empty:
                break
        return batch

    def _run(self) -> None:
        while True:
            self._gate.wait()
            if self._stop and self._q.empty():
                return
            batch = self._drain()
            if not batch:
                if self._stop:
                    return
                continue
            # rounds: the i-th request for a given sid lands in round i, so
            # per-stream FIFO order survives the fusion
            rounds: List[List[Tuple]] = []
            seen: Dict[int, int] = {}
            for req in batch:
                i = seen.get(req[0], 0)
                seen[req[0]] = i + 1
                if i == len(rounds):
                    rounds.append([])
                rounds[i].append(req)
            for rnd in rounds:
                self._apply(rnd)

    def _apply(self, rnd: List[Tuple]) -> None:
        items = [(sid, H, row0) for sid, H, row0, _, _ in rnd]
        # parent under the earliest submitter's span (cross-thread): the
        # timeline shows which request pulled this fused round in
        parent = next((p for *_, p in rnd if p is not None), None)
        try:
            with obs_trace.span("ingest.apply_round", cat="ingest",
                                parent=parent, lanes=len(items)):
                self.service.update_ragged(items,
                                           bucket_edges=self.bucket_edges)
            err = None
        except Exception as e:            # record, don't kill the worker
            err = e
        now = time.perf_counter()
        with self._lock:
            self._rounds += 1
            for sid, H, _, t0, _ in rnd:
                self._inflight[sid] -= 1
                if err is None:
                    self._applied += 1
                    self._lat.append(now - t0)
                    self._m_applied.inc()
                    self._m_latency.observe(now - t0)
                    k = H.shape[0]
                    kb = snap_bucket(k, self.bucket_edges)
                    self._real_rows += k
                    self._padded_rows += max(kb, k) - k
                else:
                    self._errors.append((sid, err))
                    self._m_errors.inc()
            if len(self._lat) > 8192:
                del self._lat[:4096]
            self._done.notify_all()
        self._m_depth.set(self._q.qsize())

    # -- control plane -----------------------------------------------------

    def hold(self) -> None:
        """Test hook: stall the worker (queue keeps filling — lets tests
        exercise backpressure deterministically)."""
        self._gate.clear()

    def release(self) -> None:
        self._gate.set()

    def flush(self, raise_errors: bool = False,
              timeout: Optional[float] = None) -> None:
        """Block until every accepted update has been applied (or failed)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._done:
            while any(v for v in self._inflight.values()):
                left = (None if deadline is None
                        else max(0.0, deadline - time.monotonic()))
                if left == 0.0 or not self._done.wait(timeout=left or 1.0):
                    if deadline is not None and time.monotonic() >= deadline:
                        raise TimeoutError("flush timed out")
            if raise_errors and self._errors:
                sid, err = self._errors[0]
                raise RuntimeError(
                    f"{len(self._errors)} ingest failure(s); first: "
                    f"stream {sid}: {err!r}") from err

    def close_stream(self, sid: int, timeout: Optional[float] = None):
        """Drain the stream's in-flight updates, then close it on the
        service — every update accepted before this call lands in the
        returned (Y, W)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._done:
            self._closed_sids.add(sid)   # no new submits for this sid
            while self._inflight.get(sid, 0) > 0:
                left = (None if deadline is None
                        else max(0.0, deadline - time.monotonic()))
                if left == 0.0 or not self._done.wait(timeout=left or 1.0):
                    if deadline is not None and time.monotonic() >= deadline:
                        raise TimeoutError(
                            f"close_stream({sid}) timed out draining")
        return self.service.close(sid)

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting work; drain what was accepted, then stop the
        worker.  Idempotent."""
        self._stop = True
        self._gate.set()
        if wait and self._worker.is_alive():
            self._worker.join(timeout=30.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False

    # -- introspection -----------------------------------------------------

    def stats(self, reset: bool = False) -> Dict[str, Any]:
        """Queue statistics.  ``reset=True`` additionally clears the
        WINDOW stats — the latency window and the real/padded row tallies
        behind ``pad_waste`` — after snapshotting, so a sustained-serving
        dashboard polling ``stats(reset=True)`` sees per-interval figures
        instead of an aggregate over the process lifetime.  The lifetime
        counters (submitted/applied/rejected/errors/rounds) are never
        reset."""
        with self._lock:
            lat = list(self._lat)
            real, padded = self._real_rows, self._padded_rows
            out = {
                "submitted": self._submitted,
                "applied": self._applied,
                "rejected": self._rejected,
                "errors": len(self._errors),
                "inflight": sum(self._inflight.values()),
                "rounds": self._rounds,
                "latency_p50_s": _percentile(lat, 50),
                "latency_p99_s": _percentile(lat, 99),
                "real_rows": real,
                "padded_rows": padded,
                "pad_waste": padded / max(1, real + padded),
            }
            if reset:
                self._lat.clear()
                self._real_rows = 0
                self._padded_rows = 0
            return out
