"""Chaos harness: a process-wide fault-point registry (ISSUE 9).

Every failure mode the fault-tolerance layer claims to survive is
*injectable* — in CI, in the chaos driver (``launch/serve.py --chaos``) and
in tests — through named fault points compiled into the hot paths:

  ``ingest.apply_round``   — fired by ``IngestQueue._apply`` before the
                             fused dispatch of each round.  Arm with
                             ``exc=WorkerKilled`` to simulate the worker
                             thread dying mid-round (the kill-mid-round
                             crash of the WAL replay contract), or a
                             transient exception to exercise
                             retry/backoff.
  ``ingest.apply_lane``    — fired per lane inside the poison-excision
                             fallback; arm with ``match={"sid": s}`` to
                             poison exactly one tenant.
  ``ingest.dispatch_lane`` — fired per lane inside the DISTRIBUTED
                             per-lane dispatch loop, before that lane's
                             sharded update; arm with ``match={"sid": s}``
                             to fail a round partway through and exercise
                             the exactly-once partial-round bookkeeping
                             (landed lanes must not re-apply on retry or
                             fallback).
  ``ckpt.pre_commit``      — fired by ``checkpoint.ckpt.save`` between
                             staging the tmp dir and the atomic
                             ``os.replace``; arm with a ``handler`` to
                             tear the staged files (torn-write chaos) or
                             an ``exc`` to crash before the commit.
  ``elastic.reshard``      — fired by ``stream.elastic.reshard_stream``
                             before the hop (device-loss simulation).

Fault points are **zero-cost when disarmed**: ``fire`` is a dict lookup
returning immediately.  Arming is per-point with an optional budget
(``times``) and an optional context ``match`` so a fault can target one
sid / one step while the rest of the traffic flows.

The driver-level scenarios (kill-worker-mid-round, torn write,
restore-onto-smaller-mesh, eviction storm) live in
:func:`run_chaos_scenario`, wired to ``launch/serve.py --chaos``.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Optional

_ARMED: Dict[str, "_Fault"] = {}
_LOCK = threading.Lock()


class FaultInjected(RuntimeError):
    """Default exception raised by an armed fault point."""


class WorkerKilled(BaseException):
    """Simulated hard crash of a worker thread.  Deliberately a
    BaseException: it must escape the per-round ``except Exception``
    error-recording path the same way a real segfault/kill would — the
    worker dies, it does not log-and-continue."""


class _Fault:
    def __init__(self, exc=None, handler=None, times=None, match=None):
        self.exc = exc
        self.handler = handler
        self.times = times            # None = unlimited
        self.match = dict(match or {})
        self.fired = 0

    def applies(self, ctx: Dict[str, Any]) -> bool:
        if self.times is not None and self.fired >= self.times:
            return False
        return all(ctx.get(k) == v for k, v in self.match.items())


def arm(point: str, *, exc: Optional[type] = None,
        handler: Optional[Callable] = None,
        times: Optional[int] = 1,
        match: Optional[Dict[str, Any]] = None) -> None:
    """Arm ``point``.  Exactly one of ``exc`` (raised at the point) or
    ``handler`` (called with the point's context kwargs; its return value
    is ignored unless the site documents otherwise) fires per matching
    ``fire``; ``times=None`` keeps the fault armed forever."""
    if exc is None and handler is None:
        exc = FaultInjected
    with _LOCK:
        _ARMED[point] = _Fault(exc=exc, handler=handler, times=times,
                               match=match)


def disarm(point: str) -> None:
    with _LOCK:
        _ARMED.pop(point, None)


def clear() -> None:
    """Disarm everything (test teardown)."""
    with _LOCK:
        _ARMED.clear()


def armed(point: str) -> bool:
    return point in _ARMED


def fire(point: str, **ctx) -> None:
    """Hot-path hook: no-op unless ``point`` is armed and the context
    matches.  An armed ``exc`` is raised here; an armed ``handler`` runs
    here (exceptions it raises propagate — a handler may itself crash the
    site)."""
    fault = _ARMED.get(point)
    if fault is None or not fault.applies(ctx):
        return
    fault.fired += 1
    if fault.handler is not None:
        fault.handler(**ctx)
        return
    raise fault.exc(f"chaos: fault injected at {point!r} ({ctx})")


def fire_count(point: str) -> int:
    fault = _ARMED.get(point)
    return 0 if fault is None else fault.fired


# ---------------------------------------------------------------------------
# Driver-level chaos scenarios (launch/serve.py --chaos)
# ---------------------------------------------------------------------------

SCENARIOS = ("kill-worker", "torn-write", "shrink-restore", "eviction-storm")


def run_chaos_scenario(scenario: str, *, n1: int = 256, n2: int = 128,
                       r: int = 8, streams: int = 8, updates: int = 3,
                       workdir: Optional[str] = None,
                       verbose: bool = True) -> Dict[str, Any]:
    """Run one end-to-end failure-and-recovery drill; returns a result
    dict whose ``recovered`` field is the scenario's pass/fail verdict.

    Every scenario builds its own small serving stack, injects the fault
    through this registry (never by monkeypatching), recovers through the
    production path (WAL replay / torn-checkpoint quarantine / elastic
    restore / QoS restore) and verifies the recovery contract — bitwise
    where the contract is bitwise.
    """
    import tempfile

    import numpy as np

    out: Dict[str, Any] = {"scenario": scenario}
    say = print if verbose else (lambda *a, **k: None)
    tmp_ctx = (tempfile.TemporaryDirectory() if workdir is None else None)
    workdir = workdir if workdir is not None else tmp_ctx.name
    rng = np.random.default_rng(0)
    try:
        if scenario == "kill-worker":
            out.update(_chaos_kill_worker(rng, n1, n2, r, streams, updates,
                                          workdir, say))
        elif scenario == "torn-write":
            out.update(_chaos_torn_write(rng, n1, n2, r, workdir, say))
        elif scenario == "shrink-restore":
            out.update(_chaos_shrink_restore(say))
        elif scenario == "eviction-storm":
            out.update(_chaos_eviction_storm(rng, n1, n2, r, streams,
                                             workdir, say))
        else:
            raise ValueError(f"unknown chaos scenario {scenario!r}; "
                             f"have {SCENARIOS}")
    finally:
        clear()
        if tmp_ctx is not None:
            tmp_ctx.cleanup()
    say(f"[chaos:{scenario}] recovered={out['recovered']}")
    return out


def _mk_traffic(rng, streams, updates, n1, n2):
    traffic = []
    for u in range(updates):
        for s in range(streams):
            k = int(rng.integers(1, 33))
            traffic.append((s, rng.standard_normal((k, n2)).astype("float32"),
                            int(rng.integers(0, n1 - k + 1))))
    return traffic


def _chaos_kill_worker(rng, n1, n2, r, streams, updates, workdir, say):
    """Kill the ingest worker mid-round; recover by replaying the WAL into
    a fresh service — finalize must be bitwise the uninterrupted run."""
    import os
    import time

    import numpy as np

    from repro.stream import wal as wal_mod
    from repro.stream.ingest import IngestQueue, WorkerDied
    from repro.stream.service import SketchService
    from repro.stream.state import StreamConfig

    cfgs = [StreamConfig(n1=n1, n2=n2, r=r, seed=s, corange=False)
            for s in range(streams)]
    traffic = _mk_traffic(rng, streams, updates, n1, n2)

    # reference: the run that never crashes
    ref = SketchService()
    ref_sids = [ref.open(c) for c in cfgs]
    for s, H, row0 in traffic:
        ref.update(ref_sids[s], H, row0=row0)
    ref_Y = [np.asarray(ref.sketch(s)) for s in ref_sids]

    # victim: journaled ingest, worker killed mid-round
    svc = SketchService()
    sids = [svc.open(c) for c in cfgs]
    wal = wal_mod.WriteAheadLog(os.path.join(workdir, "ingest.wal"))
    q = IngestQueue(svc, wal=wal)
    # every submit of one sid lands in a distinct round, so with
    # ``updates`` submits per stream at least ``updates`` rounds run —
    # killing at round index updates-1 is guaranteed to trigger, and some
    # earlier rounds have already landed (a genuine MID-stream crash)
    kill_after = max(2, updates - 1)
    arm("ingest.apply_round", exc=WorkerKilled, times=None,
        match={"round_index": kill_after})
    died = False
    for s, H, row0 in traffic:
        try:
            q.submit(sids[s], H, row0)
        except WorkerDied:
            died = True
            break
    if not died:                     # the kill may land after the last submit
        try:
            q.flush()
        except WorkerDied:
            died = True
    say(f"[chaos] worker died={died}, wal depth={wal.depth}")
    disarm("ingest.apply_round")
    q.shutdown()
    wal.close()

    # recovery: fresh service, same stream configs, replay the journal
    t0 = time.perf_counter()
    svc2 = SketchService()
    sids2 = [svc2.open(c) for c in cfgs]
    nrec, words = wal_mod.replay(wal.path, svc2,
                                 sid_map=dict(zip(sids, sids2)))
    svc2.sync()
    dt = time.perf_counter() - t0
    bitwise = all(np.array_equal(np.asarray(svc2.sketch(s)), refy)
                  for s, refy in zip(sids2, ref_Y))
    say(f"[chaos] replayed {nrec} records / {words} words "
        f"in {dt * 1e3:.1f} ms, bitwise={bitwise}")
    return {"recovered": died and bitwise, "worker_died": died,
            "replayed_records": nrec, "replayed_words": words,
            "recover_s": dt, "bitwise": bitwise}


def _chaos_torn_write(rng, n1, n2, r, workdir, say):
    """Tear a checkpoint commit; the torn step must be quarantined, never
    restored, and the previous good step must load."""
    import os

    import numpy as np

    from repro.checkpoint import ckpt
    from repro.stream.state import StreamConfig, StreamingSketch

    d = os.path.join(workdir, "ckpt")
    st = StreamingSketch(StreamConfig(n1=n1, n2=n2, r=r, seed=3,
                                      corange=False), backend="xla")
    st.update_rows(0, rng.standard_normal((32, n2)).astype("float32"))
    st.save(d, step=1)
    good_Y = np.asarray(st.Y)
    st.update_rows(32, rng.standard_normal((32, n2)).astype("float32"))

    def tear(tmp, **_):
        os.remove(os.path.join(tmp, "manifest.json"))

    arm("ckpt.pre_commit", handler=tear)
    st.save(d, step=2)
    disarm("ckpt.pre_commit")
    torn = ckpt.torn_steps(d)
    latest = ckpt.latest_step(d)
    st2 = StreamingSketch.restore(d)
    ok = (torn == [2] and latest == 1
          and np.array_equal(np.asarray(st2.Y), good_Y))
    say(f"[chaos] torn steps={torn}, latest={latest}, "
        f"restored step-1 bitwise={ok}")
    return {"recovered": ok, "torn_steps": torn, "latest_step": latest}


def _chaos_shrink_restore(say):
    """Reshard a live 8-device stream onto 4 devices (and back) in a
    subprocess with fake devices; finalize must stay bitwise."""
    import subprocess
    import sys

    code = (
        "import numpy as np, jax\n"
        "from repro.core.sketch import make_grid_mesh\n"
        "from repro.stream import ShardedStreamingSketch, StreamConfig\n"
        "from repro.stream.elastic import reshard_stream\n"
        "cfg = StreamConfig(n1=256, n2=256, r=8, seed=5, corange=False)\n"
        "rng = np.random.default_rng(0)\n"
        "slabs = [(i * 64, rng.standard_normal((64, 256))"
        ".astype('float32')) for i in range(4)]\n"
        "ref = ShardedStreamingSketch(cfg, make_grid_mesh(8, 1, 1),"
        " backend='jnp')\n"
        "for row0, H in slabs: ref.update_rows(row0, H)\n"
        "sk = ShardedStreamingSketch(cfg, make_grid_mesh(8, 1, 1),"
        " backend='jnp')\n"
        "for row0, H in slabs[:2]: sk.update_rows(row0, H)\n"
        "sk = reshard_stream(sk, (4, 1, 1))   # device loss: 8 -> 4\n"
        "sk.update_rows(slabs[2][0], slabs[2][1])\n"
        "sk = reshard_stream(sk, (8, 1, 1))   # devices came back\n"
        "sk.update_rows(slabs[3][0], slabs[3][1])\n"
        "assert np.array_equal(np.asarray(jax.device_get(sk.Y)),"
        " np.asarray(jax.device_get(ref.Y)))\n"
        "print('RESHARD_BITWISE_OK')\n")
    import os
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = os.path.join(os.path.dirname(__file__), "..", "..")
    env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    ok = "RESHARD_BITWISE_OK" in proc.stdout
    say(f"[chaos] shrink/grow reshard bitwise={ok}"
        + ("" if ok else f"\n{proc.stdout}\n{proc.stderr[-2000:]}"))
    return {"recovered": ok and proc.returncode == 0}


def _chaos_eviction_storm(rng, n1, n2, r, streams, workdir, say):
    """Hammer a budget-1 service so every touch evicts the previous
    resident to disk; state must survive the storm bitwise."""
    import os

    import numpy as np

    from repro.stream.service import SketchService
    from repro.stream.state import StreamConfig

    cfgs = [StreamConfig(n1=n1, n2=n2, r=r, seed=s, corange=False)
            for s in range(streams)]
    ref = SketchService()
    svc = SketchService(max_resident=1,
                        spill_dir=os.path.join(workdir, "spill"))
    ref_sids = [ref.open(c) for c in cfgs]
    sids = [svc.open(c) for c in cfgs]
    for rnd in range(3):
        for i in range(streams):     # every update storms an eviction
            k = int(rng.integers(1, 33))
            H = rng.standard_normal((k, n2)).astype("float32")
            row0 = int(rng.integers(0, n1 - k + 1))
            ref.update(ref_sids[i], H, row0=row0)
            svc.update(sids[i], H, row0=row0)
    ok = all(np.array_equal(np.asarray(svc.sketch(s)),
                            np.asarray(ref.sketch(rs)))
             for s, rs in zip(sids, ref_sids))
    say(f"[chaos] {svc.stats()['evicted']} evicted after storm, "
        f"bitwise={ok}")
    return {"recovered": ok, "evicted": svc.stats()["evicted"]}
