"""Streaming one-pass sketches: linear updates A <- A + H folded into
(Y = A·Omega, W = Psi·A) with Omega/Psi regenerated, never communicated.

  state.py        — StreamConfig + the single-device StreamingSketch
                    (row/col/additive ingest, checkpoint save/restore)
  distributed.py  — ShardedStreamingSketch on the (p1, p2, p3) grid
                    (full-shape + row-slab ingest, checkpointing; accepts
                    a repro.plan.Plan in place of a mesh)
  reconstruct.py  — one-pass fixed-rank A ~= Q·(Psi Q)†·W (Tropp et al.)
  service.py      — SketchService: many concurrent streams, one mesh,
                    incl. fused multi-stream batched ingest (update_batch)
"""
from .state import (  # noqa: F401
    OMEGA_SALT, PSI_SALT, StreamConfig, StreamingSketch,
    omega_matrix, psi_cols, psi_matrix,
)
from .distributed import (  # noqa: F401
    ShardedStreamingSketch, corange_sharding, corange_update,
    nystrom_finalize,
)
from .reconstruct import (  # noqa: F401
    LowRank, one_pass_reconstruct, reconstruction_error,
)
from .service import SketchService  # noqa: F401
