"""Streaming one-pass sketches: linear updates A <- A + H folded into
(Y = A·Omega, W = Psi·A) with Omega/Psi regenerated, never communicated.

  state.py        — StreamConfig + the single-device StreamingSketch
                    (row/col/additive ingest, checkpoint save/restore)
  distributed.py  — ShardedStreamingSketch on the (p1, p2, p3) grid
                    (full-shape + row-slab ingest, checkpointing; accepts
                    a repro.plan.Plan in place of a mesh)
  reconstruct.py  — one-pass fixed-rank A ~= Q·(Psi Q)†·W (Tropp et al.)
  service.py      — SketchService: many concurrent streams, one mesh,
                    incl. fused multi-stream batched ingest (update_batch),
                    shape-bucketed ragged ingest (update_ragged) and
                    QoS-classed admission/eviction with transparent restore
  ingest.py       — IngestQueue: bounded async request queue with
                    backpressure, worker-death fail-fast (WorkerDied),
                    retry/backoff and poison-lane excision
  wal.py          — WriteAheadLog: crash-safe journal of accepted updates;
                    replay-after-crash reconstructs (Y, W) bitwise
  elastic.py      — reshard_stream / drain_reshard_resume: live mesh
                    resize in one hop, bitwise finalize
  faults.py       — chaos fault-point registry + driver scenarios
                    (launch/serve.py --chaos)
"""
from .state import (  # noqa: F401
    OMEGA_SALT, PSI_SALT, SparseRows, StreamConfig, StreamingSketch,
    omega_matrix, psi_cols, psi_matrix, pow2_bucket, snap_bucket,
)
from .distributed import (  # noqa: F401
    ShardedStreamingSketch, corange_sharding, corange_update,
    nystrom_finalize, stream_shardings,
)
from .reconstruct import (  # noqa: F401
    LowRank, one_pass_reconstruct, reconstruction_error,
)
from .service import QOS_CLASSES, SketchService  # noqa: F401
from .ingest import IngestQueue, WorkerDied  # noqa: F401
from .wal import WalRecord, WriteAheadLog  # noqa: F401
from .wal import replay as wal_replay  # noqa: F401
from .elastic import drain_reshard_resume, reshard_stream  # noqa: F401
from . import faults  # noqa: F401
