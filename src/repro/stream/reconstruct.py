"""One-pass low-rank reconstruction from (Y, W) sketch state.

Tropp et al. 2017, Algorithms 4/7: given the range sketch Y = A·Omega and
the co-range sketch W = Psi·A,

    Q, _  = qr(Y)                       # orthonormal range basis (n1 x r)
    X     = (Psi·Q)† · W                # least-squares fit      (r  x n2)
    A_hat = Q · X

with an optional fixed-rank truncation (SVD of the small X factor).  Psi is
regenerated from the stream seed — the reconstruction consumes no state
beyond the sketches themselves.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .state import StreamConfig, psi_matrix


class LowRank(NamedTuple):
    """A_hat = Q @ X with Q (n1, k) orthonormal and X (k, n2)."""
    Q: jax.Array
    X: jax.Array

    @property
    def rank(self) -> int:
        return self.Q.shape[1]

    def matrix(self):
        return self.Q @ self.X


def one_pass_reconstruct(Y, W, cfg: StreamConfig,
                         rank: Optional[int] = None,
                         rcond: Optional[float] = None) -> LowRank:
    """A ~= Q·(Psi Q)†·W, optionally truncated to ``rank``."""
    Q, _ = jnp.linalg.qr(jnp.asarray(Y))
    PsiQ = psi_matrix(cfg) @ Q                       # (l, r)
    X, *_ = jnp.linalg.lstsq(PsiQ, jnp.asarray(W), rcond=rcond)
    if rank is not None and rank < X.shape[0]:
        # Fixed-rank: SVD of the small factor only (r x n2), never of A_hat.
        U, s, Vt = jnp.linalg.svd(X, full_matrices=False)
        Q = Q @ U[:, :rank]
        X = s[:rank, None] * Vt[:rank]
    return LowRank(Q, X)


def reconstruction_error(A, approx: LowRank) -> jax.Array:
    """|| A - Q X ||_F / || A ||_F."""
    A = jnp.asarray(A)
    return jnp.linalg.norm(A - approx.matrix()) / jnp.linalg.norm(A)
