"""Live mesh resize for streaming sketch state (ISSUE 9, ROADMAP item 5).

Sketch state (Y, W) is a *sum of deterministic per-slab updates* — Tropp
linearity — so it is mesh-agnostic: re-laying the accumulators onto a
grown or shrunk (p1, p2, p3) grid is ONE resharding hop with **no
recompute**, and every update applied after the hop folds into exactly the
numbers it would have folded into on the original grid (the update
programs regenerate Omega/Psi from *global* coordinates and the fold is an
elementwise add whose operands are bit-identical either side of the hop).
``finalize()`` after a resize is therefore bitwise-identical to the
never-resized run — pinned by tests/test_fault_tolerance.py across
8 -> 4 -> 8 mid-stream.

The hop's traffic is priced by ``plan.model.stream_reshard_traffic_words``
(what the compiled relayout actually moves: full per-device shards, or
nothing when the layouts coincide — pinned at drift = 0) over the
``plan.model.stream_reshard_words`` min-cut floor (each device keeps the
overlap between its old and new shards and only needs the rest), charged
to the CommLedger site ``stream.reshard``:

  * same device set (relayout, e.g. (8,1,1) -> (4,2,1) or a p3 split):
    the hop compiles to a jitted identity with ``out_shardings`` — the
    ledger parses its HLO, so measured bytes sit next to the prediction
    (drift pinned at 0 for the coinciding-layout pairs, where the
    partitioner emits no collective at all).
  * different device count (grow / shrink — the elastic case): the hop is
    a ``jax.device_put`` across device sets, which XLA does not expose as
    one parseable executable; the site is analytic (``CommLedger.record``)
    with the same min-cut prediction.

``reshard_stream`` moves one live :class:`ShardedStreamingSketch`;
``SketchService.reshard`` (service.py) moves every resident stream of a
distributed service through the same helpers; ``drain_reshard_resume``
is the degraded-mode recovery arc — quiesce the ingest queue, reshard the
service onto the surviving grid, resume — driven on simulated device loss
by the chaos harness (stream/faults.py) and ``launch/serve.py --chaos``.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.sketch import DEFAULT_AXES, make_grid_mesh
from repro.obs import ledger as obs_ledger
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from . import faults
from .state import StreamConfig

LEDGER_SITE = "stream.reshard"


def _grid_of(mesh, axes) -> Tuple[int, int, int]:
    return tuple(int(mesh.shape[a]) for a in axes)


def _check_divisible(cfg: StreamConfig, grid: Tuple[int, int, int]) -> None:
    p1, p2, p3 = grid
    if (cfg.n1 % (p1 * p2) or cfg.n2 % (p2 * p3) or cfg.n2 % p2
            or cfg.r % p3):        # n1 % (p1*p2): Y is P((p1, p2), p3)
        raise ValueError(f"stream shape ({cfg.n1},{cfg.n2},r={cfg.r}) "
                         f"not divisible by grid ({p1},{p2},{p3})")


@functools.lru_cache(maxsize=64)
def _relayout_prog(out_shardings: Tuple):
    """Jitted identity pinning its outputs to ``out_shardings`` — the
    compiled one-hop relayout (same device set).  NamedShardings are
    hashable, so every stream resharding between the same layout pair
    shares one executable."""
    return jax.jit(lambda *t: t, out_shardings=out_shardings)


def reshard_tree(arrays: Tuple, shardings: Tuple, *,
                 predicted_words: float, lower_bound_words: float,
                 itemsize: int,
                 old_grid: Tuple[int, int, int],
                 new_grid: Tuple[int, int, int]) -> Tuple:
    """Move a tuple of live arrays onto ``shardings`` in one hop, charging
    the ``stream.reshard`` ledger site and tracer span.  Chooses the
    HLO-measurable jit path when old and new shardings share one device
    set, ``jax.device_put`` otherwise (grow/shrink)."""
    m = obs_metrics.get_metrics()
    m.counter("stream_reshard_total",
              "live accumulator resharding hops (elastic resize)").inc()
    led = obs_ledger.get_ledger()
    old_devs = arrays[0].sharding.mesh.devices.flatten().tolist() \
        if hasattr(arrays[0].sharding, "mesh") else None
    new_devs = shardings[0].mesh.devices.flatten().tolist()
    same_set = old_devs is not None and set(old_devs) == set(new_devs)
    with obs_trace.span("stream.reshard", cat="stream",
                        old="x".join(map(str, old_grid)),
                        new="x".join(map(str, new_grid)),
                        path="jit" if same_set else "device_put"):
        if same_set:
            fn = _relayout_prog(tuple(shardings))
            if led is not None:
                led.observe(LEDGER_SITE, fn, tuple(arrays),
                            predicted_words=predicted_words,
                            lower_bound_words=lower_bound_words,
                            itemsize=itemsize)
            return fn(*arrays)
        if led is not None:
            led.record(LEDGER_SITE, predicted_words=predicted_words,
                       lower_bound_words=lower_bound_words,
                       itemsize=itemsize)
        return tuple(jax.device_put(a, s)
                     for a, s in zip(arrays, shardings))


def reshard_words(cfg: StreamConfig, old_grid,
                  new_grid) -> Tuple[float, float]:
    """The hop's per-device (schedule words, min-cut floor) for this
    stream, from the planner (plan/model.py)."""
    from repro.plan import model as M
    kw = dict(l=cfg.sketch_l, n2=cfg.n2, corange=cfg.corange)
    return (M.stream_reshard_traffic_words(cfg.n1, cfg.r, tuple(old_grid),
                                           tuple(new_grid), **kw),
            M.stream_reshard_words(cfg.n1, cfg.r, tuple(old_grid),
                                   tuple(new_grid), **kw))


def reshard_stream(sk, new_grid: Tuple[int, int, int], *,
                   devices: Optional[Sequence] = None):
    """Re-lay a LIVE :class:`ShardedStreamingSketch` onto ``new_grid``.

    Returns a sketch on the new mesh whose (Y, W) are the SAME accumulated
    numbers, moved in one resharding hop — no recompute, no replay.
    Updates keep flowing afterwards; ``finalize()`` is bitwise the
    never-resized run.  ``devices`` defaults to ``jax.devices()`` (grow
    re-adopts returned devices, shrink keeps the surviving prefix).
    """
    from .distributed import ShardedStreamingSketch, stream_shardings

    new_grid = tuple(int(g) for g in new_grid)
    cfg, axes = sk.cfg, tuple(sk.axes)
    old_grid = _grid_of(sk.mesh, axes)
    _check_divisible(cfg, new_grid)
    # device-loss simulation hook: arm to fail the hop itself
    faults.fire("elastic.reshard", old_grid=old_grid, new_grid=new_grid)
    new_mesh = make_grid_mesh(*new_grid, axis_names=axes, devices=devices)
    out = ShardedStreamingSketch(cfg, new_mesh, axes=axes,
                                 backend=sk.backend, blocks=sk.blocks)
    sh = stream_shardings(cfg, new_mesh, axes)
    arrays, shardings = (sk.Y,), (sh["Y"],)
    if cfg.corange:
        arrays, shardings = (sk.Y, sk.W), (sh["Y"], sh["W"])
    pred, floor = reshard_words(cfg, old_grid, new_grid)
    moved = reshard_tree(
        arrays, shardings, predicted_words=pred, lower_bound_words=floor,
        itemsize=jnp.dtype(cfg.dtype).itemsize,
        old_grid=old_grid, new_grid=new_grid)
    out.Y = moved[0]
    out.W = moved[1] if cfg.corange else None
    out.num_updates = sk.num_updates
    return out


def drain_reshard_resume(queue, new_grid: Tuple[int, int, int], *,
                         devices: Optional[Sequence] = None,
                         timeout: Optional[float] = None) -> dict:
    """Degraded-mode recovery arc on simulated device loss:

      1. **drain** — quiesce the ingest queue (every accepted request is
         applied; in-flight rounds finish on the old mesh),
      2. **reshard** — move every resident stream of the queue's service
         onto the surviving ``new_grid`` in one hop each,
      3. **resume** — the queue keeps accepting; subsequent rounds compile
         against the new mesh.

    Returns ``{"drained": n_applied, "resharded": n_streams}``.  The queue
    stays usable throughout — this is a pause, not a restart.
    """
    with obs_trace.span("stream.drain_reshard_resume", cat="stream",
                        new="x".join(map(str, new_grid))):
        drained = queue.flush(timeout=timeout)
        resharded = queue.service.reshard(new_grid, devices=devices)
    return {"drained": drained, "resharded": resharded}
