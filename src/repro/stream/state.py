"""One-pass streaming sketch state (Tropp et al. 2017; paper §4.2 + §6.3).

The sketches the paper parallelizes are *linear* in A, so they support the
one-pass streaming model of Tropp et al., *Practical sketching algorithms
for low-rank matrix approximation* (see PAPERS.md): for any additive update

    A  <-  A + H      =>      Y  <-  Y + H·Omega ,   W  <-  W + Psi·H

where Y = A·Omega (n1 x r) is the range sketch and W = Psi·A (l x n2) the
co-range sketch.  A never has to be resident; only the O((n1 + n2)·r) sketch
state is stored.  Because Omega and Psi are regenerated from a counter-based
seed (the source paper's central claim, §6.3), streaming updates inherit the
zero-communication property for free: no processor ever sends or receives a
byte of Omega or Psi, no matter how many updates arrive.

Update granularities:

  * ``update_rows(row0, H)`` — a block of rows arrives (the classic
    streaming model).  Each row of Y is produced by one full-contraction
    GEMM, so a row-partitioned stream reproduces the one-shot
    ``core.sketch.sketch_reference`` **bitwise**, for any chunking and any
    arrival order.
  * ``update_cols(col0, H)`` — a block of columns arrives; Y accumulates
    partial contractions (equal to one-shot up to FP summation order).
  * ``update(H)`` — general additive update of the full matrix.

Determinism contract: Omega/Psi entries are bitwise-invariant to tiling and
compilation context by construction (see ``core/rng.py``), and each Y row is
written by exactly one row-block update (0 + x == x in IEEE-754), so a given
row chunking produces identical bits in ANY arrival order.  Equality with
the one-shot ``sketch_reference`` is additionally bitwise whenever the
backend computes a dot's rows identically across GEMM heights — true at
small/moderate contraction sizes (pinned by tests/test_stream.py), but CPU
BLAS may switch blocking for very short chunks against a large contraction
(e.g. 64-row chunks at n2=1024), where agreement drops to reduction-order
tolerance (~1e-5).  W and overlapping/column updates accumulate in arrival
order, so they match one-shot results to FP tolerance, not bitwise.

The local accumulator here runs on one device; ``distributed.py`` holds the
mesh-sharded version and ``service.py`` the many-streams serving front end.
On TPU the local GEMM can run through the fused Pallas kernel
(``kernels/sketch_matmul.py``), which also keeps Omega out of HBM.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch import (SPARSE_KINDS, omega_tile, seed_keys,
                               sparse_omega_rows, validate_kind)

OMEGA_SALT = 0   # salt stream for Omega (range sketch)
PSI_SALT = 1     # salt stream for Psi (co-range sketch); must differ


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Shape/seed contract of one stream.

    n1, n2 : global shape of the streamed matrix A
    r      : range-sketch size (columns of Omega)
    l      : co-range-sketch size (rows of Psi); default 2r+1 per Tropp
             et al.'s l >= 2k+1 guidance, clipped to n1
    seed   : Philox seed; Omega and Psi come from the same seed under
             different salts, so one uint32 pair keys the whole stream
    kind   : Omega/Psi family — dense entry distributions ("normal" |
             "uniform" | "rademacher") or the sparse families
             ("countsketch" | "rowsample", one nonzero per row; see
             core/sketch.py SPARSE_KINDS)
    corange: track W = Psi·A (needed for general low-rank reconstruction;
             unnecessary for sketch-only and Nyström workloads)
    """
    n1: int
    n2: int
    r: int
    l: Optional[int] = None
    seed: int = 0
    kind: str = "normal"
    dtype: Any = jnp.float32
    corange: bool = True
    omega_salt: int = OMEGA_SALT
    psi_salt: int = PSI_SALT

    @property
    def sketch_l(self) -> int:
        return self.l if self.l is not None else min(2 * self.r + 1, self.n1)

    def validate(self):
        validate_kind(self.kind)
        if self.r <= 0 or self.n1 <= 0 or self.n2 <= 0:
            raise ValueError(f"bad stream shape {self}")
        if self.omega_salt == self.psi_salt and self.corange:
            raise ValueError("omega_salt and psi_salt must differ")

    # -- JSON round trip (checkpoint manifests) -----------------------------

    def to_json_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["dtype"] = jnp.dtype(self.dtype).name
        return d

    @classmethod
    def from_json_dict(cls, d: dict) -> "StreamConfig":
        d = dict(d)
        d["dtype"] = jnp.dtype(d["dtype"])
        return cls(**d)


def omega_matrix(cfg: StreamConfig, seed=None):
    """The full (n2, r) Omega of a stream (reference/inspection path)."""
    return omega_tile(cfg.seed if seed is None else seed, 0, 0,
                      cfg.n2, cfg.r, cfg.kind, cfg.dtype, salt=cfg.omega_salt)


def psi_matrix(cfg: StreamConfig, seed=None):
    """The full (l, n1) Psi.  Generated as the transpose of an (n1, l) tile
    so column slices Psi[:, i0:i1] share global row coordinates with the
    row-block updates that consume them (tile-decomposition invariance)."""
    return omega_tile(cfg.seed if seed is None else seed, 0, 0,
                      cfg.n1, cfg.sketch_l, cfg.kind, cfg.dtype,
                      salt=cfg.psi_salt, n_total=cfg.n1).T


def psi_cols(cfg: StreamConfig, row0, rows: int, seed=None):
    """Psi[:, row0:row0+rows] as an (rows, l) tile (pre-transpose layout);
    row0 may be traced.  ``n_total=cfg.n1`` pins the rowsample membership
    probability to the stream's global height, row slice or not."""
    return omega_tile(cfg.seed if seed is None else seed, row0, 0,
                      rows, cfg.sketch_l, cfg.kind, cfg.dtype,
                      salt=cfg.psi_salt, n_total=cfg.n1)


def validate_row_block(cfg: StreamConfig, row0: int, shape: Tuple[int, int]):
    """Bounds check shared by the accumulator and the service."""
    k, n2 = shape
    if n2 != cfg.n2 or row0 < 0 or row0 + k > cfg.n1:
        raise ValueError(f"row block ({row0}, {shape}) outside "
                         f"({cfg.n1}, {cfg.n2})")


@dataclasses.dataclass(frozen=True)
class SparseRows:
    """A sparse row slab in COO form: ``A[row0 + row[e], col[e]] += val[e]``.

    ``shape = (k, n2)`` is the DENSE slab shape the entries live in; the
    wire format is (indices, values) — ``2·nnz`` words instead of the
    dense slab's ``k·n2`` — which is exactly what the sparse ledger site
    and ``plan.model.sparse_payload_words`` price.
    """
    row: Any                   # (nnz,) int32, local row within the slab
    col: Any                   # (nnz,) int32, global column in [0, n2)
    val: Any                   # (nnz,) values
    shape: Tuple[int, int]     # (k, n2)

    @property
    def nnz(self) -> int:
        return int(np.shape(self.row)[0])

    @classmethod
    def from_dense(cls, H) -> "SparseRows":
        """COO of a dense slab (entry order: row-major, as np.nonzero)."""
        H = np.asarray(H)
        r, c = np.nonzero(H)
        return cls(row=np.asarray(r, np.int32), col=np.asarray(c, np.int32),
                   val=H[r, c], shape=tuple(H.shape))

    def to_dense(self, dtype=None):
        out = np.zeros(self.shape,
                       dtype or np.asarray(self.val).dtype)
        np.add.at(out, (np.asarray(self.row), np.asarray(self.col)),
                  np.asarray(self.val))
        return out

    def validate(self, cfg: StreamConfig, row0: int) -> None:
        validate_row_block(cfg, row0, self.shape)
        k, n2 = self.shape
        row = np.asarray(self.row)
        col = np.asarray(self.col)
        if row.shape != col.shape or row.shape != np.shape(self.val):
            raise ValueError(f"ragged COO arrays: {row.shape} / "
                             f"{col.shape} / {np.shape(self.val)}")
        if row.size and (row.min() < 0 or row.max() >= k
                         or col.min() < 0 or col.max() >= n2):
            raise ValueError(f"COO indices outside slab shape {self.shape}")

    def padded(self, nnz_b: int):
        """(row, col, val) padded to ``nnz_b`` entries.  Pads carry
        ``row == k`` / ``col == n2`` / ``val == 0`` and are routed into
        sacrificial accumulator rows/columns that the update program drops
        before folding — a pad can never touch a real partial sum, so
        padding cannot perturb a single result bit."""
        k, n2 = self.shape
        nnz = self.nnz
        if nnz > nnz_b:
            raise ValueError(f"nnz={nnz} exceeds bucket {nnz_b}")
        pad = nnz_b - nnz
        row = np.concatenate([np.asarray(self.row, np.int32),
                              np.full(pad, k, np.int32)])
        col = np.concatenate([np.asarray(self.col, np.int32),
                              np.full(pad, n2, np.int32)])
        val = np.concatenate([np.asarray(self.val),
                              np.zeros(pad, np.asarray(self.val).dtype)])
        return row, col, val


def nystrom_local(Y, cfg: StreamConfig):
    """(B, C) of a symmetric stream on one device: C = Omega^T·Y needs no
    second pass over A — it is computable from the sketch alone."""
    om = omega_tile(cfg.seed, 0, 0, cfg.n2, cfg.r, cfg.kind, Y.dtype,
                    salt=cfg.omega_salt)
    return Y, om.T @ Y


@functools.lru_cache(maxsize=4096)
def _local_sig(cfg: StreamConfig) -> Tuple:
    """Executable signature of the local row-block update — NOT the seed.
    Cached: it sits on the per-lane hot path of ragged batched ingest."""
    return (cfg.n1, cfg.n2, cfg.r, cfg.sketch_l if cfg.corange else None,
            cfg.kind, jnp.dtype(cfg.dtype).name, cfg.corange,
            cfg.omega_salt, cfg.psi_salt)


def _local_rowblock_update(sig: Tuple, k: int):
    """The pure local row-block update (shared single-stream/batched)."""
    n1, n2, r, l, kind, dtype_name, corange, omega_salt, psi_salt = sig
    dtype = jnp.dtype(dtype_name)

    def upd(Y, W, H, keys, row0):
        om = omega_tile(keys, 0, 0, n2, r, kind, dtype, salt=omega_salt)
        dY = H @ om                                   # full contraction
        Yk = jax.lax.dynamic_slice(Y, (row0, 0), (k, r))
        Y = jax.lax.dynamic_update_slice(Y, Yk + dY, (row0, 0))
        if corange:
            psi_c = omega_tile(keys, row0, 0, k, l, kind, dtype,
                               salt=psi_salt, n_total=n1)  # (k, l)
            W = W + psi_c.T @ H
        return Y, W

    return upd


@functools.lru_cache(maxsize=256)
def local_rowblock_prog(sig: Tuple, k: int):
    """Compiled local row-block update, shared by every StreamingSketch and
    SketchService stream with the same shape signature: the seed enters as
    a traced uint32 key pair and the row offset as a traced int32, so one
    executable serves all seeds and offsets at chunk height ``k``.

    (Eager per-update dispatch of the Philox graph costs orders of
    magnitude more than this cached program — see core/sketch.py.)
    """
    return jax.jit(_local_rowblock_update(sig, k))


def pow2_bucket(k: int) -> int:
    """Smallest power of two >= k — the default ragged bucket snap (keeps
    the number of distinct compiled bucket programs logarithmic in the
    spread of lane heights)."""
    if k <= 1:
        return 1
    return 1 << (k - 1).bit_length()


def snap_bucket(k: int, edges=None) -> int:
    """Bucket height for a k-row lane: the smallest edge >= k when
    ``edges`` (ascending bucket tops, e.g. from
    ``repro.plan.choose_bucket_edges``) is given — a lane taller than
    every edge falls back to the pow2 snap (NOT its exact height, which
    would compile one ragged program per distinct over-tall height and
    stall live traffic for seconds per new height; the pow2 fallback
    keeps the over-tall program count logarithmic, pinned by
    tests/test_sparse.py::test_snap_bucket_overtall_*) — else the pow2
    snap.

    Height-1 lanes are never padded into a taller bucket: XLA-CPU lowers
    an M=1 matmul through a gemv kernel whose K-reduction order differs
    from the packed M>=2 gemm loop, so padding a single-row slab would
    break the lane-vs-solo bitwise contract at large contractions
    (pinned by tests/test_service_scale.py)."""
    if k <= 1:
        return 1
    if edges is None:
        return pow2_bucket(k)
    for e in edges:
        if e >= k:
            return int(e)
    return pow2_bucket(k)


def _local_ragged_update(sig: Tuple, kb: int, backend: str = "jnp"):
    """One lane of the shape-bucketed ragged update: a (kb, n2) padded slab
    whose first ``kvalid`` rows are real, folded at traced ``row0``.

    Pad rows are masked dead IN-PROGRAM — the H tail is zeroed before
    either GEMM (so a NaN pad probe never reaches Y or W) and the Y fold
    is windowed to ``kvalid`` rows (``fold_rows_block(nvalid=...)``), so
    rows outside [row0, row0 + kvalid) keep their exact input bits.  For
    the valid rows the expressions are literally those of
    :func:`_local_rowblock_update` (native-dtype GEMM against the same
    regenerated Omega/Psi tiles), which is what makes lane i of a bucketed
    batch bitwise the result of updating stream i alone (pinned by
    tests/test_service_scale.py).  ``backend`` dispatches the fold body
    (kernels/local.py): the pallas fold keeps the padded frame in VMEM
    and aliases Y in-place; both backends run the same ops on the same
    operands, so the fold is bitwise across backends.
    """
    from repro.kernels.local import fold_rows_block
    n1, n2, r, l, kind, dtype_name, corange, omega_salt, psi_salt = sig
    dtype = jnp.dtype(dtype_name)

    def upd(Y, W, H, keys, row0, kvalid):
        rows = jax.lax.broadcasted_iota(jnp.int32, (kb, 1), 0)
        Hm = jnp.where(rows < kvalid, H, jnp.zeros_like(H))
        om = omega_tile(keys, 0, 0, n2, r, kind, dtype, salt=omega_salt)
        dY = Hm @ om                                  # full contraction
        start = jnp.int32(n1) - jnp.asarray(row0, jnp.int32)
        Y = fold_rows_block(Y, dY, start, backend=backend, nvalid=kvalid)
        if corange:
            # Psi columns at global rows [row0, row0 + kb): the tail draws
            # beyond kvalid (possibly beyond n1) multiply zeroed H rows,
            # so they contribute exact ±0 terms only
            psi_c = omega_tile(keys, row0, 0, kb, l, kind, dtype,
                               salt=psi_salt, n_total=n1)  # (kb, l)
            W = W + psi_c.T @ Hm
        return Y, W

    return upd


@functools.lru_cache(maxsize=128)
def local_rowblock_ragged_prog(sig: Tuple, kb: int, n_streams: int,
                               backend: str = "jnp"):
    """Compiled shape-bucketed ragged batch update: ONE call ingests
    ``n_streams`` heterogeneous lanes padded to bucket height ``kb``, each
    under its own traced Philox key pair, row offset and valid-row count.

    The stacked (Y, W) accumulator buffers are DONATED: the program
    updates them in place, so batched ingest never holds two copies of the
    fleet's sketch state in HBM (the service stacks fresh buffers per
    call, which is exactly the aliasing-safe donation case).
    """
    corange = sig[6]
    upd = _local_ragged_update(sig, kb, backend)
    batched = jax.vmap(upd, in_axes=(0, 0 if corange else None, 0, 0, 0, 0))
    return jax.jit(batched, donate_argnums=(0, 1) if corange else (0,))


@functools.lru_cache(maxsize=128)
def local_rowblock_batch_prog(sig: Tuple, k: int, n_streams: int):
    """Batched (vmapped) row-block update: one compiled call ingests the
    same-shape chunk into ``n_streams`` independent streams at once, each
    lane running under its own traced Philox key pair and row offset —
    the generated Omega/Psi lanes are bitwise those of ``n_streams``
    separate single-stream updates (counter-based generation depends only
    on (keys, global coordinates), never on the batching context).
    """
    corange = sig[6]
    upd = _local_rowblock_update(sig, k)
    batched = jax.vmap(upd, in_axes=(0, 0 if corange else None, 0, 0, 0))
    return jax.jit(batched)


def _local_sparse_update(sig: Tuple, k: int, nnz_b: int):
    """Pure sparse row-slab update: H arrives as ``nnz_b`` COO entries
    (row, col, val) of a (k, n2) slab — O(nnz) scatter-adds when the
    Omega/Psi family is itself sparse, O(nnz·r) gathered FMAs against a
    regenerated dense Omega otherwise.  Never densifies H.

    Pad entries (``row == k`` / ``col == n2`` / ``val == 0``, appended by
    :meth:`SparseRows.padded`) scatter into one sacrificial dY row / W
    column that is dropped before the fold, so they cannot flip even a
    -0.0 in a real accumulator.
    """
    n1, n2, r, l, kind, dtype_name, corange, omega_salt, psi_salt = sig
    dtype = jnp.dtype(dtype_name)
    sparse_om = kind in SPARSE_KINDS

    def upd(Y, W, row, col, val, keys, row0):
        val = val.astype(dtype)
        if sparse_om:
            # Omega row ``col`` has ONE nonzero: (bucket, value) drawn at
            # counter g = col — gathered per stored entry (bitwise equal
            # to slicing the full map; counter-based draws see only g).
            b, v = sparse_omega_rows(keys, col, r, kind, dtype,
                                     salt=omega_salt, n_total=n2)
            dY = jnp.zeros((k + 1, r), dtype).at[row, b].add(val * v)
        else:
            om = omega_tile(keys, 0, 0, n2, r, kind, dtype,
                            salt=omega_salt)
            om = jnp.concatenate([om, jnp.zeros((1, r), dtype)])  # col==n2
            dY = jnp.zeros((k + 1, r), dtype).at[row].add(
                val[:, None] * om[col])
        dY = dY[:k]
        Yk = jax.lax.dynamic_slice(Y, (row0, 0), (k, r))
        Y = jax.lax.dynamic_update_slice(Y, Yk + dY, (row0, 0))
        if corange:
            g = jnp.asarray(row0, jnp.uint32) + row.astype(jnp.uint32)
            Wp = jnp.concatenate([W, jnp.zeros((l, 1), dtype)], axis=1)
            if sparse_om:
                pb, pv = sparse_omega_rows(keys, g, l, kind, dtype,
                                           salt=psi_salt, n_total=n1)
                Wp = Wp.at[pb, col].add(pv * val)
            else:
                # dense Psi columns at the entries' global rows: (k+1, l)
                # tile rows gathered by local row (row == k pads gather a
                # real draw that lands in the dropped column)
                psi_c = omega_tile(keys, row0, 0, k + 1, l, kind, dtype,
                                   salt=psi_salt, n_total=n1)
                Wp = Wp.at[:, col].add((psi_c[row] * val[:, None]).T)
            W = Wp[:, :n2]
        return Y, W

    return upd


@functools.lru_cache(maxsize=256)
def local_sparse_prog(sig: Tuple, k: int, nnz_b: int):
    """Compiled sparse row-slab update, cached per (signature, slab height,
    nnz bucket) — ``nnz_b`` is pow2-snapped by the callers so the number
    of distinct compiled programs stays logarithmic in payload spread."""
    return jax.jit(_local_sparse_update(sig, k, nnz_b))


@functools.lru_cache(maxsize=128)
def local_sparse_batch_prog(sig: Tuple, k: int, nnz_b: int, n_streams: int):
    """Batched (vmapped) sparse row-slab update: the single-stream sparse
    program vmapped over a leading lane axis with per-lane keys, offsets
    and COO payloads — lane i's bits are those of updating stream i alone
    (counter-based draws see only (keys, global coordinates))."""
    corange = sig[6]
    upd = _local_sparse_update(sig, k, nnz_b)
    batched = jax.vmap(upd,
                       in_axes=(0, 0 if corange else None, 0, 0, 0, 0, 0))
    return jax.jit(batched)


class StreamingSketch:
    """Single-device streaming accumulator for (Y, W).

    backend:
      * ``"xla"``     — plain jnp GEMM against a regenerated Omega tile
                        (bitwise-stable vs. ``sketch_reference``).
                        ``"jnp"`` is accepted as an alias (the name the
                        distributed entry points use — kernels/local.py).
      * ``"pallas"``  — the fused TPU kernel (Omega generated in VMEM,
                        never materialized in HBM).  Numerically equal to
                        within f32-accumulation tolerance, not bitwise.
      * ``"interpret"`` — the Pallas kernel in interpret mode (CPU tests).
      * ``"auto"``    — "pallas" on TPU, else "xla".
    """

    def __init__(self, cfg: StreamConfig, backend: str = "auto"):
        cfg.validate()
        if backend == "auto":
            backend = "pallas" if jax.default_backend() == "tpu" else "xla"
        if backend == "jnp":
            backend = "xla"
        if backend not in ("xla", "pallas", "interpret"):
            raise ValueError(f"unknown backend {backend!r}")
        self.cfg = cfg
        self.backend = backend
        self.Y = jnp.zeros((cfg.n1, cfg.r), cfg.dtype)
        self.W = (jnp.zeros((cfg.sketch_l, cfg.n2), cfg.dtype)
                  if cfg.corange else None)
        self._keys = jnp.stack(seed_keys(cfg.seed))
        self.num_updates = 0

    # -- sketch kernels ----------------------------------------------------

    def _range_delta(self, H):
        """H @ Omega over the full contraction (H: (k, n2))."""
        cfg = self.cfg
        if self.backend == "xla":
            om = omega_tile(cfg.seed, 0, 0, cfg.n2, cfg.r, cfg.kind,
                            H.dtype, salt=cfg.omega_salt)
            return H @ om
        from repro.kernels.ops import sketch_matmul
        return sketch_matmul(H, seed=cfg.seed, r=cfg.r, kind=cfg.kind,
                             salt=cfg.omega_salt,
                             interpret=(self.backend == "interpret"))

    # -- updates -----------------------------------------------------------

    def update_rows(self, row0: int, H):
        """Rows [row0, row0+k) arrive (additively).  Bitwise-reproduces the
        one-shot sketch for row-partitioned streams."""
        cfg = self.cfg
        validate_row_block(cfg, row0, H.shape)
        H = jnp.asarray(H, cfg.dtype)
        if self.backend == "xla":
            fn = local_rowblock_prog(_local_sig(cfg), H.shape[0])
            self.Y, self.W = fn(self.Y, self.W, H, self._keys,
                                jnp.int32(row0))
        else:
            k = H.shape[0]
            self.Y = self.Y.at[row0:row0 + k, :].add(self._range_delta(H))
            if self.W is not None:
                self.W = self.W + psi_cols(cfg, row0, k).T @ H
        self.num_updates += 1
        return self

    def update_rows_sparse(self, row0: int, sp: SparseRows):
        """Rows [row0, row0+k) arrive as a COO slab (additively).

        Folds exactly the numbers :meth:`update_rows` would fold for the
        densified slab up to scatter-accumulation order, moves only
        ``2·nnz`` words of payload, and never materializes the dense slab
        on device.  The compiled program is cached per (signature, k,
        pow2(nnz)); the pad entries are routed into sacrificial
        rows/columns so bucket padding is bitwise-invisible.
        """
        cfg = self.cfg
        sp.validate(cfg, row0)
        nnz_b = pow2_bucket(max(1, sp.nnz))
        row, col, val = sp.padded(nnz_b)
        fn = local_sparse_prog(_local_sig(cfg), sp.shape[0], nnz_b)
        self.Y, self.W = fn(self.Y, self.W, jnp.asarray(row),
                            jnp.asarray(col), jnp.asarray(val, cfg.dtype),
                            self._keys, jnp.int32(row0))
        self.num_updates += 1
        return self

    def update_cols(self, col0: int, H):
        """Columns [col0, col0+k) arrive (additively)."""
        cfg = self.cfg
        n1, k = H.shape
        if n1 != cfg.n1 or col0 < 0 or col0 + k > cfg.n2:
            raise ValueError(f"col block ({col0}, {H.shape}) outside "
                             f"({cfg.n1}, {cfg.n2})")
        H = jnp.asarray(H, cfg.dtype)
        om_rows = omega_tile(cfg.seed, col0, 0, k, cfg.r, cfg.kind,
                             H.dtype, salt=cfg.omega_salt,
                             n_total=cfg.n2)                 # Omega[col0:,:]
        self.Y = self.Y + H @ om_rows
        if self.W is not None:
            self.W = self.W.at[:, col0:col0 + k].add(psi_matrix(cfg) @ H)
        self.num_updates += 1
        return self

    def update(self, H):
        """General additive update A <- A + H with H of full shape."""
        if H.shape != (self.cfg.n1, self.cfg.n2):
            raise ValueError(f"update shape {H.shape} != "
                             f"({self.cfg.n1}, {self.cfg.n2})")
        return self.update_rows(0, H)

    # -- finalization ------------------------------------------------------

    @property
    def sketch(self):
        """The accumulated range sketch Y = A·Omega (the Alg.-1 output B)."""
        return self.Y

    @property
    def corange_sketch(self):
        return self.W

    def nystrom(self):
        """(B, C) Nyström pair of a symmetric stream — C from the sketch
        alone, no second pass over A (see :func:`nystrom_local`)."""
        cfg = self.cfg
        if cfg.n1 != cfg.n2:
            raise ValueError("Nyström needs a square (symmetric) stream")
        if self.backend in ("pallas", "interpret"):
            from repro.kernels.ops import sketch_t_matmul
            C = sketch_t_matmul(self.Y, seed=cfg.seed, r=cfg.r,
                                kind=cfg.kind, salt=cfg.omega_salt,
                                interpret=(self.backend == "interpret"))
            return self.Y, C
        return nystrom_local(self.Y, cfg)

    def reconstruct(self, rank: Optional[int] = None, rcond=None):
        """One-pass fixed-rank approximation A ~= Q·(Psi Q)†·W."""
        from .reconstruct import one_pass_reconstruct
        if self.W is None:
            raise ValueError("reconstruction needs corange=True")
        return one_pass_reconstruct(self.Y, self.W, self.cfg, rank=rank,
                                    rcond=rcond)

    # -- checkpointing ------------------------------------------------------

    def save(self, directory: str, step: Optional[int] = None,
             keep: int = 3) -> str:
        """Checkpoint the sketch state via ``checkpoint.ckpt`` (atomic,
        mesh-agnostic): (Y, W) as arrays, (config, seed, num_updates) in
        the manifest's ``extra``.  A long-running stream that restarts from
        this checkpoint finalizes bitwise-identically to one that never
        stopped — the sketch state plus the seed IS the whole stream.
        """
        from repro.checkpoint import ckpt
        step = self.num_updates if step is None else step
        tree = {"Y": self.Y}
        if self.W is not None:
            tree["W"] = self.W
        extra = {"config": self.cfg.to_json_dict(),
                 "num_updates": self.num_updates,
                 "backend": self.backend,
                 "layout": "local"}
        return ckpt.save(directory, step, tree, extra=extra, keep=keep)

    @classmethod
    def restore(cls, directory: str, step: Optional[int] = None,
                backend: Optional[str] = None) -> "StreamingSketch":
        """Rebuild a stream (config + state) from a checkpoint.

        The saved backend is restored by default (``backend="auto"`` would
        otherwise re-resolve per machine and could continue a stream on a
        non-bitwise kernel path); pass ``backend=`` explicitly to migrate.
        """
        from repro.checkpoint import ckpt
        extra, step = ckpt.load_extra(directory, step)
        cfg = StreamConfig.from_json_dict(extra["config"])
        st = cls(cfg, backend=backend or extra.get("backend", "auto"))
        tree = {"Y": st.Y}
        if st.W is not None:
            tree["W"] = st.W
        tree, _, extra = ckpt.restore(directory, tree, step)
        st.Y = tree["Y"]
        st.W = tree.get("W")
        st.num_updates = int(extra["num_updates"])
        return st
