"""Mesh-sharded streaming sketch state (paper Alg. 1 applied per update).

State layout on the (p1, p2, p3) grid — the streaming extension of the
Alg.-1 contract (see docs/ARCHITECTURE.md):

  Y (n1 x r)  : sharded P((p1, p2), p3)   — the Alg.-1 *output* layout, so
                every update's Reduce-Scatter lands exactly on the resident
                shard; accumulation is local adds, zero extra movement.
  W (l  x n2) : sharded P(None, (p2, p3)) — column-split like A's blocks,
                replicated over p1; each update psums the per-p1 partial
                Psi_i^T·H_i over the p1 fiber.

Per additive update A <- A + H the communication is exactly the Alg.-1 cost
of sketching H (All-Gather over p3 + Reduce-Scatter over p2; zero in the
regime-1 grids p2 = p3 = 1) plus, when the co-range sketch is enabled, one
All-Reduce of l·n2/(p2·p3) words over p1.  No Omega or Psi entries are ever
communicated — both are regenerated per update from the stream seed.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.nystrom import (
    nystrom_second_stage_no_redist,
    nystrom_second_stage_redist,
)
from repro.core.sketch import (
    DEFAULT_AXES,
    input_sharding,
    omega_tile,
    output_sharding,
    rand_matmul,
)

from .state import StreamConfig, psi_cols


def corange_sharding(mesh: Mesh, axes=DEFAULT_AXES) -> NamedSharding:
    """Sharding of W per the streaming state layout."""
    return NamedSharding(mesh, P(None, (axes[1], axes[2])))


def nystrom_finalize(Y, cfg: StreamConfig, mesh: Mesh,
                     axes: Tuple[str, str, str] = DEFAULT_AXES,
                     variant: str = "auto"):
    """(B, C) of a symmetric stream from its accumulated Y, reusing the
    Alg.-2 second stages.

    Needs a 1-D (P, 1, 1) grid so Y is row-sharded — exactly the layout the
    paper's Redist / No-Redist second stages consume.  ``auto`` follows the
    paper's crossover: redist iff P > n/r (Fig. 7).
    """
    ax1, ax2, ax3 = axes
    if cfg.n1 != cfg.n2:
        raise ValueError("Nyström needs a square (symmetric) stream")
    if mesh.shape[ax2] != 1 or mesh.shape[ax3] != 1:
        raise ValueError("streaming Nyström finalize needs a (P,1,1) grid; "
                         f"have {tuple(mesh.shape.values())}")
    Pn = mesh.shape[ax1]
    if variant == "auto":
        variant = ("redist" if Pn > max(1, cfg.n1 // max(cfg.r, 1))
                   else "no_redist")
    Y = jax.device_put(Y, NamedSharding(mesh, P(ax1, None)))
    if variant == "no_redist":
        C = nystrom_second_stage_no_redist(Y, cfg.seed, cfg.r, mesh,
                                           axis=ax1, kind=cfg.kind,
                                           salt=cfg.omega_salt)
        return Y, C
    if variant == "redist":
        return nystrom_second_stage_redist(Y, cfg.seed, cfg.r, mesh,
                                           axis=ax1, kind=cfg.kind,
                                           salt=cfg.omega_salt)
    raise ValueError(variant)


def corange_update(W, H, cfg: StreamConfig, mesh: Mesh,
                   axes: Tuple[str, str, str] = DEFAULT_AXES, seed=None):
    """W + Psi·H with H in the Alg.-1 input layout and W in the streaming
    co-range layout.  Psi columns are regenerated per p1 block — the only
    traffic is the psum of the data-derived partial products."""
    ax1, ax2, ax3 = axes
    br = cfg.n1 // mesh.shape[ax1]

    def body(w_blk, h_blk):              # (l, n2/(p2p3)), (n1/p1, n2/(p2p3))
        i = jax.lax.axis_index(ax1)
        psi_c = psi_cols(cfg, i * br, br, seed=seed)       # (br, l)
        part = psi_c.T.astype(h_blk.dtype) @ h_blk
        return w_blk + jax.lax.psum(part, ax1)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, (ax2, ax3)), P(ax1, (ax2, ax3))),
                   out_specs=P(None, (ax2, ax3)))
    return fn(W, H)


class ShardedStreamingSketch:
    """Streaming (Y, W) accumulator over a (p1, p2, p3) processor grid.

    Updates are full-shape additive deltas H (zero rows/columns where
    nothing changed); each is sketched with the communication-optimal
    ``rand_matmul`` and added into the resident sketch state.  Row-disjoint
    updates reproduce the one-shot distributed sketch bitwise (untouched
    rows accumulate exact zeros).
    """

    def __init__(self, cfg: StreamConfig, mesh: Mesh,
                 axes: Tuple[str, str, str] = DEFAULT_AXES):
        cfg.validate()
        ax1, ax2, ax3 = axes
        p1, p2, p3 = (mesh.shape[a] for a in axes)
        if cfg.n1 % p1 or cfg.n2 % (p2 * p3) or cfg.n2 % p2 or cfg.r % p3:
            raise ValueError(f"stream shape ({cfg.n1},{cfg.n2},r={cfg.r}) "
                             f"not divisible by grid ({p1},{p2},{p3})")
        self.cfg = cfg
        self.mesh = mesh
        self.axes = axes
        self.Y = jax.device_put(jnp.zeros((cfg.n1, cfg.r), cfg.dtype),
                                output_sharding(mesh, axes))
        self.W = (jax.device_put(
                      jnp.zeros((cfg.sketch_l, cfg.n2), cfg.dtype),
                      corange_sharding(mesh, axes))
                  if cfg.corange else None)
        self.num_updates = 0
        self._upd = jax.jit(self._make_update())

    def _make_update(self):
        cfg, mesh, axes = self.cfg, self.mesh, self.axes

        def upd(Y, W, H):
            Y = Y + rand_matmul(H, cfg.seed, cfg.r, mesh, axes=axes,
                                kind=cfg.kind, salt=cfg.omega_salt)
            if W is not None:
                W = corange_update(W, H, cfg, mesh, axes)
            return Y, W

        return upd

    def update(self, H):
        """A <- A + H; H must be the full (n1, n2) shape (sharded or host)."""
        if H.shape != (self.cfg.n1, self.cfg.n2):
            raise ValueError(f"update shape {H.shape} != "
                             f"({self.cfg.n1}, {self.cfg.n2})")
        H = jax.device_put(jnp.asarray(H, self.cfg.dtype),
                           input_sharding(self.mesh, self.axes))
        self.Y, self.W = self._upd(self.Y, self.W, H)
        self.num_updates += 1
        return self

    # -- finalization ------------------------------------------------------

    @property
    def sketch(self):
        """Y = A·Omega in the Alg.-1 output layout P((p1, p2), p3)."""
        return self.Y

    @property
    def corange_sketch(self):
        return self.W

    def nystrom(self, variant: str = "auto"):
        """(B, C) of a symmetric stream — see :func:`nystrom_finalize`."""
        return nystrom_finalize(self.Y, self.cfg, self.mesh, self.axes,
                                variant)

    def reconstruct(self, rank: Optional[int] = None, rcond=None):
        """One-pass low-rank reconstruction (gathers the small factors)."""
        from .reconstruct import one_pass_reconstruct
        if self.W is None:
            raise ValueError("reconstruction needs corange=True")
        return one_pass_reconstruct(self.Y, self.W, self.cfg, rank=rank,
                                    rcond=rcond)
