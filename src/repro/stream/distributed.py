"""Mesh-sharded streaming sketch state (paper Alg. 1 applied per update).

State layout on the (p1, p2, p3) grid — the streaming extension of the
Alg.-1 contract (see docs/ARCHITECTURE.md):

  Y (n1 x r)  : sharded P((p1, p2), p3)   — the Alg.-1 *output* layout, so
                every update's Reduce-Scatter lands exactly on the resident
                shard; accumulation is local adds, zero extra movement.
  W (l  x n2) : sharded P(None, (p2, p3)) — column-split like A's blocks,
                replicated over p1; each update psums the per-p1 partial
                Psi_i^T·H_i over the p1 fiber.

Per additive update A <- A + H the communication is exactly the Alg.-1 cost
of sketching H (All-Gather over p3 + Reduce-Scatter over p2; zero in the
regime-1 grids p2 = p3 = 1) plus, when the co-range sketch is enabled, one
All-Reduce of l·n2/(p2·p3) words over p1.  No Omega or Psi entries are ever
communicated — both are regenerated per update from the stream seed.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.nystrom import (
    nystrom_second_stage_no_redist,
    nystrom_second_stage_redist,
    nystrom_second_stage_two_grid_fused,
)
from repro.core.sketch import (
    DEFAULT_AXES,
    input_sharding,
    omega_tile,
    output_sharding,
    rand_matmul,
)
from repro.obs import ledger as obs_ledger
from repro.obs import trace as obs_trace

from .state import StreamConfig, psi_cols, validate_row_block


def corange_sharding(mesh: Mesh, axes=DEFAULT_AXES) -> NamedSharding:
    """Sharding of W per the streaming state layout."""
    return NamedSharding(mesh, P(None, (axes[1], axes[2])))


def stream_shardings(cfg: StreamConfig, mesh: Mesh,
                     axes=DEFAULT_AXES) -> dict:
    """NamedShardings of a stream's accumulator tree ({"Y", "W"?}) — the
    single source of truth for placement at open, eviction-restore and
    checkpoint-restore time (service and ShardedStreamingSketch agree by
    construction)."""
    sh = {"Y": output_sharding(mesh, axes)}
    if cfg.corange:
        sh["W"] = corange_sharding(mesh, axes)
    return sh


def nystrom_finalize(Y, cfg: StreamConfig, mesh: Mesh,
                     axes: Tuple[str, str, str] = DEFAULT_AXES,
                     variant: str = "auto", backend: str = "auto"):
    """(B, C) of a symmetric stream from its accumulated Y, reusing the
    Alg.-2 second stages.

    Needs a 1-D (P, 1, 1) grid so Y is row-sharded — exactly the layout the
    paper's Redist / No-Redist second stages consume.  ``auto`` follows the
    paper's crossover: redist iff P > n/r (Fig. 7).  ``bound_driven`` runs
    the §5.3 general two-grid second stage: the accumulated Y plays stage
    1's B (already on the (P, 1, 1) grid), and the bound's q-grid — snapped
    to the min-words executable factorization — consumes it via
    :func:`repro.core.nystrom.nystrom_second_stage_two_grid_fused`, which
    compiles the §5.2 Redistribute and the stage-2 collectives into one
    program on the shared mesh (the (P, 1, 1) accumulator grid always
    admits one).
    ``backend`` selects the second stage's local GEMM body
    (kernels/local.py) — the pallas backend keeps Omega out of HBM at
    finalize time too.
    """
    with obs_trace.span("stream.nystrom_finalize", cat="stream",
                        variant=variant):
        return _nystrom_finalize(Y, cfg, mesh, axes, variant, backend)


def _nystrom_finalize(Y, cfg, mesh, axes, variant, backend):
    ax1, ax2, ax3 = axes
    if cfg.n1 != cfg.n2:
        raise ValueError("Nyström needs a square (symmetric) stream")
    if mesh.shape[ax2] != 1 or mesh.shape[ax3] != 1:
        raise ValueError("streaming Nyström finalize needs a (P,1,1) grid; "
                         f"have {tuple(mesh.shape.values())}")
    Pn = mesh.shape[ax1]
    if variant == "auto":
        variant = ("redist" if Pn > max(1, cfg.n1 // max(cfg.r, 1))
                   else "no_redist")
    Y = jax.device_put(Y, NamedSharding(mesh, P(ax1, None)))
    if variant == "no_redist":
        C = nystrom_second_stage_no_redist(Y, cfg.seed, cfg.r, mesh,
                                           axis=ax1, kind=cfg.kind,
                                           salt=cfg.omega_salt,
                                           backend=backend)
        return Y, C
    if variant == "redist":
        return nystrom_second_stage_redist(Y, cfg.seed, cfg.r, mesh,
                                           axis=ax1, kind=cfg.kind,
                                           salt=cfg.omega_salt,
                                           backend=backend)
    if variant == "bound_driven":
        from repro.core.grid import select_two_grid_executable
        got = select_two_grid_executable(cfg.n1, cfg.r, Pn, p=(Pn, 1, 1))
        if got is None:
            raise ValueError(f"no q-grid factorization of P={Pn} divides "
                             f"(n={cfg.n1}, r={cfg.r})")
        _, q, _exact = got
        # prefer the single-jit fused second stage: the §5.2 Redistribute
        # of the accumulated Y and the q-grid stage-2 collectives compile
        # into one program (the (P,1,1) accumulator grid always admits a
        # shared mesh; the helper falls back to the cross-mesh path
        # otherwise)
        return nystrom_second_stage_two_grid_fused(
            Y, cfg.seed, cfg.r, q, p=(Pn, 1, 1),
            devices=list(mesh.devices.flat),
            kind=cfg.kind, salt=cfg.omega_salt, backend=backend)
    raise ValueError(variant)


def corange_update(W, H, cfg: StreamConfig, mesh: Mesh,
                   axes: Tuple[str, str, str] = DEFAULT_AXES, seed=None,
                   backend: str = "jnp", blocks=None):
    """W + Psi·H with H in the Alg.-1 input layout and W in the streaming
    co-range layout.  Psi columns are regenerated per p1 block — the only
    traffic is the psum of the data-derived partial products.  The pallas
    backend generates the Psi block in VMEM inside the fused kernel
    (kernels/local.py ``sketch_t_block`` under the Psi salt)."""
    from repro.kernels.local import resolve_backend, sketch_t_block
    backend = resolve_backend(backend)
    ax1, ax2, ax3 = axes
    br = cfg.n1 // mesh.shape[ax1]

    def body(w_blk, h_blk):              # (l, n2/(p2p3)), (n1/p1, n2/(p2p3))
        i = jax.lax.axis_index(ax1)
        if backend == "jnp":
            psi_c = psi_cols(cfg, i * br, br, seed=seed)   # (br, l)
            part = psi_c.T.astype(h_blk.dtype) @ h_blk
        else:
            part = sketch_t_block(
                h_blk, cfg.seed if seed is None else seed, cfg.sketch_l,
                row0=i * br, kind=cfg.kind, salt=cfg.psi_salt,
                backend=backend, blocks=blocks)
        return w_blk + jax.lax.psum(part, ax1)

    kw = {} if backend == "jnp" else {"check_rep": False}
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, (ax2, ax3)), P(ax1, (ax2, ax3))),
                   out_specs=P(None, (ax2, ax3)), **kw)
    return fn(W, H)


# ---------------------------------------------------------------------------
# Compiled update programs — module-level lru caches so every accumulator
# (services, autotune trials, restored checkpoints) with the same
# (cfg, mesh, axes) shares one executable instead of re-tracing the
# shard_map graph per instance.  cfg is a frozen dataclass and Mesh is
# hashable, so the tuple is a valid cache key; cfg.seed is baked in
# statically, matching the original per-instance behavior.
# ---------------------------------------------------------------------------

_PROG_CACHE = 64


@functools.lru_cache(maxsize=_PROG_CACHE)
def _sharded_update_prog(cfg: StreamConfig, mesh: Mesh,
                         axes: Tuple[str, str, str], backend: str = "jnp",
                         blocks=None):
    """Full-shape additive update: Y += Alg.-1 sketch of H (+ W psum).

    jnp backend: the original program — sketch H with ``rand_matmul`` and
    add the result into the resident Y shard (dY makes an HBM round trip
    between the kernel and the add).  pallas backend: the accumulation is
    fused into the kernel accumulator via ``sketch_block(acc=y)`` — on
    regime-1 grids (p2 == 1, where the local partial IS the resident
    shard's delta) Y enters VMEM once and is written once, one HBM round
    trip instead of two; with p2 > 1 the reduce-scatter sits between the
    GEMM and the add, so only the Omega stream is elided.  Both backends
    are bitwise-identical where the local contraction is not tiled
    (kernels/local.py).
    """
    if backend == "jnp":
        def upd(Y, W, H):
            Y = Y + rand_matmul(H, cfg.seed, cfg.r, mesh, axes=axes,
                                kind=cfg.kind, salt=cfg.omega_salt,
                                backend="jnp")
            if W is not None:
                W = corange_update(W, H, cfg, mesh, axes, backend="jnp")
            return Y, W

        return jax.jit(upd)

    from repro.kernels.local import sketch_block
    ax1, ax2, ax3 = axes
    p2, p3 = mesh.shape[ax2], mesh.shape[ax3]
    blk_rows = cfg.n2 // p2
    blk_cols = cfg.r // p3

    def body(y_blk, a_blk):
        j = jax.lax.axis_index(ax2)
        k = jax.lax.axis_index(ax3)
        if p3 == 1:
            a_ij = a_blk
        else:
            a_ij = jax.lax.all_gather(a_blk, ax3, axis=1, tiled=True)
        if p2 == 1:
            # fused accumulate: Y += A_ij · Omega_jk in one kernel pass
            return sketch_block(a_ij, cfg.seed, blk_cols,
                                row0=j * blk_rows, col0=k * blk_cols,
                                kind=cfg.kind, salt=cfg.omega_salt,
                                acc=y_blk, backend=backend, blocks=blocks)
        b_partial = sketch_block(a_ij, cfg.seed, blk_cols,
                                 row0=j * blk_rows, col0=k * blk_cols,
                                 kind=cfg.kind, salt=cfg.omega_salt,
                                 backend=backend, blocks=blocks)
        return y_blk + jax.lax.psum_scatter(b_partial, ax2,
                                            scatter_dimension=0, tiled=True)

    fused = shard_map(body, mesh=mesh,
                      in_specs=(P((ax1, ax2), ax3), P(ax1, (ax2, ax3))),
                      out_specs=P((ax1, ax2), ax3), check_rep=False)

    def upd(Y, W, H):
        Y = fused(Y, H)
        if W is not None:
            W = corange_update(W, H, cfg, mesh, axes, backend=backend,
                               blocks=blocks)
        return Y, W

    return jax.jit(upd)


@functools.lru_cache(maxsize=_PROG_CACHE)
def _sharded_rowblock_prog(cfg: StreamConfig, mesh: Mesh,
                           axes: Tuple[str, str, str], k: int,
                           backend: str = "jnp", blocks=None):
    """Compiled ingest of a (k, n2) row slab at traced offset row0.

    Layout: the slab is column-sharded over (p2, p3) and replicated over
    p1 — in_specs P(None, (p2, p3)) — so the communication is one
    All-Gather of the slab over p3 plus one All-Reduce of the (k, r/p3) dY
    partial over p2 (both zero on regime-1 grids), and the co-range update
    is entirely local (W is replicated over p1 and every p1 rank computes
    the identical Psi-slab product).  Omega/Psi entries are regenerated
    from global coordinates, never communicated.

    Each Y shard adds the rows of dY that land in its resident block by
    slicing a zero-padded dY at a traced offset: out-of-overlap shards
    slice pure zeros, so row-disjoint slabs reproduce the full-shape
    additive path bitwise (0 + x == x).

    ``backend``: local GEMM body for the slab sketch and the Psi-slab
    product (kernels/local.py) — pallas keeps the Omega/Psi blocks out of
    HBM, and the traced-offset Y fold itself is fused too
    (``fold_rows_block``: the zero-padded dY frame lives only in VMEM and
    the Y shard is aliased in-place, one HBM round trip instead of the
    jnp body's materialized-frame traffic).  Both backends run the same
    ops on the same operands, so the fold is bitwise-identical.
    """
    from repro.kernels.local import (fold_rows_block, sketch_block,
                                     sketch_t_block)
    ax1, ax2, ax3 = axes
    p1, p2, p3 = (mesh.shape[a] for a in axes)
    y_rows = cfg.n1 // (p1 * p2)        # Y shard height, P((p1,p2), p3)
    r_cols = cfg.r // p3
    om_rows = cfg.n2 // p2

    def body(y_blk, w_blk, h_blk, row0):
        i = jax.lax.axis_index(ax1)
        j = jax.lax.axis_index(ax2)
        if p3 == 1:
            h_cols = h_blk                       # (k, n2/p2)
        else:
            h_cols = jax.lax.all_gather(h_blk, ax3, axis=1, tiled=True)
        kk = jax.lax.axis_index(ax3)
        if backend == "jnp":
            om = omega_tile(cfg.seed, j * om_rows, kk * r_cols,
                            om_rows, r_cols, cfg.kind, h_cols.dtype,
                            salt=cfg.omega_salt)
            part = h_cols @ om                   # (k, r/p3) partial
        else:
            part = sketch_block(h_cols, cfg.seed, r_cols,
                                row0=j * om_rows, col0=kk * r_cols,
                                kind=cfg.kind, salt=cfg.omega_salt,
                                backend=backend, blocks=blocks)
        dY = jax.lax.psum(part, ax2) if p2 > 1 else part
        # fold the overlap [g0, g0 + y_rows) n [row0, row0 + k) into the
        # resident shard: slice a zero-padded dY so that shards outside
        # the slab add exact zeros.  clip explicitly: lax.dynamic_slice
        # WRAPS negative starts (Python-style) instead of clamping, which
        # would alias the zero pad onto real dY rows for shards left of
        # the slab.  The fold itself is backend-dispatched
        # (kernels/local.py fold_rows_block): the pallas body keeps the
        # padded frame in VMEM and aliases the Y shard in-place.
        g0 = (i * p2 + j) * y_rows
        start = jnp.clip(g0 - row0 + y_rows, 0, k + y_rows)
        y_new = fold_rows_block(y_blk, dY, start, backend=backend)
        if w_blk is None:
            return y_new
        if backend == "jnp":
            psi_c = psi_cols(cfg, row0, k)       # (k, l), traced row0
            w_new = w_blk + psi_c.T.astype(h_blk.dtype) @ h_blk
        else:
            # fused accumulate: W += Psi[:, row0:row0+k] · H in one pass
            w_new = sketch_t_block(h_blk, cfg.seed, cfg.sketch_l,
                                   row0=row0, kind=cfg.kind,
                                   salt=cfg.psi_salt, acc=w_blk,
                                   backend=backend, blocks=blocks)
        return y_new, w_new

    in_h = P(None, (ax2, ax3))
    kw = {} if backend == "jnp" else {"check_rep": False}
    if cfg.corange:
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P((ax1, ax2), ax3), in_h, in_h, P()),
                       out_specs=(P((ax1, ax2), ax3), in_h), **kw)

        def upd(Y, W, H, row0):
            return fn(Y, W, H, row0)
    else:
        fn = shard_map(lambda y, h, row0: body(y, None, h, row0),
                       mesh=mesh,
                       in_specs=(P((ax1, ax2), ax3), in_h, P()),
                       out_specs=P((ax1, ax2), ax3), **kw)

        def upd(Y, W, H, row0):
            return fn(Y, H, row0), W

    return jax.jit(upd)


class ShardedStreamingSketch:
    """Streaming (Y, W) accumulator over a (p1, p2, p3) processor grid.

    Updates arrive either as full-shape additive deltas H (zero
    rows/columns where nothing changed) via :meth:`update`, or as row
    slabs via :meth:`update_rows` — the classic streaming model, without
    materializing the n1 x n2 zero frame.  Both are sketched with the
    communication-optimal collectives and added into the resident sketch
    state; row-disjoint ingest reproduces the one-shot distributed sketch
    bitwise (untouched rows accumulate exact zeros).

    ``mesh`` may also be a :class:`repro.plan.Plan` (from ``plan_stream`` /
    ``plan_sketch``); its chosen grid places the state (and its backend
    decision wins over the ``backend`` arg).

    ``backend`` selects the local GEMM body of every update
    (``"jnp"`` | ``"pallas"`` | ``"auto"`` — kernels/local.py): the pallas
    backend generates Omega/Psi blocks in VMEM and fuses the Y
    accumulation into the kernel accumulator.
    """

    def __init__(self, cfg: StreamConfig, mesh,
                 axes: Tuple[str, str, str] = DEFAULT_AXES,
                 backend: str = "auto", blocks=None):
        from repro.kernels.local import resolve_backend
        cfg.validate()
        from repro.core.sketch import SPARSE_KINDS
        if cfg.kind in SPARSE_KINDS:
            raise NotImplementedError(
                f"kind {cfg.kind!r}: distributed sparse shard_map bodies "
                "are deferred (ROADMAP item 3) — stream sparse kinds "
                "through the local StreamingSketch / SketchService")
        if not isinstance(mesh, Mesh):      # a repro.plan.Plan
            from repro.core.sketch import make_grid_mesh
            if getattr(mesh, "grid", None) is None:
                raise ValueError(f"plan {getattr(mesh, 'variant', mesh)!r} "
                                 f"carries no processor grid")
            backend = getattr(mesh, "backend", backend) or backend
            if getattr(mesh, "blocks", None):
                blocks = tuple(mesh.blocks[k] for k in ("bm", "bn", "bk"))
            mesh = make_grid_mesh(*mesh.grid)
        ax1, ax2, ax3 = axes
        p1, p2, p3 = (mesh.shape[a] for a in axes)
        if (cfg.n1 % (p1 * p2) or cfg.n2 % (p2 * p3) or cfg.n2 % p2
                or cfg.r % p3):        # n1 % (p1*p2): Y is P((p1, p2), p3)
            raise ValueError(f"stream shape ({cfg.n1},{cfg.n2},r={cfg.r}) "
                             f"not divisible by grid ({p1},{p2},{p3})")
        self.cfg = cfg
        self.mesh = mesh
        self.axes = axes
        self.backend = resolve_backend(backend)
        self.blocks = None if blocks is None else tuple(blocks)
        self.Y = jax.device_put(jnp.zeros((cfg.n1, cfg.r), cfg.dtype),
                                output_sharding(mesh, axes))
        self.W = (jax.device_put(
                      jnp.zeros((cfg.sketch_l, cfg.n2), cfg.dtype),
                      corange_sharding(mesh, axes))
                  if cfg.corange else None)
        self.num_updates = 0
        # module-level lru cache: every accumulator (and every autotune
        # trial) with the same (cfg, mesh, axes, backend) shares one
        # executable
        self._upd = _sharded_update_prog(cfg, mesh, tuple(axes),
                                         self.backend, self.blocks)
        self._audits = {}   # slab rows k (or None) -> (pred words, floor)

    def _audit(self, k: Optional[int]) -> Tuple[float, float]:
        """Ledger reference numbers, memoized per slab height: planner-
        predicted words and the Theorem-2 floor of the sketch product.

        ``k=None`` prices the full-shape :meth:`update` program — Alg. 1 on
        this grid plus (when the co-range sketch is on) the psum over p1 of
        the Psi partial (corange_update).  Integer ``k`` prices the
        ``update_rows`` slab program via ``stream_update_cost``, whose W
        update is fully local.
        """
        hit = self._audits.get(k)
        if hit is None:
            from repro.core.lower_bounds import matmul_lower_bound
            from repro.plan import model as M
            cfg = self.cfg
            grid = tuple(int(self.mesh.shape[a]) for a in self.axes)
            if k is None:
                pred = M.alg1_cost(cfg.n1, cfg.n2, cfg.r, grid,
                                   backend=self.backend).words
                if cfg.corange:
                    p1, p2, p3 = grid
                    pred += (2.0 * (1.0 - 1.0 / p1)
                             * cfg.sketch_l * cfg.n2 / (p2 * p3))
                rows = cfg.n1
            else:
                pred = M.stream_update_cost(k, cfg.n2, cfg.r, cfg.sketch_l,
                                            grid=grid, corange=cfg.corange,
                                            backend=self.backend).words
                rows = k
            try:
                floor = matmul_lower_bound(rows, cfg.n2, cfg.r,
                                           self.mesh.devices.size)
            except ValueError:          # paper assumes r < n2
                floor = 0.0
            hit = self._audits[k] = (float(pred), float(floor))
        return hit

    def update(self, H):
        """A <- A + H; H must be the full (n1, n2) shape (sharded or host)."""
        if H.shape != (self.cfg.n1, self.cfg.n2):
            raise ValueError(f"update shape {H.shape} != "
                             f"({self.cfg.n1}, {self.cfg.n2})")
        H = jax.device_put(jnp.asarray(H, self.cfg.dtype),
                           input_sharding(self.mesh, self.axes))
        led = obs_ledger.get_ledger()
        if led is not None:
            pred, floor = self._audit(None)
            led.observe("stream.update", self._upd, (self.Y, self.W, H),
                        predicted_words=pred, lower_bound_words=floor,
                        itemsize=jnp.dtype(self.cfg.dtype).itemsize)
        with obs_trace.span("stream.update", cat="stream"):
            self.Y, self.W = self._upd(self.Y, self.W, H)
        self.num_updates += 1
        return self

    # -- row-slab ingest ---------------------------------------------------

    def update_rows(self, row0: int, H):
        """Rows [row0, row0 + k) arrive additively as a (k, n2) slab.

        Bitwise-equivalent to :meth:`update` with the slab embedded in a
        zero (n1, n2) frame, without materializing that frame.  (For W the
        equivalence is bitwise when the slab lies within one p1 row block —
        otherwise the full-shape path splits the Psi product across the p1
        psum and agreement is to FP summation order.)
        """
        validate_row_block(self.cfg, row0, H.shape)
        k = H.shape[0]
        H = jax.device_put(
            jnp.asarray(H, self.cfg.dtype),
            NamedSharding(self.mesh, P(None, (self.axes[1], self.axes[2]))))
        fn = _sharded_rowblock_prog(self.cfg, self.mesh, tuple(self.axes), k,
                                    self.backend, self.blocks)
        r0 = jnp.int32(row0)
        led = obs_ledger.get_ledger()
        if led is not None:
            pred, floor = self._audit(k)
            led.observe("stream.update_rows", fn, (self.Y, self.W, H, r0),
                        predicted_words=pred, lower_bound_words=floor,
                        itemsize=jnp.dtype(self.cfg.dtype).itemsize)
        with obs_trace.span("stream.update_rows", cat="stream", k=k):
            self.Y, self.W = fn(self.Y, self.W, H, r0)
        self.num_updates += 1
        return self

    # -- checkpointing -----------------------------------------------------

    def save(self, directory: str, step: Optional[int] = None,
             keep: int = 3) -> str:
        """Checkpoint (Y, W, config, num_updates) via ``checkpoint.ckpt``.

        Arrays are stored logically (host-gathered), so a restore may use a
        different mesh or device count.  Returns the checkpoint path.
        """
        from repro.checkpoint import ckpt
        step = self.num_updates if step is None else step
        tree = {"Y": self.Y}
        if self.W is not None:
            tree["W"] = self.W
        extra = {"config": self.cfg.to_json_dict(),
                 "num_updates": self.num_updates,
                 "backend": self.backend,
                 "layout": "sharded"}
        return ckpt.save(directory, step, tree, extra=extra, keep=keep)

    @classmethod
    def restore(cls, directory: str, mesh, step: Optional[int] = None,
                axes: Tuple[str, str, str] = DEFAULT_AXES,
                backend: Optional[str] = None) -> "ShardedStreamingSketch":
        """Rebuild a stream from a checkpoint onto ``mesh`` (any grid whose
        divisibility admits the stream shape — elastic restore).  The saved
        backend is restored by default; pass ``backend=`` to migrate."""
        from repro.checkpoint import ckpt
        extra, step = ckpt.load_extra(directory, step)
        cfg = StreamConfig.from_json_dict(extra["config"])
        st = cls(cfg, mesh, axes=axes,
                 backend=backend or extra.get("backend", "jnp"))
        tree = {"Y": st.Y}
        if st.W is not None:
            tree["W"] = st.W
        tree, _, extra = ckpt.restore(directory, tree, step,
                                      shardings=stream_shardings(
                                          cfg, st.mesh, axes))
        st.Y = tree["Y"]
        st.W = tree.get("W")
        st.num_updates = int(extra["num_updates"])
        return st

    # -- finalization ------------------------------------------------------

    @property
    def sketch(self):
        """Y = A·Omega in the Alg.-1 output layout P((p1, p2), p3)."""
        return self.Y

    @property
    def corange_sketch(self):
        return self.W

    def nystrom(self, variant: str = "auto"):
        """(B, C) of a symmetric stream — see :func:`nystrom_finalize`."""
        return nystrom_finalize(self.Y, self.cfg, self.mesh, self.axes,
                                variant, backend=self.backend)

    def reconstruct(self, rank: Optional[int] = None, rcond=None):
        """One-pass low-rank reconstruction (gathers the small factors)."""
        from .reconstruct import one_pass_reconstruct
        if self.W is None:
            raise ValueError("reconstruction needs corange=True")
        return one_pass_reconstruct(self.Y, self.W, self.cfg, rank=rank,
                                    rcond=rcond)
