"""Write-ahead ingest journal: crash-safe streaming updates (ISSUE 9).

The sketch state (Y, W) is a *sum of deterministic per-slab updates*: given
``(seed, row0, H)`` the folded delta is a pure function (counter-based
Omega/Psi regeneration, core/rng.py), so a stream is fully reconstructible
from (a) its last durable checkpoint and (b) the ordered list of accepted
updates since.  The WAL makes (b) durable: every accepted request is
journaled — header plus raw H payload, CRC-sealed — *before* it is
dispatched to the device, and the journal is truncated as the applied
watermark advances.  Replay after a crash therefore reconstructs (Y, W)
**bitwise** (0 + x == x in IEEE-754 and each record re-runs the exact
update program the live path would have run), which is the Tropp-linearity
argument of docs/FAULT_MODEL.md made executable.

Record format (little-endian, append-only):

    MAGIC(4s) | header_len(u32) | header(JSON) | payload | crc32(u32)

where the CRC covers ``header + payload``.  A torn tail — a record cut by
the crash, or one whose CRC no longer matches — is *discarded at the first
bad byte*: everything before it is intact by construction (appends are
flushed+fsynced before the submit returns), everything at/after it was
never acknowledged, so dropping it is exactly the at-most-once contract a
crashed server may honor.

``depth`` (records journaled but not yet applied) is published as the
``stream_wal_depth`` gauge; replays count into ``stream_replays_total``.
"""
from __future__ import annotations

import json
import os
import struct
import threading
import zlib
from typing import Iterator, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

_MAGIC = b"SWAL"
_HDR = struct.Struct("<4sI")      # magic, header_len
_CRC = struct.Struct("<I")


class WalRecord(NamedTuple):
    """One journaled update, exactly as accepted."""
    seqno: int
    sid: int
    row0: int
    H: np.ndarray

    @property
    def words(self) -> int:
        return int(self.H.size)


class TornRecord(NamedTuple):
    """Where and why a replay stopped early (the discarded torn tail)."""
    offset: int
    reason: str


def _encode(seqno: int, sid: int, row0: int, H: np.ndarray) -> bytes:
    payload = np.ascontiguousarray(H).tobytes()
    header = json.dumps({
        "seqno": int(seqno), "sid": int(sid), "row0": int(row0),
        "shape": list(H.shape), "dtype": H.dtype.name,
        "digest": zlib.crc32(payload) & 0xFFFFFFFF,
    }).encode()
    crc = zlib.crc32(payload, zlib.crc32(header)) & 0xFFFFFFFF
    return _HDR.pack(_MAGIC, len(header)) + header + payload + _CRC.pack(crc)


def scan(path: str) -> Tuple[List[WalRecord], Optional[TornRecord]]:
    """Decode every intact record of a journal file, in append order.

    Returns ``(records, torn)`` where ``torn`` is None for a clean file and
    otherwise names the offset and reason of the first bad byte — the
    point at which the decode stops (nothing after a torn record can be
    trusted to be aligned).
    """
    records: List[WalRecord] = []
    if not os.path.exists(path):
        return records, None
    with open(path, "rb") as f:
        data = f.read()
    off = 0
    while off < len(data):
        if len(data) - off < _HDR.size:
            return records, TornRecord(off, "truncated record header")
        magic, hlen = _HDR.unpack_from(data, off)
        if magic != _MAGIC:
            return records, TornRecord(off, "bad magic")
        end = off + _HDR.size + hlen
        if end + _CRC.size > len(data):
            return records, TornRecord(off, "truncated header")
        try:
            hdr = json.loads(data[off + _HDR.size:end])
            shape = tuple(int(x) for x in hdr["shape"])
            dtype = np.dtype(hdr["dtype"])
        except (ValueError, KeyError, TypeError):
            return records, TornRecord(off, "unparseable header")
        nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        pend = end + nbytes
        if pend + _CRC.size > len(data):
            return records, TornRecord(off, "truncated payload")
        payload = data[end:pend]
        (crc,) = _CRC.unpack_from(data, pend)
        want = zlib.crc32(payload,
                          zlib.crc32(data[off + _HDR.size:end])) & 0xFFFFFFFF
        if crc != want or (zlib.crc32(payload) & 0xFFFFFFFF) != hdr["digest"]:
            return records, TornRecord(off, "crc mismatch")
        H = np.frombuffer(payload, dtype).reshape(shape)
        records.append(WalRecord(int(hdr["seqno"]), int(hdr["sid"]),
                                 int(hdr["row0"]), H))
        off = pend + _CRC.size
    return records, None


class WriteAheadLog:
    """Append-only journal of accepted-but-maybe-unapplied updates.

    Thread-safe: ``append`` runs on submitter threads, ``mark_applied`` /
    ``truncate`` on the ingest worker.  Appends are flushed and fsynced
    before returning — an acknowledged submit is durable by the time the
    caller sees its seqno.

    Reopening an existing journal resumes the seqno sequence past what is
    durable, but the applied watermark restarts at 0 (the journal does
    not persist it — every surviving record is pending until proven
    applied).  Run :func:`replay` on the reopened log before attaching a
    new ``IngestQueue``: it re-applies the pending records AND advances
    the watermark past them, so the queue resumes with an accurate depth
    and ``truncate`` can drop the replayed prefix.
    """

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._lock = threading.Lock()
        self._f = open(path, "ab")
        self._seq = 0
        self._applied = 0            # watermark: every seqno <= is applied
        # resume: continue the seqno sequence past what is already durable
        existing, torn = scan(path)
        if torn is not None:
            self._repair(existing)
        if existing:
            self._seq = existing[-1].seqno
        m = obs_metrics.get_metrics()
        self._m_depth = m.gauge(
            "stream_wal_depth",
            "journaled updates not yet covered by the applied watermark")
        self._m_depth.set(len(existing))

    # -- producer side -----------------------------------------------------

    def append(self, sid: int, row0: int, H) -> int:
        """Journal one accepted update; durable (fsync) before return.
        Returns the record's seqno."""
        H = np.asarray(H)
        with self._lock:
            self._seq += 1
            seq = self._seq
            self._f.write(_encode(seq, sid, row0, H))
            self._f.flush()
            os.fsync(self._f.fileno())
            self._m_depth.set(seq - self._applied)
        return seq

    # -- applied-watermark advance ------------------------------------------

    def mark_applied(self, seqno: int) -> None:
        """Advance the applied watermark (monotone)."""
        with self._lock:
            if seqno > self._applied:
                self._applied = seqno
            self._m_depth.set(max(0, self._seq - self._applied))

    def truncate(self) -> int:
        """Drop every record at or below the applied watermark (atomic
        rewrite: survivors to a tmp file, ``os.replace`` into place).
        Returns the number of records still journaled."""
        with self._lock:
            self._f.close()
            records, _ = scan(self.path)
            keep = [r for r in records if r.seqno > self._applied]
            tmp = self.path + ".tmp"
            with open(tmp, "wb") as f:
                for r in keep:
                    f.write(_encode(r.seqno, r.sid, r.row0, r.H))
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
            self._f = open(self.path, "ab")
            self._m_depth.set(len(keep))
            return len(keep)

    @property
    def depth(self) -> int:
        with self._lock:
            return max(0, self._seq - self._applied)

    @property
    def watermark(self) -> int:
        """Highest seqno such that every record at or below it is applied
        (or otherwise resolved — rejected / quarantined)."""
        with self._lock:
            return self._applied

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- crash recovery ------------------------------------------------------

    def _repair(self, intact: List[WalRecord]) -> None:
        """Rewrite the file to its intact prefix (drops the torn tail)."""
        self._f.close()
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            for r in intact:
                f.write(_encode(r.seqno, r.sid, r.row0, r.H))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        self._f = open(self.path, "ab")

    def pending(self) -> List[WalRecord]:
        """The records past the applied watermark, in append order (the
        replay set).  A torn tail is silently excluded — those records were
        never acknowledged."""
        records, _ = scan(self.path)
        with self._lock:
            applied = self._applied
        return [r for r in records if r.seqno > applied]


def replay(source, service, *, sid_map=None,
           watermark: int = 0) -> Tuple[int, int]:
    """Re-apply journaled updates to ``service`` in seqno order.

    ``source`` is a WAL path, a :class:`WriteAheadLog`, or an iterable of
    :class:`WalRecord`.  ``sid_map`` translates journaled sids onto the
    (re-opened) service's sids; ``watermark`` skips records already covered
    by the checkpoint the service was restored from.

    A distributed service (``service.mesh`` is not None) takes full-shape
    additive updates only, so records are applied without a row offset —
    mirroring what live distributed ingest did — and a record journaled
    with a nonzero ``row0`` (a local-mode row slab) is refused rather
    than silently applied at row 0.

    When ``source`` is a :class:`WriteAheadLog`, the applied watermark
    advances past every record replay handles (applied, or skipped as
    checkpoint-covered).  A reopened journal restarts its watermark at 0,
    so without this a queue attached after recovery could never resolve
    the pre-crash seqnos: the journal and its depth gauge would grow
    forever.

    Because each update is deterministic given ``(seed, row0, H)`` and
    sketch accumulation is an IEEE-754 sum applied in the same per-stream
    order, the replayed (Y, W) is **bitwise** the state of the
    uninterrupted run (pinned by tests/test_fault_tolerance.py).

    Returns ``(replayed_records, replayed_words)``.
    """
    wal = source if isinstance(source, WriteAheadLog) else None
    if wal is not None:
        records: Iterator[WalRecord] = iter(wal.pending())
    elif isinstance(source, str):
        records = iter(scan(source)[0])
    else:
        records = iter(source)
    distributed = getattr(service, "mesh", None) is not None
    n = words = 0
    m = obs_metrics.get_metrics()
    replays = m.counter("stream_replays_total",
                        "WAL records re-applied after a crash")
    with obs_trace.span("stream.wal_replay", cat="stream"):
        for rec in records:
            if rec.seqno <= watermark:
                if wal is not None:
                    wal.mark_applied(rec.seqno)
                continue
            sid = rec.sid if sid_map is None else sid_map[rec.sid]
            if distributed:
                if rec.row0 != 0:
                    raise ValueError(
                        f"WAL record seqno={rec.seqno} (stream {rec.sid}) "
                        f"is a row slab at row0={rec.row0}: distributed "
                        f"streams take full-shape additive updates only")
                service.update(sid, rec.H)
            else:
                service.update(sid, rec.H, row0=rec.row0)
            if wal is not None:
                wal.mark_applied(rec.seqno)
            n += 1
            words += rec.words
            replays.inc()
    return n, words
