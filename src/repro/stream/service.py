"""Batched sketch service: many concurrent streams, one mesh (ROADMAP's
"heavy traffic" serving story applied to sketching).

Each client stream owns only its (Y, W) accumulator plus a Philox key pair.
All streams with the same shape signature — (n1, n2, r, l, kind, corange,
dtype, update-chunk shape) — share ONE compiled update executable: the
per-stream seed enters the computation *traced* (as a uint32 key pair, see
``core.sketch.seed_keys``), and for local row-block ingest the row offset is
traced too.  Opening stream number 1000 therefore costs a dict insert, not
an XLA compile, which is what makes high stream fan-in viable.

Multi-tenant ingest (local mode):

  * ``update_batch``  — same-shape lanes, one vmapped dispatch.
  * ``update_ragged`` — heterogeneous row slabs.  Lanes are snapped to
    shape buckets (pow2 by default, or planner-chosen ``bucket_edges``
    from ``repro.plan.choose_bucket_edges``), padded-and-masked to the
    bucket height, and fused through one vmapped masked ``fold_rows_block``
    update per bucket with DONATED stacked (Y, W) accumulators — batched
    ingest never holds two copies of the fleet's sketch state.  Lane i is
    bitwise the result of updating stream i alone, including the
    padded/masked tail (the fixed oracle; pinned by
    tests/test_service_scale.py).

Admission/eviction: streams carry a QoS class (``pinned`` > ``standard`` >
``best_effort``).  With ``max_resident`` set, opening or touching a stream
beyond the budget evicts the coldest non-pinned resident — its (Y, W) is
checkpointed to host memory (or to disk under ``spill_dir`` via
``checkpoint/``) and restored transparently on next touch, bitwise.

Two placement modes:

  * ``mesh=None`` — local mode.  Streams live on the default device; updates
    are row-block or full-shape additive.  Row-partitioned ingest is
    bitwise-equal to the one-shot ``sketch_reference``.
  * ``mesh=Mesh(p1, p2, p3)`` — distributed mode.  Every stream's state is
    sharded per the Alg.-1 layout and each update runs the
    communication-optimal ``rand_matmul`` (plus the co-range psum); see
    ``distributed.py`` for the exact cost.

The service is the entry point wired into ``serve/engine.py``
(``make_sketch_service``); ``stream/ingest.py`` adds the async double-
buffered request queue on top.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import shutil
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.sketch import (
    DEFAULT_AXES,
    SPARSE_KINDS,
    input_sharding,
    rand_matmul,
    seed_keys,
)
from repro.obs import ledger as obs_ledger
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from .distributed import corange_update, stream_shardings
from .state import (SparseRows, StreamConfig, _local_sig,
                    local_rowblock_batch_prog, local_rowblock_prog,
                    local_rowblock_ragged_prog, local_sparse_batch_prog,
                    local_sparse_prog, nystrom_local, pow2_bucket,
                    snap_bucket, validate_row_block)

#: QoS classes, strongest first.  ``pinned`` streams are never auto-evicted;
#: among evictable residents the lowest class goes first, LRU within class.
QOS_CLASSES = ("pinned", "standard", "best_effort")
_EVICT_RANK = {"best_effort": 0, "standard": 1}


@dataclasses.dataclass
class _Stream:
    cfg: StreamConfig
    keys: jax.Array          # (2,) uint32 Philox key pair, traced into updates
    Y: jax.Array
    W: Optional[jax.Array]
    num_updates: int = 0
    qos: str = "standard"
    last_touch: int = 0
    # when set to (group_key, lane), the live (Y, W) rows reside inside the
    # service's stacked cohort buffer (``_stacks[group_key]``) and Y/W above
    # are None — see update_ragged's steady-state fast path
    stack_ref: Optional[Tuple] = None


@dataclasses.dataclass
class _Evicted:
    """A stream whose accumulators left the device: host-memory copies by
    default, or a ``checkpoint/`` directory when the service spills to
    disk.  Everything needed to rebuild the resident ``_Stream`` bitwise."""
    cfg: StreamConfig
    keys: np.ndarray
    qos: str
    num_updates: int
    host: Optional[Dict[str, np.ndarray]] = None
    path: Optional[str] = None


def _stream_sig(cfg: StreamConfig) -> Tuple:
    """Everything that forces a distinct executable — note: NOT the seed."""
    return (cfg.n1, cfg.n2, cfg.r, cfg.sketch_l, cfg.kind, cfg.corange,
            jnp.dtype(cfg.dtype).name, cfg.omega_salt, cfg.psi_salt)


class SketchService:
    """One mesh, many concurrent sketch streams.

    >>> svc = SketchService(max_resident=1000)
    >>> sid = svc.open(StreamConfig(n1=256, n2=512, r=32, seed=7),
    ...                qos="standard")
    >>> svc.update(sid, H, row0=0)          # rows arrive
    >>> svc.update_ragged([(sid, H2, 64)])  # or fused with other tenants
    >>> svc.sketch(sid)                     # the live Y = A·Omega
    >>> svc.reconstruct(sid, rank=16)       # one-pass low-rank estimate
    """

    def __init__(self, mesh: Optional[Mesh] = None,
                 axes: Tuple[str, str, str] = DEFAULT_AXES,
                 backend: str = "auto",
                 max_resident: Optional[int] = None,
                 spill_dir: Optional[str] = None):
        from repro.kernels.local import resolve_backend
        self.mesh = mesh
        self.axes = axes
        # the distributed updates' local GEMM body (kernels/local.py) and
        # the ragged fold body; single-stream local row-block ingest keeps
        # its own bitwise xla path
        self.backend = resolve_backend(backend)
        if max_resident is not None and max_resident < 1:
            raise ValueError("max_resident must be >= 1")
        self.max_resident = max_resident
        self.spill_dir = spill_dir
        self._streams: Dict[int, _Stream] = {}
        self._evicted: Dict[int, _Evicted] = {}
        # stacked ragged cohorts: group_key -> (Yb, Wb) so steady-state
        # ragged ingest feeds each round's donated output straight into the
        # next round with zero per-lane slicing (see update_ragged)
        self._stacks: Dict[Tuple, Tuple] = {}
        self._stack_keys: Dict[Tuple, jax.Array] = {}
        self._fns: Dict[Tuple, any] = {}
        self._sid = itertools.count()
        self._clock = itertools.count(1)    # LRU clock for eviction
        self._updates_total = 0             # service-lifetime, survives close
        self._audit: Dict[Tuple, Tuple[float, float]] = {}
        m = obs_metrics.get_metrics()
        self._m_updates = m.counter(
            "sketch_updates_total", "stream updates applied, by ingest path")
        self._m_evictions = m.counter(
            "sketch_evictions_total", "streams checkpointed off-device")
        self._m_spills = m.counter(
            "sketch_spills_total", "evictions written to disk (spill_dir)")
        self._m_restores = m.counter(
            "sketch_restores_total", "evicted streams restored from their "
            "checkpoint")
        self._m_resident = m.gauge(
            "sketch_resident_streams", "streams currently resident on device")
        self._m_real_rows = m.counter(
            "sketch_ragged_real_rows_total",
            "real rows folded by update_ragged")
        self._m_padded_rows = m.counter(
            "sketch_ragged_padded_rows_total",
            "pad rows dispatched by update_ragged (bucket + lane-snap waste)")

    # -- lifecycle ---------------------------------------------------------

    def open(self, cfg: StreamConfig, qos: str = "standard") -> int:
        if qos not in QOS_CLASSES:
            raise ValueError(f"qos {qos!r} not in {QOS_CLASSES}")
        cfg.validate()
        if self.mesh is not None:
            if cfg.kind in SPARSE_KINDS:
                raise NotImplementedError(
                    f"kind {cfg.kind!r}: distributed sparse shard_map "
                    "bodies are deferred (ROADMAP item 3) — open sparse-"
                    "kind streams on a local (mesh=None) service")
            p1, p2, p3 = (self.mesh.shape[a] for a in self.axes)
            if (cfg.n1 % (p1 * p2) or cfg.n2 % (p2 * p3) or cfg.n2 % p2
                    or cfg.r % p3):    # n1 % (p1*p2): Y is P((p1, p2), p3)
                raise ValueError(f"stream {cfg} not divisible by grid "
                                 f"({p1},{p2},{p3})")
        self._admit(need=1)
        if self.mesh is not None:
            sh = stream_shardings(cfg, self.mesh, self.axes)
            Y = jax.device_put(jnp.zeros((cfg.n1, cfg.r), cfg.dtype),
                               sh["Y"])
            W = (jax.device_put(jnp.zeros((cfg.sketch_l, cfg.n2), cfg.dtype),
                                sh["W"])
                 if cfg.corange else None)
        else:
            Y = jnp.zeros((cfg.n1, cfg.r), cfg.dtype)
            W = (jnp.zeros((cfg.sketch_l, cfg.n2), cfg.dtype)
                 if cfg.corange else None)
        k0, k1 = seed_keys(cfg.seed)
        sid = next(self._sid)
        self._streams[sid] = _Stream(cfg, jnp.stack([k0, k1]), Y, W,
                                     qos=qos, last_touch=next(self._clock))
        self._m_resident.set(len(self._streams))
        return sid

    def close(self, sid: int):
        """Finalize: returns the stream's final (Y, W) state — W is None
        for corange=False streams — and frees the slot (an evicted stream
        is restored from its checkpoint first, so the returned state is
        always live arrays)."""
        ev = self._evicted.pop(sid, None)
        if ev is not None:
            st = self._restore(ev)
            return st.Y, st.W
        st = self._streams.get(sid)
        if st is None:
            raise ValueError(f"unknown stream id {sid} (never opened, or "
                             f"already closed)")
        self._materialize(st)
        del self._streams[sid]
        self._m_resident.set(len(self._streams))
        return st.Y, st.W

    # -- admission / eviction ----------------------------------------------

    def _touch(self, sid: int, protect=frozenset()) -> _Stream:
        """Resolve ``sid`` to its resident stream, transparently restoring
        it from its eviction checkpoint if needed, and bump its LRU clock.
        Raises a clear ValueError for unknown (never-opened/closed) sids."""
        st = self._streams.get(sid)
        if st is None:
            ev = self._evicted.pop(sid, None)
            if ev is None:
                raise ValueError(f"unknown stream id {sid} (never opened, "
                                 f"or already closed)")
            try:
                self._admit(need=1, protect=protect)
            except RuntimeError:
                self._evicted[sid] = ev     # leave the stream restorable
                raise
            self._streams[sid] = self._restore(ev)
            st = self._streams[sid]
            self._m_resident.set(len(self._streams))
        st.last_touch = next(self._clock)
        return st

    def _admit(self, need: int, protect=frozenset()) -> None:
        """Evict coldest non-pinned residents (LRU within QoS class, lowest
        class first) until ``need`` more streams fit under ``max_resident``.
        Raises RuntimeError when the budget cannot be met (everything
        resident is pinned or belongs to the in-flight batch)."""
        if self.max_resident is None:
            return
        while len(self._streams) + need > self.max_resident:
            victims = [(sid, st) for sid, st in self._streams.items()
                       if st.qos != "pinned" and sid not in protect]
            if not victims:
                raise RuntimeError(
                    f"admission refused: all {len(self._streams)} resident "
                    f"streams are pinned or in-flight and max_resident="
                    f"{self.max_resident}")
            sid, _ = min(victims, key=lambda kv: (_EVICT_RANK[kv[1].qos],
                                                  kv[1].last_touch))
            self.evict(sid)

    def evict(self, sid: int) -> None:
        """Checkpoint a resident stream's (Y, W) off-device — to host
        memory, or to disk when the service has a ``spill_dir`` — and free
        its device slot.  Next touch restores it bitwise."""
        st = self._streams.get(sid)
        if st is None:
            if sid in self._evicted:
                return                      # idempotent
            raise ValueError(f"unknown stream id {sid} (never opened, or "
                             f"already closed)")
        with obs_trace.span("service.evict", cat="service", sid=sid,
                            spill=self.spill_dir is not None):
            self._materialize(st)
            del self._streams[sid]
            tree = {"Y": st.Y}
            if st.W is not None:
                tree["W"] = st.W
            host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
            ev = _Evicted(cfg=st.cfg,
                          keys=np.asarray(jax.device_get(st.keys)),
                          qos=st.qos, num_updates=st.num_updates)
            if self.spill_dir is not None:
                from repro.checkpoint import ckpt
                path = os.path.join(self.spill_dir, f"stream_{sid:08d}")
                ckpt.save(path, step=st.num_updates, tree=host,
                          extra={"config": st.cfg.to_json_dict(),
                                 "qos": st.qos,
                                 "num_updates": st.num_updates}, keep=1)
                ev.path = path
                self._m_spills.inc()
            else:
                ev.host = host
            self._evicted[sid] = ev
        self._m_evictions.inc()
        self._m_resident.set(len(self._streams))

    def _restore(self, ev: _Evicted) -> _Stream:
        self._m_restores.inc()
        if ev.path is not None:
            from repro.checkpoint import ckpt
            cfg = ev.cfg
            like = {"Y": jnp.zeros((cfg.n1, cfg.r), cfg.dtype)}
            if cfg.corange:
                like["W"] = jnp.zeros((cfg.sketch_l, cfg.n2), cfg.dtype)
            sh = (stream_shardings(cfg, self.mesh, self.axes)
                  if self.mesh is not None else None)
            tree, _, _ = ckpt.restore(ev.path, like, shardings=sh)
            shutil.rmtree(ev.path, ignore_errors=True)
        elif self.mesh is not None:
            sh = stream_shardings(ev.cfg, self.mesh, self.axes)
            tree = {k: jax.device_put(v, sh[k]) for k, v in ev.host.items()}
        else:
            tree = {k: jnp.asarray(v) for k, v in ev.host.items()}
        return _Stream(ev.cfg, jnp.asarray(ev.keys), tree["Y"],
                       tree.get("W"), num_updates=ev.num_updates, qos=ev.qos)

    # -- stacked-cohort bookkeeping ----------------------------------------

    def _drop_stack(self, gkey: Tuple) -> None:
        """Unstack a cohort: hand each lane its (Y, W) rows back.  Called
        the moment any member is touched by a non-ragged path — a lane
        mutated outside the stack would make the cohort rows stale."""
        entry = self._stacks.pop(gkey, None)
        self._stack_keys.pop(gkey, None)
        if entry is None:
            return
        Yb, Wb = entry
        for i, sid in enumerate(gkey[2]):
            st = self._streams.get(sid)
            if st is None or st.stack_ref != (gkey, i):
                continue
            st.Y = Yb[i]
            st.W = None if Wb is None else Wb[i]
            st.stack_ref = None

    def _materialize(self, st: _Stream) -> None:
        if st.stack_ref is not None:
            self._drop_stack(st.stack_ref[0])

    def _lane_Y(self, st: _Stream):
        if st.stack_ref is None:
            return st.Y
        gkey, i = st.stack_ref
        return self._stacks[gkey][0][i]

    def _lane_W(self, st: _Stream):
        if st.stack_ref is None:
            return st.W
        gkey, i = st.stack_ref
        Wb = self._stacks[gkey][1]
        return None if Wb is None else Wb[i]

    # -- compiled-update cache ---------------------------------------------

    def _get_update_fn(self, cfg: StreamConfig, chunk_rows: int):
        key = (_stream_sig(cfg), chunk_rows,
               None if self.mesh is None else self.mesh)
        fn = self._fns.get(key)
        if fn is None:
            # local mode resolves through the module-level program cache,
            # so the executable is shared with StreamingSketch instances
            # and other services too; self._fns just tracks what this
            # service references (num_compiled).
            fn = (self._build_dist_update(cfg)
                  if self.mesh is not None
                  else local_rowblock_prog(_local_sig(cfg), chunk_rows))
            self._fns[key] = fn
        return fn

    def _build_dist_update(self, cfg: StreamConfig):
        mesh, axes, backend = self.mesh, self.axes, self.backend

        def upd(Y, W, H, keys, row0):
            del row0                      # distributed mode is additive-only
            Y = Y + rand_matmul(H, keys, cfg.r, mesh, axes=axes,
                                kind=cfg.kind, salt=cfg.omega_salt,
                                backend=backend)
            if W is not None:
                W = corange_update(W, H, cfg, mesh, axes, seed=keys,
                                   backend=backend)
            return Y, W

        return jax.jit(upd)

    def _dist_audit(self, cfg: StreamConfig) -> Tuple[float, float]:
        """(planner-predicted words, Theorem-2 floor) of ONE full-shape
        distributed update on this mesh — the ledger's reference numbers
        for ``service.update[dist]``.  Memoized per stream signature."""
        key = _stream_sig(cfg)
        hit = self._audit.get(key)
        if hit is None:
            from repro.core.lower_bounds import matmul_lower_bound
            from repro.plan import model as M
            grid = tuple(int(self.mesh.shape[a]) for a in self.axes)
            # the dist program is Alg. 1 plus (corange on) the psum over p1
            # of the Psi partial — same closed form as
            # ShardedStreamingSketch._audit(None)
            pred = M.alg1_cost(cfg.n1, cfg.n2, cfg.r, grid,
                               backend=self.backend).words
            if cfg.corange:
                p1, p2, p3 = grid
                pred += (2.0 * (1.0 - 1.0 / p1)
                         * cfg.sketch_l * cfg.n2 / (p2 * p3))
            try:
                floor = matmul_lower_bound(cfg.n1, cfg.n2, cfg.r,
                                           int(np.prod(grid)))
            except ValueError:          # paper assumes r < n2
                floor = 0.0
            hit = self._audit[key] = (float(pred), float(floor))
        return hit

    # -- ingest ------------------------------------------------------------

    def update(self, sid: int, H, row0: Optional[int] = None):
        """Apply one update to stream ``sid``.

        Local mode: ``row0`` selects a row-block update (H is (k, n2));
        ``row0=None`` means a full-shape additive delta.  Distributed mode
        accepts full-shape additive deltas only.
        """
        st = self._touch(sid)
        self._materialize(st)
        cfg = st.cfg
        H = jnp.asarray(H, cfg.dtype)
        if self.mesh is not None:
            if row0 is not None:
                raise ValueError("distributed streams take full-shape "
                                 "additive updates (row0 must be None)")
            if H.shape != (cfg.n1, cfg.n2):
                raise ValueError(f"{H.shape} != ({cfg.n1}, {cfg.n2})")
            H = jax.device_put(H, input_sharding(self.mesh, self.axes))
            fn = self._get_update_fn(cfg, -1)
            led = obs_ledger.get_ledger()
            if led is not None:
                pred, floor = self._dist_audit(cfg)
                led.observe("service.update[dist]", fn,
                            (st.Y, st.W, H, st.keys, 0),
                            predicted_words=pred, lower_bound_words=floor,
                            itemsize=jnp.dtype(cfg.dtype).itemsize)
            with obs_trace.span("service.update", cat="service", mode="dist"):
                st.Y, st.W = fn(st.Y, st.W, H, st.keys, 0)
            self._m_updates.inc(path="dist")
        else:
            if row0 is None:
                if H.shape != (cfg.n1, cfg.n2):
                    raise ValueError(f"{H.shape} != ({cfg.n1}, {cfg.n2})")
                row0 = 0
            validate_row_block(cfg, row0, H.shape)
            fn = self._get_update_fn(cfg, H.shape[0])
            r0 = jnp.int32(row0)
            led = obs_ledger.get_ledger()
            if led is not None:
                # local mode: predicted AND floor are 0 words (P = 1) —
                # the ledger asserts the compiled program moves nothing
                led.observe("service.update[local]", fn,
                            (st.Y, st.W, H, st.keys, r0),
                            itemsize=jnp.dtype(cfg.dtype).itemsize)
            with obs_trace.span("service.update", cat="service",
                                mode="local"):
                st.Y, st.W = fn(st.Y, st.W, H, st.keys, r0)
            self._m_updates.inc(path="single")
        st.num_updates += 1
        self._updates_total += 1
        return self

    def update_sparse(self, sid: int, sp: SparseRows, row0: int = 0):
        """Apply one COO row-slab update to stream ``sid`` (local mode).

        The payload on the wire is (indices + values) — ``2·nnz`` words,
        priced at the ``service.update[sparse]`` ledger site by
        ``plan.model.sparse_payload_words`` — instead of the dense slab's
        ``k·n2``; the fold is the O(nnz) scatter program of
        ``stream/state.py:_local_sparse_update`` (bitwise vs the dense
        path for sparse Omega kinds).  Distributed streams densify and go
        through :meth:`update` until the sparse shard_map bodies land
        (ROADMAP item 3).
        """
        if self.mesh is not None:
            raise NotImplementedError(
                "update_sparse is local-mode only: distributed sparse "
                "shard_map bodies are deferred (ROADMAP item 3) — densify "
                "and use update(), or open the stream on a local service")
        st = self._touch(sid)
        self._materialize(st)
        cfg = st.cfg
        sp.validate(cfg, row0)
        nnz_b = pow2_bucket(max(1, sp.nnz))
        row, col, val = sp.padded(nnz_b)
        fn = self._get_sparse_fn(cfg, sp.shape[0], nnz_b)
        args = (st.Y, st.W, jnp.asarray(row), jnp.asarray(col),
                jnp.asarray(val, cfg.dtype), st.keys, jnp.int32(row0))
        led = obs_ledger.get_ledger()
        if led is not None:
            from repro.plan.model import sparse_payload_words
            led.record("service.update[sparse]",
                       predicted_words=sparse_payload_words(sp.nnz),
                       lower_bound_words=float(sp.nnz),
                       itemsize=jnp.dtype(cfg.dtype).itemsize,
                       detail=("nnz", sp.nnz))
        with obs_trace.span("service.update", cat="service", mode="sparse"):
            st.Y, st.W = fn(*args)
        self._m_updates.inc(path="sparse")
        st.num_updates += 1
        self._updates_total += 1
        return self

    def update_sparse_batch(self, sids, sps, row0=0):
        """Fused multi-stream sparse ingest: one compiled call folds one
        COO slab into every stream in ``sids``.

        All lanes share one slab height; payloads are pow2-padded to the
        tallest lane's nnz bucket (pads are routed into sacrificial
        rows/columns — bitwise-invisible), so lane i's result is bitwise
        :meth:`update_sparse` applied to stream i alone.  Local mode only.
        """
        if self.mesh is not None:
            raise NotImplementedError(
                "update_sparse_batch is local-mode only (ROADMAP item 3)")
        sids = list(sids)
        if len(set(sids)) != len(sids):
            raise ValueError("update_sparse_batch sids must be distinct")
        protect = frozenset(sids)
        sts = [self._touch(s, protect) for s in sids]
        for st in sts:
            self._materialize(st)
        if not sts:
            raise ValueError("update_sparse_batch needs at least one stream")
        sps = list(sps)
        if len(sps) != len(sts):
            raise ValueError(f"need {len(sts)} payloads, got {len(sps)}")
        cfg0 = sts[0].cfg
        sig = _local_sig(cfg0)
        for st in sts[1:]:
            if _local_sig(st.cfg) != sig:
                raise ValueError(
                    f"streams must share one shape signature; "
                    f"{_local_sig(st.cfg)} != {sig}")
        n = len(sts)
        row0s = ([int(row0)] * n if jnp.ndim(row0) == 0 else
                 [int(x) for x in row0])
        if len(row0s) != n:
            raise ValueError(f"row0 needs {n} entries, got {len(row0s)}")
        k = sps[0].shape[0]
        for sp, r0 in zip(sps, row0s):
            if sp.shape[0] != k:
                raise ValueError(f"lanes must share one slab height; "
                                 f"{sp.shape[0]} != {k}")
            sp.validate(cfg0, r0)
        nnz_b = pow2_bucket(max(1, max(sp.nnz for sp in sps)))
        padded = [sp.padded(nnz_b) for sp in sps]
        rows = jnp.stack([jnp.asarray(p[0]) for p in padded])
        cols = jnp.stack([jnp.asarray(p[1]) for p in padded])
        vals = jnp.stack([jnp.asarray(p[2], cfg0.dtype) for p in padded])
        key = (sig, k, nnz_b, n, "sparse_batch")
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = local_sparse_batch_prog(sig, k, nnz_b, n)
        Yb = jnp.stack([st.Y for st in sts])
        Wb = (jnp.stack([st.W for st in sts]) if cfg0.corange else None)
        keys = jnp.stack([st.keys for st in sts])
        r0s = jnp.asarray(row0s, jnp.int32)
        led = obs_ledger.get_ledger()
        if led is not None:
            from repro.plan.model import sparse_payload_words
            tot = sum(sp.nnz for sp in sps)
            led.record("service.update[sparse]",
                       predicted_words=sparse_payload_words(tot),
                       lower_bound_words=float(tot),
                       itemsize=jnp.dtype(cfg0.dtype).itemsize,
                       detail=("nnz", tot, "lanes", n))
        with obs_trace.span("service.update_sparse_batch", cat="service",
                            lanes=n):
            Yb, Wb = fn(Yb, Wb, rows, cols, vals, keys, r0s)
        self._m_updates.inc(n, path="sparse")
        for i, st in enumerate(sts):
            st.Y = Yb[i]
            if cfg0.corange:
                st.W = Wb[i]
            st.num_updates += 1
        self._updates_total += n
        return self

    def _get_sparse_fn(self, cfg: StreamConfig, k: int, nnz_b: int):
        key = (_stream_sig(cfg), k, nnz_b, "sparse")
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = local_sparse_prog(_local_sig(cfg), k,
                                                    nnz_b)
        return fn

    def update_batch(self, sids, H, row0=0):
        """Fused multi-stream ingest: one compiled call applies the same-
        shape row-block update to every stream in ``sids``.

        H    : (N, k, n2) — lane i is the update for stream ``sids[i]``.
        row0 : int applied to all lanes, or a length-N sequence of
               per-lane offsets.

        The update is the single-stream program vmapped over a leading
        stream axis with per-lane Philox key pairs, so lane i's result is
        bitwise the result of updating stream i alone (pinned by
        tests/test_stream.py); N streams cost one dispatch instead of N.
        Local mode only — distributed streams batch at the mesh level
        instead (open one service per grid).  For heterogeneous lane
        shapes use :meth:`update_ragged`.
        """
        if self.mesh is not None:
            raise NotImplementedError(
                "update_batch is local-mode only; distributed streams "
                "already amortize dispatch through the shared mesh program")
        sids = list(sids)
        if len(set(sids)) != len(sids):
            raise ValueError("update_batch sids must be distinct (duplicate "
                             "lanes would overwrite each other's update)")
        protect = frozenset(sids)           # a batch lane must not evict
        sts = [self._touch(s, protect) for s in sids]   # a sibling lane
        for st in sts:
            self._materialize(st)
        if not sts:
            raise ValueError("update_batch needs at least one stream")
        cfg0 = sts[0].cfg
        sig = _local_sig(cfg0)
        for st in sts[1:]:
            if _local_sig(st.cfg) != sig:
                raise ValueError(
                    f"streams must share one shape signature; "
                    f"{_local_sig(st.cfg)} != {sig}")
        H = jnp.asarray(H, cfg0.dtype)
        n = len(sts)
        if H.ndim != 3 or H.shape[0] != n:
            raise ValueError(f"H must be (N={n}, k, n2); got {H.shape}")
        row0s = ([int(row0)] * n if jnp.ndim(row0) == 0 else
                 [int(x) for x in row0])
        if len(row0s) != n:
            raise ValueError(f"row0 needs {n} entries, got {len(row0s)}")
        for r0 in row0s:
            validate_row_block(cfg0, r0, H.shape[1:])
        key = (sig, H.shape[1], n, "batch")
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = local_rowblock_batch_prog(
                sig, H.shape[1], n)
        Yb = jnp.stack([st.Y for st in sts])
        Wb = (jnp.stack([st.W for st in sts]) if cfg0.corange else None)
        keys = jnp.stack([st.keys for st in sts])
        r0s = jnp.asarray(row0s, jnp.int32)
        led = obs_ledger.get_ledger()
        if led is not None:
            led.observe("service.update_batch", fn, (Yb, Wb, H, keys, r0s),
                        itemsize=jnp.dtype(cfg0.dtype).itemsize)
        with obs_trace.span("service.update_batch", cat="service", lanes=n):
            Yb, Wb = fn(Yb, Wb, H, keys, r0s)
        self._m_updates.inc(n, path="batch")
        for i, st in enumerate(sts):
            st.Y = Yb[i]
            if cfg0.corange:
                st.W = Wb[i]
            st.num_updates += 1
        self._updates_total += n
        return self

    def update_ragged(self, items: Sequence[Tuple[int, Any, int]], *,
                      bucket_edges: Optional[Sequence[int]] = None,
                      pad_value: float = 0.0,
                      backend: Optional[str] = None):
        """Fused HETEROGENEOUS multi-stream ingest (the multi-tenant hot
        path): each item is ``(sid, H, row0)`` with its own row-slab shape
        ``(k_i, n2)`` and offset.

        Lanes are grouped by (shape signature, bucket height) — bucket
        height is ``snap_bucket(k_i, bucket_edges)``: pow2 snap by default,
        or planner-chosen edges from ``repro.plan.choose_bucket_edges``
        which prices padded-lane waste against dispatch amortization.  Each
        bucket runs ONE vmapped masked update (``local_rowblock_ragged_prog``)
        with the stacked (Y, W) buffers donated, so N streams cost one
        dispatch per occupied bucket and batched ingest never doubles the
        fleet's HBM.

        Pad rows are masked dead in-program: lane i's result is bitwise
        the result of updating stream i alone via :meth:`update`, whatever
        ``pad_value`` holds (NaN included — that is how the contract is
        tested).  Local mode only.

        The LANE COUNT is snapped to pow2 as well (dummy lanes carry
        ``kvalid=0`` — all-masked, provably no-ops — and zero scratch
        accumulators): without it, every distinct bucket occupancy under
        live traffic would compile a fresh program, a multi-second stall
        per new count; with it, compiles are bounded at log2(window) per
        bucket.
        """
        if self.mesh is not None:
            raise NotImplementedError(
                "update_ragged is local-mode only; distributed streams "
                "already amortize dispatch through the shared mesh program")
        items = list(items)
        if not items:
            raise ValueError("update_ragged needs at least one item")
        sids = [it[0] for it in items]
        if len(set(sids)) != len(sids):
            raise ValueError("update_ragged sids must be distinct (duplicate "
                             "lanes would overwrite each other's update)")
        edges = None if bucket_edges is None else sorted(
            int(e) for e in bucket_edges)
        protect = frozenset(sids)
        # validate everything BEFORE mutating any stream: a bad lane must
        # not leave a half-applied batch behind.  H staging stays on the
        # HOST (numpy pad into the bucket frame) — per-lane device ops here
        # would cost a dispatch each and forfeit the amortization.
        buckets: Dict[Tuple, list] = {}
        for sid, H, row0 in items:
            st = self._touch(sid, protect)
            cfg = st.cfg
            H = np.asarray(H)
            row0 = int(row0)
            validate_row_block(cfg, row0, H.shape)
            k = H.shape[0]
            kb = snap_bucket(k, edges)
            if kb > cfg.n1:
                kb = k      # never compile a frame taller than the stream
            buckets.setdefault((_local_sig(cfg), kb), []).append(
                (sid, st, H, row0, k))
        for (sig, kb), group in buckets.items():
            corange = sig[6]
            dtype = jnp.dtype(sig[5])
            n = len(group)
            ns = pow2_bucket(n)
            fkey = (sig, kb, ns, self.backend if backend is None else backend,
                    "ragged")
            fn = self._fns.get(fkey)
            if fn is None:
                fn = self._fns[fkey] = local_rowblock_ragged_prog(
                    sig, kb, ns, backend=fkey[3])
            shape = (ns, kb, group[0][2].shape[1])
            Hb = (np.zeros(shape, dtype) if pad_value == 0.0
                  else np.full(shape, pad_value, dtype))
            for i, (_, _, H, _, k) in enumerate(group):
                Hb[i, :k] = H.astype(dtype, copy=False)
            row0s = np.zeros(ns, np.int32)
            row0s[:n] = [g[3] for g in group]
            kvalids = np.zeros(ns, np.int32)   # dummy lanes: all-masked
            kvalids[:n] = [g[4] for g in group]
            # steady-state fast path: if this exact cohort (same lanes,
            # same order, same bucket) ran before and nothing touched its
            # members since, its stacked (Y, W) is still live — feed it
            # straight back in (donated!), zero per-lane stack/unstack
            gkey = (sig, kb, tuple(g[0] for g in group))
            stack = self._stacks.pop(gkey, None)
            if stack is not None:
                Yb, Wb = stack
                keys = self._stack_keys[gkey]
            else:
                for _, st, *_ in group:
                    self._materialize(st)
                pad = ns - n
                Y0, W0 = group[0][1].Y, group[0][1].W
                Yb = jnp.stack([g[1].Y for g in group]
                               + [jnp.zeros_like(Y0)] * pad)
                Wb = (jnp.stack([g[1].W for g in group]
                                + [jnp.zeros_like(W0)] * pad)
                      if corange else None)
                k0 = group[0][1].keys
                keys = jnp.stack([g[1].keys for g in group]
                                 + [jnp.zeros_like(k0)] * pad)
                self._stack_keys[gkey] = keys
            led = obs_ledger.get_ledger()
            if led is not None:
                # observe BEFORE dispatch: the stacked (Yb, Wb) are DONATED
                # and the ledger abstractifies its args immediately
                led.observe("service.update_ragged", fn,
                            (Yb, Wb, Hb, keys, row0s, kvalids),
                            itemsize=dtype.itemsize)
            with obs_trace.span("service.update_ragged", cat="service",
                                lanes=n, bucket=kb):
                Yb, Wb = fn(Yb, Wb, Hb, keys, row0s, kvalids)
            self._m_updates.inc(n, path="ragged")
            real = int(sum(g[4] for g in group))
            self._m_real_rows.inc(real)
            self._m_padded_rows.inc(ns * kb - real)
            self._stacks[gkey] = (Yb, Wb)
            for i, (_, st, *_rest) in enumerate(group):
                st.Y = st.W = None          # rows live in the cohort stack
                st.stack_ref = (gkey, i)
                st.num_updates += 1
            self._updates_total += n
        return self

    def sync(self):
        """Block until every in-flight device update has landed — resident
        lane buffers and stacked ragged cohorts alike.  The serving loop's
        barrier (benchmarks; graceful drain) without per-lane slicing."""
        leaves = [e for Yb, Wb in self._stacks.values()
                  for e in (Yb, Wb) if e is not None]
        for st in self._streams.values():
            if st.stack_ref is None:
                leaves.append(st.Y)
                if st.W is not None:
                    leaves.append(st.W)
        jax.block_until_ready(leaves)
        return self

    # -- elastic resize ----------------------------------------------------

    def reshard(self, new_grid: Tuple[int, int, int],
                devices=None) -> int:
        """Move every RESIDENT stream onto ``new_grid`` in one resharding
        hop each — the service half of elastic resize (stream/elastic.py).

        Linearity makes this a pure data movement: no recompute, no
        replay, and every post-hop update folds into bitwise the numbers
        it would have folded into on the old grid.  Evicted streams are
        already mesh-agnostic (host / disk copies) and re-land on the new
        mesh at next touch.  Compiled update executables are mesh-specific
        and are dropped; the first post-resize update per signature
        recompiles.  Returns the number of streams moved.

        Callers pausing live ingest should go through
        ``stream.elastic.drain_reshard_resume`` (drain -> reshard ->
        resume), which quiesces the IngestQueue first.
        """
        if self.mesh is None:
            raise ValueError("reshard needs a distributed service "
                             "(mesh=None is single-device)")
        from repro.core.sketch import make_grid_mesh
        from . import elastic, faults
        old_grid = tuple(int(self.mesh.shape[a]) for a in self.axes)
        new_grid = tuple(int(g) for g in new_grid)
        faults.fire("elastic.reshard", old_grid=old_grid,
                    new_grid=new_grid)
        for st in self._streams.values():
            elastic._check_divisible(st.cfg, new_grid)
        for ev in self._evicted.values():
            elastic._check_divisible(ev.cfg, new_grid)
        new_mesh = make_grid_mesh(*new_grid, axis_names=self.axes,
                                  devices=devices)
        moved = 0
        with obs_trace.span("service.reshard", cat="service",
                            old="x".join(map(str, old_grid)),
                            new="x".join(map(str, new_grid))):
            self.sync()
            for st in self._streams.values():
                self._materialize(st)
                sh = stream_shardings(st.cfg, new_mesh, self.axes)
                arrays = (st.Y,) + (() if st.W is None else (st.W,))
                shards = (sh["Y"],) + (() if st.W is None else (sh["W"],))
                pred, floor = elastic.reshard_words(st.cfg, old_grid,
                                                    new_grid)
                out = elastic.reshard_tree(
                    arrays, shards, predicted_words=pred,
                    lower_bound_words=floor,
                    itemsize=jnp.dtype(st.cfg.dtype).itemsize,
                    old_grid=old_grid, new_grid=new_grid)
                st.Y = out[0]
                st.W = out[1] if st.W is not None else None
                moved += 1
        self.mesh = new_mesh
        self._fns.clear()       # executables were mesh-specific
        self._audit.clear()
        return moved

    # -- queries -----------------------------------------------------------

    def sketch(self, sid: int):
        return self._lane_Y(self._touch(sid))

    def corange(self, sid: int):
        return self._lane_W(self._touch(sid))

    def reconstruct(self, sid: int, rank: Optional[int] = None, rcond=None):
        from .reconstruct import one_pass_reconstruct
        st = self._touch(sid)
        W = self._lane_W(st)
        if W is None:
            raise ValueError("reconstruction needs corange=True")
        return one_pass_reconstruct(self._lane_Y(st), W, st.cfg, rank=rank,
                                    rcond=rcond)

    def nystrom(self, sid: int, variant: str = "auto"):
        """(B, C) for a symmetric stream (local mode: computed in place;
        distributed mode: via the Alg.-2 second stages on a (P,1,1) grid —
        ``variant`` is ``auto``/``no_redist``/``redist``/``bound_driven``,
        the last running the §5.3 general two-grid second stage; see
        :func:`repro.stream.distributed.nystrom_finalize`)."""
        st = self._touch(sid)
        cfg = st.cfg
        if cfg.n1 != cfg.n2:
            raise ValueError("Nyström needs a square stream")
        Y = self._lane_Y(st)
        if self.mesh is None:
            return nystrom_local(Y, cfg)
        from .distributed import nystrom_finalize
        return nystrom_finalize(Y, cfg, self.mesh, self.axes, variant,
                                backend=self.backend)

    # -- introspection -----------------------------------------------------

    @property
    def num_streams(self) -> int:
        """Open streams — resident plus evicted-but-restorable."""
        return len(self._streams) + len(self._evicted)

    @property
    def num_resident(self) -> int:
        return len(self._streams)

    @property
    def num_evicted(self) -> int:
        return len(self._evicted)

    @property
    def num_compiled(self) -> int:
        """Distinct compiled update executables currently cached."""
        return len(self._fns)

    def stats(self) -> Dict[str, int]:
        return {"streams": self.num_streams,
                "resident": self.num_resident,
                "evicted": self.num_evicted,
                "compiled_updates": self.num_compiled,
                # service-lifetime count: closing a stream must not make
                # its ingested updates vanish from the ledger
                "updates": self._updates_total}
