"""Batched sketch service: many concurrent streams, one mesh (ROADMAP's
"heavy traffic" serving story applied to sketching).

Each client stream owns only its (Y, W) accumulator plus a Philox key pair.
All streams with the same shape signature — (n1, n2, r, l, kind, corange,
dtype, update-chunk shape) — share ONE compiled update executable: the
per-stream seed enters the computation *traced* (as a uint32 key pair, see
``core.sketch.seed_keys``), and for local row-block ingest the row offset is
traced too.  Opening stream number 1000 therefore costs a dict insert, not
an XLA compile, which is what makes high stream fan-in viable.

Two placement modes:

  * ``mesh=None`` — local mode.  Streams live on the default device; updates
    are row-block or full-shape additive.  Row-partitioned ingest is
    bitwise-equal to the one-shot ``sketch_reference``.
  * ``mesh=Mesh(p1, p2, p3)`` — distributed mode.  Every stream's state is
    sharded per the Alg.-1 layout and each update runs the
    communication-optimal ``rand_matmul`` (plus the co-range psum); see
    ``distributed.py`` for the exact cost.

The service is the entry point wired into ``serve/engine.py``
(``make_sketch_service``).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.sketch import (
    DEFAULT_AXES,
    input_sharding,
    output_sharding,
    rand_matmul,
    seed_keys,
)

from .distributed import corange_sharding, corange_update
from .state import (StreamConfig, _local_sig, local_rowblock_batch_prog,
                    local_rowblock_prog, nystrom_local, validate_row_block)


@dataclasses.dataclass
class _Stream:
    cfg: StreamConfig
    keys: jax.Array            # (2,) uint32 Philox key pair, traced into updates
    Y: jax.Array
    W: Optional[jax.Array]
    num_updates: int = 0


def _stream_sig(cfg: StreamConfig) -> Tuple:
    """Everything that forces a distinct executable — note: NOT the seed."""
    return (cfg.n1, cfg.n2, cfg.r, cfg.sketch_l, cfg.kind, cfg.corange,
            jnp.dtype(cfg.dtype).name, cfg.omega_salt, cfg.psi_salt)


class SketchService:
    """One mesh, many concurrent sketch streams.

    >>> svc = SketchService()
    >>> sid = svc.open(StreamConfig(n1=256, n2=512, r=32, seed=7))
    >>> svc.update(sid, H, row0=0)          # rows arrive
    >>> svc.sketch(sid)                     # the live Y = A·Omega
    >>> svc.reconstruct(sid, rank=16)       # one-pass low-rank estimate
    """

    def __init__(self, mesh: Optional[Mesh] = None,
                 axes: Tuple[str, str, str] = DEFAULT_AXES,
                 backend: str = "auto"):
        from repro.kernels.local import resolve_backend
        self.mesh = mesh
        self.axes = axes
        # the distributed updates' local GEMM body (kernels/local.py);
        # local-mode row-block ingest keeps its own bitwise xla path
        self.backend = resolve_backend(backend)
        self._streams: Dict[int, _Stream] = {}
        self._fns: Dict[Tuple, any] = {}
        self._sid = itertools.count()

    # -- lifecycle ---------------------------------------------------------

    def open(self, cfg: StreamConfig) -> int:
        cfg.validate()
        if self.mesh is not None:
            ax1, ax2, ax3 = self.axes
            p1, p2, p3 = (self.mesh.shape[a] for a in self.axes)
            if (cfg.n1 % (p1 * p2) or cfg.n2 % (p2 * p3) or cfg.n2 % p2
                    or cfg.r % p3):    # n1 % (p1*p2): Y is P((p1, p2), p3)
                raise ValueError(f"stream {cfg} not divisible by grid "
                                 f"({p1},{p2},{p3})")
            Y = jax.device_put(jnp.zeros((cfg.n1, cfg.r), cfg.dtype),
                               output_sharding(self.mesh, self.axes))
            W = (jax.device_put(jnp.zeros((cfg.sketch_l, cfg.n2), cfg.dtype),
                                corange_sharding(self.mesh, self.axes))
                 if cfg.corange else None)
        else:
            Y = jnp.zeros((cfg.n1, cfg.r), cfg.dtype)
            W = (jnp.zeros((cfg.sketch_l, cfg.n2), cfg.dtype)
                 if cfg.corange else None)
        k0, k1 = seed_keys(cfg.seed)
        sid = next(self._sid)
        self._streams[sid] = _Stream(cfg, jnp.stack([k0, k1]), Y, W)
        return sid

    def close(self, sid: int):
        """Finalize: returns the stream's final (Y, W) state — W is None
        for corange=False streams — and frees the slot."""
        st = self._streams.pop(sid)
        return st.Y, st.W

    # -- compiled-update cache ---------------------------------------------

    def _get_update_fn(self, cfg: StreamConfig, chunk_rows: int):
        key = (_stream_sig(cfg), chunk_rows,
               None if self.mesh is None else self.mesh)
        fn = self._fns.get(key)
        if fn is None:
            # local mode resolves through the module-level program cache,
            # so the executable is shared with StreamingSketch instances
            # and other services too; self._fns just tracks what this
            # service references (num_compiled).
            fn = (self._build_dist_update(cfg)
                  if self.mesh is not None
                  else local_rowblock_prog(_local_sig(cfg), chunk_rows))
            self._fns[key] = fn
        return fn

    def _build_dist_update(self, cfg: StreamConfig):
        mesh, axes, backend = self.mesh, self.axes, self.backend

        def upd(Y, W, H, keys, row0):
            del row0                      # distributed mode is additive-only
            Y = Y + rand_matmul(H, keys, cfg.r, mesh, axes=axes,
                                kind=cfg.kind, salt=cfg.omega_salt,
                                backend=backend)
            if W is not None:
                W = corange_update(W, H, cfg, mesh, axes, seed=keys,
                                   backend=backend)
            return Y, W

        return jax.jit(upd)

    # -- ingest ------------------------------------------------------------

    def update(self, sid: int, H, row0: Optional[int] = None):
        """Apply one update to stream ``sid``.

        Local mode: ``row0`` selects a row-block update (H is (k, n2));
        ``row0=None`` means a full-shape additive delta.  Distributed mode
        accepts full-shape additive deltas only.
        """
        st = self._streams[sid]
        cfg = st.cfg
        H = jnp.asarray(H, cfg.dtype)
        if self.mesh is not None:
            if row0 is not None:
                raise ValueError("distributed streams take full-shape "
                                 "additive updates (row0 must be None)")
            if H.shape != (cfg.n1, cfg.n2):
                raise ValueError(f"{H.shape} != ({cfg.n1}, {cfg.n2})")
            H = jax.device_put(H, input_sharding(self.mesh, self.axes))
            fn = self._get_update_fn(cfg, -1)
            st.Y, st.W = fn(st.Y, st.W, H, st.keys, 0)
        else:
            if row0 is None:
                if H.shape != (cfg.n1, cfg.n2):
                    raise ValueError(f"{H.shape} != ({cfg.n1}, {cfg.n2})")
                row0 = 0
            validate_row_block(cfg, row0, H.shape)
            fn = self._get_update_fn(cfg, H.shape[0])
            st.Y, st.W = fn(st.Y, st.W, H, st.keys, jnp.int32(row0))
        st.num_updates += 1
        return self

    def update_batch(self, sids, H, row0=0):
        """Fused multi-stream ingest: one compiled call applies the same-
        shape row-block update to every stream in ``sids``.

        H    : (N, k, n2) — lane i is the update for stream ``sids[i]``.
        row0 : int applied to all lanes, or a length-N sequence of
               per-lane offsets.

        The update is the single-stream program vmapped over a leading
        stream axis with per-lane Philox key pairs, so lane i's result is
        bitwise the result of updating stream i alone (pinned by
        tests/test_stream.py); N streams cost one dispatch instead of N.
        Local mode only — distributed streams batch at the mesh level
        instead (open one service per grid).
        """
        if self.mesh is not None:
            raise NotImplementedError(
                "update_batch is local-mode only; distributed streams "
                "already amortize dispatch through the shared mesh program")
        sids = list(sids)
        if len(set(sids)) != len(sids):
            raise ValueError("update_batch sids must be distinct (duplicate "
                             "lanes would overwrite each other's update)")
        sts = [self._streams[s] for s in sids]
        if not sts:
            raise ValueError("update_batch needs at least one stream")
        cfg0 = sts[0].cfg
        sig = _local_sig(cfg0)
        for st in sts[1:]:
            if _local_sig(st.cfg) != sig:
                raise ValueError(
                    f"streams must share one shape signature; "
                    f"{_local_sig(st.cfg)} != {sig}")
        H = jnp.asarray(H, cfg0.dtype)
        n = len(sts)
        if H.ndim != 3 or H.shape[0] != n:
            raise ValueError(f"H must be (N={n}, k, n2); got {H.shape}")
        row0s = ([int(row0)] * n if jnp.ndim(row0) == 0 else
                 [int(x) for x in row0])
        if len(row0s) != n:
            raise ValueError(f"row0 needs {n} entries, got {len(row0s)}")
        for r0 in row0s:
            validate_row_block(cfg0, r0, H.shape[1:])
        key = (sig, H.shape[1], n, "batch")
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = local_rowblock_batch_prog(
                sig, H.shape[1], n)
        Yb = jnp.stack([st.Y for st in sts])
        Wb = (jnp.stack([st.W for st in sts]) if cfg0.corange else None)
        keys = jnp.stack([st.keys for st in sts])
        Yb, Wb = fn(Yb, Wb, H, keys, jnp.asarray(row0s, jnp.int32))
        for i, st in enumerate(sts):
            st.Y = Yb[i]
            if cfg0.corange:
                st.W = Wb[i]
            st.num_updates += 1
        return self

    # -- queries -----------------------------------------------------------

    def sketch(self, sid: int):
        return self._streams[sid].Y

    def corange(self, sid: int):
        return self._streams[sid].W

    def reconstruct(self, sid: int, rank: Optional[int] = None, rcond=None):
        from .reconstruct import one_pass_reconstruct
        st = self._streams[sid]
        if st.W is None:
            raise ValueError("reconstruction needs corange=True")
        return one_pass_reconstruct(st.Y, st.W, st.cfg, rank=rank,
                                    rcond=rcond)

    def nystrom(self, sid: int, variant: str = "auto"):
        """(B, C) for a symmetric stream (local mode: computed in place;
        distributed mode: via the Alg.-2 second stages on a (P,1,1) grid —
        ``variant`` is ``auto``/``no_redist``/``redist``/``bound_driven``,
        the last running the §5.3 general two-grid second stage; see
        :func:`repro.stream.distributed.nystrom_finalize`)."""
        st = self._streams[sid]
        cfg = st.cfg
        if cfg.n1 != cfg.n2:
            raise ValueError("Nyström needs a square stream")
        if self.mesh is None:
            return nystrom_local(st.Y, cfg)
        from .distributed import nystrom_finalize
        return nystrom_finalize(st.Y, cfg, self.mesh, self.axes, variant,
                                backend=self.backend)

    # -- introspection -----------------------------------------------------

    @property
    def num_streams(self) -> int:
        return len(self._streams)

    @property
    def num_compiled(self) -> int:
        """Distinct compiled update executables currently cached."""
        return len(self._fns)

    def stats(self) -> Dict[str, int]:
        return {"streams": self.num_streams,
                "compiled_updates": self.num_compiled,
                "updates": sum(s.num_updates for s in self._streams.values())}
