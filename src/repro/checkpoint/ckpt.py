"""Sharded, atomic, crash-consistent, mesh-agnostic checkpointing.

Layout:  <dir>/step_<N>/
            manifest.json      — pytree structure, shapes, dtypes, step
            arrays.npz         — flattened leaves (host-gathered)

Crash-consistency contract (pinned by tests/test_fault_tolerance.py):

  * Writes are atomic: all files are staged into ``step_<N>.tmp`` (fsynced)
    and the directory is published with one ``os.replace`` — a reader never
    observes a half-written ``step_<N>``.
  * A *torn* checkpoint (a process killed between creating the final dir
    and completing its contents — possible with older writers, copied
    trees, or the ``ckpt.pre_commit`` chaos fault) is never loaded:
    ``latest_step`` only reports steps whose manifest parses and whose
    ``arrays.npz`` holds every manifest leaf; ``restore`` of an explicit
    torn step raises :class:`TornCheckpointError`; ``torn_steps`` reports
    them and ``quarantine_torn`` renames them to ``step_<N>.torn`` so they
    stop shadowing good steps without destroying forensic evidence.

Checkpoints store LOGICAL arrays (no mesh info), so restore works onto any
device count / mesh — the elastic-scaling path (launch/elastic.py,
stream/elastic.py) re-shards on load via device_put.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_RAW_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}
_STEP_RE = re.compile(r"^step_(\d{8})$")


class TornCheckpointError(RuntimeError):
    """An explicitly requested checkpoint step exists but is torn
    (incomplete manifest or arrays) and will not be loaded."""


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out.append((name, leaf))
    return out


def save(directory: str, step: int, tree, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomically write a checkpoint; returns its path.

    Everything is staged into ``step_<N>.tmp`` and fsynced, then published
    with one ``os.replace`` — a crash at any point leaves either no
    ``step_<N>`` or a complete one, never a torn directory.  The
    ``ckpt.pre_commit`` chaos fault point (stream/faults.py) fires between
    staging and publish so torn-write recovery is testable end to end.
    """
    from repro.stream import faults
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):                 # leftover of a crashed save
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        named = _flatten_with_names(tree)
        arrays = {}
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        for i, (name, leaf) in enumerate(named):
            arr = np.asarray(jax.device_get(leaf))
            key = f"a{i}"
            dtype_name = str(arr.dtype)
            if arr.dtype.kind not in "biufc":   # ml_dtypes (bf16, fp8, ...)
                arr = arr.view(_RAW_VIEW[arr.dtype.itemsize])
            arrays[key] = arr
            manifest["leaves"].append(
                {"name": name, "key": key, "shape": list(arr.shape),
                 "dtype": dtype_name})
        for fname, writer in (
                ("arrays.npz", lambda f: np.savez(f, **arrays)),
                ("manifest.json", lambda f: f.write(json.dumps(manifest)))):
            mode = "wb" if fname.endswith(".npz") else "w"
            with open(os.path.join(tmp, fname), mode) as f:
                writer(f)
                f.flush()
                os.fsync(f.fileno())
        # chaos hook: a handler here tears the STAGED files, so the commit
        # below publishes a torn step exactly the way a non-atomic writer
        # (or a partial copy) would have
        faults.fire("ckpt.pre_commit", tmp=tmp, final=final, step=step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, keep)
    return final


def _step_dirs(directory: str) -> List[Tuple[int, str]]:
    """(step, dirname) of every committed-looking step dir, sorted."""
    out = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if m:
            out.append((int(m.group(1)), d))
    return sorted(out)


def _gc(directory: str, keep: int):
    steps = _step_dirs(directory)
    for _, d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def is_complete(path: str) -> bool:
    """True iff the checkpoint dir at ``path`` is loadable: its manifest
    parses and its ``arrays.npz`` opens and holds every manifest leaf key.
    (The atomic writer can only publish complete dirs; this guards against
    torn trees from crashes of older writers, partial copies, or chaos.)"""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as arrays:
            have = set(arrays.files)
        return all(e["key"] in have for e in manifest["leaves"])
    except Exception:                     # missing file, bad zip, bad json
        return False


def torn_steps(directory: str) -> List[int]:
    """Steps present on disk but NOT loadable (skipped by ``latest_step``,
    refused by ``restore``) — the report half of the quarantine contract."""
    if not os.path.isdir(directory):
        return []
    return [s for s, d in _step_dirs(directory)
            if not is_complete(os.path.join(directory, d))]


def quarantine_torn(directory: str) -> List[int]:
    """Rename every torn ``step_<N>`` to ``step_<N>.torn`` (idempotent) so
    it stops shadowing good steps; returns the quarantined step numbers."""
    out = []
    for s in torn_steps(directory):
        src = os.path.join(directory, f"step_{s:08d}")
        dst = src + ".torn"
        if os.path.exists(dst):
            shutil.rmtree(src, ignore_errors=True)
        else:
            os.replace(src, dst)
        out.append(s)
    return out


def load_extra(directory: str,
               step: Optional[int] = None) -> Tuple[Dict, int]:
    """Read a checkpoint's ``extra`` dict (and resolved step) without
    loading arrays — consumers that must build ``tree_like`` from stored
    config (e.g. stream restore) read this first, then call ``restore``."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    return manifest.get("extra", {}), step


def latest_step(directory: str) -> Optional[int]:
    """Newest COMPLETE step (torn steps are skipped, not loaded — their
    numbers are available via :func:`torn_steps`)."""
    if not os.path.isdir(directory):
        return None
    for s, d in reversed(_step_dirs(directory)):
        if is_complete(os.path.join(directory, d)):
            return s
    return None


def restore(directory: str, tree_like, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int, Dict]:
    """Load into the structure of ``tree_like``; optionally re-shard.

    Returns (tree, step, extra).  Works across meshes/device counts —
    arrays are logical; ``shardings`` (a matching pytree of NamedSharding)
    re-places them (elastic restore)."""
    if step is None:
        step = latest_step(directory)       # skips torn steps by contract
        if step is None:
            torn = torn_steps(directory)
            raise FileNotFoundError(
                f"no loadable checkpoints in {directory}"
                + (f" (torn steps present: {torn})" if torn else ""))
    path = os.path.join(directory, f"step_{step:08d}")
    if not is_complete(path):
        raise TornCheckpointError(
            f"checkpoint step {step} in {directory} is torn (incomplete "
            f"manifest/arrays) and will not be loaded; see "
            f"ckpt.torn_steps / ckpt.quarantine_torn")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))

    named_like = _flatten_with_names(tree_like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    leaves = []
    for name, like in named_like:
        e = by_name.get(name)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = arrays[e["key"]]
        want = _np_dtype(e["dtype"])
        if arr.dtype != want:              # stored as a raw view
            arr = arr.view(want)
        want_shape = tuple(like.shape) if hasattr(like, "shape") else None
        if want_shape is not None and tuple(arr.shape) != want_shape:
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {want_shape}")
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    else:
        tree = jax.tree_util.tree_map(
            lambda a, l: jax.numpy.asarray(
                a, dtype=getattr(l, "dtype", None)), tree,
            jax.tree_util.tree_unflatten(treedef,
                                         [l for _, l in named_like]))
    return tree, step, manifest.get("extra", {})
