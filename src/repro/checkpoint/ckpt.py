"""Sharded, atomic, mesh-agnostic checkpointing.

Layout:  <dir>/step_<N>/
            manifest.json      — pytree structure, shapes, dtypes, step
            arrays.npz         — flattened leaves (host-gathered)

Writes are atomic (tmp dir + rename); ``keep`` old checkpoints are GC'd.
Checkpoints store LOGICAL arrays (no mesh info), so restore works onto any
device count / mesh — the elastic-scaling path (launch/elastic.py) re-shards
on load via device_put.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_RAW_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out.append((name, leaf))
    return out


def save(directory: str, step: int, tree, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomically write a checkpoint; returns its path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        named = _flatten_with_names(tree)
        arrays = {}
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        for i, (name, leaf) in enumerate(named):
            arr = np.asarray(jax.device_get(leaf))
            key = f"a{i}"
            dtype_name = str(arr.dtype)
            if arr.dtype.kind not in "biufc":   # ml_dtypes (bf16, fp8, ...)
                arr = arr.view(_RAW_VIEW[arr.dtype.itemsize])
            arrays[key] = arr
            manifest["leaves"].append(
                {"name": name, "key": key, "shape": list(arr.shape),
                 "dtype": dtype_name})
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def load_extra(directory: str,
               step: Optional[int] = None) -> Tuple[Dict, int]:
    """Read a checkpoint's ``extra`` dict (and resolved step) without
    loading arrays — consumers that must build ``tree_like`` from stored
    config (e.g. stream restore) read this first, then call ``restore``."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    return manifest.get("extra", {}), step


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_"))
    return steps[-1] if steps else None


def restore(directory: str, tree_like, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int, Dict]:
    """Load into the structure of ``tree_like``; optionally re-shard.

    Returns (tree, step, extra).  Works across meshes/device counts —
    arrays are logical; ``shardings`` (a matching pytree of NamedSharding)
    re-places them (elastic restore)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))

    named_like = _flatten_with_names(tree_like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    leaves = []
    for name, like in named_like:
        e = by_name.get(name)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = arrays[e["key"]]
        want = _np_dtype(e["dtype"])
        if arr.dtype != want:              # stored as a raw view
            arr = arr.view(want)
        want_shape = tuple(like.shape) if hasattr(like, "shape") else None
        if want_shape is not None and tuple(arr.shape) != want_shape:
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {want_shape}")
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    else:
        tree = jax.tree_util.tree_map(
            lambda a, l: jax.numpy.asarray(
                a, dtype=getattr(l, "dtype", None)), tree,
            jax.tree_util.tree_unflatten(treedef,
                                         [l for _, l in named_like]))
    return tree, step, manifest.get("extra", {})
