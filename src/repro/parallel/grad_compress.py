"""Sketched gradient compression for data-parallel training.

The paper's core systems insight — a random dense matrix never needs to be
communicated because every processor regenerates it from a shared
counter-based seed (§6.3; Theorem 2 regime 1) — applied to the DP gradient
all-reduce (PowerSGD-style rank-r compression):

    per DP worker, per weight matrix G (m x n), every step t:
        Omega  = Phi(key(leaf, t))                 # regenerated, zero comm
        P      = pmean( (G + E) @ Omega )          # m·r words moved
        P_hat  = orthonormalize(P)                 # thin QR, local
        Qᵀ     = pmean( P_hatᵀ @ (G + E) )         # r·n words moved
        G_hat  = P_hat @ Qᵀ                        # rank-r mean estimate
        E'     = (G + E) - P_hat @ Q_locᵀ          # error feedback, local

Communication per matrix drops from m·n to r·(m+n) words — the same
regenerate-don't-communicate arithmetic as the paper's Alg. 1 (§4.2: the
sketch operand moves, Omega never does), with the sketch itself the
standard B = A·Omega primitive at A = the gradient.  Error feedback keeps
SGD convergence (Vogels et al., PowerSGD, NeurIPS'19).

Planner integration: which leaves take the sketched exchange is a *priced*
decision — ``plan.plan_train_compression`` compares ``grad_allreduce_cost``
vs ``grad_compress_cost`` per leaf (the crossover is r < m·n/(m+n)) and its
``decision_tree()`` feeds the ``decisions`` argument here.  The legacy
``min_dim`` size heuristic remains as a fallback for direct callers.

Kernel integration: the two sketch-side GEMMs run through
``kernels/local.py`` — ``sketch_block`` generates Omega at global Philox
coordinates (in VMEM on the pallas backend: the n·r HBM stream never
exists), and the dense factors go through ``gemm_block``, whose fused
accumulator expresses the error-feedback update ``E' = M - P_hat·Q_locᵀ``
as an in-place aliased accumulation (one HBM round trip instead of a
materialized delta + read-modify-write).  Both backends accumulate in f32
with a fixed association, so untiled leaves (the interpret-mode default
block policy) are bitwise-identical across ``backend="jnp"|"pallas"`` —
the same contract ``tests/test_local_backend.py`` pins for the sketch
entry points, re-pinned for this path by ``tests/test_grad_compress.py``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.local import gemm_block, sketch_block


def _leaf_seed(idx: int, step) -> jnp.ndarray:
    """Traced (2,) uint32 Philox key pair for (leaf, step).

    The leaf index enters key0 (Knuth-hashed so adjacent leaves land far
    apart in key space); the traced step enters key1.  Keeping the step in
    the *key pair* rather than the salt is what lets the pallas kernel
    consume it: the key pair is a scalar-prefetch operand
    (``kernels/local.py::_meta``) while the salt is baked statically into
    the kernel body.  Every worker computes the identical pair from shared
    state, so Omega costs zero communication (§6.3).
    """
    k0 = jnp.uint32((0x5EEDED ^ (idx * 2654435761)) & 0xFFFFFFFF)
    return jnp.stack([k0, jnp.asarray(step, jnp.uint32)])


def _compressible(leaf, min_dim: int) -> bool:
    """Legacy size heuristic: compress matrix leaves with both folded dims
    >= ``min_dim``.  Superseded by the planner's priced ``decisions`` map
    (``plan.plan_train_compression``)."""
    if leaf.ndim < 2:
        return False
    m = math.prod(leaf.shape[:-1])
    n = leaf.shape[-1]
    return m >= min_dim and n >= min_dim


def _decision_flags(grads_flat, min_dim, decisions):
    """Per-leaf compress flags: the planner's decision map when given
    (its True entries clamped to actual matrix leaves), else the legacy
    ``min_dim`` heuristic."""
    if decisions is not None:
        flags = jax.tree_util.tree_leaves(decisions)
        if len(flags) != len(grads_flat):
            raise ValueError(
                f"decisions has {len(flags)} leaves, grads have "
                f"{len(grads_flat)} — pass plan_train_compression(...)"
                f".decision_tree() for these params")
        return [bool(f) and g.ndim >= 2 for f, g in zip(flags, grads_flat)]
    if min_dim is None:
        raise ValueError("need either decisions= (planner map) or "
                         "min_dim= (legacy heuristic)")
    return [_compressible(g, min_dim) for g in grads_flat]


def _orthonormalize(P):
    """Gram-Schmidt via QR (f32)."""
    q, _ = jnp.linalg.qr(P.astype(jnp.float32))
    return q


def compress_and_allreduce(grads, error_fb, *, step, rank: int,
                           min_dim: int = None, axis_name: str,
                           decisions=None, backend: str = "jnp",
                           kind: str = "normal", interpret=None):
    """Inside shard_map over the DP axis: replaces pmean(G) with the
    sketched exchange above.  Returns (mean_grads_approx, new_error_fb).

    Per compressed leaf (PowerSGD, NeurIPS'19, with the paper's
    regenerated Omega — §6.3 / Theorem 2 regime 1):

        M      = g + e                       (local grad + error feedback)
        P      = pmean( sketch_block(M, key(leaf, step), r) )
        P_hat  = orth(P)                     (thin QR of the m×r mean)
        Qᵀ_loc = gemm_block(P_hatᵀ, M)       (r×n local factor)
        Qᵀ     = pmean( Qᵀ_loc )
        g_hat  = gemm_block(P_hat, Qᵀ)       (≈ mean_i M_i, rank r)
        e'     = gemm_block(P_hat, Qᵀ_loc, acc=M, alpha=-1)

    Leaves whose decision is raw use an exact pmean and pass their error
    buffer through untouched.

    ``decisions`` — per-leaf bool pytree from
    ``plan.plan_train_compression(...).decision_tree()``; when None the
    legacy ``min_dim`` size heuristic decides.  ``backend`` selects the
    local GEMM bodies (``kernels/local.py``; ``"auto"`` resolves to
    pallas on TPU): identical collectives and r·(m+n) words either way,
    bitwise-identical results on untiled leaves.  ``step`` may be traced;
    it enters Omega through the Philox key pair, so a checkpoint-restored
    run regenerates the exact draws of the original (§6.3 reproducibility
    — the basis of the bitwise-resume contract in ``checkpoint/``).
    """
    flat, treedef = jax.tree_util.tree_flatten(grads)
    fb_flat = jax.tree_util.tree_leaves(error_fb)
    flags = _decision_flags(flat, min_dim, decisions)
    kw = dict(backend=backend, interpret=interpret)
    out, fb_out = [], []
    for idx, (g, e, compress) in enumerate(zip(flat, fb_flat, flags)):
        if not compress:
            out.append(jax.lax.pmean(g, axis_name))
            fb_out.append(e)
            continue
        shape = g.shape
        m = math.prod(shape[:-1])
        n = shape[-1]
        r = min(rank, m, n)
        M = g.reshape(m, n).astype(jnp.float32) + e.reshape(m, n)
        # Omega regenerated identically on every worker, keyed by
        # (leaf, step) through the Philox counter: NO communication.
        P = jax.lax.pmean(
            sketch_block(M, _leaf_seed(idx, step), r, kind=kind, **kw),
            axis_name)                                # m·r words on the wire
        P_hat = _orthonormalize(P)
        Qt_loc = gemm_block(P_hat.T, M, **kw)         # (r, n)
        Qt = jax.lax.pmean(Qt_loc, axis_name)         # r·n words on the wire
        g_hat = gemm_block(P_hat, Qt, **kw)
        # error feedback as a fused accumulation: M enters the kernel as
        # the aliased accumulator, e' = M - P_hat @ Qt_loc in one round trip
        e_new = gemm_block(P_hat, Qt_loc, acc=M, alpha=-1.0, **kw)
        out.append(g_hat.reshape(shape).astype(g.dtype))
        fb_out.append(e_new.reshape(shape).astype(e.dtype))
    grads_out = jax.tree_util.tree_unflatten(treedef, out)
    fb_tree = jax.tree_util.tree_unflatten(treedef, fb_out)
    return grads_out, fb_tree


def comm_words_exact(shapes) -> int:
    """Words a plain psum of these grads would move (per step, per worker)
    — the m·n side of the Theorem-2 regime-1 comparison."""
    return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes))


def comm_words_compressed(shapes, rank: int, min_dim: int = None,
                          decisions=None) -> int:
    """Words the sketched exchange moves: r·(m+n) per compressed leaf
    (the two factor pmeans; Omega contributes zero — §6.3), full size for
    raw leaves.  Equals ``plan.TrainCompressionPlan.exchange_words`` when
    ``decisions`` comes from the same plan; the comm ledger audits this
    prediction at runtime (``train.dp_compressed_step`` site)."""
    flat = jax.tree_util.tree_leaves(shapes)
    flags = _decision_flags(flat, min_dim, decisions)
    total = 0
    for l, compress in zip(flat, flags):
        if compress:
            m = math.prod(l.shape[:-1])
            n = int(l.shape[-1])
            r = min(rank, m, n)
            total += r * (m + n)
        else:
            total += math.prod(l.shape)
    return total


def init_error_fb(params, rank: int, min_dim: int = None, world: int = 1,
                  decisions=None):
    """Zero error-feedback buffers (f32) for compressible leaves, scalar
    zeros elsewhere (kept tiny).

    IMPORTANT: the error buffer is PER-WORKER state (each worker keeps its
    own projection residual; only their mean vanishes).  With ``world > 1``
    leaves get a leading world axis — shard it over the DP mesh axis
    (in_specs/out_specs P(dp_axis)) and strip/re-add the local singleton
    inside the shard_map body (see ``local_fb``/``stack_fb``).  The
    checkpoint contract (docs/TRAINING.md): the buffer is saved with its
    world axis and restored onto a different-width mesh via
    :func:`reshard_error_fb`.
    """
    flat, treedef = jax.tree_util.tree_flatten(params)
    flags = _decision_flags(flat, min_dim, decisions)

    def make(l, compress):
        shape = (world,) + tuple(l.shape) if world > 1 else tuple(l.shape)
        if compress:
            return jnp.zeros(shape, jnp.float32)
        return jnp.zeros((world,) if world > 1 else (), jnp.float32)
    return jax.tree_util.tree_unflatten(
        treedef, [make(l, f) for l, f in zip(flat, flags)])


def reshard_error_fb(fb, world_from: int, world_to: int):
    """Re-lay an error-feedback tree onto a different DP world size,
    preserving the per-leaf worker MEAN exactly.

    Why the mean is the right invariant: the exchange only ever sees the
    error state through collectives that are linear in it —
    ``P = pmean((G+E_i)·Omega)`` and ``Qᵀ = pmean(P_hatᵀ·(G+E_i))`` both
    depend on ``{E_i}`` solely via ``mean_i E_i`` (pmean and the GEMMs
    are linear).  Any redistribution of the residuals with the same mean
    therefore produces the same P/Qᵀ/g_hat trajectory up to f32 reduction
    order; preserving per-worker bits is impossible anyway when the
    worker count (and with it the batch sharding) changes.

    Same width: identity (bits preserved — the bitwise-resume contract).
    Shrink by an integer factor: adjacent groups are averaged.  Grow by
    an integer factor: residuals are replicated.  Incommensurate widths:
    every new worker gets the global mean.
    """
    if world_from == world_to:
        return fb

    def one(x):
        x = x[None] if world_from == 1 else x
        if world_from % world_to == 0:
            g = world_from // world_to
            x = x.reshape((world_to, g) + x.shape[1:]).mean(axis=1)
        elif world_to % world_from == 0:
            x = jnp.repeat(x, world_to // world_from, axis=0)
        else:
            x = jnp.broadcast_to(x.mean(axis=0), (world_to,) + x.shape[1:])
        return x[0] if world_to == 1 else x
    return jax.tree_util.tree_map(one, fb)


def local_fb(fb_stacked):
    """Strip the leading (local singleton) world axis inside shard_map."""
    return jax.tree_util.tree_map(lambda x: x[0], fb_stacked)


def stack_fb(fb_local):
    """Re-add the leading world axis for sharded out_specs."""
    return jax.tree_util.tree_map(lambda x: x[None], fb_local)
