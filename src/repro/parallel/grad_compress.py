"""Sketched gradient compression for data-parallel training.

The paper's core systems insight — a random dense matrix never needs to be
communicated because every processor regenerates it from a shared
counter-based seed — applied to the DP gradient all-reduce (PowerSGD-style
rank-r compression):

    per DP worker, per weight matrix G (m x n), every step t:
        Omega  = Phi(key, step=t, leaf)            # regenerated, zero comm
        P      = (G + E) @ Omega                   # m x r sketch
        P_hat  = orthonormalize( psum(P) )         # r x m words moved
        Q      = (G + E)^T @ P_hat                 # n x r
        Q_sum  = psum(Q)                           # n x r words moved
        G_hat  = P_hat @ Q_sum^T / world
        E'     = G + E - G_hat                     # error feedback

Communication per matrix drops from m·n to r·(m+n) words — the same
regenerate-don't-communicate arithmetic as the paper's Alg. 1 (§4.2: the
sketch operand moves, Omega never does — the §6.3 counter-based
regeneration claim applied to the DP axis).  Error feedback keeps SGD
convergence (Vogels et al., PowerSGD, NeurIPS'19); the sketch itself is the
paper's B = A·Omega with A = the gradient, and the r·(m+n) vs m·n saving
is the Theorem-2 regime-1 argument at the granularity of one all-reduce.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.sketch import omega_tile


def _leaf_salt(idx: int, step) -> jnp.ndarray:
    return jnp.uint32(idx * 2654435761 % (1 << 31)) + jnp.uint32(step)


def _compressible(leaf, min_dim: int) -> bool:
    if leaf.ndim < 2:
        return False
    m = math.prod(leaf.shape[:-1])
    n = leaf.shape[-1]
    return m >= min_dim and n >= min_dim


def _orthonormalize(P):
    """Gram-Schmidt via QR (f32)."""
    q, _ = jnp.linalg.qr(P.astype(jnp.float32))
    return q


def compress_and_allreduce(grads, error_fb, *, step, rank: int,
                           min_dim: int, axis_name: str):
    """Inside shard_map over the DP axis: replaces pmean(G) with the
    sketched exchange above.  Returns (mean_grads_approx, new_error_fb).

    Per leaf (PowerSGD, NeurIPS'19, with the paper's regenerated Omega):
        M      = g + e                      (local grad + error feedback)
        P      = pmean( M @ Omega )         ->  orth -> P_hat
        Q_loc  = M^T @ P_hat
        Q      = pmean( Q_loc )
        g_hat  = P_hat @ Q^T                (~= mean_i M_i, rank r)
        e'     = M - P_hat @ Q_loc^T        (local projection residual)

    ``error_fb`` matches grads (zeros at step 0); leaves too small to
    benefit use an exact pmean.
    """
    flat, treedef = jax.tree_util.tree_flatten(grads)
    fb_flat = jax.tree_util.tree_leaves(error_fb)
    out, fb_out = [], []
    for idx, (g, e) in enumerate(zip(flat, fb_flat)):
        if not _compressible(g, min_dim):
            out.append(jax.lax.pmean(g, axis_name))
            fb_out.append(e)
            continue
        shape = g.shape
        m = math.prod(shape[:-1])
        n = shape[-1]
        r = min(rank, m, n)
        M = g.reshape(m, n).astype(jnp.float32) + e.reshape(m, n)
        # Omega regenerated identically on every worker, keyed by
        # (leaf, step) through the Philox counter: NO communication.
        om = omega_tile(0x5EEDED, 0, 0, n, r, "normal", jnp.float32,
                        salt=_leaf_salt(idx, step))
        P = jax.lax.pmean(M @ om, axis_name)          # r*m words on the wire
        P_hat = _orthonormalize(P)
        Q_loc = M.T @ P_hat                           # (n, r)
        Q = jax.lax.pmean(Q_loc, axis_name)           # r*n words on the wire
        g_hat = P_hat @ Q.T
        e_new = M - P_hat @ Q_loc.T
        out.append(g_hat.reshape(shape).astype(g.dtype))
        fb_out.append(e_new.reshape(shape).astype(e.dtype))
    grads_out = jax.tree_util.tree_unflatten(treedef, out)
    fb_tree = jax.tree_util.tree_unflatten(treedef, fb_out)
    return grads_out, fb_tree


def comm_words_exact(shapes) -> int:
    """Words a plain psum of these grads would move (per step, per worker)."""
    return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes))


def comm_words_compressed(shapes, rank: int, min_dim: int) -> int:
    total = 0
    for l in jax.tree_util.tree_leaves(shapes):
        if _compressible(l, min_dim):
            m = math.prod(l.shape[:-1])
            n = int(l.shape[-1])
            r = min(rank, m, n)
            total += r * (m + n)
        else:
            total += math.prod(l.shape)
    return total


def init_error_fb(params, rank: int, min_dim: int, world: int = 1):
    """Zero error-feedback buffers (f32) for compressible leaves, scalar
    zeros elsewhere (kept tiny).

    IMPORTANT: the error buffer is PER-WORKER state (each worker keeps its
    own projection residual; only their mean vanishes).  With ``world > 1``
    leaves get a leading world axis — shard it over the DP mesh axis
    (in_specs/out_specs P(dp_axis)) and strip/re-add the local singleton
    inside the shard_map body (see ``local_fb``/``stack_fb``)."""
    def make(l):
        shape = (world,) + tuple(l.shape) if world > 1 else tuple(l.shape)
        if _compressible(l, min_dim):
            return jnp.zeros(shape, jnp.float32)
        return jnp.zeros((world,) if world > 1 else (), jnp.float32)
    return jax.tree_util.tree_map(make, params)


def local_fb(fb_stacked):
    """Strip the leading (local singleton) world axis inside shard_map."""
    return jax.tree_util.tree_map(lambda x: x[0], fb_stacked)


def stack_fb(fb_local):
    """Re-add the leading world axis for sharded out_specs."""
    return jax.tree_util.tree_map(lambda x: x[None], fb_local)
