"""GPipe-style pipeline parallelism over a mesh axis (the "pod" axis on the
multi-pod production mesh).

Stages hold disjoint layer ranges; microbatches flow stage-to-stage via
``jax.lax.ppermute`` (maps to ICI collective-permute between pods).  The
schedule is the standard GPipe loop of ``n_micro + n_stages - 1`` ticks with
bubble fraction (S-1)/(M+S-1); activations for the backward pass are kept by
jax's autodiff through the scan (remat-friendly).

This composes with TP/SP inside each stage (the stage fn is ordinary GSPMD
code over the remaining mesh axes) and with DP by vmapping microbatches.

Relation to the paper (PAPER.md): pipeline traffic is point-to-point
activations — none of it is random state, so it sits outside the
Theorem-2/3 bounds; the paper's model (§3) counts it as ordinary input
movement.  The collective-byte accounting in ``roofline/hlo.py`` measures
ppermute traffic alongside the sketching collectives so the two are
comparable on one roofline.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def pipeline(stage_fn: Callable, stage_params, x_micro, *, axis: str,
             n_stages: int):
    """Run ``stage_fn(params, x) -> x`` as a pipeline over mesh axis
    ``axis``.

    Must be called inside ``shard_map`` where ``axis`` is un-consumed.
    ``stage_params``: this stage's params (already sharded per stage, i.e.
    the local slice along the axis).  ``x_micro``: (M, micro_batch, ...) —
    the microbatch queue, identical on every stage (only stage 0 consumes
    it; other stages ignore inputs and work on permuted activations).
    Returns (M, micro_batch, ...) outputs valid on the LAST stage.
    """
    M = x_micro.shape[0]
    stage = jax.lax.axis_index(axis)
    n_ticks = M + n_stages - 1

    buf = jnp.zeros_like(x_micro[0])
    outs = jnp.zeros_like(x_micro)

    def tick(carry, t):
        buf, outs = carry
        # stage 0 ingests microbatch t (when in range)
        mb_idx = jnp.clip(t, 0, M - 1)
        fresh = jnp.where(t < M, 1, 0)
        inject = jax.lax.dynamic_index_in_dim(x_micro, mb_idx, 0,
                                              keepdims=False)
        x_in = jnp.where((stage == 0) & (fresh == 1), inject, buf)
        y = stage_fn(stage_params, x_in)
        # pass activations to the next stage (ring; last->0 ignored)
        y_next = jax.lax.ppermute(
            y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
        # last stage emits microbatch t - (n_stages - 1)
        out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
        emit = (stage == n_stages - 1) & (t >= n_stages - 1)
        outs = jax.lax.cond(
            emit,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y, out_idx, 0),
            lambda o: o, outs)
        return (y_next, outs), None

    (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
    # only the last stage holds real outputs (zeros elsewhere); sum over the
    # stage axis replicates them so callers can use plain out_specs
    return jax.lax.psum(outs, axis)


def pipeline_loss(stage_fn: Callable, loss_fn: Callable, stage_params,
                  x_micro, y_micro, *, axis: str, n_stages: int):
    """Pipelined forward + mean loss on the last stage, broadcast to all
    stages via psum (so jax.grad gives every stage its local params grads).
    """
    acts = pipeline(stage_fn, stage_params, x_micro, axis=axis,
                    n_stages=n_stages)
    stage = jax.lax.axis_index(axis)
    raw = loss_fn(acts, y_micro)
    local = jnp.where(stage == n_stages - 1, raw, 0.0)
    return jax.lax.psum(local, axis)
