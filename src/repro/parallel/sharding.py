"""Parameter/activation sharding rules (DP / TP / SP / EP over a named mesh).

Rules are (leaf-name -> dim-from-end to shard over the model axis); anything
unmatched or non-divisible replicates.  Works for both stacked (leading L)
and unstacked params.  Experts shard over the model axis (EP); dense FFN and
attention projections shard TP; embeddings shard over vocab.

Relation to the paper (PAPER.md): these shardings define the "data layout"
side of the communication model of §3 — who owns which block of each
operand.  The sketching-specific layouts (the Alg.-1 §4.2 contract for A/B
and the streaming Y/W state) live in ``core/sketch.py`` and
``stream/distributed.py`` respectively; this module covers the surrounding
LM training/serving stack, where the same principle applies: pick layouts
so collectives land where operands already live (see
docs/ARCHITECTURE.md).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ShardCtx

# leaf name -> dim (negative, from end) sharded over the model axis
_MODEL_DIM_RULES: Dict[str, int] = {
    # attention projections
    "wq": -1, "wk": -1, "wv": -1, "wo": -2,
    # dense FFN (TP)
    "w1": -1, "w2": -2, "b1": -1,
    # embeddings / heads: vocab dim
    "embed": -2, "lm_head": -2,
    # mamba
    "in_proj": -1, "conv_w": -1, "conv_b": -1,
    "x_proj": -2, "dt_proj": -1, "dt_bias": -1,
    "A_log": -2, "D": -1, "out_proj": -2, "norm_scale": -1,
}

# MoE expert tensors: shard the EXPERT dim (EP) over the model axis
_EXPERT_DIM_RULES: Dict[str, int] = {
    "w_gate": -3, "w_up": -3, "w_down": -3,
}

# dense-FFN gate/up/down reuse MoE names; disambiguated by path (.../moe/...)
_DENSE_GLU_RULES: Dict[str, int] = {
    "w_gate": -1, "w_up": -1, "w_down": -2,
}


def _leaf_rule(path_str: str, name: str) -> Optional[int]:
    if name in ("w_gate", "w_up", "w_down"):
        return (_EXPERT_DIM_RULES[name] if "moe" in path_str
                else _DENSE_GLU_RULES[name])
    # mamba2 A_log/D/dt_bias are 1-D per-head tensors
    if name in ("A_log", "D", "dt_bias"):
        return -2 if name == "A_log" else -1
    return _MODEL_DIM_RULES.get(name)


def param_shardings(params_shapes, mesh: Mesh, model_axis: str = "model"):
    """NamedSharding pytree mirroring ``params_shapes``."""
    model_size = mesh.shape[model_axis]

    def spec_of(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", str(p)))
                 for p in path]
        path_str = "/".join(str(n) for n in names)
        name = str(names[-1]) if names else ""
        dim = _leaf_rule(path_str, name)
        ndim = len(leaf.shape)
        spec = [None] * ndim
        if dim is not None and -dim <= ndim:
            d = ndim + dim
            if leaf.shape[d] % model_size == 0 and leaf.shape[d] >= model_size:
                spec[d] = model_axis
        return NamedSharding(mesh, P(*spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_of(p, l) for p, l in flat])


def batch_shardings(batch_specs, mesh: Mesh, data_axes) -> Any:
    """Batch dims shard over the data axes; everything else replicated."""
    def spec_of(leaf):
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and leaf.shape[0] > 1:
            size = int(np.prod([mesh.shape[a] for a in _as_tuple(data_axes)]))
            if leaf.shape[0] % size == 0:
                spec[0] = data_axes
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map(spec_of, batch_specs)


def cache_shardings(cache_specs, mesh: Mesh, data_axes, model_axis="model"):
    """KV/state caches: batch over data axes; head/feature dims over model.

    Cache layouts: k/v (B, T, Hk, D) or stacked (L, B, T, Hk, D);
    ssm states (L, B, H, P, N) / (L, B, dI, N); conv (L, B, K-1, C)."""
    model_size = mesh.shape[model_axis]
    data_size = int(np.prod([mesh.shape[a] for a in _as_tuple(data_axes)]))

    def spec_of(path, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        # batch dim: per-layer list caches (path starts with a list index)
        # have batch at dim 0; stacked (L, B, ...) arrays at dim 1.
        is_list_entry = path and isinstance(
            path[0], jax.tree_util.SequenceKey)
        bdim = 0 if is_list_entry else min(1, len(shape) - 1)
        if shape[bdim] % data_size == 0 and shape[bdim] >= data_size:
            spec[bdim] = data_axes
        # shard one feature dim over model: prefer the sequence/time dim
        # (large, always divisible at our shapes), else the largest
        # divisible trailing dim.
        candidates = [d for d in range(bdim + 1, len(shape))
                      if spec[d] is None
                      and shape[d] % model_size == 0
                      and shape[d] >= model_size]
        if candidates:
            best = max(candidates, key=lambda d: shape[d])
            spec[best] = model_axis
        return NamedSharding(mesh, P(*spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_specs)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_of(p, l) for p, l in flat])


def _as_tuple(x) -> Tuple:
    return x if isinstance(x, tuple) else (x,)


def make_shard_ctx(mesh: Mesh, data_axes=("data",), model_axis: str = "model",
                   use_sp: bool = True) -> ShardCtx:
    da = data_axes if len(_as_tuple(data_axes)) > 1 else _as_tuple(data_axes)[0]
    return ShardCtx(mesh=mesh, data=da, model=model_axis, use_sp=use_sp)
