from .sharding import (  # noqa: F401
    batch_shardings, cache_shardings, make_shard_ctx, param_shardings,
)
from .grad_compress import (  # noqa: F401
    compress_and_allreduce, comm_words_compressed, comm_words_exact,
    init_error_fb,
)
from .pipeline import pipeline, pipeline_loss  # noqa: F401
