"""AdamW with global-norm clipping; bf16 params with f32 moments.
Pure-pytree implementation (no optax dependency)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: object
    v: object
    count: jnp.ndarray


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def update(grads, state: AdamWState, params, lr, *,
           b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.1):
    """Returns (new_params, new_state).  Decay is decoupled and skipped for
    1-D leaves (norms/biases), the usual convention."""
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mh = m_new / c1
        vh = v_new / c2
        step = mh / (jnp.sqrt(vh) + eps)
        if p.ndim >= 2 and weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), \
            m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    unf = lambda l: jax.tree_util.tree_unflatten(treedef, l)
    return unf(new_p), AdamWState(unf(new_m), unf(new_v), count)
