from . import adamw  # noqa: F401
from .adamw import AdamWState, clip_by_global_norm, global_norm  # noqa: F401
from .schedule import warmup_cosine  # noqa: F401
