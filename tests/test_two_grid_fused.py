"""Single-jit two-grid Nyström (core.nystrom.nystrom_two_grid_fused).

Pins the whole bitwise contract of the fused §5.3 path (ISSUE acceptance
criteria):

  (a) ``nystrom_two_grid_fused`` — stage 1, the §5.2 Redistribute expressed
      IN-PROGRAM on the shared mesh of ``core.grid.two_grid_shared_mesh``,
      and stage 2, one executable — is bitwise-identical to the cross-mesh
      ``nystrom_two_grid`` (and to ``nystrom_reference`` for p2==1 ∧ q1==1
      pairs) across kinds x dtypes (f32/bf16) x non-divisible shapes x
      backends;
  (b) an HLO byte audit: the in-program Redistribute moves <= nr/P words
      per processor and the compiled program contains zero unplanned
      collectives versus the planner's prediction (stage All-Gathers /
      Reduce-Scatters + one resharding);
  (c) ``two_grid_shared_mesh`` never silently reorders devices — stage 1
      alone on the shared mesh is bitwise stage 1 on the original p-grid
      mesh — and when it returns ``None`` the dispatcher demonstrably falls
      back to the cross-mesh path (counted via monkeypatch, not timing);
  (d) the planner emits ``alg2_bound_driven_fused`` candidates that price
      at/above the Theorem 3 floor, ``Plan.execute`` dispatches them
      bitwise-equal to the direct call, and the autotuner's JOINT (p, q)
      sweep measures pairs beyond the analytic fixed-p grid and caches
      fused decisions.
"""
import math

import pytest

from _hypothesis_compat import given, settings, st
from dist_helper import run_distributed

from repro.core.grid import (
    alg2_two_grid_executable,
    factorizations_3d,
    two_grid_axis_split,
)
from repro.core.lower_bounds import nystrom_lower_bound
from repro.plan import PRESETS, explain, plan_nystrom
from repro.plan.model import (
    alg2_cost,
    alg2_fused_cost,
    fused_redistribute_words,
    redistribute_words,
)

CPU = PRESETS["cpu"]


# ---------------------------------------------------------------------------
# shared-mesh reconciliation: pure-arithmetic properties
# ---------------------------------------------------------------------------

def _pairs(P):
    facs = list(factorizations_3d(P))
    return [(p, q) for p in facs for q in facs]


@settings(max_examples=60, deadline=None)
@given(Pe=st.integers(0, 6), i=st.integers(0, 10 ** 6),
       j=st.integers(0, 10 ** 6))
def test_axis_split_refinement_property(Pe, i, j):
    """When a split exists it is a true row-major common refinement: axis
    sizes multiply to P and each grid's dims are products of CONSECUTIVE
    axis groups (so sharding over a group reproduces the standalone mesh's
    device assignment); when it doesn't, the prefix products of p and q
    genuinely fail to chain under divisibility."""
    P = 2 ** Pe * 3 ** (i % 2)          # include non-powers of two
    facs = list(factorizations_3d(P))
    p, q = facs[i % len(facs)], facs[j % len(facs)]
    split = two_grid_axis_split(p, q)
    cuts = sorted({1, P, p[0], p[0] * p[1], q[0], q[0] * q[1]})
    chains = all(b % a == 0 for a, b in zip(cuts, cuts[1:]))
    assert (split is not None) == chains or P == 1
    if split is None:
        return
    sizes, pg, qg = split
    assert math.prod(sizes) == P
    for g, groups in ((p, pg), (q, qg)):
        flat = [i for grp in groups for i in grp]
        assert flat == sorted(flat)                    # row-major order
        assert sorted(flat) == list(range(len(sizes)))  # disjoint cover
        for dim, grp in zip(g, groups):
            assert math.prod(sizes[i] for i in grp) == dim


def test_axis_split_none_cases():
    # P = 6: 2x3 vs 3x2 leading blocks cannot share one row-major order
    assert two_grid_axis_split((2, 3, 1), (3, 2, 1)) is None
    assert two_grid_axis_split((3, 2, 1), (2, 3, 1)) is None
    # but any pair where one side is 1-D always reconciles (the streamed
    # accumulator's (P,1,1) grid in particular)
    for P in (2, 4, 6, 8, 12):
        for qc in factorizations_3d(P):
            assert two_grid_axis_split((P, 1, 1), qc) is not None
    # power-of-two P: every pair chains (all cuts are powers of two)
    for p, q in _pairs(8):
        assert two_grid_axis_split(p, q) is not None
    with pytest.raises(ValueError, match="same P"):
        two_grid_axis_split((2, 1, 1), (3, 1, 1))


# ---------------------------------------------------------------------------
# fused Redistribute cost model
# ---------------------------------------------------------------------------

@settings(max_examples=80, deadline=None)
@given(ne=st.integers(4, 9), re_=st.integers(1, 6), Pe=st.integers(1, 6),
       i=st.integers(0, 10 ** 6), j=st.integers(0, 10 ** 6))
def test_fused_redistribute_min_cut_bounds(ne, re_, Pe, i, j):
    """The in-program min-cut never exceeds the cross-mesh bound nr/P, and
    the full fused cost never dips below the Theorem 3 floor."""
    n, r, P = 2 ** ne, 2 ** re_, 2 ** Pe
    if r >= n:
        return
    facs = list(factorizations_3d(P))
    p, q = facs[i % len(facs)], facs[j % len(facs)]
    if not alg2_two_grid_executable(n, r, p, q):
        return
    fw = fused_redistribute_words(n, r, p, q)
    assert 0.0 <= fw <= n * r / P + 1e-9
    cf = alg2_fused_cost(n, r, p, q)
    cx = alg2_cost(n, r, p, q)
    assert cf.words >= nystrom_lower_bound(n, r, P) - 1e-9, (p, q)
    if tuple(p) != tuple(q):
        # the min-cut replaces the nr/P all-to-all term, so the fused form
        # never prices above the cross-mesh form (and its in-program hop
        # replaces the log2(P) host-mediated hops)
        assert cf.words <= cx.words + 1e-9
        assert fw <= redistribute_words(n, r, p, q) + 1e-9
        assert cf.seconds(CPU) <= cx.seconds(CPU) + 1e-15
    # p == q: the cross-mesh model scores the in-place reuse as free while
    # the fused min-cut honestly prices the stage-1 -> stage-2 layout
    # mismatch, so no ordering is asserted there.
    assert cf.flops == cx.flops and cf.hbm_words == cx.hbm_words


def test_fused_redistribute_known_values():
    # regime-1 ideal pair: every device keeps the (n/P x r/P) intersection
    # of its row-slab and column-slab shards
    n, r, P = 64, 16, 8
    assert fused_redistribute_words(n, r, (P, 1, 1), (1, 1, P)) \
        == n * r / P - n * r / P ** 2
    assert redistribute_words(n, r, (P, 1, 1), (1, 1, P)) == n * r / P
    # identical layouts (rows over P both stages, cols unsplit): zero moved
    assert fused_redistribute_words(n, r, (P, 1, 1), (P, 1, 1)) == 0.0


# ---------------------------------------------------------------------------
# planner + autotune integration (pure: no devices needed)
# ---------------------------------------------------------------------------

def test_planner_emits_fused_candidates_and_prefers_them():
    plan = plan_nystrom(64, 4, P=8, machine=CPU)
    assert plan.variant == "alg2_bound_driven_fused" and plan.executable
    fused = [c for c in plan.candidates
             if c.variant == "alg2_bound_driven_fused"]
    cross = [c for c in plan.candidates
             if c.variant == "alg2_bound_driven"]
    assert fused and cross
    fj = next(c for c in fused if c.backend == "jnp")
    cj = next(c for c in cross if c.backend == "jnp")
    assert (fj.grid, fj.q_grid) == (cj.grid, cj.q_grid)
    assert fj.cost.words < cj.cost.words          # min-cut < nr/P here
    assert fj.seconds < cj.seconds
    assert two_grid_axis_split(fj.grid, fj.q_grid) is not None
    # forcing selects each form explicitly
    assert plan_nystrom(64, 4, P=8, machine=CPU,
                        variant="bound_driven").variant \
        == "alg2_bound_driven"
    assert plan_nystrom(64, 4, P=8, machine=CPU,
                        variant="bound_driven_fused").variant \
        == "alg2_bound_driven_fused"


def test_explain_prints_fused_vs_cross_mesh_redistribute():
    pf = plan_nystrom(64, 4, P=8, machine=CPU, variant="bound_driven_fused")
    text = explain(pf)
    assert "IN-PROGRAM" in text and "min-cut" in text
    assert "cross-mesh device_put would move" in text
    pc = plan_nystrom(64, 4, P=8, machine=CPU, variant="bound_driven")
    textc = explain(pc)
    assert "cross-mesh device_put" in textc
    assert "fused form would move" in textc


def test_autotune_joint_pq_sweep_and_fused_cache(tmp_path):
    """The (p, q) sweep is JOINT — it measures stage-1 grids beyond the
    analytic fixed p — and the winning fused decision round-trips through
    the versioned cache (entries re-validated for exact dims)."""
    from repro.plan import autotune
    from repro.plan.autotune import AutotuneCache

    plan = plan_nystrom(64, 4, P=8, machine=CPU)
    assert plan.variant == "alg2_bound_driven_fused"
    records = []
    calls = []

    def fake_timer(fn):
        calls.append(fn)
        return 1e-3 * len(calls)

    cache = AutotuneCache(str(tmp_path / "tune.json"))
    tuned = autotune(plan, cache=cache, timer=fake_timer, records=records)
    assert len(calls) >= 2
    swept = {(rec["variant"], tuple(rec["grid"])) for rec in records
             if rec["variant"].startswith("alg2_bound_driven")}
    p_grids = {g for _, g in swept}
    assert len(p_grids) > 1, f"joint sweep must vary p, saw {p_grids}"
    assert any(v == "alg2_bound_driven_fused" for v, _ in swept)
    # cache entry for the fused winner: a second autotune is a pure hit
    assert tuned.variant in ("alg2_bound_driven", "alg2_bound_driven_fused")
    assert alg2_two_grid_executable(64, 4, tuned.grid, tuned.q_grid)
    if tuned.variant == "alg2_bound_driven_fused":
        assert two_grid_axis_split(tuned.grid, tuned.q_grid) is not None

    def no_timer(fn):
        raise AssertionError("cache hit must skip measurement")

    again = autotune(plan_nystrom(64, 4, P=8, machine=CPU), cache=cache,
                     timer=no_timer)
    assert (again.variant, again.grid, again.q_grid) == \
        (tuned.variant, tuned.grid, tuned.q_grid)
    assert cache.hits >= 1


# ---------------------------------------------------------------------------
# execution: the bitwise property matrix + HLO byte audit (8 fake devices)
# ---------------------------------------------------------------------------

def test_fused_bitwise_matrix():
    """Fused == cross-mesh bitwise across (p, q) pairs x kinds x dtypes x
    backends, == nystrom_reference for p2==1 ∧ q1==1 pairs, including a
    shape the ideal grids do NOT divide (the snap path)."""
    run_distributed(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import (nystrom_reference, nystrom_two_grid,
                        nystrom_two_grid_fused, nystrom_auto,
                        nystrom_second_stage_two_grid,
                        nystrom_second_stage_two_grid_fused)
from repro.plan import plan_nystrom, PRESETS
CPU = PRESETS["cpu"]
assert len(jax.devices()) == 8

seed, n, r = 5, 64, 16
X = jax.random.normal(jax.random.key(2), (n, 8)); S = X @ X.T
Bref, Cref = nystrom_reference(S, seed, r)

# (p, q) matrix: bitwise-safe pairs (p2==1, q1==1) also match the
# single-device reference; split pairs still match the cross-mesh path
# bit for bit (grouped-axis collectives reduce in the same order).
for (p, q) in [((8,1,1), (1,1,8)), ((8,1,1), (1,2,4)), ((4,1,2), (1,4,2)),
               ((2,1,4), (1,8,1)), ((8,1,1), (2,1,4)), ((2,2,2), (4,2,1)),
               ((1,2,4), (2,2,2))]:
    Bx, Cx = nystrom_two_grid(S, seed, r, p=p, q=q)
    Bf, Cf = nystrom_two_grid_fused(S, seed, r, p=p, q=q)
    assert np.array_equal(np.asarray(Bx), np.asarray(Bf)), (p, q)
    assert np.array_equal(np.asarray(Cx), np.asarray(Cf)), (p, q)
    if p[1] == 1 and q[0] == 1:
        assert np.array_equal(np.asarray(Bf), np.asarray(Bref)), (p, q)
        assert np.array_equal(np.asarray(Cf), np.asarray(Cref)), (p, q)
print("OK pair matrix")

# kinds x backends on a genuinely two-grid pair
for kind in ("normal", "uniform", "rademacher"):
    for backend in ("jnp", "pallas"):
        Bx, Cx = nystrom_two_grid(S, seed, r, p=(8,1,1), q=(1,2,4),
                                  kind=kind, backend=backend)
        Bf, Cf = nystrom_two_grid_fused(S, seed, r, p=(8,1,1), q=(1,2,4),
                                        kind=kind, backend=backend)
        assert np.array_equal(np.asarray(Bx), np.asarray(Bf)), (kind, backend)
        assert np.array_equal(np.asarray(Cx), np.asarray(Cf)), (kind, backend)
print("OK kinds x backends")

# bf16 inputs (f32 accumulation contract), both backends
Sb = S.astype(jnp.bfloat16)
for backend in ("jnp", "pallas"):
    Bx, Cx = nystrom_two_grid(Sb, seed, r, p=(8,1,1), q=(1,1,8),
                              backend=backend)
    Bf, Cf = nystrom_two_grid_fused(Sb, seed, r, p=(8,1,1), q=(1,1,8),
                                    backend=backend)
    assert Bf.dtype == jnp.bfloat16 and Cf.dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(Bx, np.float32),
                          np.asarray(Bf, np.float32)), backend
    assert np.array_equal(np.asarray(Cx, np.float32),
                          np.asarray(Cf, np.float32)), backend
print("OK bf16")

# a shape the IDEAL bound-driven grids do not divide: the snapped pair
# still runs fused and bitwise (n=48, r=12 — non-power-of-two dims)
n2, r2 = 48, 12
X2 = jax.random.normal(jax.random.key(4), (n2, 6)); S2 = X2 @ X2.T
from repro.core.grid import select_two_grid_executable
p2_, q2_, exact = select_two_grid_executable(n2, r2, 8)
assert not exact    # genuinely snapped
for (p, q) in [(p2_, q2_), ((8,1,1), (2,1,4))]:
    Bx, Cx = nystrom_two_grid(S2, seed, r2, p=p, q=q)
    Bf, Cf = nystrom_two_grid_fused(S2, seed, r2, p=p, q=q)
    assert np.array_equal(np.asarray(Bx), np.asarray(Bf)), (p, q)
    assert np.array_equal(np.asarray(Cx), np.asarray(Cf)), (p, q)
print("OK non-divisible snap")

# planner-chosen fused plan: Plan.execute IS the direct call, and
# nystrom_auto prefers the fused path
pf = plan_nystrom(n, r, P=8, machine=CPU, variant="bound_driven_fused")
assert pf.variant == "alg2_bound_driven_fused" and pf.executable
B, C = pf.execute(S, seed=seed)
Bd, Cd = nystrom_two_grid_fused(S, seed, r, p=pf.grid, q=pf.q_grid)
assert np.array_equal(np.asarray(B), np.asarray(Bd))
assert np.array_equal(np.asarray(C), np.asarray(Cd))
Ba, Ca, _, v = nystrom_auto(S, seed, r, variant="bound_driven")
assert v == "bound_driven"
assert np.array_equal(np.asarray(Ca), np.asarray(Cref))
print("OK plan dispatch")

# the fused standalone second stage (streamed-Y finalize form) matches the
# cross-mesh second stage bitwise for row-sharded B
for q in [(1, 2, 4), (2, 1, 4), (1, 1, 8)]:
    Bx, Cx = nystrom_second_stage_two_grid(Bref, seed, r, q)
    Bf, Cf = nystrom_second_stage_two_grid_fused(Bref, seed, r, q)
    assert np.array_equal(np.asarray(Bx), np.asarray(Bf)), q
    assert np.array_equal(np.asarray(Cx), np.asarray(Cf)), q
print("OK fused second stage")

# error paths stay loud
try:
    nystrom_two_grid_fused(S, seed, 7, p=(8,1,1), q=(1,1,8))
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "not divisible" in str(e)
print("OK errors")
""", timeout=900)


def test_hlo_redistribute_byte_audit():
    """The compiled fused program's Redistribute moves <= nr/P words per
    processor and the collective schedule contains EXACTLY the planner's
    predicted stage collectives plus the one in-program resharding —
    nothing unplanned, and no host-mediated transfer in the hot path."""
    run_distributed(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.grid import two_grid_shared_mesh
from repro.core.nystrom import (_nystrom_two_grid_fused_prog, _spec_entry)
from repro.core.sketch import seed_keys
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline.hlo import collective_bytes_of
assert len(jax.devices()) == 8

seed, n, r = 5, 64, 16
S = jax.random.normal(jax.random.key(2), (n, n)); S = S @ S.T / n
ITEM = 4   # f32

REDIST = ("all-to-all", "collective-permute")
STAGE = {"all-gather", "reduce-scatter"}

for (p, q) in [((8,1,1), (1,1,8)), ((8,1,1), (2,1,4)), ((8,1,1), (1,2,4)),
               ((2,2,2), (4,2,1))]:
    shared = two_grid_shared_mesh(p, q)
    assert shared is not None, (p, q)
    pa = shared.p_axes
    A = jax.device_put(S, NamedSharding(
        shared.mesh, P(_spec_entry(pa[0]), _spec_entry(pa[1] + pa[2]))))
    keys = jnp.stack(seed_keys(seed))
    fn = _nystrom_two_grid_fused_prog(r, shared, "normal", "jnp", None)
    cb = collective_bytes_of(fn.lower(A, keys).compile().as_text())

    # (1) every collective kind is planned: the Alg.-1 / stage-2
    # All-Gathers and Reduce-Scatters, plus the one in-program resharding
    assert set(cb.by_kind) <= STAGE | set(REDIST), (p, q, cb)
    n_ag = int(p[2] > 1) + int(q[1] > 1)
    n_rs = int(p[1] > 1) + int(q[0] > 1)
    assert cb.counts.get("all-gather", 0) == n_ag, (p, q, cb)
    assert cb.counts.get("reduce-scatter", 0) == n_rs, (p, q, cb)

    # (2) the Redistribute itself: each resharding hop carries at most the
    # §5.2 bound nr/P words per processor (B's full per-device shard)
    budget = n * r / 8 * ITEM
    for kind in REDIST:
        if kind in cb.by_kind:
            assert cb.by_kind[kind] <= budget + 1e-6, (p, q, kind, cb)
    assert sum(cb.counts.get(k, 0) for k in REDIST) <= 2, (p, q, cb)

    # (3) the §5.2 Redistribute lives inside the ONE compiled executable:
    # either as its own all-to-all / collective-permute, or absorbed into
    # the adjacent stage collectives by the partitioner (only possible
    # because it IS in-program — the whole point of the fused form)
    assert any(k in cb.by_kind for k in REDIST) or (n_ag + n_rs) >= 1, \
        (p, q, cb)
print("OK audit")

# the pure regime-1 pair: the redistribute is the ONLY collective and its
# bytes are exactly the per-device B shard
shared = two_grid_shared_mesh((8,1,1), (1,1,8))
A = jax.device_put(S, NamedSharding(
    shared.mesh, P(_spec_entry(shared.p_axes[0]), None)))
keys = jnp.stack(seed_keys(seed))
fn = _nystrom_two_grid_fused_prog(r, shared, "normal", "jnp", None)
cb = collective_bytes_of(fn.lower(A, keys).compile().as_text())
assert cb.total == n * r / 8 * ITEM, cb
print("OK exact regime-1 bytes")
""", timeout=900)


def test_shared_mesh_stage1_bitwise_and_fallback():
    """(c): the shared mesh preserves the p-grid device assignment — stage
    1 alone on it is bitwise Alg. 1 on the standalone p-grid mesh — and an
    incompatible pair demonstrably falls back to the cross-mesh dispatcher
    (counted via monkeypatch on 6 devices, where (2,3,1)/(3,2,1) cannot
    share a device order)."""
    run_distributed(r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import rand_matmul, make_grid_mesh
from repro.core.grid import two_grid_shared_mesh, two_grid_axis_split
from repro.core.sketch import input_sharding
from repro.core.compat import shard_map
from repro.core.nystrom import _axes_index, _spec_entry
from repro.kernels.local import sketch_block
from repro.core.sketch import seed_keys
assert len(jax.devices()) == 8

seed, n, r = 11, 64, 16
A = jax.random.normal(jax.random.key(1), (n, n))

for (p, q) in [((8,1,1), (1,1,8)), ((2,1,4), (1,8,1)), ((2,2,2), (4,2,1))]:
    shared = two_grid_shared_mesh(p, q)
    # no silent reorder: the shared mesh holds the SAME devices in the
    # SAME flat order as both standalone grid meshes
    assert list(shared.mesh.devices.flat) \
        == list(make_grid_mesh(*p).devices.flat) \
        == list(make_grid_mesh(*q).devices.flat), (p, q)

    # stage 1 alone, on the shared mesh's p-axis groups
    mesh, (pa1, pa2, pa3) = shared.mesh, shared.p_axes
    p1, p2, p3 = p
    keys = jnp.stack(seed_keys(seed))
    blk_rows, blk_cols = n // p2, r // p3

    def stage1(a_blk):
        j = _axes_index(mesh, pa2)
        k = _axes_index(mesh, pa3)
        a_ij = a_blk if p3 == 1 else jax.lax.all_gather(
            a_blk, pa3, axis=1, tiled=True)
        b = sketch_block(a_ij, keys, blk_cols, row0=j * blk_rows,
                         col0=k * blk_cols, kind="normal")
        if p2 == 1:
            return b
        return jax.lax.psum_scatter(b, pa2, scatter_dimension=0, tiled=True)

    in_spec = P(_spec_entry(pa1), _spec_entry(pa2 + pa3))
    out_spec = P(_spec_entry(pa1 + pa2), _spec_entry(pa3))
    Ash = jax.device_put(A, NamedSharding(mesh, in_spec))
    Bshared = jax.jit(shard_map(stage1, mesh=mesh, in_specs=in_spec,
                                out_specs=out_spec))(Ash)

    mesh_p = make_grid_mesh(*p)
    Bp = rand_matmul(jax.device_put(A, input_sharding(mesh_p)), seed, r,
                     mesh_p)
    assert np.array_equal(np.asarray(Bshared), np.asarray(Bp)), (p, q)
print("OK stage-1 bitwise on shared mesh")

# fallback: an incompatible pair routes through the cross-mesh dispatcher
import repro.core.nystrom as nys
devices6 = jax.devices()[:6]
assert two_grid_axis_split((2,3,1), (3,2,1)) is None
n6, r6 = 36, 6
X6 = jax.random.normal(jax.random.key(7), (n6, 4)); S6 = X6 @ X6.T
calls = []
orig = nys.nystrom_two_grid
def counting(*a, **kw):
    calls.append((kw.get("p"), kw.get("q")))
    return orig(*a, **kw)
nys.nystrom_two_grid = counting
try:
    Bf, Cf = nys.nystrom_two_grid_fused(S6, 5, r6, p=(2,3,1), q=(3,2,1),
                                        devices=devices6)
finally:
    nys.nystrom_two_grid = orig
assert calls == [((2,3,1), (3,2,1))], calls
Bx, Cx = orig(S6, 5, r6, p=(2,3,1), q=(3,2,1), devices=devices6)
assert np.array_equal(np.asarray(Bf), np.asarray(Bx))
assert np.array_equal(np.asarray(Cf), np.asarray(Cx))
# and a compatible pair never touches the cross-mesh dispatcher
calls.clear()
nys.nystrom_two_grid = counting
try:
    nys.nystrom_two_grid_fused(S6, 5, r6, p=(6,1,1), q=(1,1,6),
                               devices=devices6)
finally:
    nys.nystrom_two_grid = orig
assert calls == [], calls
print("OK fallback counted")
""", timeout=900)
