"""Use the real ``hypothesis`` when installed; otherwise a deterministic
pure-pytest fallback so the property tests still *execute* on minimal
environments instead of failing at collection.

The fallback draws ``max_examples`` example tuples from a per-test seeded
``random.Random`` (seeded by the test name, so runs are reproducible and
order-independent) and loops the test body over them inside a single pytest
test.  It implements exactly the strategy surface this repo uses:
``st.integers``, ``st.floats``, ``st.sampled_from``.

This is NOT a hypothesis replacement — no shrinking, no adaptive search, no
database.  Install ``hypothesis`` (see requirements-dev.txt) for the real
thing; CI does.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import random
    import zlib

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class st:  # noqa: N801 — mimics `strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

    def settings(max_examples: int = 20, **_ignored):
        """Records max_examples on the (already ``given``-wrapped) test."""
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    fn(**drawn)
            # pytest must see a zero-arg test, not the wrapped signature
            # (else the drawn parameters look like missing fixtures).
            del wrapper.__wrapped__
            return wrapper
        return deco
