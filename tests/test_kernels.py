"""Pallas kernel validation (interpret mode): shape/dtype sweeps against the
pure-jnp oracle, bitwise Omega parity, and padding correctness."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.kernels import (
    gen_omega, nystrom_fused, sketch_matmul, sketch_t_matmul,
)
from repro.kernels.ref import (
    omega_ref, sketch_matmul_ref, sketch_t_matmul_ref,
)

I = dict(interpret=True)


# ---------------------------------------------------------------------------
# Bitwise Omega parity: the kernel's in-VMEM generator == oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["normal", "uniform", "rademacher"])
@pytest.mark.parametrize("br,bc", [(8, 8), (16, 8), (32, 16)])
def test_gen_omega_bitwise(kind, br, bc):
    om_k = gen_omega(seed=123, n2=64, r=32, br=br, bc=bc, kind=kind, **I)
    om_r = omega_ref(123, 64, 32, kind)
    np.testing.assert_array_equal(np.asarray(om_k), np.asarray(om_r))


def test_gen_omega_nonaligned_shapes():
    om_k = gen_omega(seed=5, n2=37, r=13, br=16, bc=8, **I)
    om_r = omega_ref(5, 37, 13)
    np.testing.assert_array_equal(np.asarray(om_k), np.asarray(om_r))


# ---------------------------------------------------------------------------
# Padding invariance (ops.py contract): rounding r / n2 up to block
# multiples must not SHIFT the Philox draws of in-range entries — padded
# tail columns/rows draw at their own global coordinates and are sliced
# off, so the padded run is bitwise the unpadded one.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["normal", "uniform", "rademacher"])
def test_gen_omega_padding_never_shifts_draws(kind):
    """The padded generator's in-range block equals the same block of a
    larger unpadded generation — draws are a pure function of global
    coordinates, bitwise."""
    big = np.asarray(gen_omega(seed=5, n2=64, r=32, br=16, bc=8, kind=kind,
                               **I))
    # n2=37 pads to 48, r=13 pads to 16: in-range entries must be the
    # corresponding prefix of the bigger generation, bit for bit
    pad = np.asarray(gen_omega(seed=5, n2=37, r=13, br=16, bc=8, kind=kind,
                               **I))
    np.testing.assert_array_equal(pad, big[:37, :13])


def test_sketch_matmul_r_padding_bitwise():
    """Padding only the output columns (r up to bn multiples) leaves the
    contraction untouched, so in-range columns are bitwise the run whose
    blocks divide r exactly."""
    A = jax.random.normal(jax.random.key(1), (32, 64))
    padded = sketch_matmul(A, seed=7, r=11, bm=32, bn=8, bk=64, **I)
    exact = sketch_matmul(A, seed=7, r=16, bm=32, bn=16, bk=64, **I)
    np.testing.assert_array_equal(np.asarray(padded),
                                  np.asarray(exact)[:, :11])


def test_sketch_matmul_row_padding_bitwise():
    """Zero-padded A rows produce zero output rows that are sliced away;
    in-range rows see the identical contraction."""
    A = jax.random.normal(jax.random.key(1), (30, 64))
    Ap = jnp.pad(A, ((0, 2), (0, 0)))
    padded = sketch_matmul(A, seed=7, r=16, bm=16, bn=16, bk=64, **I)
    exact = sketch_matmul(Ap, seed=7, r=16, bm=16, bn=16, bk=64, **I)
    np.testing.assert_array_equal(np.asarray(padded),
                                  np.asarray(exact)[:30])


def test_sketch_t_matmul_r_padding_bitwise():
    """Same invariance for the transposed kernel: padded Omega columns
    (output rows of C) draw at their own coordinates and are sliced off."""
    B = jax.random.normal(jax.random.key(2), (64, 16))
    padded = sketch_t_matmul(B, seed=9, r=13, bm=8, bn=16, bk=64, **I)
    exact = sketch_t_matmul(B, seed=9, r=16, bm=16, bn=16, bk=64, **I)
    np.testing.assert_array_equal(np.asarray(padded),
                                  np.asarray(exact)[:13])


# ---------------------------------------------------------------------------
# sketch_matmul: B = A @ Omega
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 0.1)])
@pytest.mark.parametrize("shape,r,blocks", [
    ((32, 64), 16, (16, 8, 16)),
    ((40, 72), 24, (8, 8, 24)),      # block-aligned after min()
    ((33, 50), 11, (16, 8, 16)),     # needs padding in every dim
    ((8, 8), 4, (8, 8, 8)),
    ((128, 96), 32, (32, 16, 32)),
])
def test_sketch_matmul_vs_ref(dtype, tol, shape, r, blocks):
    bm, bn, bk = blocks
    A = jax.random.normal(jax.random.key(1), shape).astype(dtype)
    B = sketch_matmul(A, seed=7, r=r, bm=bm, bn=bn, bk=bk, **I)
    ref = sketch_matmul_ref(A, 7, r)
    assert B.shape == (shape[0], r)
    assert B.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(B, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("kind", ["uniform", "rademacher"])
def test_sketch_matmul_kinds(kind):
    A = jax.random.normal(jax.random.key(2), (32, 48))
    B = sketch_matmul(A, seed=3, r=16, bm=16, bn=8, bk=16, kind=kind, **I)
    ref = sketch_matmul_ref(A, 3, 16, kind)
    np.testing.assert_allclose(np.asarray(B), np.asarray(ref),
                               rtol=2e-5, atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(
    n1=st.integers(4, 70), n2=st.integers(4, 70), r=st.integers(2, 40),
    bm=st.sampled_from([8, 16, 32]), bn=st.sampled_from([8, 16]),
    bk=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**62),
)
def test_sketch_matmul_property(n1, n2, r, bm, bn, bk, seed):
    A = jax.random.normal(jax.random.key(0), (n1, n2))
    B = sketch_matmul(A, seed=seed, r=r, bm=bm, bn=bn, bk=bk, **I)
    ref = sketch_matmul_ref(A, seed, r)
    np.testing.assert_allclose(np.asarray(B), np.asarray(ref),
                               rtol=3e-5, atol=3e-4)


def test_block_shape_independence():
    """The kernel result must not depend on the tiling (the in-kernel
    generator is keyed by global coordinates)."""
    A = jax.random.normal(jax.random.key(4), (64, 96))
    outs = [np.asarray(sketch_matmul(A, seed=11, r=32, bm=bm, bn=bn, bk=bk, **I))
            for (bm, bn, bk) in [(8, 8, 8), (16, 16, 32), (32, 8, 96), (64, 32, 48)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-4)


# ---------------------------------------------------------------------------
# sketch_t_matmul: C = Omega^T @ B
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,r", [((64, 32), 16), ((50, 21), 13), ((16, 16), 8)])
def test_sketch_t_matmul_vs_ref(shape, r):
    B = jax.random.normal(jax.random.key(5), shape)
    C = sketch_t_matmul(B, seed=13, r=r, bm=8, bn=8, bk=16, **I)
    ref = sketch_t_matmul_ref(B, 13, r)
    assert C.shape == (r, shape[1])
    np.testing.assert_allclose(np.asarray(C), np.asarray(ref),
                               rtol=3e-5, atol=3e-4)


def test_nystrom_fused_pair_matches_core():
    """Fused-kernel Nyström == core (shard-map-free) reference path."""
    from repro.core.nystrom import nystrom_reference
    n, r = 48, 16
    X = jax.random.normal(jax.random.key(6), (n, 8))
    S = X @ X.T
    Bk, Ck = nystrom_fused(S, seed=21, r=r, bm=16, bn=8, bk=16, **I)
    Br, Cr = nystrom_reference(S, 21, r)
    np.testing.assert_allclose(np.asarray(Bk), np.asarray(Br),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(Ck), np.asarray(Cr),
                               rtol=1e-4, atol=1e-2)


def test_kernel_lowers_for_tpu_structurally():
    """The pallas_call must trace and lower (abstract eval) without running —
    catches BlockSpec/grid mistakes that interpret mode can hide."""
    A = jax.ShapeDtypeStruct((512, 1024), jnp.bfloat16)
    fn = lambda a: sketch_matmul(a, seed=1, r=256, bm=256, bn=128, bk=512,
                                 interpret=True)
    jax.eval_shape(fn, A)  # abstract evaluation only
