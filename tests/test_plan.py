"""Planner subsystem (repro.plan).

Contract pillars (ISSUE acceptance criteria):
  (a) ``plan_sketch`` / ``plan_nystrom`` never predict below the Theorem 2/3
      lower bounds, in every regime;
  (b) when a shard_map variant wins, its analytic words equal the paper's
      closed forms ``alg1_bandwidth_words`` / ``alg2_bandwidth_words``
      exactly, and the Alg.-1 grid agrees with ``select_matmul_grid``;
  (c) below the paper's crossover (Thm. 2 regime 1, P <= n1) the planner
      picks the zero-communication local-regenerate variant;
  (d) ``Plan.execute`` is bitwise-identical to calling the underlying entry
      point directly (single-device here; multi-device in a subprocess);
  (e) the autotune cache round-trips: first call measures + persists,
      second call is a pure cache hit (the timer must not run).
"""
import json
import math
import os

import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st
from dist_helper import run_distributed

from repro.core import sketch_reference
from repro.core.grid import (
    alg1_bandwidth_words,
    alg2_bandwidth_words,
    select_matmul_grid,
)
from repro.core.lower_bounds import matmul_lower_bound, nystrom_lower_bound
from repro.plan import (
    AutotuneCache,
    PRESETS,
    autotune,
    explain,
    plan_nystrom,
    plan_sketch,
    plan_stream,
    regime_sweep,
    shape_bucket,
)

CPU = PRESETS["cpu"]


# ---------------------------------------------------------------------------
# (a) predictions never beat the lower bound; (b) tight where the paper is
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(n1e=st.integers(0, 6), n2e=st.integers(2, 8),
       re_=st.integers(0, 5), Pe=st.integers(0, 9))
def test_plan_sketch_never_below_bound(n1e, n2e, re_, Pe):
    n1, n2, r, P = 2 ** n1e, 2 ** n2e, 2 ** re_, 2 ** Pe
    if r >= n2 or P > n1 * n2 * r:
        return
    plan = plan_sketch(n1, n2, r, P=P, machine=CPU)
    lb = matmul_lower_bound(n1, n2, r, P)
    assert plan.lower_bound_words == lb
    assert plan.predicted_words >= lb - 1e-9, (plan.variant, plan.grid)
    # every scored candidate respects the bound too (it is a LOWER bound)
    for c in plan.candidates:
        if c.variant != "alg1_communicating":
            assert c.cost.words >= lb - 1e-9, c


@settings(max_examples=40, deadline=None)
@given(ne=st.integers(4, 9), re_=st.integers(1, 6), Pe=st.integers(0, 8))
def test_plan_nystrom_never_below_bound(ne, re_, Pe):
    n, r, P = 2 ** ne, 2 ** re_, 2 ** Pe
    if r >= n:
        return
    plan = plan_nystrom(n, r, P=P, machine=CPU)
    lb = nystrom_lower_bound(n, r, P)
    assert plan.lower_bound_words == lb
    assert plan.predicted_words >= lb - 1e-9, (plan.variant, plan.grid)
    # every executable candidate — including the §5.3 bound-driven general
    # two-grid pair — respects the Theorem 3 floor on its own grids
    for c in plan.candidates:
        if c.executable:
            assert c.cost.words >= lb - 1e-9, (c.variant, c.grid, c.q_grid)


def test_alg1_choice_equals_closed_form_and_grid_selector():
    """(b): in each Theorem-2 regime the shard_map winner's words are the
    paper's closed form on its own grid; the grid agrees with
    ``select_matmul_grid`` whenever that grid is executable, and is the
    min-words *executable* factorization otherwise.

    (The §4.3 ideal grids of regimes 2/3 put p1 = n1, so B's
    P((p1, p2), p3) layout would have to split one-row blocks p2 ways —
    analytically tight but not runnable by Alg. 1's reduce-scatter; the
    planner must snap to what the program can execute.)
    """
    from repro.core.grid import factorizations_3d
    from repro.plan.planner import _alg1_executable

    cases = [
        (64, 256, 16, 32),     # regime 1: P <= n1
        (16, 1024, 8, 64),     # regime 2: n1 < P <= n1n2/r
        (256, 64, 16, 4096),   # regime 3: P > n1n2/r
    ]
    for (n1, n2, r, P) in cases:
        plan = plan_sketch(n1, n2, r, P=P, machine=CPU)
        g = select_matmul_grid(n1, n2, r, P)
        assert plan.variant == "alg1"
        assert plan.regime == g.regime
        assert plan.executable
        assert _alg1_executable(n1, n2, r, plan.grid)
        # chosen cost IS the paper's closed form on the chosen grid
        assert plan.predicted_words == alg1_bandwidth_words(n1, n2, r,
                                                            *plan.grid)
        if _alg1_executable(n1, n2, r, g.shape):
            # selector's grid runs -> exact agreement (and tightness)
            assert plan.grid == g.shape, (plan.grid, g.shape)
            assert math.isclose(plan.predicted_words,
                                matmul_lower_bound(n1, n2, r, P),
                                abs_tol=1e-9)
        else:
            # snapped: optimal among what the program can execute
            best = min(alg1_bandwidth_words(n1, n2, r, *c)
                       for c in factorizations_3d(P)
                       if _alg1_executable(n1, n2, r, c))
            assert plan.predicted_words == best
    # regime 1's ideal grid is always executable on divisible shapes, so
    # the agreement branch above is exercised there
    assert plan_sketch(64, 256, 16, P=32, machine=CPU).grid == (32, 1, 1)


def test_alg2_choice_equals_closed_form():
    for P in (4, 8, 16):
        plan = plan_nystrom(4096, 256, P=P, machine=CPU)
        assert plan.variant in ("alg2_no_redist", "alg2_redist")
        assert plan.predicted_words == alg2_bandwidth_words(
            4096, 256, plan.grid, plan.q_grid)


def test_zero_communication_regime_below_crossover():
    """(c): P <= n1 -> the (P, 1, 1) local-regenerate grid, zero words."""
    for P in (2, 8, 32, 64):
        plan = plan_sketch(64, 512, 16, P=P, machine=CPU)
        assert plan.regime == 1
        assert plan.grid == (P, 1, 1)
        assert plan.predicted_words == 0.0
        assert plan.lower_bound_words == 0.0


def test_nystrom_crossover_bandwidth_dominated():
    """At paper scale the redist/no_redist choice follows the Fig.-7 rule
    (at tiny sizes latency legitimately dominates; not asserted there)."""
    n, r = 49152, 4096          # n/r = 12
    below = plan_nystrom(n, r, P=4, machine=CPU)
    above = plan_nystrom(n, r, P=64, machine=CPU)
    assert below.variant == "alg2_no_redist"
    # above the crossover the planner abandons no_redist for the redist
    # all-to-all family — since PR 5 in its fused single-jit form: the
    # regime-1 bound-driven pair IS the redist layout p=(P,1,1), q=(1,1,P),
    # with the §5.2 Redistribute in-program at the layout min-cut < nr/P
    assert above.variant == "alg2_bound_driven_fused"
    assert (above.grid, above.q_grid) == ((64, 1, 1), (1, 1, 64))
    # and the words honor the closed forms on both sides
    assert below.predicted_words == alg2_bandwidth_words(n, r, (4, 1, 1),
                                                         (4, 1, 1))
    from repro.plan.model import alg2_fused_cost
    assert above.predicted_words == alg2_fused_cost(
        n, r, (64, 1, 1), (1, 1, 64)).words
    assert above.predicted_words < alg2_bandwidth_words(n, r, (64, 1, 1),
                                                        (1, 1, 64))
    # the plain redist closed form still backs the cross-mesh candidates
    redist = [c for c in above.candidates if c.variant == "alg2_redist"]
    assert redist and redist[0].cost.words == alg2_bandwidth_words(
        n, r, (64, 1, 1), (1, 1, 64))


def test_infeasible_shape_yields_analytic_only_plan():
    plan = plan_sketch(7, 7, 3, P=4, machine=CPU)   # nothing divides
    assert not plan.executable
    with pytest.raises(ValueError):
        plan.execute(np.zeros((7, 7), np.float32))


# ---------------------------------------------------------------------------
# (d) execute == direct call (single device; multi-device in subprocess)
# ---------------------------------------------------------------------------

def test_execute_local_bitwise():
    n1, n2, r, seed = 48, 64, 8, 11
    A = jax.random.normal(jax.random.key(0), (n1, n2))
    plan = plan_sketch(n1, n2, r, P=1, machine=CPU)
    assert plan.variant == "local_xla"
    np.testing.assert_array_equal(
        np.asarray(plan.execute(A, seed=seed)),
        np.asarray(sketch_reference(A, seed, r)))


def test_execute_stream_local_bitwise():
    n1, n2, r, seed = 48, 64, 8, 3
    A = jax.random.normal(jax.random.key(2), (n1, n2))
    plan = plan_stream(n1, n2, r, P=1, chunk_rows=16, machine=CPU)
    st_acc = plan.execute(A, seed=seed)
    np.testing.assert_array_equal(
        np.asarray(st_acc.sketch),
        np.asarray(sketch_reference(A, seed, r)))


def test_execute_pallas_interpret_matches_reference():
    n1, n2, r, seed = 32, 32, 8, 2
    A = jax.random.normal(jax.random.key(4), (n1, n2))
    plan = plan_sketch(n1, n2, r, P=1, machine=CPU, allow_pallas=True)
    assert plan.variant == "pallas_fused"   # fewer HBM words than local_xla
    B = plan.execute(A, seed=seed)
    np.testing.assert_allclose(np.asarray(B),
                               np.asarray(sketch_reference(A, seed, r)),
                               rtol=2e-5, atol=2e-4)


def test_execute_distributed_bitwise():
    run_distributed(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import rand_matmul, make_grid_mesh, nystrom_reference
from repro.core.sketch import input_sharding
from repro.core.nystrom import nystrom_no_redist, nystrom_redist
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.plan import plan_sketch, plan_nystrom, PRESETS
CPU = PRESETS["cpu"]
assert len(jax.devices()) == 8

seed, n1, n2, r = 7, 16, 64, 8
A = jax.random.normal(jax.random.key(1), (n1, n2))
plan = plan_sketch(n1, n2, r, P=8, machine=CPU)
assert plan.variant == "alg1", plan.variant
B = plan.execute(A, seed=seed)
mesh = make_grid_mesh(*plan.grid)
B_direct = rand_matmul(jax.device_put(A, input_sharding(mesh)),
                       seed, r, mesh)
assert np.array_equal(np.asarray(B), np.asarray(B_direct))
print("OK alg1 execute bitwise")

n, rn = 64, 16
X = jax.random.normal(jax.random.key(4), (n, 8)); S = X @ X.T
pn = plan_nystrom(n, rn, P=8, machine=CPU)
assert pn.variant in ("alg2_no_redist", "alg2_redist"), pn.variant
B2, C2 = pn.execute(S, seed=5)
mesh1 = Mesh(np.asarray(jax.devices()), ("x",))
Sx = jax.device_put(S, NamedSharding(mesh1, P("x", None)))
fn = nystrom_no_redist if pn.variant == "alg2_no_redist" else nystrom_redist
Bd, Cd = fn(Sx, 5, rn, mesh1, axis="x")
assert np.array_equal(np.asarray(B2), np.asarray(Bd))
assert np.array_equal(np.asarray(C2), np.asarray(Cd))
print("OK alg2 execute bitwise")

# wiring: rand_matmul_auto plan path == direct
from repro.core import rand_matmul_auto
B3, g, mesh3 = rand_matmul_auto(A, seed, r, grid="plan")
assert g.shape == plan.grid
assert np.array_equal(np.asarray(B3), np.asarray(B_direct))
print("OK rand_matmul_auto plan path")

# grid="auto" snaps to an executable factorization when the ideal §4.3
# grid does not divide the shape (12 % 8 != 0 -> not (8,1,1))
A12 = jax.random.normal(jax.random.key(2), (12, 50))
B4, g4, _ = rand_matmul_auto(A12, seed, 8, grid="auto")
assert 12 % g4.p1 == 0 and 50 % (g4.p2 * g4.p3) == 0 and 8 % g4.p3 == 0
from repro.core import sketch_reference as sref
assert np.allclose(np.asarray(B4), np.asarray(sref(A12, seed, 8)),
                   atol=1e-4)
print("OK grid=auto divisibility snap")

# wiring: service + sharded stream accept a Plan
from repro.serve import make_sketch_service
from repro.stream import StreamConfig, ShardedStreamingSketch
svc = make_sketch_service(plan=plan)
assert svc.mesh is not None
sid = svc.open(StreamConfig(n1=n1, n2=n2, r=r, seed=seed, corange=False))
svc.update(sid, jnp.asarray(A))
assert np.array_equal(np.asarray(svc.sketch(sid)), np.asarray(B_direct))
st = ShardedStreamingSketch(StreamConfig(n1=n1, n2=n2, r=r, seed=seed),
                            plan)
st.update(jnp.asarray(A))
assert np.array_equal(np.asarray(st.sketch), np.asarray(B_direct))
print("OK plan-driven service + stream")
""")


# ---------------------------------------------------------------------------
# (e) autotune: measured refinement + cache round trip with a fake timer
# ---------------------------------------------------------------------------

def test_autotune_cache_round_trip(tmp_path):
    path = os.path.join(str(tmp_path), "tune.json")
    plan = plan_sketch(64, 128, 16, P=1, machine=CPU)

    calls = []

    def fake_timer(fn):
        calls.append(fn)
        return 1e-3 * len(calls)      # first measured candidate wins

    cache = AutotuneCache(path)
    tuned = autotune(plan, cache=cache, timer=fake_timer)
    assert calls, "timer must run on a cache miss"
    assert cache.misses == 1 and cache.hits == 0
    assert tuned.measured_seconds == pytest.approx(1e-3)
    assert tuned.executable

    # persisted, versioned, atomic
    from repro.plan.autotune import CACHE_VERSION
    with open(path) as f:
        data = json.load(f)
    assert data["version"] == CACHE_VERSION
    assert len(data["entries"]) == 1

    # second invocation (fresh cache object): pure hit, timer must NOT run
    def forbidden_timer(fn):
        raise AssertionError("timer ran on a cache hit")

    cache2 = AutotuneCache(path)
    tuned2 = autotune(plan, cache=cache2, timer=forbidden_timer)
    assert cache2.hits == 1 and cache2.misses == 0
    assert tuned2.variant == tuned.variant
    assert tuned2.blocks == tuned.blocks
    assert tuned2.measured_seconds == tuned.measured_seconds

    # stale-version cache files are ignored, not crashed on
    with open(path, "w") as f:
        json.dump({"version": -1, "entries": {"x": {}}}, f)
    assert len(AutotuneCache(path)) == 0


def test_autotune_measures_real_execution(tmp_path):
    """With the default wall-clock timer the tuned plan still executes
    bitwise-identically (the tuner only reorders, never rewrites math)."""
    n1, n2, r, seed = 32, 64, 8, 9
    A = jax.random.normal(jax.random.key(3), (n1, n2))
    plan = plan_sketch(n1, n2, r, P=1, machine=CPU)
    tuned = autotune(plan, cache=os.path.join(str(tmp_path), "t.json"))
    assert tuned.measured_seconds is not None and tuned.measured_seconds > 0
    np.testing.assert_array_equal(
        np.asarray(tuned.execute(A, seed=seed)),
        np.asarray(sketch_reference(A, seed, r)))


def test_autotune_cache_hit_revalidates_against_exact_dims(tmp_path):
    """(16,64,8) and (9,50,8) share one pow2 bucket key, but the cached
    (8,1,1)-style decision does not divide the second shape — the hit must
    fall back to measuring (or analytic), never execute a bad grid."""
    path = os.path.join(str(tmp_path), "tune.json")
    good = plan_sketch(16, 64, 8, P=8, machine=CPU)
    from repro.plan import cache_key
    bad = plan_sketch(9, 50, 8, P=8, machine=CPU)
    assert cache_key(good) == cache_key(bad)   # the collision under test
    assert good.executable and not bad.executable

    autotune(good, cache=path, timer=lambda fn: 1e-3)
    calls = []

    def counting_timer(fn):
        calls.append(fn)
        return 1e-3

    tuned_bad = autotune(bad, cache=path, timer=counting_timer)
    # no executable candidates exist for (9,50,8): nothing measured, and
    # crucially the cached (dividing) grid was NOT stamped onto the plan
    assert not calls
    assert not tuned_bad.executable
    with pytest.raises(ValueError):
        tuned_bad.execute(np.zeros((9, 50), np.float32))


def test_autotune_rescores_predictions_for_the_winner(tmp_path):
    """The tuned plan's predicted words must describe the tuned grid, not
    the pre-tune analytic favorite (explain/bound audit correctness)."""
    from repro.core.grid import alg1_bandwidth_words as w

    def timer_prefers_last(fn):
        timer_prefers_last.n += 1
        return 1.0 / timer_prefers_last.n      # later candidate "faster"

    timer_prefers_last.n = 0
    plan = plan_sketch(16, 64, 8, P=8, machine=CPU)
    run = {"tuned": autotune(plan, cache=None, timer=timer_prefers_last)}
    tuned = run["tuned"]
    assert tuned.predicted_words == w(16, 64, 8, *tuned.grid)
    # and a cache round-trip preserves the rescored numbers
    path = os.path.join(str(tmp_path), "t.json")
    autotune(plan, cache=path, timer=lambda fn: 1e-3)
    hit = autotune(plan, cache=path,
                   timer=lambda fn: pytest.fail("hit must not measure"))
    assert hit.predicted_words == w(16, 64, 8, *hit.grid)


def test_stream_plan_carries_corange():
    n1, n2, r = 32, 48, 8
    M_ = (jax.random.normal(jax.random.key(1), (n1, 4))
          @ jax.random.normal(jax.random.key(2), (4, n2)))
    plan = plan_stream(n1, n2, r, P=1, chunk_rows=16, corange=True,
                       machine=CPU)
    acc = plan.execute(M_, seed=3)
    assert acc.corange_sketch is not None
    acc.reconstruct(rank=4)       # must not raise (W is tracked)


def test_entry_points_reject_analytic_only_plans():
    from repro.core import nystrom_auto, rand_matmul_auto
    bad = plan_sketch(7, 7, 3, P=4, machine=CPU)
    with pytest.raises(ValueError, match="analytic-only"):
        rand_matmul_auto(np.zeros((7, 7), np.float32), 0, 3, P_procs=4,
                         plan=bad)
    bad_n = plan_nystrom(30, 7, P=8, machine=CPU)
    assert not bad_n.executable
    with pytest.raises(ValueError, match="analytic-only"):
        nystrom_auto(np.zeros((30, 30), np.float32), 0, 7, plan=bad_n)


def test_shape_bucket():
    assert [shape_bucket(x) for x in (1, 2, 3, 64, 65, 1000)] == \
        [1, 2, 4, 64, 128, 1024]


# ---------------------------------------------------------------------------
# explain / reports
# ---------------------------------------------------------------------------

def test_explain_mentions_regime_bound_and_candidates():
    plan = plan_sketch(16, 1024, 8, P=64, machine=CPU)
    text = explain(plan)
    assert "Theorem 2 regime 2" in text
    assert "alg1" in text and "lower bound" in text
    assert "alg1_communicating" in text          # the Fig.-3 contrast row
    assert str(plan.grid) in text

    pn = plan_nystrom(4096, 256, P=8, machine=CPU)
    tn = explain(pn)
    assert "Theorem 3" in tn and "crossover" in tn


def test_regime_sweep_table():
    table = regime_sweep(plan_sketch, (4096, 4096, 256),
                         [1, 8, 65536], machine=CPU)
    lines = table.splitlines()
    assert len(lines) == 5                       # header + sep + 3 rows
    assert "variant" in lines[0]


# ---------------------------------------------------------------------------
# (g) machine-model calibration from grid-sweep residuals (autotune.py)
# ---------------------------------------------------------------------------

def test_calibrate_machine_model_recovers_alpha_beta():
    """Times synthesized from a known (alpha, beta) over a communicating
    grid sweep must be fit back to those values (within lstsq noise)."""
    import dataclasses
    from repro.plan import calibrate_machine_model
    from repro.plan import model as M

    true = dataclasses.replace(CPU, alpha=3e-5, byte_bw=2e9)
    recs = []
    for grid in ((8, 1, 1), (2, 2, 2), (1, 4, 2), (4, 2, 1), (1, 1, 8)):
        c = M.alg1_cost(64, 128, 16, grid)
        recs.append({"words": c.words, "messages": c.messages,
                     "flops": c.flops, "hbm_words": c.hbm_words,
                     "itemsize": 4, "seconds": c.seconds(true, 4)})
    fit = calibrate_machine_model(recs, base=CPU)
    assert abs(fit.alpha - true.alpha) / true.alpha < 0.05
    assert abs(fit.byte_bw - true.byte_bw) / true.byte_bw < 0.05
    assert fit.name.endswith("_calibrated")
    # compute/memory rates come from the base preset, untouched
    assert fit.flop_rate == CPU.flop_rate and fit.hbm_bw == CPU.hbm_bw


def test_calibrate_machine_model_degenerate_keeps_base():
    """Zero-communication records carry no network information — the base
    terms must survive unchanged instead of fitting noise."""
    from repro.plan import calibrate_machine_model
    recs = [{"words": 0.0, "messages": 0.0, "flops": 1e6,
             "hbm_words": 1e4, "itemsize": 4, "seconds": 1e-4}]
    fit = calibrate_machine_model(recs, base=CPU)
    assert fit.alpha == CPU.alpha and fit.byte_bw == CPU.byte_bw


def test_sweep_records_round_trip(tmp_path):
    """sweep_records measures every candidate with the injected timer and
    the JSON round-trips through save_sweep/load_sweep."""
    from repro.plan import load_sweep, save_sweep, sweep_records

    plan = plan_sketch(32, 64, 8, P=1, machine=CPU)
    recs = sweep_records(plan, timer=lambda fn: 1e-3, machine=CPU)
    assert recs and all(r["seconds"] == 1e-3 for r in recs)
    assert all({"words", "flops", "hbm_words", "itemsize"} <= set(r)
               for r in recs)
    path = os.path.join(str(tmp_path), "sweep.json")
    save_sweep(recs, path)
    assert load_sweep(path) == recs


def test_autotune_records_and_presets(tmp_path):
    """autotune(records=...) captures one record per timed candidate, and
    a preset entry short-circuits measurement on a cache miss (then seeds
    the writable cache)."""
    from repro.plan import AutotuneCache, cache_key

    plan = plan_sketch(64, 128, 16, P=1, machine=CPU)
    recs = []
    tuned = autotune(plan, timer=lambda fn: 1e-3, records=recs,
                     presets={})
    assert tuned.measured_seconds == 1e-3
    assert len(recs) >= 1 and all("seconds" in r for r in recs)

    # preset hit: no measurement, decision restored, cache seeded
    key = cache_key(plan)
    preset = {key: {"variant": "local_xla", "grid": None, "q_grid": None,
                    "blocks": None, "chunk_rows": None, "backend": "jnp",
                    "source": "analytic", "seconds": None}}

    def forbidden_timer(fn):
        raise AssertionError("timer ran despite a preset hit")

    cache = AutotuneCache(os.path.join(str(tmp_path), "t.json"))
    got = autotune(plan, cache=cache, timer=forbidden_timer, presets=preset)
    assert got.variant == "local_xla"
    assert cache.get(key) is not None       # preset copied into the cache


def test_autotune_cache_entry_preserves_backend(tmp_path):
    """A tuned pallas-backend decision round-trips through the cache with
    its backend and block shape."""
    import dataclasses
    from repro.plan.autotune import _entry_from_plan, _plan_from_entry

    plan = plan_sketch(64, 128, 16, P=8, machine=CPU)
    tuned = dataclasses.replace(plan, backend="pallas", grid=(8, 1, 1),
                                blocks={"bm": 128, "bn": 128, "bk": 256},
                                measured_seconds=1e-3, executable=True)
    entry = _entry_from_plan(tuned)
    assert entry["backend"] == "pallas" and entry["source"] == "measured"
    restored = _plan_from_entry(plan, entry)
    assert restored.backend == "pallas"
    assert restored.blocks == {"bm": 128, "bn": 128, "bk": 256}
