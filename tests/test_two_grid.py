"""§5.3 bound-driven general two-grid Nyström (core.nystrom.nystrom_two_grid).

Contract pillars (ISSUE acceptance criteria):
  (a) ``plan_nystrom`` returns an ``executable=True`` ``alg2_bound_driven``
      candidate whose ``Plan.execute`` runs on 8 fake devices and is bitwise
      ``nystrom_two_grid`` called directly — and, for a (p, q) pair whose
      contractions are never split (p2 == 1, q1 == 1), bitwise
      ``nystrom_reference`` with p != q;
  (b) predicted words for every *executable* candidate stay at or above the
      Theorem 3 lower bound across swept (n, r, P);
  (c) the snap policy mirrors Alg. 1's ``grid="auto"``: the ideal
      bound-driven pair when it divides, else the min-words executable pair
      of factorizations, else an analytic-only candidate.
"""
import math

import pytest

from _hypothesis_compat import given, settings, st
from dist_helper import run_distributed

from repro.core.grid import (
    alg2_bandwidth_words,
    alg2_two_grid_executable,
    factorizations_3d,
    select_nystrom_grids,
    select_two_grid_executable,
)
from repro.core.lower_bounds import nystrom_lower_bound
from repro.plan import PRESETS, explain, plan_nystrom

CPU = PRESETS["cpu"]


# ---------------------------------------------------------------------------
# (b) planner audit invariants across the new variant
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(ne=st.integers(4, 9), re_=st.integers(1, 6), Pe=st.integers(1, 8))
def test_bound_driven_candidate_never_below_bound(ne, re_, Pe):
    n, r, P = 2 ** ne, 2 ** re_, 2 ** Pe
    if r >= n:
        return
    plan = plan_nystrom(n, r, P=P, machine=CPU)
    lb = nystrom_lower_bound(n, r, P)
    bd = [c for c in plan.candidates if c.variant == "alg2_bound_driven"]
    assert bd, "bound_driven candidate must always be scored for P > 1"
    for c in bd:
        assert c.cost.words >= lb - 1e-9, (c.grid, c.q_grid, c.cost.words)
        # the candidate prices at the paper's closed form on its own grids
        assert math.isclose(c.cost.words,
                            alg2_bandwidth_words(n, r, c.grid, c.q_grid),
                            rel_tol=1e-12)
        if c.executable:
            assert alg2_two_grid_executable(n, r, c.grid, c.q_grid)
    # every executable candidate — not just the winner — respects the bound
    for c in plan.candidates:
        if c.executable:
            assert c.cost.words >= lb - 1e-9, c


@settings(max_examples=60, deadline=None)
@given(ne=st.integers(3, 9), re_=st.integers(1, 6), Pe=st.integers(1, 8))
def test_select_two_grid_snap_policy(ne, re_, Pe):
    """(c): exact == the §5.3 ideal pair; snapped == min-words executable."""
    n, r, P = 2 ** ne, 2 ** re_, 2 ** Pe
    if r >= n:
        return
    got = select_two_grid_executable(n, r, P)
    ideal = select_nystrom_grids(n, r, P, variant="bound_driven")
    if got is None:
        # nothing divides: no executable pair may exist among factorizations
        assert not any(
            alg2_two_grid_executable(n, r, pc, qc)
            for pc in factorizations_3d(P) for qc in factorizations_3d(P))
        return
    p, q, exact = got
    assert p[0] * p[1] * p[2] == P and q[0] * q[1] * q[2] == P
    assert alg2_two_grid_executable(n, r, p, q)
    if exact:
        assert (p, q) == (tuple(ideal.p), tuple(ideal.q))
    else:
        best = min(alg2_bandwidth_words(n, r, pc, qc)
                   for pc in factorizations_3d(P)
                   for qc in factorizations_3d(P)
                   if alg2_two_grid_executable(n, r, pc, qc))
        assert math.isclose(alg2_bandwidth_words(n, r, p, q), best,
                            rel_tol=1e-12)


def test_bound_driven_is_only_executable_variant_when_1d_cannot_run():
    """r % P != 0 rules the 1-D variants out, but the two-grid pair runs —
    the planner can now dispatch in regimes that were analytic-only.  The
    single-jit fused form wins over the cross-mesh form whenever the pair
    admits a shared mesh (fewer Redistribute words, no host hop)."""
    plan = plan_nystrom(64, 4, P=8, machine=CPU)   # r=4 < P=8
    assert plan.executable
    assert plan.variant == "alg2_bound_driven_fused"
    assert plan.grid != plan.q_grid
    cross = [c for c in plan.candidates if c.variant == "alg2_bound_driven"]
    assert cross and any(c.executable for c in cross)
    one_d = [c for c in plan.candidates
             if c.variant in ("alg2_no_redist", "alg2_redist")]
    assert one_d and not any(c.executable for c in one_d)


def test_plan_nystrom_variant_forcing():
    pn = plan_nystrom(64, 16, P=8, machine=CPU, variant="bound_driven")
    assert pn.variant == "alg2_bound_driven" and pn.executable
    assert pn.grid != pn.q_grid
    # the un-forced candidates stay in the audit trail
    assert {c.variant for c in pn.candidates} >= {
        "alg2_no_redist", "alg2_redist", "alg2_bound_driven"}
    assert plan_nystrom(64, 16, P=8, machine=CPU,
                        variant="redist").variant == "alg2_redist"
    with pytest.raises(ValueError, match="needs P > 1"):
        plan_nystrom(64, 16, P=1, machine=CPU, variant="bound_driven")
    with pytest.raises(ValueError, match="unknown variant"):
        plan_nystrom(64, 16, P=8, machine=CPU, variant="fastest")


def test_explain_reports_two_grid_redistribution():
    pn = plan_nystrom(64, 4, P=8, machine=CPU, variant="bound_driven")
    text = explain(pn)
    assert "general two-grid" in text
    assert "Redistribute" in text
    assert str(pn.q_grid) in text


def test_indivisible_two_grid_is_analytic_only():
    plan = plan_nystrom(30, 7, P=8, machine=CPU)
    bd = [c for c in plan.candidates if c.variant == "alg2_bound_driven"]
    assert bd and not bd[0].executable
    assert "no (p, q) factorization" in bd[0].note


def test_autotune_sweeps_q_grids_for_bound_driven():
    from repro.plan import autotune
    plan = plan_nystrom(64, 4, P=8, machine=CPU)    # bound_driven wins
    assert plan.variant == "alg2_bound_driven_fused"
    seen = []

    def fake_timer(fn):
        seen.append(fn)
        return 1e-3 * len(seen)

    tuned = autotune(plan, cache=None, timer=fake_timer)
    assert len(seen) >= 2, "(p, q) sweep must measure more than one option"
    assert tuned.variant in ("alg2_bound_driven", "alg2_bound_driven_fused")
    assert tuned.q_grid is not None
    assert alg2_two_grid_executable(64, 4, tuned.grid, tuned.q_grid)
    # rescoring describes the tuned pair, not the pre-tune favorite
    from repro.plan.model import alg2_fused_cost
    want = (alg2_fused_cost(64, 4, tuned.grid, tuned.q_grid).words
            if tuned.variant == "alg2_bound_driven_fused"
            else alg2_bandwidth_words(64, 4, tuned.grid, tuned.q_grid))
    assert math.isclose(tuned.predicted_words, want, rel_tol=1e-12)


# ---------------------------------------------------------------------------
# (a) execution on 8 fake devices: bitwise contracts
# ---------------------------------------------------------------------------

def test_two_grid_execution_bitwise():
    run_distributed(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import (nystrom_reference, nystrom_two_grid, nystrom_auto,
                        nystrom_second_stage_two_grid)
from repro.plan import plan_nystrom, PRESETS
CPU = PRESETS["cpu"]
assert len(jax.devices()) == 8

seed, n, r = 5, 64, 16
X = jax.random.normal(jax.random.key(2), (n, 8)); S = X @ X.T
Bref, Cref = nystrom_reference(S, seed, r)

# (p, q) pairs that never split a contraction (p2 == 1, q1 == 1) are
# bitwise vs the single-device reference — including p != q pairs that
# nystrom_general's shared-axis mesh cannot express.
for (p, q) in [((8,1,1), (1,1,8)), ((8,1,1), (1,2,4)), ((4,1,2), (1,4,2)),
               ((8,1,1), (1,4,2)), ((2,1,4), (1,8,1))]:
    B, C = nystrom_two_grid(S, seed, r, p=p, q=q)
    assert np.array_equal(np.asarray(B), np.asarray(Bref)), (p, q)
    assert np.array_equal(np.asarray(C), np.asarray(Cref)), (p, q)
print("OK bitwise-safe pairs")

# split-contraction pairs (p2 > 1 or q1 > 1) reorder partial sums: close,
# not bitwise — same contract as the other shard_map variants.
for (p, q) in [((8,1,1), (2,1,4)), ((2,2,2), (4,2,1)), ((1,2,4), (2,2,2))]:
    B, C = nystrom_two_grid(S, seed, r, p=p, q=q)
    assert np.allclose(np.asarray(B), np.asarray(Bref), atol=1e-3), (p, q)
    assert np.allclose(np.asarray(C), np.asarray(Cref), atol=1e-2), (p, q)
print("OK split pairs close")

# acceptance: an executable=True alg2_bound_driven candidate whose
# Plan.execute is bitwise nystrom_reference with p != q (regime-1 ideal
# grids p=(8,1,1), q=(1,1,8) keep both contractions whole)...
pn = plan_nystrom(n, r, P=8, machine=CPU, variant="bound_driven")
assert pn.variant == "alg2_bound_driven" and pn.executable
assert pn.grid != pn.q_grid, (pn.grid, pn.q_grid)
B, C = pn.execute(S, seed=seed)
assert np.array_equal(np.asarray(B), np.asarray(Bref))
assert np.array_equal(np.asarray(C), np.asarray(Cref))
# ...and Plan.execute IS the direct call
Bd, Cd = nystrom_two_grid(S, seed, r, p=pn.grid, q=pn.q_grid)
assert np.array_equal(np.asarray(B), np.asarray(Bd))
assert np.array_equal(np.asarray(C), np.asarray(Cd))
print("OK plan bound_driven bitwise vs reference and direct call")

# regime 2 (r < P): a genuinely two-grid pair q=(2,1,4) the 1-D variants
# cannot run at all (r % P != 0); the single-jit fused form wins in auto
# mode and execute == the cross-mesh direct call, bitwise.
pn2 = plan_nystrom(n, 4, P=8, machine=CPU)
assert pn2.variant == "alg2_bound_driven_fused" and pn2.executable
assert pn2.q_grid not in (pn2.grid, (1, 1, 8)), pn2.q_grid
B2, C2 = pn2.execute(S, seed=seed)
B2d, C2d = nystrom_two_grid(S, seed, 4, p=pn2.grid, q=pn2.q_grid)
assert np.array_equal(np.asarray(B2), np.asarray(B2d))
assert np.array_equal(np.asarray(C2), np.asarray(C2d))
B2r, C2r = nystrom_reference(S, seed, 4)
assert np.allclose(np.asarray(B2), np.asarray(B2r), atol=1e-3)
assert np.allclose(np.asarray(C2), np.asarray(C2r), atol=1e-2)
print("OK regime-2 bound_driven execute == direct")

# nystrom_auto dispatches both the explicit variant and a bound-driven plan
Ba, Ca, mesh_q, v = nystrom_auto(S, seed, r, variant="bound_driven")
assert v == "bound_driven"
assert np.array_equal(np.asarray(Ca), np.asarray(Cref))
Bp, Cp, _, vp = nystrom_auto(S, seed, r, plan=pn)
assert vp == "bound_driven"
assert np.array_equal(np.asarray(Cp), np.asarray(Cref))
print("OK nystrom_auto bound_driven")

# the second stage alone consumes any row-sharded B (streaming finalize)
B3, C3 = nystrom_second_stage_two_grid(Bref, seed, r, (1, 2, 4))
assert np.array_equal(np.asarray(C3), np.asarray(Cref))
print("OK standalone second stage")

# streamed Y -> bound_driven finalize, vs the one-shot reference
from repro.core.sketch import make_grid_mesh
from repro.stream import StreamConfig, SketchService
svc = SketchService(mesh=make_grid_mesh(8, 1, 1))
sid = svc.open(StreamConfig(n1=n, n2=n, r=r, seed=seed, corange=False))
for (i0, i1) in [(0, 32), (32, 64)]:
    svc.update(sid, jnp.zeros((n, n)).at[i0:i1].set(S[i0:i1]))
Bs, Cs = svc.nystrom(sid, variant="bound_driven")
assert np.allclose(np.asarray(Bs), np.asarray(Bref), atol=1e-4)
assert np.allclose(np.asarray(Cs), np.asarray(Cref), atol=1e-3)
print("OK stream bound_driven finalize")

# indivisible grids fail loudly, not with an opaque XLA error
try:
    nystrom_two_grid(S, seed, 7, p=(8,1,1), q=(1,1,8))
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "not divisible" in str(e)
print("OK error paths")
""")
