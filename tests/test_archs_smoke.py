"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes and no NaNs; plus a
decode step against the family's cache structure."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, applicable_shapes
from repro.models import get_api, input_specs
from repro.models.api import count_active_params

B, S = 2, 32


def _smoke_batch(cfg, key):
    kt, kl, kf = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        nf = cfg.num_frontend_tokens
        batch["tokens"] = batch["tokens"][:, : S - nf]
        batch["labels"] = batch["labels"][:, : S - nf]
        batch["frontend_feats"] = jax.random.normal(
            kf, (B, nf, cfg.frontend_dim))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(kf, (B, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch):
    cfg = get_config(arch).reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    batch = _smoke_batch(cfg, jax.random.key(1))
    loss = jax.jit(lambda p, b: api.loss(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grads_finite(arch):
    cfg = get_config(arch).reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    batch = _smoke_batch(cfg, jax.random.key(1))
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: api.loss(p, cfg, batch)))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat), arch
    # at least some gradient signal
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in flat)
    assert gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    cache = api.init_cache(cfg, B, 16)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, c: api.decode_step(p, cfg, tok, c, jnp.int32(3)))(
            params, cache)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
    # cache structure preserved
    assert (jax.tree_util.tree_structure(new_cache)
            == jax.tree_util.tree_structure(cache))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_well_formed(arch):
    cfg = get_config(arch)
    for shape in applicable_shapes(cfg):
        specs = input_specs(cfg, shape)
        leaves = jax.tree_util.tree_leaves(specs)
        assert leaves, (arch, shape.name)
        for l in leaves:
            assert isinstance(l, jax.ShapeDtypeStruct)
            assert all(int(d) >= 0 for d in l.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_active_param_count(arch):
    cfg = get_config(arch).reduced()
    api = get_api(cfg)
    shapes = jax.eval_shape(lambda: api.init(jax.random.key(0), cfg))
    n_act = count_active_params(cfg, shapes)
    n_tot = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))
    assert 0 < n_act <= n_tot
    if cfg.n_experts:
        assert n_act < n_tot    # MoE: active < total


def test_full_config_param_counts_sane():
    """Full (non-reduced) configs: param counts within 25% of the advertised
    model sizes — catches dimension transcription errors."""
    expected = {
        "llama3-8b": 8.0e9,
        "internlm2-20b": 19.9e9,
        "dbrx-132b": 132e9,
        "falcon-mamba-7b": 7.3e9,
        "gemma2-2b": 2.6e9,       # incl. 0.59B embed x2 (tied counted once)
        "h2o-danube-3-4b": 4.0e9,
        "granite-moe-1b-a400m": 1.3e9,
        "zamba2-1.2b": 1.2e9,
    }
    for arch, target in expected.items():
        cfg = get_config(arch)
        api = get_api(cfg)
        shapes = jax.eval_shape(lambda a=api, c=cfg: a.init(jax.random.key(0), c))
        n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))
        assert abs(n - target) / target < 0.3, (arch, n, target)
