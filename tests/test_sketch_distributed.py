"""Distributed Alg. 1 / Alg. 2 correctness + collective-schedule checks.

Each test runs in a subprocess with 8 fake XLA devices (the main pytest
process keeps 1 device per the dry-run isolation rule).  Assertions are
printed from the subprocess and re-raised here on failure.
"""
from dist_helper import run_distributed

COMMON = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import (rand_matmul, rand_matmul_communicating,
                        sketch_reference, make_grid_mesh,
                        nystrom_no_redist, nystrom_redist, nystrom_general,
                        nystrom_reference, relative_error, reconstruct)
from repro.core.sketch import input_sharding, output_sharding
from repro.roofline.hlo import collective_bytes_of
assert len(jax.devices()) == 8
"""


def test_alg1_matches_reference_on_all_grids():
    run_distributed(COMMON + r"""
seed, n1, n2, r = 11, 16, 48, 8
A = jax.random.normal(jax.random.key(1), (n1, n2))
ref = sketch_reference(A, seed, r)
for shape in [(8,1,1), (2,2,2), (1,4,2), (1,2,4), (4,2,1), (2,4,1), (1,8,1), (1,1,8)]:
    mesh = make_grid_mesh(*shape)
    Ash = jax.device_put(A, input_sharding(mesh))
    B = rand_matmul(Ash, seed, r, mesh)
    assert B.shape == ref.shape, (shape, B.shape)
    err = float(jnp.abs(B - ref).max())
    assert err < 1e-4, (shape, err)
    assert not bool(jnp.any(jnp.isnan(B)))
print("OK")
""")


def test_alg1_zero_communication_when_P_le_n1():
    """Regime 1 (P <= n1): the paper proves W = 0; the compiled HLO for the
    (P,1,1) grid must contain zero collective bytes."""
    run_distributed(COMMON + r"""
seed, n1, n2, r = 3, 16, 32, 8
mesh = make_grid_mesh(8, 1, 1)
A = jax.device_put(jax.random.normal(jax.random.key(0), (n1, n2)),
                   input_sharding(mesh))
fn = jax.jit(lambda a: rand_matmul(a, seed, r, mesh))
comp = fn.lower(A).compile()
cb = collective_bytes_of(comp.as_text())
assert cb.total == 0, f"expected zero collective bytes, got {cb}"
print("OK")
""")


def test_alg1_collective_schedule_matches_paper():
    """2x2x2 grid: exactly one all-gather (over p3) and one reduce-scatter
    (over p2), with byte volumes matching the paper's cost model."""
    run_distributed(COMMON + r"""
seed, n1, n2, r = 3, 8, 64, 16
p1, p2, p3 = 2, 2, 2
mesh = make_grid_mesh(p1, p2, p3)
A = jax.device_put(jax.random.normal(jax.random.key(0), (n1, n2)),
                   input_sharding(mesh))
fn = jax.jit(lambda a: rand_matmul(a, seed, r, mesh))
comp = fn.lower(A).compile()
cb = collective_bytes_of(comp.as_text())
assert cb.counts.get("all-gather", 0) == 1, cb.counts
assert cb.counts.get("reduce-scatter", 0) == 1, cb.counts
# paper cost model, in words (f32 = 4 bytes), per-processor operand sizes
# (the parser reports per-device bytes):
# AG operand per proc: n1/p1 * n2/(p2 p3); RS operand per proc: n1/p1 * r/p3
ag_bytes = (n1 // p1) * (n2 // (p2 * p3)) * 4
rs_bytes = (n1 // p1) * (r // p3) * 4
assert cb.by_kind["all-gather"] == ag_bytes, cb.by_kind
assert cb.by_kind["reduce-scatter"] == rs_bytes, cb.by_kind
assert cb.num_partitions == 8
print("OK")
""")


def test_alg1_beats_communicating_omega():
    """Fig. 3: regenerating Omega must move strictly fewer bytes than
    all-gathering it."""
    run_distributed(COMMON + r"""
seed, n1, n2, r = 3, 16, 64, 8
mesh = make_grid_mesh(2, 2, 2)
A = jax.device_put(jax.random.normal(jax.random.key(0), (n1, n2)),
                   input_sharding(mesh))
gen = jax.jit(lambda a: rand_matmul(a, seed, r, mesh)).lower(A).compile()
com = jax.jit(lambda a: rand_matmul_communicating(a, seed, r, mesh)).lower(A).compile()
gb = collective_bytes_of(gen.as_text()).total
cbt = collective_bytes_of(com.as_text()).total
assert gb < cbt, (gb, cbt)
# results agree
Bg = rand_matmul(A, seed, r, mesh)
Bc = rand_matmul_communicating(A, seed, r, mesh)
assert float(jnp.abs(Bg - Bc).max()) < 1e-4
print("OK")
""")


def test_nystrom_variants_match_reference():
    run_distributed(COMMON + r"""
seed, n, r = 5, 64, 16
S = jax.random.normal(jax.random.key(2), (n, n)); S = S @ S.T / n
Bref, Cref = nystrom_reference(S, seed, r)
mesh = Mesh(np.asarray(jax.devices()), ("x",))
Ssh = jax.device_put(S, NamedSharding(mesh, P("x", None)))
for fn, name in [(nystrom_no_redist, "no_redist"), (nystrom_redist, "redist")]:
    B, C = fn(Ssh, seed, r, mesh)
    assert float(jnp.abs(B - Bref).max()) < 1e-4, name
    assert float(jnp.abs(C - Cref).max()) < 1e-3, name
# C must be (numerically) symmetric: C = Omega^T A Omega with symmetric A
B, C = nystrom_no_redist(Ssh, seed, r, mesh)
assert float(jnp.abs(C - C.T).max()) < 1e-3
print("OK")
""")


def test_nystrom_general_two_grid():
    run_distributed(COMMON + r"""
seed, n, r = 5, 64, 16
S = jax.random.normal(jax.random.key(2), (n, n)); S = S @ S.T / n
Bref, Cref = nystrom_reference(S, seed, r)
for shape in [(2,2,2), (8,1,1), (2,4,1)]:
    mesh = make_grid_mesh(*shape)
    Ssh = jax.device_put(S, input_sharding(mesh))
    B, C = nystrom_general(Ssh, seed, r, mesh)
    assert float(jnp.abs(B - Bref).max()) < 1e-4, shape
    assert float(jnp.abs(C - Cref).max()) < 1e-3, shape
print("OK")
""")


def test_nystrom_comm_crossover():
    """Fig. 7: Redist comm is O(nr/P), No-Redist is O(r^2); with P=8 and
    n/r = 4 < P, Redist must move fewer bytes."""
    run_distributed(COMMON + r"""
seed, n, r = 5, 128, 32   # n/r = 4 < P = 8
mesh = Mesh(np.asarray(jax.devices()), ("x",))
S = jax.random.normal(jax.random.key(2), (n, n)); S = S @ S.T / n
Ssh = jax.device_put(S, NamedSharding(mesh, P("x", None)))
nr = jax.jit(lambda a: nystrom_no_redist(a, seed, r, mesh)).lower(Ssh).compile()
rd = jax.jit(lambda a: nystrom_redist(a, seed, r, mesh)).lower(Ssh).compile()
b_nr = collective_bytes_of(nr.as_text()).total
b_rd = collective_bytes_of(rd.as_text()).total
assert b_rd < b_nr, (b_rd, b_nr)
# and the reverse regime: n/r large => no_redist cheaper
n2_, r2_ = 512, 8   # n/r = 64 > P
S2 = jax.random.normal(jax.random.key(3), (n2_, n2_)); S2 = S2 @ S2.T / n2_
S2sh = jax.device_put(S2, NamedSharding(mesh, P("x", None)))
nr2 = jax.jit(lambda a: nystrom_no_redist(a, seed, r2_, mesh)).lower(S2sh).compile()
rd2 = jax.jit(lambda a: nystrom_redist(a, seed, r2_, mesh)).lower(S2sh).compile()
assert collective_bytes_of(nr2.as_text()).total < collective_bytes_of(rd2.as_text()).total
print("OK")
""")


def test_nystrom_reconstruction_error_low_rank():
    """Tab. 2 analogue: a rank-k PSD matrix is approximated to ~machine
    precision once r exceeds k."""
    run_distributed(COMMON + r"""
seed, n, k, r = 7, 128, 8, 32
X = jax.random.normal(jax.random.key(1), (n, k))
S = X @ X.T          # exact rank k
mesh = Mesh(np.asarray(jax.devices()), ("x",))
Ssh = jax.device_put(S, NamedSharding(mesh, P("x", None)))
B, C = nystrom_no_redist(Ssh, seed, r, mesh)
err = float(relative_error(S, B, C))
assert err < 1e-4, err
print("OK")
""")
