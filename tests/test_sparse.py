"""The sparse sketch family (PR 10): CountSketch + coordinated row
sampling as first-class Omega kinds.

Pins, in order:
  * bitwise tile/offset/gather invariance of the per-row Philox draws
    (same contract as the dense Irwin-Hall generator — a draw depends
    only on (seed, salt, global row index), never on the tiling);
  * the O(nnz) scatter apply against the materialized-Omega GEMM;
  * sparse streaming ingest: `update_rows_sparse` vs the dense row-block
    path (bitwise for sparse kinds), nnz-bucket pad invariance (bitwise),
    service lane-vs-solo (bitwise), and the `service.update[sparse]`
    ledger site pricing the COO payload at (indices + values) words;
  * the planner's dense-vs-sparse choice: sparse wins the high-sparsity
    regime, dense wins dense inputs, exactly one crossover in between,
    honest notes on the loser;
  * eager kind validation at every public entry point (a typo'd kind
    fails with the valid list BEFORE tracing);
  * the `snap_bucket` over-tall-lane regression: heights above the top
    planner edge snap to pow2 instead of compiling one program per
    distinct height.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import nystrom as NY
from repro.core.kinds import DENSE_KINDS, SPARSE_KINDS, VALID_KINDS
from repro.core.sketch import (omega_tile, rand_matmul, rand_matmul_auto,
                               sketch_reference, sketch_sparse_apply,
                               sparse_omega_map, sparse_omega_rows,
                               validate_kind)
from repro.plan import model as M
from repro.plan.planner import plan_sketch, plan_stream
from repro.stream import (SketchService, SparseRows, StreamConfig,
                          StreamingSketch)
from repro.stream.state import pow2_bucket, snap_bucket

SEED = 7


# ---------------------------------------------------------------------------
# draw invariance
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    kind=st.sampled_from(SPARSE_KINDS),
    row0=st.integers(0, 40),
    col0=st.integers(0, 12),
    rows=st.integers(1, 24),
    cols=st.integers(1, 4),
)
def test_sparse_tile_never_shifts_draws(kind, row0, col0, rows, cols):
    """Any (row0, col0, rows, cols) window is the same bits as the slice
    of one full-matrix generation — the tile decomposition of Alg. 1."""
    n, r = 64, 16
    full = np.asarray(omega_tile(SEED, 0, 0, n, r, kind))
    tile = np.asarray(omega_tile(SEED, row0, col0, rows, cols, kind,
                                 r_total=r, n_total=n))
    np.testing.assert_array_equal(
        tile, full[row0:row0 + rows, col0:col0 + cols])


@pytest.mark.parametrize("kind", SPARSE_KINDS)
def test_sparse_map_matches_materialized_tile(kind):
    """The O(n) (bucket, value) map IS the dense tile, scattered."""
    n, r = 96, 8
    bucket, value = sparse_omega_map(SEED, n, r, kind)
    dense = np.zeros((n, r), np.float32)
    dense[np.arange(n), np.asarray(bucket)] = np.asarray(value)
    np.testing.assert_array_equal(
        dense, np.asarray(omega_tile(SEED, 0, 0, n, r, kind)))


@pytest.mark.parametrize("kind", SPARSE_KINDS)
def test_sparse_gather_draws_context_invariant(kind):
    """Gathered draws at arbitrary (repeated, unordered) indices equal
    the full map's entries — a draw sees only its global row index."""
    n, r = 64, 16
    bucket, value = sparse_omega_map(SEED, n, r, kind)
    g = np.asarray([3, 3, 63, 0, 17, 3, 41], np.int32)
    gb, gv = sparse_omega_rows(SEED, g, r, kind, n_total=n)
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(bucket)[g])
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(value)[g])


def test_sparse_structure():
    """One nonzero per CountSketch row, sign ±1; rowsample keeps a row
    with p = r/n and scales survivors by 1/sqrt(p) (unbiased)."""
    n, r = 2048, 32
    cs = np.asarray(omega_tile(SEED, 0, 0, n, r, "countsketch"))
    assert ((cs != 0).sum(axis=1) == 1).all()
    assert set(np.unique(cs)) == {-1.0, 0.0, 1.0}
    rs = np.asarray(omega_tile(SEED, 0, 0, n, r, "rowsample"))
    nnz_rows = (rs != 0).any(axis=1)
    p = r / n
    assert abs(nnz_rows.mean() - p) < 4 * np.sqrt(p * (1 - p) / n)
    vals = np.unique(np.abs(rs[rs != 0]))
    np.testing.assert_allclose(vals, [1.0 / np.sqrt(np.float32(p))],
                               rtol=1e-6)
    # E[Omega Omega^T] diag ~ 1: kept rows contribute exactly 1/p
    diag = np.einsum("ij,ij->i", rs, rs)
    np.testing.assert_allclose(np.unique(diag[nnz_rows]), [1.0 / p],
                               rtol=1e-5)


def test_sparse_salt_streams_differ():
    """Omega (salt 0) and Psi (salt 1) draws are independent streams."""
    n, r = 512, 16
    b0, _ = sparse_omega_map(SEED, n, r, "countsketch", salt=0)
    b1, _ = sparse_omega_map(SEED, n, r, "countsketch", salt=1)
    assert (np.asarray(b0) != np.asarray(b1)).any()


# ---------------------------------------------------------------------------
# O(nnz) apply
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", SPARSE_KINDS)
def test_sketch_sparse_apply_matches_gemm(kind):
    n, r = 128, 16
    A = np.random.default_rng(0).standard_normal((24, n)).astype(np.float32)
    got = np.asarray(sketch_sparse_apply(jnp.asarray(A), SEED, r, kind=kind))
    want = A @ np.asarray(omega_tile(SEED, 0, 0, n, r, kind))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_sparse_rows_roundtrip():
    H = np.zeros((6, 10), np.float32)
    H[1, 3] = 2.0
    H[5, 9] = -1.5
    sp = SparseRows.from_dense(H)
    assert sp.nnz == 2
    np.testing.assert_array_equal(sp.to_dense(), H)
    row, col, val = sp.padded(8)
    assert (row[2:] == 6).all() and (col[2:] == 10).all()
    assert (val[2:] == 0).all()
    with pytest.raises(ValueError):
        sp.padded(1)


# ---------------------------------------------------------------------------
# streaming ingest
# ---------------------------------------------------------------------------

def _sparse_slab(k, n2, nnz, rng):
    H = np.zeros((k, n2), np.float32)
    idx = rng.choice(k * n2, size=nnz, replace=False)
    H.flat[idx] = rng.standard_normal(nnz).astype(np.float32)
    return H


@pytest.mark.parametrize("kind", SPARSE_KINDS)
def test_update_rows_sparse_bitwise_vs_dense(kind):
    """For sparse Omega kinds the COO path folds the exact same scatter
    terms as the densified slab — bitwise."""
    cfg = StreamConfig(n1=48, n2=64, r=8, seed=SEED, kind=kind)
    rng = np.random.default_rng(2)
    H1 = _sparse_slab(16, 64, 41, rng)
    H2 = _sparse_slab(16, 64, 7, rng)
    a = StreamingSketch(cfg, backend="xla")
    a.update_rows_sparse(0, SparseRows.from_dense(H1))
    a.update_rows_sparse(32, SparseRows.from_dense(H2))
    b = StreamingSketch(cfg, backend="xla")
    b.update_rows_sparse(0, SparseRows.from_dense(H1))
    b.update_rows_sparse(32, SparseRows.from_dense(H2))
    np.testing.assert_array_equal(np.asarray(a.Y), np.asarray(b.Y))
    np.testing.assert_array_equal(np.asarray(a.W), np.asarray(b.W))
    # and equals the dense row-block path to fp32 tolerance (the scatter
    # accumulation order is the only difference; for countsketch each
    # (row, bucket) cell takes contributions from disjoint entries so the
    # sums agree to the bit in practice)
    d = StreamingSketch(cfg, backend="xla")
    d.update_rows(0, H1)
    d.update_rows(32, H2)
    np.testing.assert_allclose(np.asarray(a.Y), np.asarray(d.Y), atol=1e-5)
    np.testing.assert_allclose(np.asarray(a.W), np.asarray(d.W), atol=1e-5)


@pytest.mark.parametrize("kind", ["countsketch", "normal"])
def test_update_rows_sparse_pad_bucket_bitwise(kind):
    """The same payload padded into a LARGER nnz bucket folds identical
    bits: pads are routed to sacrificial rows/columns, never masked-by-
    value (a 0.0 add could still flip a -0.0)."""
    from repro.stream.state import _local_sig, local_sparse_prog
    cfg = StreamConfig(n1=32, n2=48, r=8, seed=SEED, kind=kind)
    sp = SparseRows.from_dense(
        _sparse_slab(8, 48, 19, np.random.default_rng(3)))
    a = StreamingSketch(cfg, backend="xla")
    a.update_rows_sparse(8, sp)                      # bucket = pow2(19) = 32
    row, col, val = sp.padded(256)                   # force a bigger bucket
    fn = local_sparse_prog(_local_sig(cfg), 8, 256)
    b = StreamingSketch(cfg, backend="xla")
    Y, W = fn(b.Y, b.W, jnp.asarray(row), jnp.asarray(col),
              jnp.asarray(val, cfg.dtype), b._keys, jnp.int32(8))
    np.testing.assert_array_equal(np.asarray(a.Y), np.asarray(Y))
    np.testing.assert_array_equal(np.asarray(a.W), np.asarray(W))


@pytest.mark.parametrize("kind", ["countsketch", "rowsample", "normal"])
def test_service_sparse_lane_vs_solo_bitwise(kind):
    """update_sparse_batch lane i == update_rows_sparse on stream i alone,
    bit for bit, including heterogeneous per-lane nnz."""
    rng = np.random.default_rng(4)
    seeds = (11, 99, 5)
    nnzs = (13, 29, 1)
    svc = SketchService()
    sids = [svc.open(StreamConfig(n1=32, n2=48, r=8, seed=s, kind=kind))
            for s in seeds]
    Hs = [_sparse_slab(8, 48, nnz, rng) for nnz in nnzs]
    sps = [SparseRows.from_dense(H) for H in Hs]
    row0s = [0, 16, 24]
    svc.update_sparse_batch(sids, sps, row0=row0s)
    for sid, sp, r0, s in zip(sids, sps, row0s, seeds):
        solo = StreamingSketch(
            StreamConfig(n1=32, n2=48, r=8, seed=s, kind=kind),
            backend="xla")
        solo.update_rows_sparse(r0, sp)
        st = svc._streams[sid]
        np.testing.assert_array_equal(np.asarray(st.Y), np.asarray(solo.Y))
        np.testing.assert_array_equal(np.asarray(st.W), np.asarray(solo.W))


def test_service_sparse_ledger_prices_coo_payload():
    """The service.update[sparse] site predicts (indices + values) =
    2·nnz words — the sparse communication model, not dense k·n2 tiles."""
    from repro.obs import ledger as OL
    led = OL.install_ledger()
    try:
        svc = SketchService()
        sid = svc.open(StreamConfig(n1=32, n2=48, r=8, seed=SEED,
                                    kind="countsketch"))
        sp = SparseRows.from_dense(
            _sparse_slab(8, 48, 21, np.random.default_rng(5)))
        svc.update_sparse(sid, sp, row0=0)
        sites = [s for s in led.sites()
                 if s.name == "service.update[sparse]"]
        assert len(sites) == 1
        site = sites[0]
        assert site.calls == 1
        assert site.predicted_words == M.sparse_payload_words(21) == 42.0
        assert site.lower_bound_words == 21.0
    finally:
        OL.uninstall_ledger()


def test_sparse_rejected_on_distributed_service():
    from repro.core.sketch import make_grid_mesh
    from repro.stream import ShardedStreamingSketch
    cfg = StreamConfig(n1=32, n2=48, r=8, kind="countsketch")
    with pytest.raises(NotImplementedError, match="ROADMAP item 3"):
        SketchService(mesh=make_grid_mesh(1, 1, 1)).open(cfg)
    with pytest.raises(NotImplementedError, match="ROADMAP item 3"):
        ShardedStreamingSketch(cfg, make_grid_mesh(1, 1, 1))
    with pytest.raises(NotImplementedError, match="local-mode only"):
        SketchService(mesh=make_grid_mesh(1, 1, 1)).update_sparse(
            0, SparseRows.from_dense(np.zeros((1, 1), np.float32)))


# ---------------------------------------------------------------------------
# planner: dense vs sparse
# ---------------------------------------------------------------------------

def test_plan_sketch_picks_sparse_then_dense():
    n1 = n2 = 1024
    r = 8
    lo = plan_sketch(n1, n2, r, P=1, nnz=int(0.001 * n1 * n2))
    assert lo.variant == "local_sparse"
    assert lo.kind == "countsketch"     # family substitution is explicit
    hi = plan_sketch(n1, n2, r, P=1, nnz=n1 * n2)
    assert hi.variant != "local_sparse"
    assert hi.kind == "normal"
    # the losing sparse candidate says who beat it and at what density
    note = next(c.note for c in hi.candidates
                if c.variant == "local_sparse")
    assert "dense wins" in note
    # no nnz declared -> candidate list is the pre-PR-10 dense race
    assert all("sparse" not in c.variant
               for c in plan_sketch(n1, n2, r, P=1).candidates)


@settings(max_examples=8, deadline=None)
@given(r=st.sampled_from([4, 8, 16]),
       n=st.sampled_from([256, 512, 1024]))
def test_plan_sketch_single_crossover(r, n):
    """Scanning density upward flips the choice sparse -> dense at most
    once (the cost model is monotone in nnz)."""
    choices = []
    for d in (0.0005, 0.002, 0.01, 0.05, 0.2, 0.5, 0.8, 1.0):
        p = plan_sketch(n, n, r, P=1, nnz=max(1, int(d * n * n)))
        choices.append(p.variant == "local_sparse")
    flips = sum(1 for a, b in zip(choices, choices[1:]) if a != b)
    assert flips <= 1
    assert not choices[-1] or choices[0]   # never dense-then-sparse


@settings(max_examples=10, deadline=None)
@given(P=st.sampled_from([2, 4, 8, 16]),
       d=st.sampled_from([0.001, 0.1, 1.0]))
def test_dense_fallback_never_undercuts_thm2_floor(P, d):
    """Entering the sparse race never lets the DENSE candidates dip below
    the Theorem-2 floor: the sparse family prices a different payload,
    but the dense fallback's words/proc still respect the bound."""
    from repro.core.lower_bounds import matmul_lower_bound
    n1 = n2 = 512
    r = 8
    p = plan_sketch(n1, n2, r, P=P, nnz=max(1, int(d * n1 * n2)))
    floor = matmul_lower_bound(n1, n2, r, P)
    for c in p.candidates:
        if "sparse" not in c.variant and c.executable:
            assert c.cost.words >= floor - 1e-6, (c.variant, c.cost.words)


def test_plan_sketch_sparse_kind_kept():
    p = plan_sketch(512, 512, 8, P=1, kind="rowsample", nnz=100)
    assert p.variant == "local_sparse" and p.kind == "rowsample"


def test_plan_sketch_distributed_sparse_is_analytic():
    p = plan_sketch(1024, 1024, 8, P=8, nnz=1000)
    assert p.variant != "alg1_sparse"          # not executable yet
    c = next(c for c in p.candidates if c.variant == "alg1_sparse")
    assert not c.executable and "ROADMAP item 3" in c.note
    # the sparse formula: COO panel over p3 + dense B reduce-scatter
    p1, p2, p3 = c.grid
    want = ((1.0 - 1.0 / p3) * M.sparse_payload_words(1000) / (p1 * p2)
            + (1.0 - 1.0 / p2) * 1024 * 8 / (p1 * p3))
    assert c.cost.words == pytest.approx(want)


def test_plan_stream_sparse_executes():
    n1, n2, r = 64, 128, 8
    A = _sparse_slab(n1, n2, 200, np.random.default_rng(6))
    p = plan_stream(n1, n2, r, P=1, chunk_rows=16, corange=True, nnz=200)
    assert p.variant == "stream_sparse" and p.kind == "countsketch"
    st = p.execute(A, seed=SEED)
    cfg = StreamConfig(n1=n1, n2=n2, r=r, seed=SEED, kind=p.kind,
                       corange=True)
    ref = StreamingSketch(cfg, backend="xla")
    for row0 in range(0, n1, 16):
        ref.update_rows_sparse(
            row0, SparseRows.from_dense(A[row0:row0 + 16]))
    np.testing.assert_array_equal(np.asarray(st.Y), np.asarray(ref.Y))
    # dense input keeps the dense streaming plan
    pd = plan_stream(n1, n2, r, P=1, chunk_rows=16, nnz=n1 * n2)
    assert pd.variant != "stream_sparse"


def test_explain_prints_sparse_choice():
    from repro.plan.explain import explain
    txt = explain(plan_sketch(1024, 1024, 8, P=1, nnz=10_000))
    assert "local_sparse" in txt
    assert "indices+values" in txt and "2*nnz" in txt


# ---------------------------------------------------------------------------
# eager kind validation, one test per entry point
# ---------------------------------------------------------------------------

def test_validate_kind_lists_valid_kinds():
    with pytest.raises(ValueError, match="rowsample"):
        validate_kind("bogus")
    for k in VALID_KINDS:
        validate_kind(k)


def test_rand_matmul_rejects_bad_kind_eagerly():
    # mesh=None: the kind check fires before any mesh/device work
    with pytest.raises(ValueError, match="unknown omega kind"):
        rand_matmul(np.zeros((4, 4), np.float32), 0, 2, None, kind="bogus")
    with pytest.raises(NotImplementedError, match="ROADMAP item 3"):
        rand_matmul(np.zeros((4, 4), np.float32), 0, 2, None,
                    kind="countsketch")


def test_rand_matmul_auto_rejects_bad_kind_eagerly():
    with pytest.raises(ValueError, match="unknown omega kind"):
        rand_matmul_auto(np.zeros((4, 4), np.float32), 0, 2, kind="bogus")


def test_sketch_reference_rejects_bad_kind_eagerly():
    with pytest.raises(ValueError, match="unknown omega kind"):
        sketch_reference(np.zeros((4, 4), np.float32), 0, 2, kind="bogus")


@pytest.mark.parametrize("entry", [
    NY.nystrom_no_redist, NY.nystrom_redist,
    NY.nystrom_second_stage_no_redist, NY.nystrom_second_stage_redist,
])
def test_nystrom_1d_entry_points_reject_bad_kind_eagerly(entry):
    A = np.zeros((8, 8), np.float32)
    with pytest.raises(ValueError, match="unknown omega kind"):
        entry(A, 0, 4, None, kind="bogus")
    with pytest.raises(NotImplementedError, match="ROADMAP item 3"):
        entry(A, 0, 4, None, kind="countsketch")


def test_nystrom_two_grid_rejects_bad_kind_eagerly():
    A = np.zeros((8, 8), np.float32)
    with pytest.raises(ValueError, match="unknown omega kind"):
        NY.nystrom_two_grid(A, 0, 4, p=(1, 1, 1), q=(1, 1, 1), kind="bogus")
    with pytest.raises(NotImplementedError, match="ROADMAP item 3"):
        NY.nystrom_two_grid(A, 0, 4, p=(1, 1, 1), q=(1, 1, 1),
                            kind="rowsample")


def test_nystrom_auto_rejects_bad_kind_eagerly():
    A = np.zeros((8, 8), np.float32)
    with pytest.raises(ValueError, match="unknown omega kind"):
        NY.nystrom_auto(A, 0, 4, kind="bogus")
    with pytest.raises(NotImplementedError, match="ROADMAP item 3"):
        NY.nystrom_auto(A, 0, 4, kind="countsketch")


def test_nystrom_reference_accepts_sparse_kinds():
    """The single-device reference materializes the tile, so the sparse
    family works there today — only the shard_map bodies are deferred."""
    A = np.eye(16, dtype=np.float32)
    for kind in SPARSE_KINDS:
        B, C = NY.nystrom_reference(A, SEED, 4, kind=kind)
        om = np.asarray(omega_tile(SEED, 0, 0, 16, 4, kind))
        np.testing.assert_allclose(np.asarray(B), om, atol=1e-6)
    with pytest.raises(ValueError, match="unknown omega kind"):
        NY.nystrom_reference(A, SEED, 4, kind="bogus")


def test_stream_config_validate_rejects_bad_kind():
    with pytest.raises(ValueError, match="unknown omega kind"):
        StreamConfig(n1=8, n2=8, r=2, kind="bogus").validate()
    # sparse kinds are VALID stream configs (local streaming supports them)
    StreamConfig(n1=8, n2=8, r=2, kind="countsketch").validate()


# ---------------------------------------------------------------------------
# snap_bucket over-tall regression
# ---------------------------------------------------------------------------

def test_snap_bucket_overtall_snaps_to_pow2():
    edges = [4, 8]
    assert snap_bucket(3, edges) == 4
    assert snap_bucket(8, edges) == 8
    # taller than every edge: pow2 fallback, NOT the exact height
    for k in (9, 10, 11, 13):
        assert snap_bucket(k, edges) == pow2_bucket(k) == 16


def test_snap_bucket_overtall_lanes_share_one_program():
    """Regression: over-tall ragged lanes (k above the top bucket edge)
    used to compile one program PER DISTINCT HEIGHT; now they share the
    pow2 bucket.  Counted against the service's compiled-program cache."""
    svc = SketchService()
    cfgs = [StreamConfig(n1=32, n2=24, r=4, seed=s) for s in range(3)]
    sids = [svc.open(c) for c in cfgs]
    rng = np.random.default_rng(8)
    items = [(sid, rng.standard_normal((k, 24)).astype(np.float32), 0)
             for sid, k in zip(sids, (9, 10, 11))]
    svc.update_ragged(items, bucket_edges=[4, 8])
    ragged_keys = {k for k in svc._fns if k[-1] == "ragged"}
    assert len(ragged_keys) == 1          # one bucket: kb = pow2 = 16
    assert next(iter(ragged_keys))[1] == 16
    # and the fold is still lane-exact vs solo updates
    for (sid, H, row0), cfg in zip(items, cfgs):
        solo = StreamingSketch(cfg, backend="xla")
        solo.update_rows(row0, H)
        np.testing.assert_array_equal(
            np.asarray(svc.sketch(sid)), np.asarray(solo.Y))


def test_sparse_kinds_listed():
    assert set(SPARSE_KINDS) == {"countsketch", "rowsample"}
    assert set(DENSE_KINDS) == {"normal", "uniform", "rademacher"}
