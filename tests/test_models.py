"""Model-level consistency: step-by-step decode must reproduce the
teacher-forced forward logits (validates KV caches, ring buffers, SSM state
carry, shared-block caches, cross-attention caches)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import get_api
from repro.models import transformer, whisper as whisper_mod

B, S = 2, 16


def _full_logits_dense(params, cfg, tokens):
    h, _ = transformer.lm_hidden(params, cfg, tokens, remat=False)
    W = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", h, W)
    from repro.models.common import softcap
    return softcap(logits, cfg.final_softcap)


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma2-2b",
                                  "granite-moe-1b-a400m"])
def test_decode_matches_forward_dense(arch):
    overrides = {}
    if arch == "granite-moe-1b-a400m":
        overrides["capacity_factor"] = 8.0   # avoid token drops in the test
    cfg = get_config(arch).reduced(**overrides)
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)

    ref = _full_logits_dense(params, cfg, tokens)

    cache = api.init_cache(cfg, B, S)
    step = jax.jit(lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos))
    outs = []
    for t in range(S):
        logits, cache = step(params, tokens[:, t:t + 1], cache, jnp.int32(t))
        outs.append(logits)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_windowed_ring_cache_matches_forward():
    """danube (SWA): window smaller than the sequence -> ring buffer path."""
    cfg = get_config("h2o-danube-3-4b").reduced(window=6)
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    ref = _full_logits_dense(params, cfg, tokens)
    cache = api.init_cache(cfg, B, S)          # ring length = window
    assert cache[0]["k"].shape[1] == 6
    step = jax.jit(lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos))
    outs = []
    for t in range(S):
        logits, cache = step(params, tokens[:, t:t + 1], cache, jnp.int32(t))
        outs.append(logits)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_prefill_then_decode_matches_forward():
    cfg = get_config("llama3-8b").reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    ref = _full_logits_dense(params, cfg, tokens)

    half = S // 2
    logits_p, cache = transformer.prefill(params, cfg, tokens[:, :half],
                                          remat=False, max_len=S)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0], np.float32),
                               np.asarray(ref[:, half - 1], np.float32),
                               rtol=2e-3, atol=2e-3)
    step = jax.jit(lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos))
    for t in range(half, S):
        logits, cache = step(params, tokens[:, t:t + 1], cache, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   np.asarray(ref[:, t], np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_prefill_ring_handoff_windowed():
    """Prefill a windowed model then decode — ring slot arithmetic must
    line up across the handoff, including S % window != 0."""
    cfg = get_config("h2o-danube-3-4b").reduced(window=6)
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    ref = _full_logits_dense(params, cfg, tokens)
    half = 9                                    # 9 % 6 != 0
    _, cache = transformer.prefill(params, cfg, tokens[:, :half],
                                   remat=False, max_len=S)
    step = jax.jit(lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos))
    for t in range(half, S):
        logits, cache = step(params, tokens[:, t:t + 1], cache, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   np.asarray(ref[:, t], np.float32),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-1.2b"])
def test_decode_matches_forward_ssm_hybrid(arch):
    cfg = get_config(arch).reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)

    if arch == "falcon-mamba-7b":
        from repro.models.mamba_lm import mamba_lm_hidden
        h = mamba_lm_hidden(params, cfg, tokens, remat=False)
    else:
        from repro.models.zamba import hybrid_hidden
        h = hybrid_hidden(params, cfg, tokens, remat=False)
    ref = jnp.einsum("bsd,vd->bsv", h, params["lm_head"])

    cache = api.init_cache(cfg, B, S)
    step = jax.jit(lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos))
    outs = []
    for t in range(S):
        logits, cache = step(params, tokens[:, t:t + 1], cache, jnp.int32(t))
        outs.append(logits)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-3, atol=5e-3)


def test_whisper_decode_matches_teacher_forced():
    cfg = get_config("whisper-tiny").reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    frames = jax.random.normal(jax.random.key(2), (B, cfg.enc_seq, cfg.d_model))
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)

    enc = whisper_mod.encode(params, cfg, frames, remat=False)
    h = whisper_mod.decode_hidden(params, cfg, tokens, enc, remat=False)
    ref = jnp.einsum("bsd,vd->bsv", h, params["embed"])

    cache = api.init_cache(cfg, B, S)
    ck, cv = whisper_mod.encdec_prepare_cross(params, cfg, enc)
    cache = dict(cache, cross_k=ck, cross_v=cv)
    step = jax.jit(lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos))
    outs = []
    for t in range(S):
        logits, cache = step(params, tokens[:, t:t + 1], cache, jnp.int32(t))
        outs.append(logits)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_nystrom_attention_approximates_exact():
    """Landmark attention should beat a trivial baseline at approximating
    exact softmax attention on smooth inputs."""
    from repro.models.attention import attn_init, attention, nystrom_attention
    d, H, Hk, D = 32, 4, 4, 8
    S = 64
    params = attn_init(jax.random.key(0), d, H, Hk, D, jnp.float32)
    t = jnp.linspace(0, 4, S)
    x = jnp.stack([jnp.sin(t * (i + 1) / 4) for i in range(d)], -1)[None]
    exact = attention(params, x, n_heads=H, n_kv_heads=Hk, head_dim=D,
                      causal=False, use_rope=False)
    approx = nystrom_attention(params, x, n_heads=H, n_kv_heads=Hk,
                               head_dim=D, n_landmarks=16, use_rope=False)
    err = float(jnp.linalg.norm(exact - approx) / jnp.linalg.norm(exact))
    assert err < 0.35, err
    assert not bool(jnp.any(jnp.isnan(approx)))
