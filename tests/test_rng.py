"""Philox-4x32 correctness + the tile-decomposition-invariance property that
makes regeneration communication-free."""
import numpy as np
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import rng


# ---------------------------------------------------------------------------
# 16-bit-limb mulhilo vs native 64-bit reference
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(a=st.integers(0, 2**32 - 1), b=st.integers(0, 2**32 - 1))
def test_mulhilo32_matches_uint64_reference(a, b):
    hi, lo = rng._mulhilo32(jnp.uint32(a), jnp.uint32(b))
    prod = (a * b) & 0xFFFFFFFFFFFFFFFF
    assert int(lo) == prod & 0xFFFFFFFF
    assert int(hi) == prod >> 32


def test_mulhilo32_vectorized():
    an = np.arange(0, 2**32 - 1, 104729, dtype=np.uint64)
    bn = np.arange(1, 2**32, 99991, dtype=np.uint64)[: an.shape[0]]
    hi, lo = rng._mulhilo32(jnp.asarray(an.astype(np.uint32)),
                            jnp.asarray(bn.astype(np.uint32)))
    prod = an * bn
    np.testing.assert_array_equal(
        np.asarray(lo).astype(np.uint64), prod & np.uint64(0xFFFFFFFF))
    np.testing.assert_array_equal(
        np.asarray(hi).astype(np.uint64), prod >> np.uint64(32))


# ---------------------------------------------------------------------------
# Philox known-answer test (Random123 reference vectors)
# ---------------------------------------------------------------------------

def test_philox_4x32_10_known_answer():
    """Reference vectors from the Random123 distribution (kat_vectors):
    philox4x32-10 with counter=0, key=0 and all-ones inputs."""
    out = rng.philox_4x32(
        (jnp.uint32(0), jnp.uint32(0), jnp.uint32(0), jnp.uint32(0)),
        (jnp.uint32(0), jnp.uint32(0)))
    got = [int(x) for x in out]
    assert got == [0x6627E8D5, 0xE169C58D, 0xBC57AC4C, 0x9B00DBD8]

    out = rng.philox_4x32(
        tuple(jnp.uint32(0xFFFFFFFF) for _ in range(4)),
        (jnp.uint32(0xFFFFFFFF), jnp.uint32(0xFFFFFFFF)))
    got = [int(x) for x in out]
    assert got == [0x408F276D, 0x41C83B0E, 0xA20BC7C6, 0x6D5451FD]

    out = rng.philox_4x32(
        (jnp.uint32(0x243F6A88), jnp.uint32(0x85A308D3),
         jnp.uint32(0x13198A2E), jnp.uint32(0x03707344)),
        (jnp.uint32(0xA4093822), jnp.uint32(0x299F31D0)))
    got = [int(x) for x in out]
    assert got == [0xD16CFE09, 0x94FDCCEB, 0x5001E420, 0x24126EA1]


# ---------------------------------------------------------------------------
# Tile-decomposition invariance: the regenerate-don't-communicate invariant
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(2, 48), cols=st.integers(2, 48),
    r0=st.integers(0, 1000), c0=st.integers(0, 1000),
    seed=st.integers(0, 2**63 - 1),
)
def test_tile_decomposition_invariance(rows, cols, r0, c0, seed):
    k0 = jnp.uint32(seed & 0xFFFFFFFF)
    k1 = jnp.uint32(seed >> 32)
    full = rng.philox_normal_grid(k0, k1, jnp.uint32(r0), jnp.uint32(c0),
                                  rows, cols)
    # split into 4 quadrants generated independently
    rh, ch = rows // 2, cols // 2
    q00 = rng.philox_normal_grid(k0, k1, jnp.uint32(r0), jnp.uint32(c0), rh, ch)
    q01 = rng.philox_normal_grid(k0, k1, jnp.uint32(r0), jnp.uint32(c0 + ch),
                                 rh, cols - ch)
    q10 = rng.philox_normal_grid(k0, k1, jnp.uint32(r0 + rh), jnp.uint32(c0),
                                 rows - rh, ch)
    q11 = rng.philox_normal_grid(k0, k1, jnp.uint32(r0 + rh),
                                 jnp.uint32(c0 + ch), rows - rh, cols - ch)
    reassembled = jnp.block([[q00, q01], [q10, q11]])
    np.testing.assert_array_equal(np.asarray(full), np.asarray(reassembled))


def test_uniform_range_and_moments():
    u = rng.philox_uniform_grid(jnp.uint32(1), jnp.uint32(2),
                                jnp.uint32(0), jnp.uint32(0), 512, 512)
    u = np.asarray(u)
    assert u.min() >= 0.0 and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 5e-3
    assert abs(u.var() - 1 / 12) < 5e-3


def test_normal_moments_and_independence_across_salt():
    g1 = np.asarray(rng.philox_normal_grid(jnp.uint32(1), jnp.uint32(2),
                                           jnp.uint32(0), jnp.uint32(0),
                                           512, 512, salt=0))
    g2 = np.asarray(rng.philox_normal_grid(jnp.uint32(1), jnp.uint32(2),
                                           jnp.uint32(0), jnp.uint32(0),
                                           512, 512, salt=1))
    assert abs(g1.mean()) < 5e-3
    assert abs(g1.std() - 1.0) < 5e-3
    corr = np.corrcoef(g1.ravel(), g2.ravel())[0, 1]
    assert abs(corr) < 5e-3
    assert not np.array_equal(g1, g2)


def test_block_omega_matches_omega_full():
    key = jax.random.key(42)
    n2, r, p2, p3 = 24, 8, 3, 2
    full = rng.omega_full(key, n2, r, p2, p3)
    br, bc = n2 // p2, r // p3
    for j in range(p2):
        for k in range(p3):
            blk = rng.block_omega(key, j, k, br, bc, p3)
            np.testing.assert_array_equal(
                np.asarray(full[j * br:(j + 1) * br, k * bc:(k + 1) * bc]),
                np.asarray(blk))


def test_philox_omega_full_deterministic():
    a = rng.philox_omega_full(123, 32, 8)
    b = rng.philox_omega_full(123, 32, 8)
    c = rng.philox_omega_full(124, 32, 8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
