"""Run a python snippet in a subprocess with N fake XLA host devices.

The main pytest process must keep the default single CPU device (smoke tests
and benches see 1 device), so every multi-device test executes in its own
subprocess with XLA_FLAGS set before jax initializes.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_distributed(code: str, ndev: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"distributed subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout
