"""Distributed features on 8 fake devices (subprocess): sketched gradient
compression, GPipe pipeline over a mesh axis, elastic checkpoint restore,
parameter sharding rules."""

from dist_helper import run_distributed


def test_grad_compression_reduces_comm_and_converges():
    run_distributed(r"""
import jax, jax.numpy as jnp
import numpy as np
from repro.core.compat import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.parallel.grad_compress import (compress_and_allreduce,
    init_error_fb, comm_words_exact, comm_words_compressed)
from repro.roofline.hlo import collective_bytes_of

mesh = Mesh(np.asarray(jax.devices()), ("data",))
D, H = 64, 128
key = jax.random.key(0)
# low-rank target: rank-8 compression can represent the full gradient
U = jax.random.normal(key, (D, 4))
V = jax.random.normal(jax.random.fold_in(key, 1), (4, H))
W_true = U @ V / 2

def loss_fn(params, x):
    y = x @ W_true
    pred = x @ params["w"]
    return jnp.mean((pred - y) ** 2)

params = {"w": jnp.zeros((D, H))}
from repro.parallel.grad_compress import local_fb, stack_fb
fb = init_error_fb(params, rank=8, min_dim=16, world=8)  # per-worker state

def step(params, fb, x, t):
    g = jax.grad(loss_fn)(params, x)
    g, fb_l = compress_and_allreduce(g, local_fb(fb), step=t, rank=8,
                                     min_dim=16, axis_name="data")
    params = jax.tree_util.tree_map(lambda p, gg: p - 20.0 * gg, params, g)
    return params, stack_fb(fb_l)

sfn = shard_map(step, mesh=mesh,
                    in_specs=(P(), P("data"), P("data"), P()),
                    out_specs=(P(), P("data")), check_vma=False)
sfn = jax.jit(sfn)

# comm volume: compressed HLO must move fewer collective bytes than psum
x0 = jax.random.normal(jax.random.key(1), (16, D))
comp = sfn.lower(params, fb, x0, jnp.int32(0)).compile()
cbytes = collective_bytes_of(comp.as_text()).total

def step_exact(params, x):
    g = jax.grad(loss_fn)(params, x)
    g = jax.lax.pmean(g, "data")
    return jax.tree_util.tree_map(lambda p, gg: p - 20.0 * gg, params, g)
exact = jax.jit(shard_map(step_exact, mesh=mesh,
                in_specs=(P(), P("data")), out_specs=P(),
                check_vma=False))
ebytes = collective_bytes_of(exact.lower(params, x0).compile().as_text()).total
assert cbytes < ebytes, (cbytes, ebytes)
print("comm bytes: compressed", cbytes, "exact", ebytes)

# words model agrees qualitatively
assert comm_words_compressed(params, 8, 16) < comm_words_exact(params)

# convergence with error feedback + trajectory match vs exact SGD
pe = {"w": jnp.zeros((D, H))}
losses = []
for t in range(300):
    x = jax.random.normal(jax.random.fold_in(key, t), (16 * 8, D))
    params, fb = sfn(params, fb, x, jnp.int32(t))
    pe = exact(pe, x)
    losses.append(float(loss_fn(params, x)))
assert losses[-1] < 0.01 * losses[0], (losses[0], losses[-1])
# rank-8 compression of a rank-4 problem reproduces exact DP-SGD
drift = float(jnp.abs(params["w"] - pe["w"]).max())
assert drift < 1e-3, drift
print("OK", losses[0], "->", losses[-1], "drift", drift)
""")


def test_compressed_equals_exact_at_full_rank():
    """With rank >= min(m, n), PowerSGD reconstructs the exact mean
    gradient (orthonormal basis spans the full row space)."""
    run_distributed(r"""
import jax, jax.numpy as jnp
import numpy as np
from repro.core.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P
from repro.parallel.grad_compress import compress_and_allreduce, init_error_fb

mesh = Mesh(np.asarray(jax.devices()), ("data",))
m, n = 24, 16
grads = {"w": jax.random.normal(jax.random.key(0), (8 * m, n))}

def body(g_local):
    fb = init_error_fb({"w": g_local}, rank=n, min_dim=4)
    out, _ = compress_and_allreduce({"w": g_local}, fb, step=jnp.int32(0),
                                    rank=n, min_dim=4, axis_name="data")
    exact = jax.lax.pmean(g_local, "data")
    return out["w"], exact

fn = shard_map(body, mesh=mesh, in_specs=P("data"),
                   out_specs=(P(), P()), check_vma=False)
approx, exact = fn(grads["w"].reshape(8, m, n).reshape(8 * m, n))
err = float(jnp.abs(approx - exact).max())
assert err < 1e-4, err
print("OK", err)
""")


def test_pipeline_matches_sequential():
    run_distributed(r"""
import jax, jax.numpy as jnp
import numpy as np
from repro.core.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P
from repro.parallel.pipeline import pipeline

n_stages, M, B, D = 4, 8, 2, 16
mesh = Mesh(np.asarray(jax.devices()[:n_stages]), ("pod",))
Ws = jax.random.normal(jax.random.key(0), (n_stages, D, D)) * 0.3
x = jax.random.normal(jax.random.key(1), (M, B, D))

def stage_fn(w, h):
    return jnp.tanh(h @ w)

def run_pipe(ws_local, xq):
    return pipeline(stage_fn, ws_local[0], xq, axis="pod",
                    n_stages=n_stages)

fn = shard_map(run_pipe, mesh=mesh,
                   in_specs=(P("pod"), P()), out_specs=P(),
                   check_vma=False)
out = fn(Ws, x)

# sequential reference
ref = x
for s in range(n_stages):
    ref = stage_fn(Ws[s], ref)
err = float(jnp.abs(out - ref).max())
assert err < 1e-5, err

# HLO contains collective-permute (the stage handoff)
txt = jax.jit(fn).lower(Ws, x).compile().as_text()
assert "collective-permute" in txt
print("OK", err)
""", ndev=8)


def test_param_shardings_rules():
    run_distributed(r"""
import jax, jax.numpy as jnp
import numpy as np
from repro.core.compat import shard_map
from repro.configs import get_config
from repro.models import get_api
from repro.parallel.sharding import param_shardings
from repro.launch.mesh import make_production_mesh

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("llama3-8b")
api = get_api(cfg)
shapes = jax.eval_shape(lambda: api.init(jax.random.key(0), cfg))
sh = param_shardings(shapes, mesh)

def find(path_frag):
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    for p, s in flat:
        name = "/".join(str(getattr(x, 'key', x)) for x in p)
        if path_frag in name:
            return name, s
    raise KeyError(path_frag)

n, s = find("wq")
assert s.spec[-1] == "model", (n, s.spec)
n, s = find("wo")
assert s.spec[-2] == "model", (n, s.spec)
n, s = find("embed")
assert s.spec[0] == "model", (n, s.spec)   # vocab-sharded
n, s = find("w_down")
assert s.spec[-2] == "model", (n, s.spec)

# MoE: experts sharded
cfg2 = get_config("dbrx-132b")
shapes2 = jax.eval_shape(lambda: get_api(cfg2).init(jax.random.key(0), cfg2))
sh2 = param_shardings(shapes2, mesh)
flat = jax.tree_util.tree_flatten_with_path(sh2)[0]
moe_gate = [s for p, s in flat
            if "moe" in "/".join(str(getattr(x, 'key', x)) for x in p)
            and "w_gate" in "/".join(str(getattr(x, 'key', x)) for x in p)]
assert moe_gate and moe_gate[0].spec[1] == "model", moe_gate[0].spec
print("OK")
""")


def test_elastic_restore_across_meshes(tmp_path):
    run_distributed(r"""
import jax, jax.numpy as jnp
import numpy as np
import tempfile, os
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.models import get_api
from repro.train.step import init_state
from repro.checkpoint import ckpt
from repro.launch.elastic import elastic_restore, remesh, rescale_accum

cfg = get_config("llama3-8b").reduced(n_layers=2, d_model=64, d_ff=128,
                                      vocab=128, head_dim=16)
api = get_api(cfg)
run = RunConfig(steps=10)
state = init_state(api, cfg, run, jax.random.key(0))
d = tempfile.mkdtemp()
ckpt.save(d, 5, state)

# restore onto an 8-device (4x2) mesh
mesh8 = remesh(jax.devices(), dp=4, tp=2)
st8, step, _ = elastic_restore(d, state, mesh=mesh8)
assert step == 5

# "failure": restore the same checkpoint onto a 4-device (2x2) mesh
mesh4 = remesh(jax.devices()[:4], dp=2, tp=2)
st4, step, _ = elastic_restore(d, state, mesh=mesh4)
for a, b in zip(jax.tree_util.tree_leaves(st8.params),
                jax.tree_util.tree_leaves(st4.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# grad-accum rescaling preserves global batch
accum8, gb8 = rescale_accum(global_batch=256, per_device_batch=8, dp_size=4)
accum4, gb4 = rescale_accum(global_batch=256, per_device_batch=8, dp_size=2)
assert gb8 == gb4 == 256
assert accum4 == 2 * accum8
print("OK")
""")


def test_compat_vmem_scratch_probe():
    """The pallas-TPU VMEM probe lives in core/compat.py behind an explicit
    jax-version check (no dead try/except fallback).  This file is part of
    the jax-floor CI shard, so the probe is exercised on the minimum
    supported jax on every PR: importing repro.core runs the import-time
    probe, and the allocation below runs the accessor."""
    import jax.numpy as jnp

    from repro.core import compat

    assert compat.JAX_VERSION >= (0, 4, 30), compat.JAX_VERSION
    scratch = compat.vmem_scratch((8, 128), jnp.float32)
    # pltpu.VMEM yields a memory-space-tagged scratch allocation usable in
    # pallas_call scratch_shapes; shape must round-trip.
    assert tuple(scratch.shape) == (8, 128)
