"""Grid selection optimality: Alg. 1 cost == Theorem 2 bound in all regimes
(the paper's tightness claim, §4.3), and the §5.3 Nyström grid trade-offs."""
import math

from _hypothesis_compat import given, settings, st

from repro.core.grid import (
    alg1_bandwidth_words,
    factorizations_3d,
    select_matmul_grid,
    select_nystrom_grids,
)
from repro.core.lower_bounds import (
    matmul_lower_bound,
    nystrom_lower_bound,
)


def test_alg1_cost_matches_bound_case1():
    n1, n2, r, P = 64, 256, 16, 32        # P <= n1
    g = select_matmul_grid(n1, n2, r, P)
    assert g.shape == (32, 1, 1)
    assert g.bandwidth_words == 0.0
    assert matmul_lower_bound(n1, n2, r, P) == 0.0


def test_alg1_cost_matches_bound_case2():
    n1, n2, r, P = 16, 1024, 8, 64        # n1 < P <= n1n2/r
    g = select_matmul_grid(n1, n2, r, P)
    assert g.shape == (16, 4, 1)
    assert math.isclose(g.bandwidth_words, matmul_lower_bound(n1, n2, r, P))


def test_alg1_cost_matches_bound_case3():
    n1, n2, r, P = 4, 64, 16, 256         # P > n1n2/r = 16
    g = select_matmul_grid(n1, n2, r, P)
    # ideal: p1=4, p2=sqrt(256*64/(16*4))=16, p3=sqrt(256*16/(4*64))=4
    assert g.shape == (4, 16, 4)
    assert math.isclose(g.bandwidth_words, matmul_lower_bound(n1, n2, r, P))


@settings(max_examples=50, deadline=None)
@given(
    n1e=st.integers(0, 6), n2e=st.integers(2, 8),
    re_=st.integers(0, 5), Pe=st.integers(0, 9),
)
def test_alg1_grid_never_beats_bound_and_close_when_divisible(n1e, n2e, re_, Pe):
    """The algorithm's cost can never be below the lower bound; with
    power-of-two dims (always divisible) the best grid should be within a
    small factor of it."""
    n1, n2, r, P = 2 ** n1e, 2 ** n2e, 2 ** re_, 2 ** Pe
    if r >= n2:
        return
    if P > n1 * n2 * r:
        return  # more processors than iteration points: no load-balanced grid
    g = select_matmul_grid(n1, n2, r, P)
    lb = matmul_lower_bound(n1, n2, r, P)
    assert g.bandwidth_words >= lb - 1e-9
    # all dims are powers of two -> exact optimal grid exists
    best = min(
        alg1_bandwidth_words(n1, n2, r, a, b, c)
        for (a, b, c) in factorizations_3d(P)
        if a <= n1 and b <= n2 and c <= r
    ) if any(a <= n1 and b <= n2 and c <= r
             for (a, b, c) in factorizations_3d(P)) else None
    if best is not None:
        assert g.bandwidth_words <= best + 1e-9


def test_nystrom_variant_crossover():
    """Redist comm O(nr/P) vs No-Redist O(r^2): crossover at P ~ n/r."""
    n, r = 50000, 5000
    small = select_nystrom_grids(n, r, 4, variant="auto")
    large = select_nystrom_grids(n, r, 64, variant="auto")
    assert small.variant == "no_redist"
    assert large.variant == "redist"


def test_nystrom_costs_close_to_bound():
    n, r = 4096, 256
    for P in [2, 8, 64, 512, 4096]:
        lb = nystrom_lower_bound(n, r, P)
        gr = select_nystrom_grids(n, r, P, variant="bound_driven")
        # paper §5.3: cost is within nr/P (cases 1-2), r (case 3) or
        # sqrt(nr(n+r)/P) (case 4) of the bound
        slack = max(n * r / P, r, math.sqrt(n * r * (n + r) / P))
        own = (n * n + 2 * n * r + r * r) / P
        assert gr.bandwidth_words <= lb + own + slack + 1e-6


def test_no_redist_cost_is_r_squared_like():
    n, r, P = 4096, 64, 16
    g = select_nystrom_grids(n, r, P, variant="no_redist")
    expect = (1 - 1 / P) * r * r
    assert math.isclose(g.bandwidth_words, expect, rel_tol=1e-9)
    assert not g.redistributes_B


def test_redist_cost_scales_with_P():
    n, r = 8192, 128
    c = [select_nystrom_grids(n, r, P, variant="redist").bandwidth_words
         for P in (8, 16, 32)]
    assert c[0] > c[1] > c[2]   # shrinks with P (O(nr/P) dominates)
