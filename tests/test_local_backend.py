"""Backend-matrix tests for the fused local GEMM layer (kernels/local.py).

Pins the tentpole contract of the zero-Omega-HBM work:

  (a) interpret-mode Pallas vs jnp **bitwise** parity for ``sketch_block``
      / ``sketch_t_block`` across all three omega kinds, nonzero
      row0/col0 offsets, bf16 inputs with f32 accumulation, non-divisible
      shapes, and the fused ``acc`` accumulation;
  (b) ``backend="auto"`` never changes numerics (property test);
  (c) every distributed path (Alg. 1 grids, both Nyström 1-D variants,
      the general and bound-driven two-grid forms, the sharded streaming
      updates incl. row slabs and the co-range sketch) produces bitwise-
      identical results on both backends — so the existing Theorem-audit
      and two-grid bitwise contracts hold for the Pallas backend too;
  (d) the Theorem-2 zero-communication audit passes on the Pallas
      backend: the compiled (P,1,1) update has zero collective bytes, and
      the 2x2x2 collective schedule (bytes moved) is identical to jnp's —
      the backend changes the HBM roofline, never the network;
  (e) the planner picks the backend analytically (HBM roofline) and
      ``Plan.execute`` dispatches it.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from dist_helper import run_distributed

import jax
import jax.numpy as jnp

from repro.kernels.local import (
    default_local_blocks, resolve_backend, sketch_block, sketch_t_block,
)

KINDS = ("normal", "uniform", "rademacher")
OFFSETS = ((0, 0), (32, 5))


# ---------------------------------------------------------------------------
# (a) local bitwise parity matrix
# ---------------------------------------------------------------------------

def test_resolve_backend():
    assert resolve_backend("jnp") == "jnp"
    assert resolve_backend("xla") == "jnp"          # stream alias
    assert resolve_backend("pallas") == "pallas"
    assert resolve_backend("auto") in ("jnp", "pallas")
    if jax.default_backend() != "tpu":
        assert resolve_backend("auto") == "jnp"
    with pytest.raises(ValueError):
        resolve_backend("mkl")


def test_default_blocks_interpret_exact():
    """Interpret mode takes one exact tile: no padding, no k split — the
    bitwise default."""
    assert default_local_blocks(33, 11, 50, interpret=True) == (33, 11, 50)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("off", OFFSETS)
def test_sketch_block_backend_parity(kind, off):
    A = jax.random.normal(jax.random.key(0), (16, 48))
    r0, c0 = off
    j = sketch_block(A, 7, 8, row0=r0, col0=c0, kind=kind, backend="jnp")
    p = sketch_block(A, 7, 8, row0=r0, col0=c0, kind=kind, backend="pallas")
    np.testing.assert_array_equal(np.asarray(j), np.asarray(p))


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("off", OFFSETS)
def test_sketch_t_block_backend_parity(kind, off):
    B = jax.random.normal(jax.random.key(2), (48, 16))
    r0, c0 = off
    j = sketch_t_block(B, 7, 8, row0=r0, col0=c0, kind=kind, salt=1,
                       backend="jnp")
    p = sketch_t_block(B, 7, 8, row0=r0, col0=c0, kind=kind, salt=1,
                       backend="pallas")
    np.testing.assert_array_equal(np.asarray(j), np.asarray(p))


def test_fused_acc_parity_and_semantics():
    """sketch_block(acc=Y) == Y + sketch_block() on both backends, bitwise
    — the fused accumulator adds in the same order as the jnp body."""
    A = jax.random.normal(jax.random.key(0), (16, 48))
    Y = jax.random.normal(jax.random.key(1), (16, 8))
    base = Y + sketch_block(A, 7, 8, backend="jnp")
    for backend in ("jnp", "pallas"):
        got = sketch_block(A, 7, 8, acc=Y, backend=backend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
    W = jax.random.normal(jax.random.key(3), (8, 16))
    B = jax.random.normal(jax.random.key(2), (48, 16))
    tbase = W + sketch_t_block(B, 7, 8, backend="jnp")
    for backend in ("jnp", "pallas"):
        got = sketch_t_block(B, 7, 8, acc=W, backend=backend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(tbase))


def test_bf16_inputs_f32_accumulation_parity():
    A = jax.random.normal(jax.random.key(0), (16, 48)).astype(jnp.bfloat16)
    j = sketch_block(A, 7, 8, backend="jnp")
    p = sketch_block(A, 7, 8, backend="pallas")
    assert j.dtype == jnp.bfloat16 and p.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(j, np.float32),
                                  np.asarray(p, np.float32))
    B = A.T
    j = sketch_t_block(B, 7, 8, backend="jnp")
    p = sketch_t_block(B, 7, 8, backend="pallas")
    np.testing.assert_array_equal(np.asarray(j, np.float32),
                                  np.asarray(p, np.float32))


def test_nondivisible_shapes_parity():
    A = jax.random.normal(jax.random.key(4), (33, 50))
    np.testing.assert_array_equal(
        np.asarray(sketch_block(A, 9, 11, backend="jnp")),
        np.asarray(sketch_block(A, 9, 11, backend="pallas")))
    B = jax.random.normal(jax.random.key(5), (50, 21))
    np.testing.assert_array_equal(
        np.asarray(sketch_t_block(B, 9, 13, backend="jnp")),
        np.asarray(sketch_t_block(B, 9, 13, backend="pallas")))


def test_explicit_blocks_k_unsplit_parity_and_scale():
    """m/n tiling keeps bitwise parity as long as the contraction is not
    split; scale multiplies the in-kernel tile identically."""
    A = jax.random.normal(jax.random.key(0), (16, 48))
    j = sketch_block(A, 7, 8, scale=0.25, backend="jnp")
    p = sketch_block(A, 7, 8, scale=0.25, backend="pallas",
                     blocks=(8, 4, 48))
    np.testing.assert_array_equal(np.asarray(j), np.asarray(p))


def test_k_split_blocks_tolerance():
    """Splitting the contraction regroups the f32 reduction — documented
    as tolerance-level, not bitwise."""
    A = jax.random.normal(jax.random.key(0), (16, 48))
    j = sketch_block(A, 7, 8, backend="jnp")
    p = sketch_block(A, 7, 8, backend="pallas", blocks=(16, 8, 16))
    np.testing.assert_allclose(np.asarray(j), np.asarray(p),
                               rtol=2e-5, atol=2e-5)


def test_fold_rows_block_backend_parity():
    """The row-slab Y fold (stream ``update_rows``) is backend-dispatched
    (``fold_rows_block``): the pallas body runs the identical zero-pad +
    traced-offset slice + add inside one kernel (padded frame in VMEM, Y
    aliased in-place) and must be BITWISE the jnp body across in-range,
    clipped-left, clipped-right, and fully-out-of-overlap offsets."""
    from repro.kernels.local import fold_rows_block
    y = jax.random.normal(jax.random.key(0), (8, 6))
    d = jax.random.normal(jax.random.key(1), (5, 6))
    m, k = y.shape[0], d.shape[0]
    for start in (0, 1, 3, m, k + m):      # clip range is [0, k + m]
        j = fold_rows_block(y, d, jnp.int32(start), backend="jnp")
        p = fold_rows_block(y, d, jnp.int32(start), backend="pallas")
        np.testing.assert_array_equal(np.asarray(j), np.asarray(p))
    # start == m places d exactly at the top of y
    top = fold_rows_block(y, d, jnp.int32(m), backend="pallas")
    np.testing.assert_array_equal(
        np.asarray(top)[:k], np.asarray(y[:k] + d))
    # fully outside the overlap: both backends add exact zeros
    out = fold_rows_block(y, d, jnp.int32(0), backend="pallas")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(y))
    # traced start under jit, and bf16 state
    f = jax.jit(lambda y, d, s: fold_rows_block(y, d, s, backend="pallas"))
    np.testing.assert_array_equal(
        np.asarray(f(y, d, jnp.int32(7))),
        np.asarray(fold_rows_block(y, d, 7, backend="jnp")))
    yb, db = y.astype(jnp.bfloat16), d.astype(jnp.bfloat16)
    np.testing.assert_array_equal(
        np.asarray(fold_rows_block(yb, db, jnp.int32(9), backend="pallas"),
                   np.float32),
        np.asarray(fold_rows_block(yb, db, jnp.int32(9), backend="jnp"),
                   np.float32))


def test_fold_rows_block_padded_path_parity():
    """The native-TPU tiling pads the fold frame to (8, 128)-aligned
    shapes; the in-kernel top pad is then TALLER than the logical shard,
    so the traced start must be shifted by (mp - m) or the slab delta
    lands rows too low.  Forced through interpret mode so CI pins the
    padding contract the compiled path relies on (padding never shifts
    in-range placement)."""
    from repro.kernels.local import _fold_rows_jnp, _fold_rows_pallas
    y = jax.random.normal(jax.random.key(0), (6, 6))
    d = jax.random.normal(jax.random.key(1), (5, 6))
    for start in (0, 2, 6, 11):       # clip range is [0, k + m]
        ref = _fold_rows_jnp(y, d, jnp.int32(start))
        got = _fold_rows_pallas(y, d, jnp.int32(start), interpret=True,
                                pad_to=(8, 128, 8))
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got),
                                      err_msg=f"start={start}")


def test_traced_seed_and_offsets_under_jit():
    A = jax.random.normal(jax.random.key(0), (16, 48))
    keys = jnp.array([7, 0], jnp.uint32)
    f = jax.jit(lambda a, k, r0: sketch_block(a, k, 8, row0=r0,
                                              backend="pallas"))
    g = jax.jit(lambda a, k, r0: sketch_block(a, k, 8, row0=r0,
                                              backend="jnp"))
    np.testing.assert_array_equal(
        np.asarray(f(A, keys, jnp.uint32(32))),
        np.asarray(g(A, keys, jnp.uint32(32))))


# ---------------------------------------------------------------------------
# (b) backend="auto" never changes numerics
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n1=st.integers(4, 40), n2=st.integers(4, 60), r=st.integers(2, 16),
       seed=st.integers(0, 2 ** 62),
       kind=st.sampled_from(list(KINDS)))
def test_auto_backend_property(n1, n2, r, seed, kind):
    A = jax.random.normal(jax.random.key(1), (n1, n2))
    ref = sketch_block(A, seed, r, kind=kind, backend="jnp")
    auto = sketch_block(A, seed, r, kind=kind, backend="auto")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(auto))
    # and the explicitly-forced fused kernel agrees bitwise too
    fused = sketch_block(A, seed, r, kind=kind, backend="pallas")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(fused))


# ---------------------------------------------------------------------------
# (c) distributed paths, both backends, bitwise (8 fake devices)
# ---------------------------------------------------------------------------

COMMON = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import (rand_matmul, sketch_reference, make_grid_mesh,
                        nystrom_no_redist, nystrom_redist, nystrom_general,
                        nystrom_reference)
from repro.core.nystrom import nystrom_two_grid
from repro.core.sketch import input_sharding, output_sharding
assert len(jax.devices()) == 8
"""


def test_distributed_backends_bitwise():
    run_distributed(COMMON + r"""
seed, n1, n2, r = 11, 16, 48, 8
A = jax.random.normal(jax.random.key(1), (n1, n2))
ref = sketch_reference(A, seed, r)
for shape in [(8,1,1), (2,2,2), (1,4,2), (4,2,1), (1,1,8)]:
    mesh = make_grid_mesh(*shape)
    Ash = jax.device_put(A, input_sharding(mesh))
    Bj = rand_matmul(Ash, seed, r, mesh, backend="jnp")
    Bp = rand_matmul(Ash, seed, r, mesh, backend="pallas")
    assert np.array_equal(np.asarray(Bj), np.asarray(Bp)), shape
    assert float(jnp.abs(Bj - ref).max()) < 1e-4, shape

n, rr = 64, 16
S = jax.random.normal(jax.random.key(2), (n, n)); S = S @ S.T / n
Bref, Cref = nystrom_reference(S, 5, rr)
mesh = Mesh(np.asarray(jax.devices()), ("x",))
Ssh = jax.device_put(S, NamedSharding(mesh, P("x", None)))
for fn in (nystrom_no_redist, nystrom_redist):
    Bj, Cj = fn(Ssh, 5, rr, mesh, backend="jnp")
    Bp, Cp = fn(Ssh, 5, rr, mesh, backend="pallas")
    assert np.array_equal(np.asarray(Bj), np.asarray(Bp)), fn
    assert np.array_equal(np.asarray(Cj), np.asarray(Cp)), fn

# §5.3 bound-driven two-grid: the bitwise-safe pair (p2==1, q1==1) stays
# bitwise vs the single-device reference on BOTH backends
Bj, Cj = nystrom_two_grid(S, 5, rr, p=(8,1,1), q=(1,1,8), backend="jnp")
Bp, Cp = nystrom_two_grid(S, 5, rr, p=(8,1,1), q=(1,1,8), backend="pallas")
assert np.array_equal(np.asarray(Bj), np.asarray(Bp))
assert np.array_equal(np.asarray(Cj), np.asarray(Cp))
assert np.array_equal(np.asarray(Bp), np.asarray(Bref))
assert np.array_equal(np.asarray(Cp), np.asarray(Cref))

# one-mesh general two-grid
mesh2 = make_grid_mesh(2, 2, 2)
Ssh2 = jax.device_put(S, input_sharding(mesh2))
Bj, Cj = nystrom_general(Ssh2, 5, rr, mesh2, backend="jnp")
Bp, Cp = nystrom_general(Ssh2, 5, rr, mesh2, backend="pallas")
assert np.array_equal(np.asarray(Bj), np.asarray(Bp))
assert np.array_equal(np.asarray(Cj), np.asarray(Cp))
print("OK")
""", timeout=900)


def test_sharded_stream_backends_bitwise():
    run_distributed(COMMON + r"""
from repro.stream import ShardedStreamingSketch
from repro.stream.state import StreamConfig

cfg = StreamConfig(n1=16, n2=48, r=8, seed=3, corange=True)
mesh = make_grid_mesh(4, 1, 2)
H1 = jax.random.normal(jax.random.key(3), (16, 48))
H2 = jax.random.normal(jax.random.key(4), (16, 48))
stj = ShardedStreamingSketch(cfg, mesh, backend="jnp")
stp = ShardedStreamingSketch(cfg, mesh, backend="pallas")
for st in (stj, stp):
    st.update(H1)
    st.update(H2)
    st.update_rows(4, np.asarray(H1)[4:8])       # row slab + corange
assert np.array_equal(np.asarray(stj.Y), np.asarray(stp.Y))
assert np.array_equal(np.asarray(stj.W), np.asarray(stp.W))

# fused Y accumulate (p2 == 1) and the scatter path (p2 > 1); row-slab
# ingest exercises the fused traced-offset Y fold (fold_rows_block) on
# every grid shape — shards left of, inside, and right of the slab
for g in ((8,1,1), (2,2,2)):
    c2 = StreamConfig(n1=16, n2=48, r=8, seed=3, corange=False)
    meshg = make_grid_mesh(*g)
    a = ShardedStreamingSketch(c2, meshg, backend="jnp").update(H1)
    b = ShardedStreamingSketch(c2, meshg, backend="pallas").update(H1)
    for st in (a, b):
        st.update_rows(6, np.asarray(H2)[6:12])
        st.update_rows(0, np.asarray(H2)[0:2])
    assert np.array_equal(np.asarray(a.Y), np.asarray(b.Y)), g

# symmetric stream: Nyström finalize on both backends, bitwise
S = jax.random.normal(jax.random.key(2), (16, 16)); S = S @ S.T / 16
c3 = StreamConfig(n1=16, n2=16, r=8, seed=5, corange=False)
m1 = make_grid_mesh(8, 1, 1)
fj = ShardedStreamingSketch(c3, m1, backend="jnp").update(S)
fp = ShardedStreamingSketch(c3, m1, backend="pallas").update(S)
for variant in ("no_redist", "redist", "bound_driven"):
    Bj, Cj = fj.nystrom(variant)
    Bp, Cp = fp.nystrom(variant)
    assert np.array_equal(np.asarray(Bj), np.asarray(Bp)), variant
    assert np.array_equal(np.asarray(Cj), np.asarray(Cp)), variant
print("OK")
""", timeout=900)


def test_zero_comm_and_schedule_pallas():
    """Theorem-2 audits hold on the Pallas backend: zero collective bytes
    on the (P,1,1) grid, and the 2x2x2 collective schedule moves exactly
    the same bytes as the jnp backend — fusing the local GEMM must not
    change the network schedule."""
    run_distributed(COMMON + r"""
from repro.roofline.hlo import collective_bytes_of
seed, n1, n2, r = 3, 16, 32, 8
mesh = make_grid_mesh(8, 1, 1)
A = jax.device_put(jax.random.normal(jax.random.key(0), (n1, n2)),
                   input_sharding(mesh))
fn = jax.jit(lambda a: rand_matmul(a, seed, r, mesh, backend="pallas"))
cb = collective_bytes_of(fn.lower(A).compile().as_text())
assert cb.total == 0, f"expected zero collective bytes, got {cb}"

n1, n2, r = 8, 64, 16
mesh = make_grid_mesh(2, 2, 2)
A = jax.device_put(jax.random.normal(jax.random.key(0), (n1, n2)),
                   input_sharding(mesh))
texts = {}
for backend in ("jnp", "pallas"):
    fn = jax.jit(lambda a, b=backend: rand_matmul(a, seed, r, mesh,
                                                  backend=b))
    texts[backend] = collective_bytes_of(fn.lower(A).compile().as_text())
assert texts["jnp"].by_kind == texts["pallas"].by_kind, texts
assert texts["pallas"].counts.get("all-gather", 0) == 1
assert texts["pallas"].counts.get("reduce-scatter", 0) == 1
print("OK")
""", timeout=900)


# ---------------------------------------------------------------------------
# (e) planner integration
# ---------------------------------------------------------------------------

def test_planner_picks_pallas_on_hbm_roofline():
    from repro.plan import PRESETS, plan_nystrom, plan_sketch, plan_stream
    t = plan_sketch(4096, 4096, 256, P=8, machine=PRESETS["tpu_v5e"])
    assert t.variant == "alg1" and t.backend == "pallas"
    jn = [c for c in t.candidates
          if c.variant == "alg1" and c.backend == "jnp"][0]
    pl = [c for c in t.candidates
          if c.variant == "alg1" and c.backend == "pallas"][0]
    assert pl.cost.words == jn.cost.words          # network untouched
    assert pl.cost.hbm_words < jn.cost.hbm_words   # Omega stream elided
    assert plan_nystrom(4096, 256, P=8,
                        machine=PRESETS["tpu_v5e"]).backend == "pallas"
    assert plan_stream(4096, 4096, 256, P=8,
                       machine=PRESETS["tpu_v5e"]).backend == "pallas"
    # CPU machine: pallas rows reported but never chosen
    c = plan_sketch(64, 128, 16, P=8, machine=PRESETS["cpu"])
    assert c.backend == "jnp"
    assert any(x.backend == "pallas" and not x.executable
               for x in c.candidates)


def test_plan_execute_dispatches_backend():
    """A pallas-backend distributed plan executes (interpret mode on CPU)
    bitwise-identically to the jnp plan."""
    run_distributed(r"""
import dataclasses
import jax, numpy as np
from repro.plan import PRESETS, plan_sketch
A = jax.random.normal(jax.random.key(0), (16, 48))
pj = plan_sketch(16, 48, 8, P=8, machine=PRESETS["cpu"])
assert pj.backend == "jnp"
pp_c = [c for c in pj.candidates if c.backend == "pallas"][0]
pp = dataclasses.replace(pj, backend="pallas", grid=pp_c.grid,
                         executable=True)
Bj = pj.execute(A, seed=11)
Bp = pp.execute(A, seed=11)
assert np.array_equal(np.asarray(Bj), np.asarray(Bp))
print("OK")
""", timeout=900)


def test_hbm_roofline_words_table():
    from repro.plan.model import hbm_roofline_words
    # plain GEMM: jnp moves A + Omega + B, pallas drops the k·n Omega term
    assert hbm_roofline_words(64, 128, 16, "jnp") == 64 * 128 + 128 * 16 \
        + 64 * 16
    assert hbm_roofline_words(64, 128, 16, "pallas") == 64 * 128 + 64 * 16
    # accumulate consumers: 4 m·n round-trip words vs the fused kernel's 2
    dj = hbm_roofline_words(64, 128, 16, "jnp", accumulate=True)
    dp = hbm_roofline_words(64, 128, 16, "pallas", accumulate=True)
    assert dj - hbm_roofline_words(64, 128, 16, "jnp") == 3 * 64 * 16
    assert dp - hbm_roofline_words(64, 128, 16, "pallas") == 64 * 16
