"""Substrate tests: data determinism, optimizer behaviour, checkpointing,
fault-tolerant loop (failure injection + bit-exact resume), serving."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data.pipeline import DataConfig, Pipeline, make_batch
from repro.models import get_api
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine
from repro.train.loop import StragglerMonitor, train_loop
from repro.train.step import init_state, make_train_step


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_step_indexed():
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=4, seed=3)
    b1 = make_batch(cfg, 7)
    b2 = make_batch(cfg, 7)
    b3 = make_batch(cfg, 8)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))


def test_pipeline_resume_bit_exact():
    cfg = DataConfig(vocab=97, seq_len=8, global_batch=2, seed=0)
    p1 = Pipeline(cfg)
    batches = [next(p1) for _ in range(5)]
    state = p1.state()
    p2 = Pipeline.from_state(cfg, state)
    b6a = next(p1)
    b6b = next(p2)
    np.testing.assert_array_equal(np.asarray(b6a["tokens"]),
                                  np.asarray(b6b["tokens"]))


def test_tokens_in_range():
    cfg = DataConfig(vocab=31, seq_len=64, global_batch=4, seed=1)
    b = make_batch(cfg, 0)
    t = np.asarray(b["tokens"])
    assert t.min() >= 0 and t.max() < 31


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(5.0)}
    st = adamw.init(params)
    for i in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2)(params)
        params, st = adamw.update(grads, st, params, 0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert abs(float(params["b"])) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.int32(s), peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] < lrs[9]                 # warmup rises
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[99] < lrs[20]               # decays
    assert lrs[99] >= 0.099                # final_frac floor


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    for s in (10, 20, 30, 40):
        ckpt.save(d, s, tree, extra={"data": {"step": s, "seed": 0}},
                  keep=2)
    assert ckpt.latest_step(d) == 40
    dirs = sorted(os.listdir(d))
    assert len([x for x in dirs if x.startswith("step_")]) == 2  # GC'd
    got, step, extra = ckpt.restore(d, tree)
    assert step == 40
    assert extra["data"]["step"] == 40
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    assert got["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(d, {"a": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# training loop: convergence, failure injection, straggler monitor
# ---------------------------------------------------------------------------

def _tiny_setup(tmp_path, steps=60, ckpt_every=10):
    cfg = get_config("llama3-8b").reduced(n_layers=2, d_model=32, d_ff=64,
                                          vocab=64, head_dim=8)
    api = get_api(cfg)
    run = RunConfig(steps=steps, learning_rate=5e-3, warmup_steps=5,
                    checkpoint_every=ckpt_every,
                    checkpoint_dir=str(tmp_path / "ck"), remat=False)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4,
                          seed=0)
    state = init_state(api, cfg, run, jax.random.key(0))
    step_fn = jax.jit(make_train_step(api, cfg, run))
    return cfg, api, run, data_cfg, state, step_fn


def test_training_loss_decreases(tmp_path):
    _, _, run, data_cfg, state, step_fn = _tiny_setup(tmp_path, steps=60)
    res = train_loop(step_fn, state, data_cfg, run)
    assert np.mean(res.losses[-10:]) < np.mean(res.losses[:10]) - 0.2
    assert res.checkpoints, "checkpoints were written"


def test_failure_injection_recovers_and_resumes(tmp_path):
    _, _, run, data_cfg, state, step_fn = _tiny_setup(tmp_path, steps=40,
                                                      ckpt_every=10)
    crashed = {"done": False}

    def injector(step):
        if step == 25 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node failure")

    res = train_loop(step_fn, state, data_cfg, run,
                     failure_injector=injector)
    assert res.restarts == 1
    assert int(res.state.step) == 40
    # compare against an uninterrupted run: states must match bit-exactly
    # because the stream is step-indexed and restore is exact
    _, _, run2, data2, state2, step2 = _tiny_setup(tmp_path / "b", steps=40,
                                                   ckpt_every=10)
    res2 = train_loop(step2, state2, data2, run2)
    for a, b in zip(jax.tree_util.tree_leaves(res.state.params),
                    jax.tree_util.tree_leaves(res2.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(alpha=0.9, k=3.0)
    import random
    random.seed(0)
    for s in range(50):
        mon.observe(s, 0.1 + random.random() * 0.001)
    assert not mon.flagged
    mon.observe(50, 1.0)
    assert mon.flagged and mon.flagged[0]["step"] == 50


def test_nan_guard_skips_update(tmp_path):
    cfg, api, run, data_cfg, state, _ = _tiny_setup(tmp_path, steps=3,
                                                    ckpt_every=0)
    calls = {"n": 0}

    def bad_step(st, batch):
        calls["n"] += 1
        if calls["n"] == 2:
            return st, {"loss": jnp.float32(jnp.nan),
                        "grad_norm": jnp.float32(0), "lr": jnp.float32(0)}
        return st._replace(step=st.step + 1), {
            "loss": jnp.float32(1.0), "grad_norm": jnp.float32(0),
            "lr": jnp.float32(0)}

    res = train_loop(bad_step, state, data_cfg, run)
    assert len(res.losses) == 2            # nan step skipped
    assert calls["n"] == 3


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_batched_server_continuous_batching():
    from repro.serve.engine import BatchedServer, Request
    cfg = get_config("llama3-8b").reduced(n_layers=2, d_model=32, d_ff=64,
                                          vocab=64, head_dim=8)
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    server = BatchedServer(params, cfg, slots=2, max_len=32, eos=-1)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=4)
            for i in range(5)]        # 5 requests > 2 slots: queueing
    for r in reqs:
        server.submit(r)
    server.run()
    assert all(len(r.out) == 4 for r in reqs)
    assert all(r.done for r in reqs)


def test_greedy_decode_is_deterministic():
    from repro.serve.engine import BatchedServer, Request
    cfg = get_config("gemma2-2b").reduced(n_layers=2)
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    outs = []
    for _ in range(2):
        server = BatchedServer(params, cfg, slots=1, max_len=16, eos=-1)
        r = Request(rid=0, prompt=[3, 1, 4], max_new=5)
        server.submit(r)
        server.run()
        outs.append(tuple(r.out))
    assert outs[0] == outs[1]
