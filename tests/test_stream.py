"""Streaming one-pass sketch subsystem (repro.stream).

Contract pillars:
  (a) streamed row-block updates reproduce the one-shot ``sketch_reference``
      **bitwise**, under any chunking and arrival order — including the
      distributed row-slab path vs. the full-shape additive path;
  (b) one-pass reconstruction matches the one-shot low-rank baseline;
  (c) updates add zero Omega/Psi communication — the compiled update step
      moves exactly the Alg.-1 collective bytes (zero on regime-1 grids),
      plus only the data-derived co-range psum when enabled;
  (d) checkpoints round-trip bitwise (sketch state + seed IS the stream);
  (e) batched multi-stream ingest is bitwise N independent streams.

Distributed assertions run in a subprocess with 8 fake XLA devices (same
isolation rule as test_sketch_distributed.py).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from dist_helper import run_distributed

from repro.core import nystrom_reference, sketch_reference
from repro.stream import (
    SketchService,
    StreamConfig,
    StreamingSketch,
    psi_matrix,
    reconstruction_error,
)


# ---------------------------------------------------------------------------
# (a) bitwise equality under arbitrary row chunking
# ---------------------------------------------------------------------------

CHUNKINGS = [
    [(0, 48)],                                    # one-shot as a stream
    [(0, 16), (16, 32), (32, 48)],                # equal blocks, in order
    [(32, 48), (0, 7), (7, 32)],                  # ragged, out of order
    [(i, i + 1) for i in range(48)],              # one row at a time
    [(1, 48), (0, 1)],                            # pathological split
]


@pytest.mark.parametrize("chunks", CHUNKINGS,
                         ids=["oneshot", "equal", "ragged", "rowwise", "tail"])
def test_rowblock_stream_bitwise_equals_reference(chunks):
    n1, n2, r, seed = 48, 64, 8, 11
    A = jax.random.normal(jax.random.key(0), (n1, n2))
    ref = np.asarray(sketch_reference(A, seed, r))
    st = StreamingSketch(StreamConfig(n1=n1, n2=n2, r=r, seed=seed),
                         backend="xla")
    for (i0, i1) in chunks:
        st.update_rows(i0, A[i0:i1])
    np.testing.assert_array_equal(np.asarray(st.sketch), ref)


@pytest.mark.parametrize("kind", ["normal", "uniform", "rademacher"])
def test_rowblock_stream_bitwise_all_kinds(kind):
    n1, n2, r, seed = 32, 40, 8, 5
    A = jax.random.normal(jax.random.key(2), (n1, n2))
    ref = np.asarray(sketch_reference(A, seed, r, kind))
    st = StreamingSketch(StreamConfig(n1=n1, n2=n2, r=r, seed=seed,
                                      kind=kind), backend="xla")
    for i0 in range(0, n1, 8):
        st.update_rows(i0, A[i0:i0 + 8])
    np.testing.assert_array_equal(np.asarray(st.sketch), ref)


def test_colblock_and_additive_streams_match_reference():
    """Column/overlapping updates split the contraction, so they match to FP
    tolerance (documented), not bitwise."""
    n1, n2, r, seed = 32, 64, 8, 3
    A = jax.random.normal(jax.random.key(1), (n1, n2))
    ref = np.asarray(sketch_reference(A, seed, r))

    st = StreamingSketch(StreamConfig(n1=n1, n2=n2, r=r, seed=seed))
    for j in range(0, n2, 16):
        st.update_cols(j, A[:, j:j + 16])
    np.testing.assert_allclose(np.asarray(st.sketch), ref,
                               rtol=1e-5, atol=1e-4)

    st2 = StreamingSketch(StreamConfig(n1=n1, n2=n2, r=r, seed=seed))
    half = jnp.concatenate([A[:16], jnp.zeros((16, n2))], axis=0)
    st2.update(half)
    st2.update(jnp.asarray(A) - half)       # overlapping additive deltas
    np.testing.assert_allclose(np.asarray(st2.sketch), ref,
                               rtol=1e-5, atol=1e-4)


def test_corange_sketch_matches_oneshot():
    n1, n2, r, seed = 48, 64, 8, 11
    cfg = StreamConfig(n1=n1, n2=n2, r=r, seed=seed)
    A = jax.random.normal(jax.random.key(0), (n1, n2))
    st = StreamingSketch(cfg)
    for (i0, i1) in [(24, 48), (0, 13), (13, 24)]:
        st.update_rows(i0, A[i0:i1])
    Wref = np.asarray(psi_matrix(cfg) @ A)
    np.testing.assert_allclose(np.asarray(st.corange_sketch), Wref,
                               rtol=1e-5, atol=1e-4)


def test_pallas_backend_matches_reference():
    """The fused-kernel ingest path (interpret mode on CPU)."""
    n1, n2, r, seed = 32, 32, 8, 2
    A = jax.random.normal(jax.random.key(9), (n1, n2))
    st = StreamingSketch(StreamConfig(n1=n1, n2=n2, r=r, seed=seed,
                                      corange=False), backend="interpret")
    st.update_rows(0, A[:16])
    st.update_rows(16, A[16:])
    np.testing.assert_allclose(np.asarray(st.sketch),
                               np.asarray(sketch_reference(A, seed, r)),
                               rtol=2e-5, atol=2e-4)


# ---------------------------------------------------------------------------
# (b) one-pass reconstruction vs. the one-shot baseline
# ---------------------------------------------------------------------------

def test_one_pass_reconstruction_matches_oneshot_baseline():
    n1, n2, k = 64, 96, 6
    M = (jax.random.normal(jax.random.key(1), (n1, k))
         @ jax.random.normal(jax.random.key(2), (k, n2)))
    cfg = StreamConfig(n1=n1, n2=n2, r=24, seed=3)

    streamed = StreamingSketch(cfg)
    for i in range(0, n1, 12):
        streamed.update_rows(i, M[i:i + 12])
    oneshot = StreamingSketch(cfg).update_rows(0, M)

    err_s = float(reconstruction_error(M, streamed.reconstruct()))
    err_o = float(reconstruction_error(M, oneshot.reconstruct()))
    # exact-rank input: both must hit ~machine precision, and agree
    assert err_s < 1e-4, err_s
    assert abs(err_s - err_o) < 1e-5, (err_s, err_o)

    # fixed-rank truncation keeps the target rank and the error floor
    lr = streamed.reconstruct(rank=k)
    assert lr.rank == k
    assert float(reconstruction_error(M, lr)) < 1e-4


def test_streaming_nystrom_matches_reference():
    n, r, seed = 48, 16, 5
    X = jax.random.normal(jax.random.key(4), (n, 6))
    S = X @ X.T
    st = StreamingSketch(StreamConfig(n1=n, n2=n, r=r, seed=seed,
                                      corange=False))
    for i in range(0, n, 16):
        st.update_rows(i, S[i:i + 16])
    B, C = st.nystrom()
    Bref, Cref = nystrom_reference(S, seed, r)
    np.testing.assert_allclose(np.asarray(B), np.asarray(Bref),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(C), np.asarray(Cref),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# sketch service: many streams, one mesh, shared executables
# ---------------------------------------------------------------------------

def test_service_streams_share_one_executable():
    n1, n2, r = 48, 64, 8
    A = jax.random.normal(jax.random.key(0), (n1, n2))
    svc = SketchService()
    sa = svc.open(StreamConfig(n1=n1, n2=n2, r=r, seed=11))
    sb = svc.open(StreamConfig(n1=n1, n2=n2, r=r, seed=999))
    for i in range(0, n1, 16):
        svc.update(sa, A[i:i + 16], row0=i)
        svc.update(sb, A[i:i + 16], row0=i)
    np.testing.assert_array_equal(np.asarray(svc.sketch(sa)),
                                  np.asarray(sketch_reference(A, 11, r)))
    np.testing.assert_array_equal(np.asarray(svc.sketch(sb)),
                                  np.asarray(sketch_reference(A, 999, r)))
    # different seeds, same shape signature -> ONE compiled update
    assert svc.num_compiled == 1, svc.stats()
    assert svc.num_streams == 2
    svc.close(sa)
    assert svc.num_streams == 1


def test_service_reconstruct_and_validation():
    svc = SketchService()
    cfg = StreamConfig(n1=32, n2=48, r=16, seed=7)
    sid = svc.open(cfg)
    M = (jax.random.normal(jax.random.key(5), (32, 4))
         @ jax.random.normal(jax.random.key(6), (4, 48)))
    svc.update(sid, M[:16], row0=0)
    svc.update(sid, M[16:], row0=16)
    assert float(reconstruction_error(M, svc.reconstruct(sid))) < 1e-4
    with pytest.raises(ValueError):
        svc.update(sid, M[:16], row0=20)    # overruns n1
    with pytest.raises(ValueError):
        svc.open(StreamConfig(n1=0, n2=4, r=2))


# ---------------------------------------------------------------------------
# (d) checkpointing: save/restore round-trips bitwise
# ---------------------------------------------------------------------------

def test_streaming_checkpoint_round_trip_bitwise(tmp_path):
    n1, n2, r, seed = 48, 64, 8, 5
    A = jax.random.normal(jax.random.key(0), (n1, n2))
    st = StreamingSketch(StreamConfig(n1=n1, n2=n2, r=r, seed=seed))
    st.update_rows(0, A[:24])
    st.update_rows(24, A[24:])
    path = st.save(str(tmp_path))
    assert "step_" in path

    st2 = StreamingSketch.restore(str(tmp_path))
    assert st2.cfg == st.cfg and st2.num_updates == 2
    # the backend travels with the checkpoint ("auto" re-resolution could
    # silently continue a stream on a non-bitwise kernel path)
    assert st2.backend == st.backend
    np.testing.assert_array_equal(np.asarray(st.Y), np.asarray(st2.Y))
    np.testing.assert_array_equal(np.asarray(st.W), np.asarray(st2.W))

    # bitwise-identical finalize: restored stream reconstructs the same
    lr1, lr2 = st.reconstruct(rank=4), st2.reconstruct(rank=4)
    np.testing.assert_array_equal(np.asarray(lr1.Q), np.asarray(lr2.Q))
    np.testing.assert_array_equal(np.asarray(lr1.X), np.asarray(lr2.X))

    # ...and further updates continue bitwise-identically to an unbroken run
    extra = jax.random.normal(jax.random.key(9), (16, n2))
    st.update_rows(8, extra)
    st2.update_rows(8, extra)
    np.testing.assert_array_equal(np.asarray(st.Y), np.asarray(st2.Y))


def test_streaming_checkpoint_no_corange(tmp_path):
    cfg = StreamConfig(n1=32, n2=48, r=8, seed=3, corange=False)
    A = jax.random.normal(jax.random.key(1), (32, 48))
    st = StreamingSketch(cfg)
    st.update_rows(0, A)
    st.save(str(tmp_path), step=7)
    st2 = StreamingSketch.restore(str(tmp_path))
    assert st2.W is None and st2.num_updates == 1
    np.testing.assert_array_equal(np.asarray(st.Y), np.asarray(st2.Y))


# ---------------------------------------------------------------------------
# (e) batched multi-stream fused ingest (one compiled call, N streams)
# ---------------------------------------------------------------------------

def test_service_update_batch_bitwise_vs_independent_streams():
    n1, n2, r, N = 48, 64, 8, 4
    seeds = [11, 99, 7, 2 ** 40 + 3]          # incl. a >32-bit key pair
    A = jax.random.normal(jax.random.key(0), (n1, n2))
    chunks = [(0, 16), (16, 32), (32, 48)]   # uniform height: one program

    batched = SketchService()
    sids = [batched.open(StreamConfig(n1=n1, n2=n2, r=r, seed=s))
            for s in seeds]
    singles = []
    for s in seeds:
        st = StreamingSketch(StreamConfig(n1=n1, n2=n2, r=r, seed=s),
                             backend="xla")
        singles.append(st)
    for (i0, i1) in chunks:
        batched.update_batch(sids, jnp.stack([A[i0:i1]] * N), row0=i0)
        for st in singles:
            st.update_rows(i0, A[i0:i1])

    for sid, st, s in zip(sids, singles, seeds):
        np.testing.assert_array_equal(np.asarray(batched.sketch(sid)),
                                      np.asarray(st.sketch))
        np.testing.assert_array_equal(np.asarray(batched.corange(sid)),
                                      np.asarray(st.corange_sketch))
        np.testing.assert_array_equal(np.asarray(batched.sketch(sid)),
                                      np.asarray(sketch_reference(A, s, r)))

    # N streams, any number of batched calls: ONE compiled batch program
    assert batched.num_compiled == 1, batched.stats()


def test_service_update_batch_per_lane_offsets_and_validation():
    n1, n2, r = 32, 48, 8
    A = jax.random.normal(jax.random.key(5), (n1, n2))
    svc = SketchService()
    sids = [svc.open(StreamConfig(n1=n1, n2=n2, r=r, seed=s,
                                  corange=False)) for s in (1, 2)]
    # per-lane row offsets: lane 0 ingests the top half, lane 1 the bottom
    svc.update_batch(sids, jnp.stack([A[:16], A[16:]]), row0=[0, 16])
    ref0 = np.asarray(sketch_reference(A, 1, r))
    got0 = np.asarray(svc.sketch(sids[0]))
    np.testing.assert_array_equal(got0[:16], ref0[:16])
    assert np.all(got0[16:] == 0)

    with pytest.raises(ValueError):
        svc.update_batch(sids, jnp.stack([A[:16], A[16:]]), row0=[0])
    with pytest.raises(ValueError):   # mixed shape signatures
        other = svc.open(StreamConfig(n1=n1, n2=n2, r=r + 8, seed=3,
                                      corange=False))
        svc.update_batch([sids[0], other],
                         jnp.stack([A[:16], A[:16]]), row0=0)
    with pytest.raises(ValueError):   # duplicate lanes would clobber
        svc.update_batch([sids[0], sids[0]],
                         jnp.stack([A[:16], A[16:]]), row0=[0, 16])
    with pytest.raises(NotImplementedError):
        from repro.core.sketch import make_grid_mesh
        SketchService(mesh=make_grid_mesh(1, 1, 1)).update_batch(
            [0], A[None, :16], row0=0)


# ---------------------------------------------------------------------------
# distributed: bitwise vs one-shot Alg. 1, and (c) zero Omega communication
# ---------------------------------------------------------------------------

_COMMON = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import (rand_matmul, rand_matmul_communicating,
                        sketch_reference, nystrom_reference, make_grid_mesh)
from repro.core.sketch import input_sharding
from repro.roofline.hlo import collective_bytes_of
from repro.stream import (StreamConfig, ShardedStreamingSketch, SketchService,
                          psi_matrix)
assert len(jax.devices()) == 8
"""


def test_sharded_stream_bitwise_and_zero_omega_comm():
    run_distributed(_COMMON + r"""
seed, n1, n2, r = 7, 16, 48, 8
A = jax.random.normal(jax.random.key(1), (n1, n2))
ref = np.asarray(sketch_reference(A, seed, r))

for shape in [(8,1,1), (2,2,2)]:
    mesh = make_grid_mesh(*shape)
    cfg = StreamConfig(n1=n1, n2=n2, r=r, seed=seed)
    st = ShardedStreamingSketch(cfg, mesh)
    rows = ShardedStreamingSketch(cfg, mesh)
    for (i0, i1) in [(0, 4), (4, 12), (12, 16)]:
        H = jnp.zeros((n1, n2)).at[i0:i1].set(A[i0:i1])
        st.update(H)
        rows.update_rows(i0, A[i0:i1])          # slab only, no zero frame
    oneshot = rand_matmul(jax.device_put(A, input_sharding(mesh)),
                          seed, r, mesh)
    # row-disjoint streamed updates == one-shot Alg. 1, bitwise
    assert np.array_equal(np.asarray(st.sketch), np.asarray(oneshot)), shape
    assert np.allclose(np.asarray(st.sketch), ref, atol=1e-4), shape
    Wref = np.asarray(psi_matrix(cfg) @ A)
    assert np.allclose(np.asarray(st.corange_sketch), Wref, atol=1e-4), shape
    # row-slab ingest == the full-shape additive path, bitwise on Y
    assert np.array_equal(np.asarray(rows.sketch), np.asarray(st.sketch)), shape
    assert np.allclose(np.asarray(rows.corange_sketch), Wref,
                       atol=1e-4), shape
print("OK bitwise")

# out-of-order, ragged slabs also reproduce the one-shot result bitwise,
# and slabs aligned to p1 row blocks keep W bitwise too
mesh = make_grid_mesh(8, 1, 1)
cfg = StreamConfig(n1=n1, n2=n2, r=r, seed=seed)
ragged = ShardedStreamingSketch(cfg, mesh)
for (i0, i1) in [(12, 16), (0, 7), (7, 12)]:
    ragged.update_rows(i0, A[i0:i1])
oneshot = rand_matmul(jax.device_put(A, input_sharding(mesh)), seed, r, mesh)
assert np.array_equal(np.asarray(ragged.sketch), np.asarray(oneshot))
aligned_full = ShardedStreamingSketch(cfg, mesh)
aligned_rows = ShardedStreamingSketch(cfg, mesh)
for i0 in range(0, n1, 2):          # p1-block-aligned slabs (n1/p1 = 2)
    H = jnp.zeros((n1, n2)).at[i0:i0+2].set(A[i0:i0+2])
    aligned_full.update(H)
    aligned_rows.update_rows(i0, A[i0:i0+2])
assert np.array_equal(np.asarray(aligned_rows.sketch),
                      np.asarray(aligned_full.sketch))
assert np.array_equal(np.asarray(aligned_rows.corange_sketch),
                      np.asarray(aligned_full.corange_sketch))
# same (cfg, mesh) -> accumulators share ONE compiled update executable
# (module-level program cache; keeps autotune trials compile-free too)
assert aligned_rows._upd is aligned_full._upd
print("OK update_rows")

# sharded checkpoint: save on one grid, restore on another, bitwise state
import tempfile
ckdir = tempfile.mkdtemp()
ragged.save(ckdir)
restored = ShardedStreamingSketch.restore(ckdir, make_grid_mesh(2, 2, 2))
assert np.array_equal(np.asarray(restored.Y), np.asarray(ragged.Y))
assert np.array_equal(np.asarray(restored.W), np.asarray(ragged.W))
assert restored.num_updates == ragged.num_updates
print("OK sharded checkpoint")

# omega_salt is honored on the distributed path (independent salted streams)
from repro.stream import StreamConfig as SC
from repro.stream.state import omega_matrix
mesh = make_grid_mesh(2, 2, 2)
cfgs = SC(n1=n1, n2=n2, r=r, seed=seed, omega_salt=2, psi_salt=5)
sts = ShardedStreamingSketch(cfgs, mesh)
sts.update(jax.device_put(A, input_sharding(mesh)))
assert np.allclose(np.asarray(sts.sketch),
                   np.asarray(A @ omega_matrix(cfgs)), atol=1e-4)
assert not np.allclose(np.asarray(sts.sketch), ref, atol=1e-3)
print("OK salt")

# ---- (c) communication accounting of the compiled update step ----------
# Regime-1 grid (P,1,1): Theorem 2 says zero; the streaming update must
# also be zero — Omega/Psi regenerated, B/W shards resident.
mesh = make_grid_mesh(8, 1, 1)
cfg = StreamConfig(n1=16, n2=32, r=8, seed=3, corange=False)
st = ShardedStreamingSketch(cfg, mesh)
H = jax.device_put(jnp.zeros((16, 32)), input_sharding(mesh))
cb = collective_bytes_of(st._upd.lower(st.Y, st.W, H).compile().as_text())
assert cb.total == 0, cb
print("OK regime1 zero bytes")

# General grid: the update moves EXACTLY the one-shot Alg.-1 bytes (the
# all-gather of H + reduce-scatter of dY) — i.e. zero *additional* Omega
# communication — and strictly fewer bytes than the Omega-communicating
# baseline.
mesh = make_grid_mesh(2, 2, 2)
cfg = StreamConfig(n1=16, n2=64, r=8, seed=3, corange=False)
st = ShardedStreamingSketch(cfg, mesh)
H = jax.device_put(jnp.zeros((16, 64)), input_sharding(mesh))
cb_up = collective_bytes_of(st._upd.lower(st.Y, st.W, H).compile().as_text())
cb_one = collective_bytes_of(
    jax.jit(lambda a: rand_matmul(a, 3, 8, mesh)).lower(H).compile().as_text())
assert cb_up.total == cb_one.total, (cb_up, cb_one)
assert cb_up.counts == cb_one.counts, (cb_up, cb_one)
cb_com = collective_bytes_of(
    jax.jit(lambda a: rand_matmul_communicating(a, 3, 8, mesh))
    .lower(A := H).compile().as_text())
assert cb_up.total < cb_com.total, (cb_up, cb_com)
print("OK update == alg1 bytes")

# Co-range tracking adds exactly the data-derived psum of the W partial
# (l x n2/(p2 p3) f32 words per device) — still zero Omega/Psi bytes.
cfg2 = StreamConfig(n1=16, n2=64, r=8, seed=3, corange=True)
st2 = ShardedStreamingSketch(cfg2, mesh)
cb2 = collective_bytes_of(st2._upd.lower(st2.Y, st2.W, H).compile().as_text())
expect = cfg2.sketch_l * (64 // 4) * 4
assert cb2.total - cb_up.total == expect, (cb2, cb_up, expect)
print("OK corange accounting")

# ---- streaming Nystrom + service sharing (same subprocess: one jax init,
# same 8 fake devices) --------------------------------------------------
X = jax.random.normal(jax.random.key(4), (64, 8)); S = X @ X.T
mesh = make_grid_mesh(8, 1, 1)
svc = SketchService(mesh=mesh)
sid = svc.open(StreamConfig(n1=64, n2=64, r=16, seed=5, corange=False))
for (i0, i1) in [(0, 32), (32, 64)]:
    svc.update(sid, jnp.zeros((64, 64)).at[i0:i1].set(S[i0:i1]))
Bref, Cref = nystrom_reference(S, 5, 16)
for variant in ("no_redist", "redist"):
    B, C = svc.nystrom(sid, variant=variant)
    assert np.allclose(np.asarray(B), np.asarray(Bref), atol=1e-4), variant
    assert np.allclose(np.asarray(C), np.asarray(Cref), atol=1e-3), variant
print("OK nystrom variants")

# many distributed streams share one compiled update
sid2 = svc.open(StreamConfig(n1=64, n2=64, r=16, seed=77, corange=False))
svc.update(sid2, jnp.asarray(S))
assert svc.num_compiled == 1, svc.stats()
assert np.allclose(np.asarray(svc.sketch(sid2)),
                   np.asarray(sketch_reference(S, 77, 16)), atol=1e-4)
print("OK service sharing")
""")
