"""Tier-1 guard for the CI shard matrix (scripts/check_ci_shards.py).

A test file must never silently fall out of tier-1: the rest shard's
--ignore list has to equal the union of files the named shards run.  This
runs the same check the CI lint job runs, so the invariant holds locally
too (the hazard CHANGES.md called out when the shards were introduced).
"""
import importlib.util
import pathlib

_SCRIPT = (pathlib.Path(__file__).resolve().parents[1]
           / "scripts" / "check_ci_shards.py")


def _load():
    spec = importlib.util.spec_from_file_location("check_ci_shards", _SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_every_test_file_runs_in_exactly_one_shard():
    mod = _load()
    errors, info = mod.check()
    assert not errors, "\n".join(errors)
    # this very file is new since the shards were written: it must be
    # covered by the generated rest shard, not lost
    assert "tests/test_ci_shards.py" in info["rest_only"] \
        or "tests/test_ci_shards.py" in info["named"]


def test_parser_catches_both_failure_modes(tmp_path):
    mod = _load()
    good = (_SCRIPT.parents[1] / ".github" / "workflows" / "ci.yml")
    text = good.read_text()
    # drop one --ignore= occurrence -> that file would run twice
    broken = text.replace("--ignore=tests/test_plan.py", "", 1)
    p = tmp_path / "ci.yml"
    p.write_text(broken)
    errors, _ = mod.check(ci_path=p)
    assert any("TWICE" in e for e in errors)
    # ignore a file no shard names -> it would never run
    broken2 = text.replace(
        "--ignore=tests/test_plan.py",
        "--ignore=tests/test_plan.py --ignore=tests/test_ci_shards.py", 1)
    p.write_text(broken2)
    errors2, _ = mod.check(ci_path=p)
    assert any("NEVER" in e for e in errors2)
