"""End-to-end behaviour tests for the paper's system."""
import subprocess
import sys

import numpy as np

import jax

from dist_helper import SRC


def test_end_to_end_sketch_to_nystrom_single_device():
    """Paper pipeline on one device: sketch -> core -> reconstruction,
    with the distributed-identical Philox Omega."""
    from repro.core import (nystrom_reference, relative_error,
                            sketch_reference)
    n, k, r = 128, 8, 32
    X = jax.random.normal(jax.random.key(0), (n, k))
    S = X @ X.T
    B = sketch_reference(S, 3, r)
    assert B.shape == (n, r)
    Bn, C = nystrom_reference(S, 3, r)
    np.testing.assert_allclose(np.asarray(B), np.asarray(Bn), rtol=1e-5)
    assert float(relative_error(S, Bn, C)) < 1e-4


def test_end_to_end_training_run():
    """Train a reduced LM for 60 steps: loss must drop, checkpoints must
    appear."""
    import tempfile
    from repro.configs import get_config
    from repro.configs.base import RunConfig
    from repro.data.pipeline import DataConfig
    from repro.models import get_api
    from repro.train.loop import train_loop
    from repro.train.step import init_state, make_train_step
    from repro.checkpoint import ckpt

    cfg = get_config("llama3-8b").reduced(n_layers=2, d_model=32, d_ff=64,
                                          vocab=64, head_dim=8)
    api = get_api(cfg)
    with tempfile.TemporaryDirectory() as d:
        run = RunConfig(steps=60, learning_rate=5e-3, warmup_steps=5,
                        checkpoint_every=20, checkpoint_dir=d, remat=False)
        data_cfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
        state = init_state(api, cfg, run, jax.random.key(0))
        step_fn = jax.jit(make_train_step(api, cfg, run))
        res = train_loop(step_fn, state, data_cfg, run)
        assert np.mean(res.losses[-10:]) < np.mean(res.losses[:10])
        assert ckpt.latest_step(d) == 60


def test_dryrun_single_cell_on_production_mesh():
    """The multi-pod dry-run machinery end-to-end for one cell on the real
    512-device mesh (subprocess; ~1 min)."""
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=512'\n"
        "from repro.launch.dryrun import run_cell\n"
        "rec = run_cell('whisper-tiny', 'train_4k', multi_pod=True)\n"
        "assert 'error' not in rec, rec\n"
        "assert rec['chips'] == 512\n"
        "assert rec['hlo_flops'] > 0 and rec['collective_bytes'] > 0\n"
        "print('OK', rec['bottleneck'])\n"
    )
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


def test_serving_end_to_end():
    from repro.configs import get_config
    from repro.models import get_api
    from repro.serve.engine import BatchedServer, Request
    cfg = get_config("falcon-mamba-7b").reduced(n_layers=2)
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    server = BatchedServer(params, cfg, slots=2, max_len=32, eos=-1)
    reqs = [Request(rid=i, prompt=[1, 2 + i], max_new=4) for i in range(3)]
    for r in reqs:
        server.submit(r)
    server.run()
    assert all(r.done and len(r.out) == 4 for r in reqs)
