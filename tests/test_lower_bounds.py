"""Theorem 2 / Theorem 3 closed forms vs the paper's optimization problems.

The hypothesis properties are an executable re-proof of the KKT case
analysis: for random (dims, P) the closed form must equal the numeric
optimum of Lemma 5 / Lemma 6 and sit below the classical GEMM bound.
"""
import math

from _hypothesis_compat import given, settings, st

from repro.core.lower_bounds import (
    gemm_lower_bound,
    matmul_access_lower_bound,
    matmul_lower_bound,
    matmul_regime,
    minimize_access_matmul,
    minimize_access_nystrom,
    nystrom_access_lower_bound,
    nystrom_lower_bound,
    nystrom_regime,
)

# ---------------------------------------------------------------------------
# Theorem 2
# ---------------------------------------------------------------------------


def test_regimes_partition_P_space():
    n1, n2, r = 100, 200, 10
    cases = [matmul_regime(n1, n2, r, P) for P in range(1, 4001)]
    # non-decreasing case index, all three present
    assert cases == sorted(cases)
    assert set(cases) == {1, 2, 3}


def test_zero_communication_iff_P_le_n1():
    n1, n2, r = 64, 256, 16
    for P in [1, 2, 32, 64]:
        assert matmul_lower_bound(n1, n2, r, P) == 0.0
    for P in [65, 128, 1024]:
        assert matmul_lower_bound(n1, n2, r, P) > 0.0


def test_matmul_case2_formula():
    n1, n2, r = 16, 1024, 8
    P = 64  # n1 < P <= n1*n2/r = 2048
    assert matmul_regime(n1, n2, r, P) == 2
    expect = r - n1 * r / P
    assert math.isclose(matmul_lower_bound(n1, n2, r, P), expect)


def test_matmul_case3_formula():
    n1, n2, r = 8, 64, 16
    P = 64  # > n1*n2/r = 32
    assert matmul_regime(n1, n2, r, P) == 3
    expect = 2 * math.sqrt(n1 * n2 * r / P) - (n1 * n2 + n1 * r) / P
    assert math.isclose(matmul_lower_bound(n1, n2, r, P), expect)


@settings(max_examples=60, deadline=None)
@given(
    n1=st.integers(2, 2000),
    n2=st.integers(2, 2000),
    r_frac=st.floats(0.01, 0.95),
    P=st.integers(1, 4096),
)
def test_closed_form_equals_numeric_optimum_matmul(n1, n2, r_frac, P):
    r = max(1, int(n2 * r_frac))
    if r >= n2:
        r = n2 - 1
    closed = matmul_access_lower_bound(n1, n2, r, P)
    numeric = minimize_access_matmul(n1, n2, r, P)
    assert numeric >= closed * (1 - 1e-6) - 1e-9   # closed form is a true LB
    assert numeric <= closed * (1 + 1e-3) + 1e-6   # and it is attained


@settings(max_examples=60, deadline=None, derandomize=True)
@given(
    n1=st.integers(2, 5000),
    n2=st.integers(8, 5000),
    r_frac=st.floats(0.01, 0.25),
    P=st.integers(1, 10000),
)
def test_sketching_never_accesses_more_than_gemm(n1, n2, r_frac, P):
    """Access form of the paper's 'random input needs less communication'
    claim, within the paper's operating regime r << n2.  (The W forms are
    not directly comparable because the sketching processor owns less data
    — no Omega share — and our GEMM access form is approximate near its
    regime boundaries, so a 2% slack is allowed.)"""
    r = max(1, min(n2 - 1, int(n2 * r_frac)))
    if P > n1 * n2 * r:
        return  # more processors than iteration points: bounds are vacuous
    sk_access = matmul_access_lower_bound(n1, n2, r, P)
    ge = gemm_lower_bound(n1, n2, r, P)
    ge_access = ge + (n1 * n2 + n2 * r + n1 * r) / P
    assert sk_access <= ge_access * 1.02 + 1.0


def test_sketching_W_below_gemm_W_at_paper_scales():
    for (n1, n2, r, P) in [(50000, 50000, 500, 64), (50000, 50000, 5000, 128),
                           (10**6, 10**6, 1000, 256), (4096, 4096, 256, 4096)]:
        assert (matmul_lower_bound(n1, n2, r, P)
                <= gemm_lower_bound(n1, n2, r, P) + 1e-6)


def test_bound_continuous_at_case_boundaries():
    n1, n2, r = 32, 512, 8
    # boundary 1: P = n1
    lo = matmul_lower_bound(n1, n2, r, n1)
    hi = matmul_lower_bound(n1, n2, r, n1 + 1)
    assert abs(hi - lo) < r  # jump bounded by one case-2 increment
    # boundary 2: P = n1*n2/r
    Pb = n1 * n2 // r
    lo = matmul_lower_bound(n1, n2, r, Pb)
    hi = matmul_lower_bound(n1, n2, r, Pb + 1)
    assert abs(hi - lo) / max(lo, 1.0) < 0.05


# ---------------------------------------------------------------------------
# Theorem 3
# ---------------------------------------------------------------------------


def test_nystrom_regimes_partition():
    n, r = 300, 20
    cases = [nystrom_regime(n, r, P) for P in range(1, 20000)]
    assert cases == sorted(cases)
    assert set(cases) == {1, 2, 3, 4}


def test_nystrom_case_formulas():
    n, r = 256, 16
    # case 1: P <= r
    P = 8
    assert nystrom_regime(n, r, P) == 1
    assert math.isclose(nystrom_access_lower_bound(n, r, P),
                        (n * n + n * r + r * r) / P)
    assert nystrom_lower_bound(n, r, P) == 0.0
    # case 2: r < P <= n
    P = 64
    assert nystrom_regime(n, r, P) == 2
    assert math.isclose(nystrom_access_lower_bound(n, r, P),
                        (n * n + n * r) / P + r)
    # case 3: n < P <= n(n+r)/r
    P = 1024
    assert nystrom_regime(n, r, P) == 3
    assert math.isclose(nystrom_access_lower_bound(n, r, P),
                        n * n / P + r + n * r / P)
    # case 4
    P = 8192
    assert nystrom_regime(n, r, P) == 4
    assert math.isclose(nystrom_access_lower_bound(n, r, P),
                        2 * math.sqrt(n * r * (n + r) / P))


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(4, 3000),
    r_frac=st.floats(0.01, 0.9),
    P=st.integers(1, 30000),
)
def test_closed_form_equals_numeric_optimum_nystrom(n, r_frac, P):
    r = max(1, min(n - 1, int(n * r_frac)))
    closed = nystrom_access_lower_bound(n, r, P)
    numeric = minimize_access_nystrom(n, r, P)
    assert numeric >= closed * (1 - 1e-6) - 1e-9
    assert numeric <= closed * (1 + 1e-3) + 1e-6


@settings(max_examples=40, deadline=None)
@given(n=st.integers(8, 2000), P=st.integers(1, 4096))
def test_nystrom_bound_nonnegative_and_zero_smallP(n, P):
    r = max(1, n // 8)
    if r >= n:
        return
    W = nystrom_lower_bound(n, r, P)
    assert W >= 0.0
    if P <= r:
        assert W == 0.0


def test_paper_scale_numbers():
    """Sanity at the paper's experimental scales."""
    # metabarcoding: n1=n2=1e6, r=1000, P=256 -> regime 1, zero comm
    assert matmul_regime(10**6, 10**6, 1000, 256) == 1
    assert matmul_lower_bound(10**6, 10**6, 1000, 256) == 0.0
    # CIFAR kernel: n=50000, r=5000 -> crossover near P = n/r = 10
    assert nystrom_regime(50000, 5000, 8) == 1
    assert nystrom_lower_bound(50000, 5000, 8) == 0.0
