"""repro.obs tier-1 shard: spans, metrics, and the communication ledger.

What is pinned here:

  * the ledger's measured collective bytes equal the DIRECT HLO-audit
    numbers (``roofline/hlo.collective_bytes_of`` on the same executable)
    exactly — on the pinned (8,1,1) / (2,2,2) streaming schedules and the
    fused two-grid regime-1 pair the PR 4/5 tests audit;
  * tracer + ledger overhead on the jitted ragged-update hot path stays
    under 2% of the untraced wall time;
  * the Prometheus text exposition against a golden file;
  * drift-flag -> autotune revalidation (property-tested flag predicate);
  * cross-thread span parenting through the async ingest queue;
  * collective-permute / all-to-all byte classification on captured HLO
    snippets (including identity-only routing no-ops and async -start
    forms).
"""
import contextlib
import json
import math
import pathlib
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from dist_helper import run_distributed

from repro import obs
from repro.obs import ledger as obs_ledger
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.roofline.hlo import collective_bytes_of

GOLDEN = pathlib.Path(__file__).parent / "golden" / "obs_prometheus.txt"


@pytest.fixture(autouse=True)
def _clean_obs():
    """Tracer/ledger are process-global and off by default — guarantee
    every test starts and ends uninstalled."""
    obs.uninstall_observability()
    yield
    obs.uninstall_observability()


@contextlib.contextmanager
def fresh_metrics():
    """Swap in an isolated MetricsRegistry (the default one is process-
    global and always on)."""
    prev = obs_metrics.get_metrics()
    reg = obs_metrics.MetricsRegistry()
    obs_metrics.set_metrics(reg)
    try:
        yield reg
    finally:
        obs_metrics.set_metrics(prev)


class _FakeFn:
    """Quacks like a jitted function for CommLedger.observe: .lower()
    .compile().as_text() returns a canned HLO module text."""

    def __init__(self, text: str):
        self._text = text

    def lower(self, *args):
        return self

    def compile(self):
        return self

    def as_text(self):
        return self._text


# one moving all-reduce of a f32[16,8] = 512-byte operand
_AR_512 = """
HloModule m, num_partitions=4
%p0 = f32[16,8]{1,0} parameter(0)
%ar = f32[16,8]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}
"""


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_counter_basic():
    c = obs_metrics.Counter("c_total")
    c.inc()
    c.inc(2.5)
    c.inc(3, path="ragged")
    assert c.value() == 3.5
    assert c.value(path="ragged") == 3
    assert c.value(path="other") == 0.0
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_inc_dec():
    g = obs_metrics.Gauge("g")
    g.set(5)
    g.inc(2)
    g.dec(3)
    assert g.value() == 4.0
    g.set(1, queue="a")
    assert g.value(queue="a") == 1.0
    assert g.value() == 4.0


def test_histogram_percentile_matches_numpy():
    h = obs_metrics.Histogram("h", buckets=(1.0, 10.0))
    assert h.percentile(50) == 0.0          # empty window: never raises
    rng = np.random.default_rng(0)
    xs = rng.exponential(0.01, size=257)
    for x in xs:
        h.observe(float(x))
    for q in (0, 50, 90, 99, 100):
        assert h.percentile(q) == pytest.approx(np.percentile(xs, q),
                                                rel=1e-12)
    assert h.count() == 257


def test_histogram_window_stays_bounded():
    h = obs_metrics.Histogram("h", buckets=(1.0,))
    n = obs_metrics._RAW_WINDOW + 100
    for i in range(n):
        h.observe(float(i))
    st_ = h._states[()]
    assert st_.count == n                   # totals never truncate
    assert len(st_.window) <= obs_metrics._RAW_WINDOW
    # the window keeps the most recent values, so high quantiles track
    assert h.percentile(100) == float(n - 1)


def test_registry_kind_clash_and_names():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("a_total")
    with pytest.raises(TypeError):
        reg.gauge("a_total")
    reg.gauge("b")
    assert list(reg.names()) == ["a_total", "b"]
    assert reg.counter("a_total") is reg.counter("a_total")


def test_prometheus_exposition_golden():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("requests_total")
    c.inc()
    c.inc(2, path="ragged")
    reg.gauge("queue_depth").set(3)
    h = reg.histogram("lat_seconds", buckets=(0.5, 2.0))
    for v in (0.25, 0.5, 4.0):              # le is inclusive: 0.5 in-bucket
        h.observe(v)
    assert reg.prometheus_text() == GOLDEN.read_text()


def test_prometheus_empty_registry_and_zero_series():
    reg = obs_metrics.MetricsRegistry()
    assert reg.prometheus_text() == ""
    reg.counter("n_total")                  # registered, never incremented
    assert "n_total 0" in reg.prometheus_text()


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_nesting_and_ids():
    t = obs.install_tracer()
    with obs_trace.span("outer", cat="t") as a:
        assert obs_trace.current_span_id() == a.span_id
        with obs_trace.span("inner", cat="t", k=3) as b:
            assert b.parent == a.span_id
    assert obs_trace.current_span_id() is None
    names = {s.name: s for s in t.spans}
    assert names["inner"].parent_id == names["outer"].span_id
    assert names["outer"].parent_id is None
    assert names["inner"].args == {"k": 3}
    assert names["inner"].dur_ns >= 0


def test_trace_decorator():
    t = obs.install_tracer()

    @t.trace("my.op", cat="x")
    def f(v):
        return v + 1

    assert f(1) == 2
    (s,) = t.spans
    assert (s.name, s.cat) == ("my.op", "x")


def test_chrome_export(tmp_path):
    t = obs.install_tracer()
    with obs_trace.span("a", cat="c", n=7):
        with obs_trace.span("b"):
            pass
    path = t.export_chrome(str(tmp_path / "trace.json"))
    doc = json.loads(pathlib.Path(path).read_text())
    evs = {e["name"]: e for e in doc["traceEvents"]}
    assert evs["a"]["ph"] == "X" and evs["a"]["cat"] == "c"
    assert evs["b"]["cat"] == "repro"       # empty cat gets a default
    assert evs["a"]["args"]["n"] == 7
    assert evs["b"]["args"]["parent_id"] == evs["a"]["args"]["span_id"]
    assert evs["a"]["dur"] >= evs["b"]["dur"] >= 0


def test_max_spans_bound():
    t = obs.install_tracer(obs.Tracer(max_spans=2))
    for i in range(4):
        with obs_trace.span(f"s{i}"):
            pass
    assert len(t.spans) == 2 and t.dropped == 2
    t.clear()
    assert t.spans == [] and t.dropped == 0


def test_span_is_shared_noop_when_uninstalled():
    assert obs_trace.get_tracer() is None
    c1 = obs_trace.span("a")
    c2 = obs_trace.span("b", cat="x", k=1)
    assert c1 is c2                         # one shared nullcontext
    with c1:
        assert obs_trace.current_span_id() is None


def test_cross_thread_explicit_parent():
    t = obs.install_tracer()
    with obs_trace.span("submit") as ctx:
        parent = obs_trace.current_span_id()
        assert parent == ctx.span_id
    th = threading.Thread(
        target=lambda: obs_trace.span("apply", parent=parent).__enter__()
        .__exit__(None, None, None))
    th.start()
    th.join()
    names = {s.name: s for s in t.spans}
    assert names["apply"].parent_id == names["submit"].span_id
    assert names["apply"].tid != names["submit"].tid


# ---------------------------------------------------------------------------
# ledger
# ---------------------------------------------------------------------------

def test_observe_accumulates_per_signature():
    led = obs.install_ledger()
    fn = jax.jit(lambda x: x * 2)
    x = jnp.ones((4, 4), jnp.float32)
    led.observe("t.op", fn, (x,))
    led.observe("t.op", fn, (x,), wall_s=0.5)
    assert len(led) == 1
    site = led.site("t.op")
    assert site.calls == 2 and site.wall_s == 0.5
    # single-device executable: zero collective bytes, at a zero floor
    assert site.measured_bytes_per_call == 0.0
    assert site.bound_fraction == 1.0 and site.drift == 0.0
    led.observe("t.op", fn, (jnp.ones((8, 4)),))
    assert len(led) == 2                    # new signature, new site


def test_observe_scalar_arg_with_committed_sharding():
    """Regression: a 0-d operand committed to one device (jnp.int32 row
    offset) must not pin the lazy re-lowering — only mesh (Named)
    shardings constrain it."""
    led = obs.install_ledger()
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    a = jax.device_put(jnp.ones((4, 4)), NamedSharding(mesh, P("x", None)))
    r0 = jnp.int32(3)                       # SingleDeviceSharding-committed
    fn = jax.jit(lambda a, i: a + i)
    fn(a, r0)
    site = led.observe("t.mixed", fn, (a, r0))
    assert site.measured_bytes_per_call == 0.0


def test_observe_before_donation_is_safe():
    led = obs.install_ledger()
    fn = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    x = jnp.ones((8,), jnp.float32)
    site = led.observe("t.donated", fn, (x,))
    fn(x)                                   # x's buffer is donated here
    assert site.measured_bytes_per_call == 0.0


def test_record_analytic_site():
    led = obs.install_ledger()
    led.record("plan.x", predicted_words=10.0, lower_bound_words=5.0,
               wall_s=0.1, detail=("a",))
    led.record("plan.x", wall_s=0.2, detail=("a",))
    site = led.site("plan.x")
    assert site.calls == 2 and site.wall_s == pytest.approx(0.3)
    assert site.measured_bytes_per_call is None
    assert site.bound_fraction is None and site.drift is None


def test_audit_conventions():
    led = obs.install_ledger()
    args = (np.zeros((2, 2), np.float32),)
    # measured 512 B = 128 words over a zero floor / zero prediction
    s = led.observe("inf.case", _FakeFn(_AR_512), args)
    assert s.measured_bytes_per_call == 512.0
    assert s.measured_words_per_call == 128.0
    assert s.bound_fraction == math.inf and s.drift == math.inf
    led.clear()
    s = led.observe("exact.case", _FakeFn(_AR_512), args,
                    predicted_words=128.0, lower_bound_words=64.0)
    assert s.drift == 0.0 and s.bound_fraction == 2.0
    assert led.total_measured_bytes() == 512.0
    assert led.total_measured_bytes("other") == 0.0


def test_itemsize_scales_words():
    led = obs.install_ledger()
    s = led.observe("f64.case", _FakeFn(_AR_512),
                    (np.zeros(1, np.float64),), itemsize=8)
    assert s.measured_words_per_call == 64.0


# ---------------------------------------------------------------------------
# report: honesty table, drift flags, autotune revalidation
# ---------------------------------------------------------------------------

def test_honesty_report_renders():
    led = obs.install_ledger()
    led.observe("site.a", _FakeFn(_AR_512), (np.zeros(1),),
                predicted_words=100.0, lower_bound_words=64.0, wall_s=0.5)
    led.record("site.b", predicted_words=7.0)
    txt = obs.honesty_report(led)
    lines = txt.splitlines()
    assert lines[0].split() == ["site", "calls", "pred_words", "meas_words",
                                "thm_floor", "bound_frac", "drift", "wall_s"]
    assert "site.a" in txt and "site.b" in txt
    assert "128" in txt                     # measured words rendered
    # analytic-only site renders '-' for the measured columns
    brow = next(ln for ln in lines if ln.startswith("site.b"))
    assert "-" in brow
    # roofline column: 128 words/call at 256 words/s over 0.5 s wall = 1.0
    txt2 = obs.honesty_report(led, machine_words_per_s=256.0)
    assert "roofline_frac" in txt2.splitlines()[0]
    arow = next(ln for ln in txt2.splitlines() if ln.startswith("site.a"))
    assert arow.rstrip().endswith("1")


@settings(max_examples=40, deadline=None)
@given(mult=st.floats(min_value=0.05, max_value=20.0),
       threshold=st.floats(min_value=0.0, max_value=3.0))
def test_drift_flag_predicate_property(mult, threshold):
    """A site flags iff |measured - predicted| / predicted > threshold."""
    led = obs_ledger.CommLedger()
    measured = 128.0                        # words (512 B / itemsize 4)
    pred = measured * mult
    led.observe("s", _FakeFn(_AR_512), (np.zeros(1),),
                predicted_words=pred)
    drift = (measured - pred) / pred
    flags = obs.drift_flags(led, threshold=threshold)
    assert bool(flags) == (abs(drift) > threshold)
    if flags:
        assert flags[0][1] == pytest.approx(drift)


def test_drift_flags_sorted_and_validated():
    led = obs_ledger.CommLedger()
    led.observe("small", _FakeFn(_AR_512), (np.zeros(1),),
                predicted_words=100.0)      # drift +0.28
    led.observe("big", _FakeFn(_AR_512), (np.zeros(2),),
                predicted_words=32.0)       # drift +3.0
    led.record("analytic", predicted_words=1.0)   # never flags
    flags = obs.drift_flags(led, threshold=0.25)
    assert [s.name for s, _ in flags] == ["big", "small"]
    with pytest.raises(ValueError):
        obs.drift_flags(led, threshold=-0.1)


def test_revalidate_autotune_pops_drifted_entries(tmp_path):
    from repro.plan.autotune import AutotuneCache
    cache = AutotuneCache(str(tmp_path / "tune.json"))
    cache.put("k/drifted", {"variant": "v"})
    cache.put("k/fine", {"variant": "v"})
    led = obs_ledger.CommLedger()
    led.observe("s1", _FakeFn(_AR_512), (np.zeros(1),),
                predicted_words=32.0, cache_key="k/drifted")
    led.observe("s2", _FakeFn(_AR_512), (np.zeros(2),),
                predicted_words=128.0, cache_key="k/fine")   # drift 0
    popped = obs.revalidate_autotune(led, cache, threshold=0.25)
    assert popped == ["k/drifted"]
    assert cache.get("k/drifted") is None
    assert cache.get("k/fine") is not None
    # idempotent: already-popped keys return nothing the second time
    assert obs.revalidate_autotune(led, cache, threshold=0.25) == []


def test_plan_execute_records_analytic_site():
    from repro.plan import plan_sketch
    from repro.plan.autotune import cache_key
    led = obs.install_ledger()
    plan = plan_sketch(32, 16, 8, P=1)
    out = plan.execute(np.ones((32, 16), np.float32))
    assert out.shape == (32, 8)
    site = next(s for s in led.sites() if s.name.startswith("plan.execute["))
    assert site.calls == 1 and site.wall_s > 0
    assert site.cache_key == cache_key(plan)
    assert site.measured_bytes_per_call is None   # analytic-only


# ---------------------------------------------------------------------------
# HLO classification: collective-permute / all-to-all (roofline/hlo.py)
# ---------------------------------------------------------------------------

def test_hlo_collective_permute_moving():
    cb = collective_bytes_of("""
HloModule m, num_partitions=4
%p0 = f32[16,8]{1,0} parameter(0)
%cp = f32[16,8]{1,0} collective-permute(%p0), \
source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
""")
    assert cb.by_kind == {"collective-permute": 512.0}
    assert cb.counts == {"collective-permute": 1}
    assert cb.permute_pairs == 4 and cb.permute_identity_pairs == 0
    assert cb.redistribute_total == 512.0 and cb.total == 512.0
    assert cb.num_partitions == 4


def test_hlo_collective_permute_identity_only_is_noop():
    cb = collective_bytes_of("""
HloModule m
%p0 = f32[16,8]{1,0} parameter(0)
%cp = f32[16,8]{1,0} collective-permute(%p0), \
source_target_pairs={{0,0},{1,1}}
""")
    assert cb.total == 0.0 and cb.counts == {}
    assert cb.permute_pairs == 0 and cb.permute_identity_pairs == 0


def test_hlo_collective_permute_mixed_pairs_counted():
    cb = collective_bytes_of("""
HloModule m
%p0 = f32[16,8]{1,0} parameter(0)
%cp = f32[16,8]{1,0} collective-permute(%p0), \
source_target_pairs={{0,0},{1,2},{2,1},{3,3}}
""")
    assert cb.by_kind == {"collective-permute": 512.0}
    assert cb.permute_pairs == 2 and cb.permute_identity_pairs == 2


def test_hlo_collective_permute_async_start_form():
    cb = collective_bytes_of("""
HloModule m
%p0 = f32[16,8]{1,0} parameter(0)
%cps = (f32[16,8]{1,0}, f32[16,8]{1,0}) collective-permute-start(%p0), \
source_target_pairs={{0,1},{1,0}}
%cpd = f32[16,8]{1,0} collective-permute-done(%cps)
""")
    # -start counted once via its operand; -done contributes nothing
    assert cb.by_kind == {"collective-permute": 512.0}
    assert cb.counts == {"collective-permute": 1}
    assert cb.permute_pairs == 2


def test_hlo_all_to_all_bytes_and_group_size_one():
    cb = collective_bytes_of("""
HloModule m
%p0 = f32[32,4]{1,0} parameter(0)
%a2a = f32[32,4]{1,0} all-to-all(%p0), replica_groups={{0,1,2,3}}, \
dimensions={0}
%deg = f32[32,4]{1,0} all-to-all(%p0), replica_groups={{0}}, \
dimensions={0}
""")
    assert cb.by_kind == {"all-to-all": 512.0}      # degenerate one skipped
    assert cb.counts == {"all-to-all": 1}
    assert cb.redistribute_total == 512.0


def test_hlo_redistribute_total_excludes_reductions():
    cb = collective_bytes_of("""
HloModule m
%p0 = f32[16,8]{1,0} parameter(0)
%ar = f32[16,8]{1,0} all-reduce(%p0), replica_groups={{0,1}}
%cp = f32[16,8]{1,0} collective-permute(%p0), \
source_target_pairs={{0,1},{1,0}}
""")
    assert cb.total == 1024.0
    assert cb.redistribute_total == 512.0


def test_hlo_unresolvable_operand_falls_back_to_result_shape():
    cb = collective_bytes_of("""
HloModule m
%cp = f32[4,4]{1,0} collective-permute(%unknown), \
source_target_pairs={{0,1}}
""")
    assert cb.by_kind == {"collective-permute": 64.0}


# ---------------------------------------------------------------------------
# ingest stats hardening (satellite: percentile math + reset semantics)
# ---------------------------------------------------------------------------

def test_percentile_guards():
    from repro.stream.ingest import _percentile
    assert _percentile([], 50) == 0.0
    assert _percentile(None, 99) == 0.0
    assert _percentile([float("nan"), float("inf")], 50) == 0.0
    assert _percentile([0.25], 99) == 0.25
    xs = [0.1, 0.2, 0.3, 0.4]
    assert _percentile(xs, 50) == pytest.approx(np.percentile(xs, 50))
    # non-finite entries are dropped, not propagated
    assert _percentile([0.5, float("nan")], 50) == 0.5


def _local_service_and_queue(n_streams=2, n1=32, n2=16, r=4):
    from repro.serve.engine import make_ingest_queue, make_sketch_service
    from repro.stream.state import StreamConfig
    svc = make_sketch_service()
    sids = [svc.open(StreamConfig(n1=n1, n2=n2, r=r, seed=s))
            for s in range(n_streams)]
    return svc, sids, make_ingest_queue(svc, depth=16, window=8)


def test_stats_reset_clears_window_not_lifetime():
    svc, sids, q = _local_service_and_queue()
    with q:
        for sid in sids:
            q.submit(sid, np.ones((4, 16), np.float32), 0)
        q.flush(raise_errors=True)
        st1 = q.stats(reset=True)
        assert st1["submitted"] == 2 and st1["applied"] == 2
        assert st1["latency_p99_s"] > 0.0
        assert st1["real_rows"] == 8
        st2 = q.stats()
        # window figures cleared...
        assert st2["latency_p50_s"] == 0.0 and st2["latency_p99_s"] == 0.0
        assert st2["real_rows"] == 0 and st2["padded_rows"] == 0
        assert st2["pad_waste"] == 0.0
        # ...lifetime counters preserved
        assert st2["submitted"] == 2 and st2["applied"] == 2
        assert st2["rounds"] == st1["rounds"]


# ---------------------------------------------------------------------------
# serving metrics + cross-thread parenting through the ingest queue
# ---------------------------------------------------------------------------

def test_service_and_queue_publish_metrics():
    with fresh_metrics() as reg:
        svc, sids, q = _local_service_and_queue(n_streams=3)
        with q:
            svc.update(sids[0], np.ones((32, 16), np.float32))
            for sid in sids:
                q.submit(sid, np.ones((5, 16), np.float32), 0)
            q.flush(raise_errors=True)
        upd = reg.counter("sketch_updates_total")
        assert upd.value(path="single") == 1
        assert upd.value(path="ragged") == 3
        assert reg.counter("ingest_submitted_total").value() == 3
        assert reg.counter("ingest_applied_total").value() == 3
        assert reg.gauge("sketch_resident_streams").value() == 3
        assert reg.histogram("ingest_drain_latency_seconds").count() >= 1
        assert reg.counter("sketch_ragged_real_rows_total").value() == 15
        text = reg.prometheus_text()
        assert 'sketch_updates_total{path="ragged"} 3' in text
        assert "ingest_drain_latency_seconds_count" in text


def test_service_eviction_metrics():
    from repro.stream.service import SketchService
    from repro.stream.state import StreamConfig
    with fresh_metrics() as reg:
        svc = SketchService(max_resident=1)
        a = svc.open(StreamConfig(n1=16, n2=16, r=4, seed=0))
        svc.update(a, np.ones((16, 16), np.float32))
        b = svc.open(StreamConfig(n1=16, n2=16, r=4, seed=1))  # evicts a
        svc.update(a, np.ones((16, 16), np.float32))           # restores a
        del b
        assert reg.counter("sketch_evictions_total").value() >= 1
        assert reg.counter("sketch_restores_total").value() >= 1
        assert reg.gauge("sketch_resident_streams").value() == 1


def test_ingest_spans_parent_across_threads():
    tracer = obs.install_tracer()
    svc, sids, q = _local_service_and_queue(n_streams=1)
    with q:
        q.hold()
        with obs_trace.span("client.request", cat="test"):
            q.submit(sids[0], np.ones((4, 16), np.float32), 0)
            submit_parent = None  # captured by the queue, not by us
        q.release()
        q.flush(raise_errors=True)
    del submit_parent
    names = {}
    for s in tracer.spans:
        names.setdefault(s.name, s)
    client = names["client.request"]
    apply_ = names["ingest.apply_round"]
    assert apply_.parent_id == client.span_id
    assert apply_.tid != client.tid         # stitched across the worker


# ---------------------------------------------------------------------------
# recovery observability (ISSUE 9): WAL / replay / reshard / retry signals
# ---------------------------------------------------------------------------

def test_recovery_metrics_and_spans(tmp_path):
    """Every fault-tolerance path leaves an audit trail: the WAL depth
    gauge drains back to 0, replay/reshard/retry count, and the recovery
    arcs open named spans."""
    from repro.core.sketch import make_grid_mesh
    from repro.stream import faults
    from repro.stream import wal as wal_mod
    from repro.stream.elastic import drain_reshard_resume
    from repro.stream.ingest import IngestQueue
    from repro.stream.service import SketchService
    from repro.stream.state import StreamConfig

    tracer = obs.install_tracer()
    cfg = StreamConfig(n1=32, n2=16, r=4, seed=0, corange=False)
    try:
        with fresh_metrics() as reg:
            # journaled ingest: the depth gauge returns to 0 once applied
            svc = SketchService()
            sid = svc.open(cfg)
            wal = wal_mod.WriteAheadLog(str(tmp_path / "ingest.wal"))
            with IngestQueue(svc, wal=wal) as q:
                q.submit(sid, np.ones((4, 16), np.float32), 0)
                q.flush(raise_errors=True)
            wal.close()
            assert reg.gauge("stream_wal_depth").value() == 0

            # replay counts each re-applied record
            svc2 = SketchService()
            sid2 = svc2.open(cfg)
            n, _ = wal_mod.replay(wal.path, svc2, sid_map={sid: sid2})
            assert n == 1
            assert reg.counter("stream_replays_total").value() == 1

            # a transient round failure counts one retry
            faults.arm("ingest.apply_round", exc=faults.FaultInjected,
                       times=1)
            with IngestQueue(svc, max_retries=1, backoff_base=0.0) as q2:
                q2.submit(sid, np.ones((4, 16), np.float32), 0)
                q2.flush(raise_errors=True)
            faults.clear()
            assert reg.counter("ingest_retries_total").value() == 1

            # drain -> reshard -> resume counts one hop per stream
            dsvc = SketchService(mesh=make_grid_mesh(1, 1, 1))
            dsid = dsvc.open(cfg)
            with IngestQueue(dsvc) as q3:
                q3.submit(dsid, np.ones((32, 16), np.float32))
                out = drain_reshard_resume(q3, (1, 1, 1))
            assert out["resharded"] == 1
            assert reg.counter("stream_reshard_total").value() == 1

            text = reg.prometheus_text()
            for name in ("stream_wal_depth", "stream_replays_total",
                         "stream_reshard_total", "ingest_retries_total",
                         "ingest_quarantined_total"):
                assert name in text, name
    finally:
        faults.clear()

    names = {s.name for s in tracer.spans}
    assert {"stream.wal_replay", "stream.reshard",
            "stream.drain_reshard_resume"} <= names
    resh = next(s for s in tracer.spans if s.name == "stream.reshard")
    assert resh.args["old"] == "1x1x1" and resh.args["new"] == "1x1x1"
    assert resh.args["path"] == "jit"    # same device set -> measurable


# ---------------------------------------------------------------------------
# overhead budget: tracer + ledger on the jitted ragged-update hot path
# ---------------------------------------------------------------------------

def test_traced_update_ragged_overhead_under_2pct():
    from repro.stream.service import SketchService
    from repro.stream.state import StreamConfig
    svc = SketchService()
    sids = [svc.open(StreamConfig(n1=256, n2=128, r=8, seed=s,
                                  corange=False))
            for s in range(16)]
    items = [(sid, np.ones((64, 128), np.float32), 0) for sid in sids]

    def one_round():
        svc.update_ragged(items)
        svc.sync()

    one_round()                             # compile + warm every path

    def timed():
        t0 = time.perf_counter()
        one_round()
        return time.perf_counter() - t0

    # INTERLEAVED pairs: an untraced and a traced round back to back per
    # rep, so both classes sample the same noise environment (separate
    # min-of-N blocks make the min estimator compare different warming /
    # scheduling regimes and swamp a percent-level budget).  The tracer
    # and ledger are REUSED across pairs and warmed once: the budget is a
    # steady-state property (install once, run many rounds) — a fresh
    # ledger per pair would bill every traced round as a first call at
    # its signature (abstractify + site registration, ~50us) and measure
    # install churn, not the hot path.  The budget must hold for SOME
    # attempt, not on the first try.
    tracer = obs.Tracer(max_spans=1_000_000)
    ledger = obs.CommLedger()
    obs.install_tracer(tracer)
    obs.install_ledger(ledger)
    one_round()                             # warm first-observe machinery
    obs.uninstall_observability()
    for attempt in range(6):
        untraced = traced = math.inf
        for _ in range(40):
            untraced = min(untraced, timed())
            obs.install_tracer(tracer)
            obs.install_ledger(ledger)
            try:
                traced = min(traced, timed())
            finally:
                obs.uninstall_observability()
        if traced <= 1.02 * untraced:
            break
    else:
        pytest.fail(f"traced/untraced = {traced / untraced:.4f} > 1.02 "
                    f"after {attempt + 1} attempts")


# ---------------------------------------------------------------------------
# the acceptance audit: ledger bytes == direct HLO audit, exactly
# ---------------------------------------------------------------------------

def test_ledger_matches_hlo_audits_distributed():
    run_distributed("""
import numpy as np, jax, jax.numpy as jnp
from repro import obs
from repro.core.sketch import make_grid_mesh
from repro.roofline.hlo import collective_bytes_of
from repro.stream.state import StreamConfig
from repro.stream.distributed import ShardedStreamingSketch, input_sharding

tracer, ledger, _ = obs.install_observability()

# --- Alg. 1 (P,1,1) = (8,1,1): the zero-communication regime ---
mesh = make_grid_mesh(8, 1, 1)
st = ShardedStreamingSketch(StreamConfig(n1=16, n2=32, r=8, seed=3,
                                         corange=False), mesh)
st.update(jnp.ones((16, 32), jnp.float32))
s = ledger.site("stream.update")
assert s.measured_bytes_per_call == 0.0, s
assert s.drift == 0.0 and s.bound_fraction == 1.0, s
assert ledger.total_measured_bytes() == 0.0
assert len(tracer.spans) >= 1
ledger.clear()
print("OK 811")

# --- (2,2,2): ledger == direct parse of the SAME executable ---
mesh2 = make_grid_mesh(2, 2, 2)
cfg_no = StreamConfig(n1=16, n2=64, r=8, seed=3, corange=False)
cfg_co = StreamConfig(n1=16, n2=64, r=8, seed=3, corange=True)
H = jnp.ones((16, 64), jnp.float32)
meas = {}
for tag, cfg in (("no", cfg_no), ("co", cfg_co)):
    st2 = ShardedStreamingSketch(cfg, mesh2)
    st2.update(H)
    st2.update(H)
    site = ledger.site("stream.update")
    Hd = jax.device_put(H, input_sharding(mesh2, st2.axes))
    direct = collective_bytes_of(
        st2._upd.lower(st2.Y, st2.W, Hd).compile().as_text())
    assert site.calls == 2, site
    assert site.measured_bytes_per_call == direct.total, (site, direct)
    assert site.measured_bytes == 2 * direct.total
    meas[tag] = site.measured_bytes_per_call
    ledger.clear()
# corange delta: the Psi-partial psum moves exactly l * n2/(p2 p3) words
assert meas["co"] - meas["no"] == cfg_co.sketch_l * (64 // 4) * 4, meas
print("OK 222 update")

# --- row-slab ingest: the slab cost model is exact on this grid ---
st2 = ShardedStreamingSketch(cfg_co, mesh2)
st2.update_rows(0, jnp.ones((4, 64), jnp.float32))
s3 = ledger.site("stream.update_rows")
assert s3.measured_bytes_per_call is not None
assert s3.drift == 0.0, s3          # measured == stream_update_cost words
ledger.clear()
print("OK 222 rows")

# --- service dist path on (8,1,1): zero bytes at the bound ---
from repro.stream.service import SketchService
svc = SketchService(mesh=mesh)
sid = svc.open(StreamConfig(n1=64, n2=64, r=16, seed=5, corange=False))
svc.update(sid, np.ones((64, 64), np.float32))
s4 = ledger.site("service.update[dist]")
assert s4.measured_bytes_per_call == 0.0, s4
assert s4.drift == 0.0 and s4.bound_fraction == 1.0, s4
ledger.clear()
print("OK service dist")

# --- fused two-grid regime-1 pair p=(8,1,1), q=(1,1,8): the in-program
# Redistribute is the ONLY traffic and carries exactly nr/P per device ---
from repro.core.nystrom import nystrom_two_grid_fused
n, r = 64, 16
rng = np.random.default_rng(0)
G = rng.standard_normal((n, n)).astype(np.float32)
S = jnp.asarray(G @ G.T)
nystrom_two_grid_fused(S, 7, r, p=(8, 1, 1), q=(1, 1, 8))
s5 = ledger.site("nystrom.two_grid_fused")
assert s5.measured_bytes_per_call == n * r / 8 * 4, s5
cb = s5.collectives()
assert cb.redistribute_total == cb.total, cb
print("OK fused pair")

# honesty report renders all of it without error
print(obs.honesty_report(ledger))
""", timeout=900)
